"""Shared benchmark utilities: timing, CSV emission, algorithm registry.

All timing helpers are observability-aware (DESIGN.md §12): pass
``label=`` and every measured duration is also recorded into the active
``repro.obs`` metrics registry (histogram ``bench_seconds{label=}``) —
with no registry installed the recording is a no-op, so standalone
benchmark runs are unaffected.  This is the single timing path every
bench_*.py script shares; hand-rolled ``perf_counter`` pairs belong here,
not in the scripts.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.obs import metrics as obs_metrics


def _sync(r):
    jax.block_until_ready(jax.tree.leaves(r))
    return r


def time_fn(fn, *args, warmup: int = 1, repeat: int = 3,
            label: str | None = None, **kw):
    """Median wall time (s) of fn(*args) with block_until_ready.

    ``warmup`` compile/warm calls are unmeasured; each of the ``repeat``
    measured samples is recorded into the active obs registry under
    ``bench_seconds{label=}`` when ``label`` is given.
    """
    for _ in range(warmup):
        r = _sync(fn(*args, **kw))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        r = _sync(fn(*args, **kw))
        dt = time.perf_counter() - t0
        times.append(dt)
        if label is not None:
            obs_metrics.observe("bench_seconds", dt, label=label)
    return float(np.median(times)), r


def time_once(fn, *args, label: str | None = None, **kw):
    """One timed call — (seconds, result) with block_until_ready; no
    warmup (cold-vs-warm comparisons time the first call deliberately)."""
    t0 = time.perf_counter()
    r = _sync(fn(*args, **kw))
    dt = time.perf_counter() - t0
    if label is not None:
        obs_metrics.observe("bench_seconds", dt, label=label)
    return dt, r


def measure_rounds(phases: dict, rounds: int = 5,
                   label_prefix: str | None = None) -> dict:
    """Interleaved phase timing: one call of every phase per round,
    medians across rounds.  Host speed drifts on shared machines; a
    per-phase timing block lets the drift land unevenly and corrupt the
    phase *ratios*, so every round cycles through all phases once (with
    one unmeasured warmup/compile round first)."""
    for fn in phases.values():          # warmup/compile round
        _sync(fn())
    acc = {k: [] for k in phases}
    for _ in range(rounds):
        for k, fn in phases.items():
            t0 = time.perf_counter()
            _sync(fn())
            dt = time.perf_counter() - t0
            acc[k].append(dt)
            if label_prefix is not None:
                obs_metrics.observe("bench_seconds", dt,
                                    label=f"{label_prefix}/{k}")
    return {k: float(np.median(v)) for k, v in acc.items()}


def algorithms(include_gdbscan=True, include_tiled=True, include_auto=False):
    # everything routable goes through the stable top-level surface
    # (repro.dbscan); only the comparator baselines reach deeper
    import repro
    from repro.core import gdbscan
    from repro.kernels import dbscan_tiled
    algos = {
        "fdbscan": lambda p, e, m: repro.dbscan(p, e, m,
                                                algorithm="fdbscan"),
        "fdbscan-densebox":
            lambda p, e, m: repro.dbscan(p, e, m,
                                         algorithm="fdbscan-densebox"),
    }
    if include_tiled:
        algos["tiled-mxu"] = lambda p, e, m: dbscan_tiled(p, e, m)
    if include_auto:
        # the unified dispatcher: backend choice + plan cache across eps
        algos["auto"] = lambda p, e, m: repro.dbscan(p, e, m,
                                                     algorithm="auto")
    if include_gdbscan:
        algos["gdbscan"] = gdbscan
    return algos


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
