"""Shared benchmark utilities: timing, CSV emission, algorithm registry."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, repeat: int = 3, **kw):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(r))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(r))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), r


def algorithms(include_gdbscan=True, include_tiled=True, include_auto=False):
    # everything routable goes through the stable top-level surface
    # (repro.dbscan); only the comparator baselines reach deeper
    import repro
    from repro.core import gdbscan
    from repro.kernels import dbscan_tiled
    algos = {
        "fdbscan": lambda p, e, m: repro.dbscan(p, e, m,
                                                algorithm="fdbscan"),
        "fdbscan-densebox":
            lambda p, e, m: repro.dbscan(p, e, m,
                                         algorithm="fdbscan-densebox"),
    }
    if include_tiled:
        algos["tiled-mxu"] = lambda p, e, m: dbscan_tiled(p, e, m)
    if include_auto:
        # the unified dispatcher: backend choice + plan cache across eps
        algos["auto"] = lambda p, e, m: repro.dbscan(p, e, m,
                                                     algorithm="auto")
    if include_gdbscan:
        algos["gdbscan"] = gdbscan
    return algos


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
