"""Paper abstract claim: computing clusters costs at most ~2x neighbor
determination. We time the three phases (preprocessing / main sweeps /
border assignment) separately and report main+border relative to
preprocessing-equivalent traversal cost.
"""
from __future__ import annotations

import numpy as np

from repro.core import fdbscan, grid, lbvh
from repro.data import pointclouds
from .common import emit, time_fn


def run(n: int = 4096, quick: bool = False):
    import jax.numpy as jnp
    for dset, eps, minpts in ([("portotaxi_like", 0.01, 50)] if quick else
                              [("portotaxi_like", 0.01, 50),
                               ("ngsim_like", 0.005, 100),
                               ("hacc_like", 0.03, 5)]):
        pts = jnp.asarray(pointclouds.load(dset, n))
        segs = grid.build_segments_densebox(pts, eps, minpts)
        tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)

        t_pre, core = time_fn(fdbscan._preprocess, tree, segs, eps, minpts)
        # the paper's comparator: FULL neighbor determination (no early exit)
        from repro.core import traversal
        t_full, _ = time_fn(traversal.count_neighbors, tree, segs, eps,
                            2**31 - 1)
        t_main, (labels, sweeps) = time_fn(fdbscan._main_phase, tree, segs,
                                           eps, core)
        t_border, _ = time_fn(fdbscan._assign_borders, tree, segs, eps,
                              core, labels)
        ratio_full = (t_main + t_border) / max(t_full, 1e-9)
        per_sweep = t_main / max(int(sweeps), 1) / max(t_full, 1e-9)
        emit(f"phase_cost/{dset}/preprocess-earlyexit", t_pre * 1e6,
             f"minpts={minpts}")
        emit(f"phase_cost/{dset}/neighbor-determination-full", t_full * 1e6,
             "paper comparator")
        emit(f"phase_cost/{dset}/main+border", (t_main + t_border) * 1e6,
             f"sweeps={int(sweeps)};ratio_vs_full={ratio_full:.2f};"
             f"per_sweep_vs_full={per_sweep:.2f}")


if __name__ == "__main__":
    run()
