"""Paper abstract claim: computing clusters costs at most ~2x neighbor
determination.

We time the phases of the fused pipeline against the paper's comparator —
FULL neighbor determination (no early exit) — and report:

  * ratio_clustering_vs_nd: (fused first pass + remaining sweeps + border)
    relative to full neighbor determination (the paper's <= 2x bound),
  * traversal-loop iteration counts before/after fusion: the seed spent a
    count pass + a first sweep (two walks, one work unit per loop trip);
    the fused engine spends one walk at ``unroll`` work units per trip,
  * per-run traversal counts (n_sweeps + 1 vs the seed's n_sweeps + 2).

``run(json_out=...)`` additionally emits a machine-readable trajectory
file (BENCH_traversal.json) so future PRs can track the hot path, and
``wallclock()`` contributes per-scenario end-to-end dbscan wall clock for
the Pallas engine vs the reference engine — measured back-to-back through
the obs metrics layer so the committed *ratio* is drift-resistant and can
be gated by ``run.py --check``.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import fdbscan, grid, lbvh, traversal
from repro.data import pointclouds
from .common import emit, measure_rounds, time_fn

INT_MAX = 2**31 - 1

# 2-D and 3-D scenarios with the paper's full-scale (n=16384) minpts.
# ``minpts`` scales with the subsample size so the density regime (dense
# cell occupancy / core fraction) matches the paper's setting — at the
# full-data minpts a 2k subsample has zero dense cells and ~2/3 noise,
# which is structurally unlike the workload the claim is about.
SCENARIOS = [
    ("portotaxi_like", 0.01, 50),   # 2-D
    ("hacc_like", 0.03, 5),         # 3-D
    ("ngsim_like", 0.005, 100),     # 2-D, high minpts
]
FULL_N = 16384


def _scaled_minpts(minpts_full: int, n: int) -> int:
    return max(3, minpts_full * n // FULL_N)


def _sum_iters(tr):
    return int(np.asarray(tr.iters).sum())


# Interleaved timing rounds (common.measure_rounds): phase *ratios* are
# the quantity this benchmark exists to report, so host-speed drift must
# land evenly across phases.
_ROUNDS = 5


def _scenarios(quick: bool, only):
    if only is not None:
        return [s for s in SCENARIOS if s[0] in only]
    return SCENARIOS[:2] if quick else SCENARIOS


def wallclock(n: int = 4096, quick: bool = False, only=None,
              rounds: int = 3) -> dict:
    """End-to-end dbscan wall clock per scenario: the Pallas tree engine
    vs the reference traversal engine, measured through the obs layer —
    each timed call lands in a local metrics registry's ``bench_seconds``
    histogram (DESIGN.md §12). The *reported* time is the median of the
    raw samples, not the histogram p50: the sketch's exponential buckets
    quantize to a few percent, which is exactly the scale of the gate's
    drift tolerance.  Engines are interleaved round-robin so host drift
    cannot masquerade as an engine regression; the ratio (not either
    absolute time) is what ``run.py --check`` gates — as a hard limit:
    the pallas engine must *win* (ratio <= 1.0 + drift tolerance) on
    every scenario.

    Plans are resolved explicitly (``query_plan=``) so the warmup round
    pays planning + compile + the tuner's depth-rank calibration, the
    measured rounds see the steady state users see, and the pallas
    plan's chosen ``tuned_config`` (core.tune) can be reported alongside
    the ratio."""
    from repro.core import dispatch
    from repro.obs import metrics as obs_metrics
    engines = (("reference", "fdbscan"), ("pallas", "pallas-tree"))
    prev = obs_metrics.active()
    reg = obs_metrics.install(obs_metrics.Registry())
    try:
        out = {}
        for dset, eps, minpts_full in _scenarios(quick, only):
            minpts = _scaled_minpts(minpts_full, n)
            pts = pointclouds.load(dset, n)
            plans = {}
            for eng, algo in engines:   # warmup/compile round, unmeasured
                plans[eng] = dispatch.plan(pts, eps, minpts, algorithm=algo)
                dispatch.dbscan(pts, eps, minpts, query_plan=plans[eng])
            samples = {eng: [] for eng, _ in engines}
            for _ in range(rounds):     # interleaved measured rounds
                for eng, algo in engines:
                    dt, _ = time_fn(dispatch.dbscan, pts, eps, minpts,
                                    query_plan=plans[eng], warmup=0,
                                    repeat=1,
                                    label=f"dbscan/{dset}/{eng}")
                    samples[eng].append(dt)
            t = {eng: float(np.median(s)) for eng, s in samples.items()}
            tuned = plans["pallas"].tune
            out[dset] = {
                "t_dbscan_reference_us": t["reference"] * 1e6,
                "t_dbscan_pallas_us": t["pallas"] * 1e6,
                "wall_ratio_pallas_over_ref":
                    t["pallas"] / max(t["reference"], 1e-9),
                "tuned_config": tuned.describe() if tuned else None,
            }
    finally:
        if prev is not None:
            obs_metrics.install(prev)
        else:
            obs_metrics.uninstall()
    return out


def _setup(dset: str, n: int, eps: float, minpts: int):
    """(segs, tree, core, labels0, vals0, fused_init, labels_fix, sweeps,
    stats) — the shared fixture for timing and counter collection."""
    import jax.numpy as jnp
    pts = jnp.asarray(pointclouds.load(dset, n))
    segs = grid.build_segments_densebox(pts, eps, minpts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    core, labels0, vals0, absorbed, _ = fdbscan._fused_first_pass(
        tree, segs, eps, minpts)
    fused_init = (vals0, absorbed)
    labels_fix, sweeps, stats = fdbscan._sweep_to_fixpoint(
        tree, segs, eps, core, labels0, collect_stats=True,
        fused_init=fused_init)
    return segs, tree, core, labels0, vals0, fused_init, labels_fix, \
        sweeps, stats


def _phase_predicates(segs, core, eps):
    """(all, loose, core) predicate batches shared by timing and counters."""
    import jax.numpy as jnp
    nq = segs.n_points
    return (traversal.intersects(traversal.sphere(eps)),
            traversal.intersects(
                traversal.sphere(eps),
                ids=traversal._ids_from_mask(nq, ~segs.dense_pt)),
            traversal.intersects(
                traversal.sphere(eps),
                ids=traversal._ids_from_mask(nq, core)))


def _counter_traces(tree, segs, core, labels0, vals0, eps, minpts: int):
    """(pre, sweep1, fused, pallas) traces — THE definition of the
    before/after fusion loop-trip counters and the Pallas kernel's work
    counters, shared by ``run`` (BENCH_traversal.json) and ``counters``
    (the --check gate) so they can never diverge. ``pallas`` is the same
    fused walk executed by the lane-tiled kernel (kernels/traverse.py);
    its ``evals`` must equal the engine's and its ``iters`` come out of
    the kernel as a per-lane output."""
    import jax.numpy as jnp
    from repro.kernels import traverse as pallas_traverse
    pred_all, pred_loose, pred_core = _phase_predicates(segs, core, eps)
    ones = jnp.ones(segs.n_points, bool)
    pre_tr = traversal.traverse(
        tree, segs, pred_loose, traversal.CountVisitor(cap=minpts),
        unroll=1)
    sweep1_tr = traversal.traverse(
        tree, segs, pred_core, traversal.MinLabelVisitor(labels0, core),
        unroll=1)
    fused_tr = traversal.traverse(
        tree, segs, pred_all,
        traversal.CountMinLabelVisitor(vals0, ones, cap=minpts - 1))
    pallas_tr = pallas_traverse.traverse(
        tree, segs, pred_all,
        traversal.CountMinLabelVisitor(vals0, ones, cap=minpts - 1))
    return pre_tr, sweep1_tr, fused_tr, pallas_tr


def counters(n: int = 4096, quick: bool = False, only=None) -> dict:
    """Deterministic work counters only (no timing rounds) — the quantity
    ``benchmarks/run.py --check`` gates regressions on. ``only`` (a set of
    dataset names) overrides the quick/full scenario selection so the gate
    re-measures exactly what the committed trajectory file covers."""
    records = {}
    for dset, eps, minpts_full in _scenarios(quick, only):
        minpts = _scaled_minpts(minpts_full, n)
        segs, tree, core, labels0, vals0, fused_init, _, sweeps, stats = \
            _setup(dset, n, eps, minpts)
        nq = segs.n_points
        pre_tr, sweep1_tr, fused_tr, pallas_tr = _counter_traces(
            tree, segs, core, labels0, vals0, eps, minpts)
        assert int(np.asarray(pallas_tr.evals).sum()) == \
            int(np.asarray(fused_tr.evals).sum()), \
            "pallas kernel evals drifted from the reference engine"
        records[dset] = {
            "n": int(nq), "eps": eps, "minpts": minpts,
            "loop_iters_before_fusion": _sum_iters(pre_tr)
                                        + _sum_iters(sweep1_tr),
            "loop_iters_after_fusion": _sum_iters(fused_tr),
            "pallas_loop_iters": _sum_iters(pallas_tr),
            "pallas_evals": int(np.asarray(pallas_tr.evals).sum()),
            "n_sweeps": 1 + sweeps,
            "sweep_iters_per_sweep": stats["iters_per_sweep"],
            "sweep_evals_per_sweep": stats["evals_per_sweep"],
        }
    return records


def run(n: int = 4096, quick: bool = False, json_out: str | None = None):
    import jax.numpy as jnp
    from repro.kernels import traverse as pallas_traverse
    records = {}
    for dset, eps, minpts_full in (SCENARIOS[:2] if quick else SCENARIOS):
        minpts = _scaled_minpts(minpts_full, n)
        segs, tree, core, labels0, vals0, fused_init, labels_fix, sweeps, \
            stats = _setup(dset, n, eps, minpts)
        nq = segs.n_points
        ones = jnp.ones(nq, bool)
        pred_all, pred_loose, pred_core = _phase_predicates(segs, core, eps)
        phases = {
            # the paper's comparator: FULL neighbor determination
            "full": lambda: traversal.traverse(
                tree, segs, pred_all, traversal.CountVisitor(cap=INT_MAX)),
            # BEFORE fusion (seed shape): early-exit count over loose
            # points + first min-label sweep over core queries gathering
            # core values — exactly the seed's two single-work-unit walks
            "pre": lambda: traversal.traverse(
                tree, segs, pred_loose, traversal.CountVisitor(cap=minpts),
                unroll=1),
            "sweep1": lambda: traversal.traverse(
                tree, segs, pred_core,
                traversal.MinLabelVisitor(labels0, core), unroll=1),
            # AFTER fusion: one walk, count saturating at min_pts - 1
            "fused": lambda: traversal.traverse(
                tree, segs, pred_all,
                traversal.CountMinLabelVisitor(vals0, ones, cap=minpts - 1)),
            # the same fused walk through the Pallas kernel engine
            # (interpret mode off-TPU — a lowering comparator, not a
            # wall-clock claim there)
            "fused_pallas": lambda: pallas_traverse.traverse(
                tree, segs, pred_all,
                traversal.CountMinLabelVisitor(vals0, ones, cap=minpts - 1)),
            "main": lambda: fdbscan._sweep_to_fixpoint(
                tree, segs, eps, core, labels0, fused_init=fused_init)[0],
            "border": lambda: fdbscan._assign_borders(tree, segs, eps,
                                                      core, labels_fix),
        }
        t = measure_rounds(phases, rounds=_ROUNDS)
        t_full, t_pre, t_sweep1 = t["full"], t["pre"], t["sweep1"]
        t_fused, t_main, t_border = t["fused"], t["main"], t["border"]

        pre_tr, sweep1_tr, fused_tr, pallas_tr = _counter_traces(
            tree, segs, core, labels0, vals0, eps, minpts)
        iters_before = _sum_iters(pre_tr) + _sum_iters(sweep1_tr)
        iters_after = _sum_iters(fused_tr)

        t_cluster = t_fused + t_main + t_border
        ratio = t_cluster / max(t_full, 1e-9)
        n_sweeps = 1 + sweeps
        rec = {
            "n": int(nq), "eps": eps, "minpts": minpts,
            "t_neighbor_determination_us": t_full * 1e6,
            "t_fused_first_pass_us": t_fused * 1e6,
            "t_fused_first_pass_pallas_us": t["fused_pallas"] * 1e6,
            "t_separate_pre_plus_sweep_us": (t_pre + t_sweep1) * 1e6,
            "t_main_sweeps_us": t_main * 1e6,
            "t_border_us": t_border * 1e6,
            "t_total_clustering_us": t_cluster * 1e6,
            "ratio_clustering_vs_nd": ratio,
            "loop_iters_before_fusion": iters_before,
            "loop_iters_after_fusion": iters_after,
            "pallas_loop_iters": _sum_iters(pallas_tr),
            "pallas_evals": int(np.asarray(pallas_tr.evals).sum()),
            "iters_speedup": iters_before / max(iters_after, 1),
            "n_sweeps": n_sweeps,
            "n_traversals": n_sweeps + 1,
            "n_traversals_seed_equivalent": n_sweeps + 2,
            "frontier_per_sweep": stats["frontier_per_sweep"],
            "active_queries_per_sweep": stats["active_per_sweep"],
            "sweep_iters_per_sweep": stats["iters_per_sweep"],
        }
        records[dset] = rec
        emit(f"phase_cost/{dset}/neighbor-determination-full", t_full * 1e6,
             "paper comparator")
        emit(f"phase_cost/{dset}/first-pass-fused", t_fused * 1e6,
             f"vs_separate={(t_pre + t_sweep1) * 1e6:.1f}us;"
             f"iters {iters_before}->{iters_after}")
        emit(f"phase_cost/{dset}/first-pass-pallas",
             t["fused_pallas"] * 1e6,
             f"kernel iters={_sum_iters(pallas_tr)};"
             f"evals={int(np.asarray(pallas_tr.evals).sum())}")
        emit(f"phase_cost/{dset}/total-clustering", t_cluster * 1e6,
             f"ratio_vs_nd={ratio:.2f};sweeps={n_sweeps};"
             f"traversals={n_sweeps + 1}")
    # end-to-end wall clock, pallas vs reference, through the obs layer
    for dset, w in wallclock(n=n, quick=quick).items():
        records[dset].update(w)
        emit(f"phase_cost/{dset}/dbscan-wall-pallas",
             w["t_dbscan_pallas_us"],
             f"ref={w['t_dbscan_reference_us']:.1f}us;"
             f"ratio={w['wall_ratio_pallas_over_ref']:.2f}")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {json_out}")
    return records


def main(argv=None) -> int:
    import argparse
    import os
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-out", default="BENCH_traversal.json")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tune", action="store_true",
                    help="regenerate with the measured autotuner "
                         "(REPRO_TUNE=search) and fail (exit 1) if any "
                         "scenario's wall_ratio_pallas_over_ref exceeds "
                         "1.0 — the `make bench-tune` entry")
    args = ap.parse_args(argv)
    if args.tune:
        os.environ.setdefault("REPRO_TUNE", "search")
    records = run(n=args.n, quick=args.quick, json_out=args.json_out)
    if args.tune:
        losses = {d: r["wall_ratio_pallas_over_ref"]
                  for d, r in records.items()
                  if r.get("wall_ratio_pallas_over_ref", 0.0) > 1.0}
        if losses:
            print(f"# FAIL: pallas loses wall clock on {losses}")
            return 1
        print("# OK: pallas wins wall clock on every scenario")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
