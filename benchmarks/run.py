"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run``          — smoke sizes (CI-friendly)
``python -m benchmarks.run --full``   — paper-scale sizes (n=16384 etc.)

Output: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: minpts,eps,scaling,cosmo,memory,"
                         "phase,kernels,dist_evals,distributed,stream")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_cosmo, bench_distance_evals, bench_distributed,
                   bench_eps, bench_kernels, bench_memory, bench_minpts,
                   bench_phase_cost, bench_scaling, bench_stream)
    suites = {
        "minpts": lambda: bench_minpts.run(n=16384 if args.full else 2048,
                                           quick=quick),
        "eps": lambda: bench_eps.run(n=16384 if args.full else 2048,
                                     quick=quick),
        "scaling": lambda: bench_scaling.run(
            sizes=(4096, 16384, 65536, 131072) if args.full
            else (1024, 2048), quick=quick),
        "cosmo": lambda: bench_cosmo.run(n=36000 if args.full else 4000,
                                         quick=quick),
        "memory": lambda: bench_memory.run(quick=quick),
        # the phase suite measures the paper's headline <=2x bound; below
        # n=4096 the subsampled scenarios leave the density regime the
        # claim is about, so quick mode keeps the larger size
        "phase": lambda: bench_phase_cost.run(n=16384 if args.full else 4096,
                                              quick=quick,
                                              json_out="BENCH_traversal.json"),
        "kernels": lambda: bench_kernels.run(quick=quick),
        "dist_evals": lambda: bench_distance_evals.run(
            n=16384 if args.full else 2048, quick=quick),
        # ring vs sharded tree (8 virtual devices, subprocess); 16384 stays
        # in quick mode — it is the acceptance size for the >=10x evals
        # claim recorded in BENCH_distributed.json
        "distributed": lambda: bench_distributed.run(
            sizes=(4096, 16384, 65536) if args.full else (4096, 16384),
            quick=quick),
        # streaming insert vs full recluster; 32768 is the acceptance size
        # for the >=5x wall-clock claim recorded in BENCH_stream.json
        "stream": lambda: bench_stream.run(n=32768 if args.full else 4096,
                                           quick=quick),
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# suite: {name}", flush=True)
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
