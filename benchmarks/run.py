"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run``          — smoke sizes (CI-friendly)
``python -m benchmarks.run --full``   — paper-scale sizes (n=16384 etc.)
``python -m benchmarks.run --check``  — regression gate: re-measure the
    *deterministic* work counters (traversal loop trips, sharded distance
    evaluations, streaming repair/compaction work on the mixed
    insert/delete/window trace) and fail if any regresses more than
    ``CHECK_THRESHOLD``x
    against the committed ``BENCH_*.json`` trajectory files. Absolute
    wall-clock numbers are never gated (CI machines drift); counters
    cannot. The one wall-clock quantity that IS gated is the
    pallas-vs-reference end-to-end *ratio* from ``BENCH_traversal.json``:
    both engines are re-measured interleaved on the same machine through
    the obs layer (bench_phase_cost.wallclock), so the ratio is drift-free
    even though each absolute time is not. It is gated as a HARD limit —
    the pallas engine must win (ratio <= WALL_RATIO_LIMIT) on *every*
    scenario, and the committed ratios must themselves be <= 1.0; the
    old ratio-of-ratios comparison let a committed 2-of-3 loss pass
    indefinitely. The gate also writes the tuner's chosen per-scenario
    configs to ``tuner_configs.json`` for the CI artifact upload.

Output: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECK_THRESHOLD = 1.5
# Hard per-scenario ceiling for the pallas-vs-reference end-to-end wall
# ratio: the kernel must win (<= 1.0) with a small drift tolerance for
# shared-machine noise. A committed ratio above 1.0 fails outright.
WALL_RATIO_LIMIT = 1.05


def _check_ratio(failures: list, name: str, got: float, committed: float,
                 floor: float = 1.0):
    """``floor`` guards the divide: 1.0 for integer work counters (a
    committed 0 means "got must stay ~0"), a tiny epsilon for float
    ratios where flooring at 1 would silently mask regressions below 1."""
    ratio = got / max(committed, floor)
    status = "FAIL" if ratio > CHECK_THRESHOLD else "ok"
    print(f"check,{name},{committed},{got},{ratio:.3f},{status}")
    if ratio > CHECK_THRESHOLD:
        failures.append(f"{name}: {committed} -> {got} "
                        f"({ratio:.2f}x > {CHECK_THRESHOLD}x)")


def check() -> None:
    """The ``--check`` gate over the committed BENCH_*.json counters."""
    failures: list[str] = []
    print("check,name,committed,measured,ratio,status")

    trav_path = os.path.join(REPO, "BENCH_traversal.json")
    if os.path.exists(trav_path):
        with open(trav_path) as f:
            committed = json.load(f)
        from . import bench_phase_cost
        n = next(iter(committed.values()))["n"]
        # re-measure exactly the committed scenario set; a committed
        # scenario the suite no longer knows is a gate failure, not a
        # silent skip
        got = bench_phase_cost.counters(n=n, only=set(committed))
        for dset in committed:
            if dset not in got:
                failures.append(f"traversal/{dset}: committed in "
                                "BENCH_traversal.json but no longer "
                                "measurable (scenario renamed/removed?)")
                print(f"check,traversal/{dset},-,-,-,FAIL (unmeasured)")
                continue
            rec, ref = got[dset], committed[dset]
            if (rec["eps"], rec["minpts"]) != (ref["eps"], ref["minpts"]):
                failures.append(
                    f"traversal/{dset}: workload drifted (committed "
                    f"eps={ref['eps']}/minpts={ref['minpts']}, bench now "
                    f"uses eps={rec['eps']}/minpts={rec['minpts']}) — "
                    "regenerate BENCH_traversal.json")
                continue
            for key in ("loop_iters_before_fusion",
                        "loop_iters_after_fusion",
                        "pallas_loop_iters", "pallas_evals"):
                if key not in ref:
                    continue  # pre-kernel trajectory file
                _check_ratio(failures, f"traversal/{dset}/{key}",
                             rec[key], ref[key])
            _check_ratio(failures, f"traversal/{dset}/sweep_iters_total",
                         sum(rec["sweep_iters_per_sweep"]),
                         sum(ref["sweep_iters_per_sweep"]))
        # pallas-vs-reference wall clock, gated as a HARD limit: both
        # engines are re-measured interleaved (obs-layer histograms, same
        # machine) so the *ratio* is drift-free, and the pallas engine
        # must win every scenario. The committed ratio must itself be
        # <= 1.0; anything above means BENCH_traversal.json predates the
        # autotuner and must be regenerated (``make bench-tune``).
        wall_dsets = {d for d in committed
                      if "wall_ratio_pallas_over_ref" in committed[d]
                      and d in got}
        if wall_dsets:
            for dset in sorted(wall_dsets):
                ref_ratio = committed[dset]["wall_ratio_pallas_over_ref"]
                if ref_ratio > 1.0:
                    print(f"check,traversal/{dset}/wall_ratio_committed,"
                          f"{ref_ratio},-,-,FAIL")
                    failures.append(
                        f"traversal/{dset}: committed wall ratio "
                        f"{ref_ratio} > 1.0 — regenerate "
                        "BENCH_traversal.json with `make bench-tune`")
            wall = bench_phase_cost.wallclock(n=n, only=wall_dsets)
            tuner_configs = {}
            for dset in sorted(wall_dsets):
                got_ratio = wall[dset]["wall_ratio_pallas_over_ref"]
                status = "FAIL" if got_ratio > WALL_RATIO_LIMIT else "ok"
                print(f"check,traversal/{dset}/wall_ratio_pallas_over_ref,"
                      f"{WALL_RATIO_LIMIT},{got_ratio},"
                      f"{got_ratio / WALL_RATIO_LIMIT:.3f},{status}")
                if got_ratio > WALL_RATIO_LIMIT:
                    failures.append(
                        f"traversal/{dset}: pallas engine lost the wall "
                        f"race (ratio {got_ratio:.3f} > hard limit "
                        f"{WALL_RATIO_LIMIT})")
                tuner_configs[dset] = wall[dset].get("tuned_config")
            # artifact for CI: which configs the tuner actually chose
            with open(os.path.join(REPO, "tuner_configs.json"), "w") as f:
                json.dump(tuner_configs, f, indent=2, sort_keys=True)
                f.write("\n")
    else:
        print("check,traversal,-,-,-,skipped (no BENCH_traversal.json)")

    dist_path = os.path.join(REPO, "BENCH_distributed.json")
    if os.path.exists(dist_path):
        with open(dist_path) as f:
            committed = json.load(f)
        from . import bench_distributed
        # gate on the smallest committed size only: counters are exact at
        # any n, and CI shouldn't pay for the 16k+ collective programs
        key = min(committed, key=lambda k: committed[k]["n"])
        n = committed[key]["n"]
        if (committed[key]["eps"], committed[key]["minpts"]) != \
                (bench_distributed.EPS, bench_distributed.MINPTS):
            failures.append(
                f"distributed/n{n}: workload drifted (committed "
                f"eps={committed[key]['eps']}/minpts="
                f"{committed[key]['minpts']}, bench now uses "
                f"eps={bench_distributed.EPS}/minpts="
                f"{bench_distributed.MINPTS}) — regenerate "
                "BENCH_distributed.json")
        else:
            got = bench_distributed.measure_evals((n,))
            _check_ratio(failures, f"distributed/n{n}/tree_distance_evals",
                         got[f"n{n}"]["tree_distance_evals"],
                         committed[key]["tree_distance_evals"])
    else:
        print("check,distributed,-,-,-,skipped (no BENCH_distributed.json)")

    stream_path = os.path.join(REPO, "BENCH_stream.json")
    if os.path.exists(stream_path):
        with open(stream_path) as f:
            committed = json.load(f)
        if "mixed" not in committed:
            print("check,stream,-,-,-,skipped (pre-mixed BENCH_stream.json"
                  " — regenerate)")
        else:
            from . import bench_stream
            ref = committed["mixed"]
            drift = {k: ref[k] for k in ("n", "window", "batch", "seed",
                                         "buffer_max", "delete_every",
                                         "delete_frac")}
            if (drift != {k: bench_stream.MIXED[k] for k in drift}
                    or (ref["eps"], ref["minpts"]) != (bench_stream.EPS,
                                                       bench_stream.MINPTS)):
                failures.append(
                    "stream/mixed: workload drifted (committed "
                    f"{drift} eps={ref['eps']}/minpts={ref['minpts']}) — "
                    "regenerate BENCH_stream.json")
            else:
                # the dynamic trace is fully deterministic: the repair /
                # compaction work counters are exact, so gate them (and
                # the exact survivor counts) — never the wall clock
                got = bench_stream.mixed_workload()
                for key in ("repair_sweeps", "n_compactions", "n_merges",
                            "n_active", "n_tombstoned"):
                    _check_ratio(failures, f"stream/mixed/{key}",
                                 got[key], ref[key])
    else:
        print("check,stream,-,-,-,skipped (no BENCH_stream.json)")

    serve_path = os.path.join(REPO, "BENCH_serve.json")
    if os.path.exists(serve_path):
        with open(serve_path) as f:
            committed = json.load(f)
        from . import bench_serve
        ref = committed["snapshot_vs_handle_check"]
        if (ref["eps"], ref["minpts"], ref["n"]) != \
                (bench_serve.EPS, bench_serve.MINPTS, bench_serve.CHECK_N):
            failures.append(
                "serve/snapshot_vs_handle_check: workload drifted "
                f"(committed n={ref['n']} eps={ref['eps']}/minpts="
                f"{ref['minpts']}) — regenerate BENCH_serve.json")
        else:
            # steady-state jit stability is exact: zero new programs, gated
            # as an equality (committed 0 + threshold still pins got <= 1)
            rec = bench_serve.recompile_steadystate()
            _check_ratio(failures, "serve/recompiles/new_programs_steady",
                         rec["new_programs_steady"],
                         committed["recompiles"]["new_programs_steady"])
            # snapshot-vs-handle speedup: both engines re-measured
            # interleaved, gated as an inverted ratio-of-ratios (bigger
            # speedup is better, so a drop shows up as ratio > threshold)
            got = bench_serve.snapshot_vs_handle(n=ref["n"])
            _check_ratio(failures, "serve/snapshot_vs_handle/speedup",
                         1.0 / got["speedup"], 1.0 / ref["speedup"],
                         floor=1e-9)
    else:
        print("check,serve,-,-,-,skipped (no BENCH_serve.json)")

    if failures:
        print("# REGRESSION GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"#   {f_}", file=sys.stderr)
        sys.exit(1)
    print("# regression gate passed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="regression-gate the deterministic counters "
                         "against the committed BENCH_*.json files")
    ap.add_argument("--only", default=None,
                    help="comma list: minpts,eps,scaling,cosmo,memory,"
                         "phase,kernels,dist_evals,distributed,stream,"
                         "serve")
    args = ap.parse_args()
    if args.check:
        check()
        return
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_cosmo, bench_distance_evals, bench_distributed,
                   bench_eps, bench_kernels, bench_memory, bench_minpts,
                   bench_phase_cost, bench_scaling, bench_serve,
                   bench_stream)
    suites = {
        "minpts": lambda: bench_minpts.run(n=16384 if args.full else 2048,
                                           quick=quick),
        "eps": lambda: bench_eps.run(n=16384 if args.full else 2048,
                                     quick=quick),
        "scaling": lambda: bench_scaling.run(
            sizes=(4096, 16384, 65536, 131072) if args.full
            else (1024, 2048), quick=quick),
        "cosmo": lambda: bench_cosmo.run(n=36000 if args.full else 4000,
                                         quick=quick),
        "memory": lambda: bench_memory.run(quick=quick),
        # the phase suite measures the paper's headline <=2x bound; below
        # n=4096 the subsampled scenarios leave the density regime the
        # claim is about, so quick mode keeps the larger size
        "phase": lambda: bench_phase_cost.run(n=16384 if args.full else 4096,
                                              quick=quick,
                                              json_out="BENCH_traversal.json"),
        "kernels": lambda: bench_kernels.run(quick=quick),
        "dist_evals": lambda: bench_distance_evals.run(
            n=16384 if args.full else 2048, quick=quick),
        # ring vs sharded tree (8 virtual devices, subprocess); 16384 stays
        # in quick mode — it is the acceptance size for the >=10x evals
        # claim recorded in BENCH_distributed.json
        "distributed": lambda: bench_distributed.run(
            sizes=(4096, 16384, 65536) if args.full else (4096, 16384),
            quick=quick),
        # streaming insert vs full recluster; 32768 is the acceptance size
        # for the >=5x wall-clock claim recorded in BENCH_stream.json
        "stream": lambda: bench_stream.run(n=32768 if args.full else 4096,
                                           quick=quick),
        # the serving plane: snapshot-vs-handle speedup (>= 50x), open-loop
        # multi-tenant aggregate throughput, and the zero-recompile witness
        "serve": lambda: bench_serve.run(quick=quick),
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# suite: {name}", flush=True)
        fn()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
