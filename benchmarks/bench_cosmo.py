"""Paper Fig. 6 & 7: 3-D cosmology problem (HACC-like surrogate).

Fig. 6: minpts sweep at fixed eps — at low minpts DenseBox ~ FDBSCAN, at
high minpts dense cells vanish and DenseBox pays pure overhead.
Fig. 7: eps sweep at minpts=2 (friends-of-friends) — growing eps pulls
points into dense cells and DenseBox pulls ahead (paper: 16x at eps=1.0).
"""
from __future__ import annotations

import numpy as np

from repro.core.grid import build_segments_densebox
from repro.data import pointclouds
from .common import algorithms, emit, time_fn


def run(n: int = 8000, quick: bool = False):
    pts = pointclouds.halos_3d(n, n_halos=60, seed=7)
    algos = algorithms(include_gdbscan=False, include_tiled=False)

    eps0 = 0.02  # "physics" eps for the surrogate box
    for minpts in ([2, 5] if quick else [2, 5, 20, 100]):
        segs = build_segments_densebox(np.asarray(pts), eps0, minpts)
        frac = float(np.asarray(segs.dense_pt).mean())
        for name, fn in algos.items():
            dt, res = time_fn(fn, pts, eps0, minpts,
                              warmup=1, repeat=1 if quick else 3)
            emit(f"cosmo_minpts/mp{minpts}/{name}", dt * 1e6,
                 f"clusters={res.n_clusters};dense_frac={frac:.2f}")

    for eps in ([0.01, 0.04] if quick else [0.01, 0.02, 0.04, 0.08]):
        segs = build_segments_densebox(np.asarray(pts), eps, 2)
        frac = float(np.asarray(segs.dense_pt).mean())
        for name, fn in algos.items():
            dt, res = time_fn(fn, pts, eps, 2,
                              warmup=1, repeat=1 if quick else 3)
            emit(f"cosmo_eps/e{eps}/{name}", dt * 1e6,
                 f"clusters={res.n_clusters};dense_frac={frac:.2f}")


if __name__ == "__main__":
    run()
