"""Render the EXPERIMENTS.md roofline table from dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline_report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def table(recs):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| useful/HLO flops | fit GiB/chip | multi-pod |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        a, s = r["arch"], r["shape"]
        if r["status"] == "SKIP":
            rows.append(f"| {a} | {s} | — | — | — | SKIP | — | — | — "
                        f"<!-- {r['reason']} -->|")
            continue
        if r["status"] == "FAIL":
            rows.append(f"| {a} | {s} | FAIL | | | | | | |")
            continue
        ro = r.get("roofline", {})
        fit = r.get("fit", {}).get("memory", {})
        temp = fit.get("temp_bytes")
        arg = fit.get("argument_bytes") or 0
        total = (temp or 0) + arg
        mp = r.get("multi_pod", {}).get("status", "-")
        rows.append(
            f"| {a} | {s} | {ro.get('t_compute_s', 0):.4f} "
            f"| {ro.get('t_memory_s', 0):.4f} "
            f"| {ro.get('t_collective_s', 0):.4f} "
            f"| {ro.get('dominant', '-')}"
            f" | {ro.get('useful_flops_ratio') and format(ro['useful_flops_ratio'], '.2f') or '-'}"
            f" | {fmt_bytes(total)} | {mp} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if r["status"] == "OK" and "roofline" in r]
    if not ok:
        return "(no roofline records)"
    def frac(r):
        ro = r["roofline"]
        return ro["t_compute_s"] / max(ro["bound_time_s"], 1e-12)
    worst = sorted(ok, key=frac)[:5]
    coll = sorted(ok, key=lambda r: -r["roofline"]["t_collective_s"])[:5]
    lines = ["", "worst roofline fraction (compute/bound):"]
    for r in worst:
        lines.append(f"  {r['arch']}/{r['shape']}: frac={frac(r):.3f} "
                     f"dominant={r['roofline']['dominant']}")
    lines.append("most collective-bound:")
    for r in coll:
        lines.append(f"  {r['arch']}/{r['shape']}: "
                     f"t_coll={r['roofline']['t_collective_s']:.3f}s "
                     f"({r['roofline']['wire_bytes']/2**30:.1f} GiB wire)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print(table(recs))
    print(summary(recs))


if __name__ == "__main__":
    main()
