"""Roofline for the distributed DBSCAN pipeline on the production pod.

Run as its own process (sets 512 host devices before importing jax):

  PYTHONPATH=src python -m benchmarks.dbscan_roofline [-n 16777216]

Two parts:
  1. *Compile proof*: the ring kernel (shard_map + ppermute + tile
     epilogues) lowers and compiles on the 16x16 pod mesh and on the
     2x16x16 multi-pod mesh from ShapeDtypeStructs — the distribution
     config is coherent. Collective ops are counted from the HLO.
  2. *Analytic roofline* (cost_analysis counts loop bodies once, so the
     ring/sweep terms are derived explicitly): per-device tile FLOPs,
     ppermute wire bytes, HBM traffic of the resident block, and the
     overlap ratio (permute time / tile-compute time) that double
     buffering must hide.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json

import jax
import jax.numpy as jnp


def analytic(n, d, ndev, peak, hbm, ici, sweeps=4):
    n_loc = n // ndev
    flops_per_pair = 2 * d + 5                     # MXU form + compare
    tile_flops = n_loc * n_loc * flops_per_pair    # one ring step
    ring_flops = tile_flops * ndev                 # full pass, per device
    wire_step = n_loc * d * 4                      # traveling block, f32
    wire_labels = n_loc * 4 * 2                    # labels+core per step
    t_comp_step = tile_flops / peak
    t_wire_step = (wire_step + wire_labels) / ici
    passes = 1 + sweeps + 1                        # count + sweeps + border
    jump_wire = sweeps * n * 4 / ndev * 2          # all-gathers of labels
    return {
        "n": n, "ndev": ndev, "n_loc": n_loc, "passes": passes,
        "t_compute_s": passes * ring_flops / peak,
        "t_collective_s": (passes * ndev * t_wire_step
                           + jump_wire / ici),
        "t_memory_s": passes * ndev * (2 * n_loc * d * 4) / hbm,
        "overlap_ratio_step": t_wire_step / t_comp_step,
        "tile_flops": tile_flops,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("-n", type=int, default=2**24)
    ap.add_argument("--compile-n", type=int, default=2**20)
    ap.add_argument("--out")
    args = ap.parse_args()

    from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                   make_production_mesh)
    from repro.launch.roofline import collective_wire_bytes

    rec = {}
    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        tag = "2x16x16" if multi else "16x16"
        # lower the ring kernel (shard_map body) from SDS inputs
        from repro.distributed import ring_dbscan as rd
        pts_sds = jax.ShapeDtypeStruct((args.compile_n, 3), jnp.float32)
        cell = _lower_ring(rd, mesh, pts_sds, args.compile_n)
        rec[tag] = cell
        print(f"[dbscan-roofline] {tag}: compile OK; "
              f"collectives={cell['collective_counts']}")

    ana = analytic(args.n, 3, 256, PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
    rec["analytic_16M"] = ana
    print("[dbscan-roofline] analytic (n=%d over %d chips):" %
          (ana["n"], ana["ndev"]))
    for k in ("t_compute_s", "t_memory_s", "t_collective_s",
              "overlap_ratio_step"):
        print(f"  {k}: {ana[k]:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


def _lower_ring(rd, mesh, pts_sds, n):
    """Lower ring_dbscan's shard_map kernel on ``mesh`` from SDS inputs."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.roofline import collective_wire_bytes

    axis = "data"
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    n_pad = ((n + ndev - 1) // ndev) * ndev
    eps, min_pts = 0.01, 5
    n_loc = n_pad // ndev
    perm = [(i, (i + 1) % ndev) for i in range(ndev)]

    # borrow the library's kernel by calling ring_dbscan in lower-only mode:
    # replicate its construction with the same helpers
    import jax.numpy as jnp
    from jax import lax

    count_tile = rd._count_tile
    minlabel_tile = rd._minlabel_tile
    INT_MAX = rd.INT_MAX
    _vary = rd._vary

    def kernel(local_pts):
        me = lax.axis_index(axis)
        gid = me.astype(jnp.int32) * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        valid = gid < n

        def count_body(i, carry):
            counts, block = carry
            counts = counts + count_tile(local_pts, block, eps)
            return counts, lax.ppermute(block, axis, perm)

        counts, _ = lax.fori_loop(0, ndev, count_body,
                                  (_vary(jnp.zeros(n_loc, jnp.int32), axis),
                                   local_pts))
        core = (counts >= min_pts) & valid
        labels = jnp.where(core, gid, INT_MAX)

        def ring(i, carry):
            best, bp, bl, bc = carry
            got = minlabel_tile(local_pts, bp, bl, bc, eps)
            return (jnp.minimum(best, got), lax.ppermute(bp, axis, perm),
                    lax.ppermute(bl, axis, perm), lax.ppermute(bc, axis, perm))

        best, _, _, _ = lax.fori_loop(
            0, ndev, ring, (_vary(jnp.full(n_loc, INT_MAX, jnp.int32), axis),
                            local_pts, labels, core))
        labels = jnp.where(core, jnp.minimum(labels, best), labels)
        table = lax.all_gather(labels, axis, tiled=True)
        safe = jnp.where(labels == INT_MAX, 0, labels)
        labels = jnp.where(labels == INT_MAX, labels, table[safe])
        return labels, core

    fn = rd._shard_map(kernel, mesh, in_specs=P(axis),
                       out_specs=(P(axis), P(axis)))
    pad_sds = jax.ShapeDtypeStruct((n_pad, 3), jnp.float32)
    with mesh:
        lowered = jax.jit(fn).lower(pad_sds)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    wire = collective_wire_bytes(compiled.as_text(), mesh.devices.size)
    return {"status": "OK",
            "hlo_flops_loopbody": float(cost.get("flops", 0)),
            "collective_counts": wire["counts"],
            "wire_bytes_loopbody": wire["total"]}


if __name__ == "__main__":
    main()
