"""Distributed DBSCAN: dense systolic ring vs sharded tree + eps-halo.

The quantity under test is the ISSUE-2 claim: per-shard BVH traversal with
eps-halo exchange does the clustering with a small fraction of the ring
pass's pairwise distance evaluations (>= 10x fewer at n=16384), at equal
labels. We report exact distance-evaluation counts (the paper's work
metric — measured by the traversal engine for the tree path, analytic
``(2 + sweeps) * n_pad^2`` for the dense ring, which evaluates every pair
in every phase rotation) plus wall clock for both, and emit
``BENCH_distributed.json``.

Multi-device CPU execution needs ``XLA_FLAGS`` set before jax import, so
``run()`` re-executes this module in a subprocess with 8 forced host
devices; ``python -m benchmarks.bench_distributed`` does the same.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# Above this the dense ring's wall clock is minutes on CPU; its eval count
# stays analytic either way, so larger sizes skip the ring timing only.
RING_MAX_N = 16384
N_DEVICES = 8
EPS, MINPTS = 0.02, 10


def _inner(sizes, json_out):
    import jax
    import numpy as np
    from repro.data import pointclouds
    from repro.distributed.ring_dbscan import ring_dbscan, tree_dbscan_sharded
    from repro.core.validate import same_partition
    from .common import emit, time_once

    ndev = len(jax.devices())
    records = {}
    for n in sizes:
        pts = pointclouds.taxi_2d(n)
        n_pad = ((n + ndev - 1) // ndev) * ndev

        tree_cold, (tree_res, st) = time_once(
            tree_dbscan_sharded, pts, EPS, MINPTS, with_stats=True,
            label=f"dist/n{n}/tree_cold")
        tree_warm, (tree_res, st) = time_once(
            tree_dbscan_sharded, pts, EPS, MINPTS, with_stats=True,
            label=f"dist/n{n}/tree_warm")

        rec = {
            "n": n, "n_pad": n_pad, "ndev": ndev,
            "eps": EPS, "minpts": MINPTS,
            "tree_wall_s": tree_warm, "tree_wall_cold_s": tree_cold,
            "tree_distance_evals": st["distance_evals"],
            "tree_sweeps": st["n_sweeps"],
            "n_clusters": tree_res.n_clusters,
        }
        if n <= RING_MAX_N:
            rec["ring_wall_cold_s"], ring_res = time_once(
                ring_dbscan, pts, EPS, MINPTS,
                label=f"dist/n{n}/ring_cold")
            rec["ring_wall_s"], ring_res = time_once(  # warm, like the tree
                ring_dbscan, pts, EPS, MINPTS,
                label=f"dist/n{n}/ring_warm")
            rec["ring_sweeps"] = ring_res.n_sweeps
            assert same_partition(np.asarray(ring_res.labels),
                                  np.asarray(tree_res.labels))
            ring_evals = (2 + ring_res.n_sweeps) * n_pad * n_pad
        else:
            # analytic only: same sweep count as the tree path's fixpoint
            # (both run min-label sweeps to convergence over one protocol)
            rec["ring_wall_s"] = None
            rec["ring_sweeps"] = st["n_sweeps"]
            ring_evals = (2 + st["n_sweeps"]) * n_pad * n_pad
        rec["ring_distance_evals"] = ring_evals
        rec["evals_ratio_ring_over_tree"] = (
            ring_evals / max(st["distance_evals"], 1))
        records[f"n{n}"] = rec
        emit(f"distributed/n{n}/tree-sharded", rec["tree_wall_s"] * 1e6,
             f"evals={st['distance_evals']};sweeps={st['n_sweeps']}")
        emit(f"distributed/n{n}/ring-dense",
             (rec["ring_wall_s"] or 0.0) * 1e6,
             f"evals={ring_evals};ratio="
             f"{rec['evals_ratio_ring_over_tree']:.1f}x")
    if json_out:
        with open(json_out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {json_out}")
    return records


def _evals_only(sizes):
    """Deterministic work counters for the sharded tree path (no timing,
    no ring comparator) — the ``run.py --check`` regression gate."""
    from repro.data import pointclouds
    from repro.distributed.ring_dbscan import tree_dbscan_sharded
    out = {}
    for n in sizes:
        pts = pointclouds.taxi_2d(n)
        _, st = tree_dbscan_sharded(pts, EPS, MINPTS, with_stats=True)
        out[f"n{n}"] = {"tree_distance_evals": st["distance_evals"],
                        "tree_sweeps": st["n_sweeps"]}
    print("EVALS_JSON=" + json.dumps(out))


def measure_evals(sizes) -> dict:
    """Run :func:`_evals_only` under 8 forced host devices; parsed dict."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{N_DEVICES}",
               PYTHONPATH=os.path.join(repo, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_distributed",
           "--evals-only", "--sizes", ",".join(str(n) for n in sizes)]
    r = subprocess.run(cmd, env=env, cwd=repo, text=True,
                       capture_output=True)
    if r.returncode != 0:
        sys.stderr.write(r.stderr)
        raise RuntimeError("bench_distributed evals-only run failed")
    for line in r.stdout.splitlines():
        if line.startswith("EVALS_JSON="):
            return json.loads(line[len("EVALS_JSON="):])
    raise RuntimeError(f"no EVALS_JSON line in output:\n{r.stdout}")


def run(sizes=(4096, 16384), quick: bool = False,
        json_out: str = "BENCH_distributed.json"):
    """Spawn the measurement under 8 forced host devices and relay output."""
    if quick:
        sizes = tuple(n for n in sizes if n <= 16384)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{N_DEVICES}",
               PYTHONPATH=os.path.join(repo, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_distributed", "--inner",
           "--sizes", ",".join(str(n) for n in sizes)]
    if json_out:
        cmd += ["--json", json_out]
    r = subprocess.run(cmd, env=env, cwd=repo, text=True,
                       capture_output=True)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr)
        raise RuntimeError("bench_distributed inner run failed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--evals-only", action="store_true")
    ap.add_argument("--sizes", default="4096,16384")
    ap.add_argument("--json", default="BENCH_distributed.json")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    if args.evals_only:
        _evals_only(sizes)
    elif args.inner:
        _inner(sizes, args.json)
    else:
        run(sizes, json_out=args.json)


if __name__ == "__main__":
    main()
