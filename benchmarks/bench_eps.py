"""Paper Fig. 4(d)(e)(f): impact of eps, minpts and size fixed.

Paper finding reproduced: tree methods are insensitive to eps; the
G-DBSCAN-style adjacency baseline degrades as eps (and the edge count)
grows.
"""
from __future__ import annotations

from repro.data import pointclouds
from .common import algorithms, emit, time_fn

# paper: minpts = 500 / 50 / 100 for NGSIM / PortoTaxi / 3DRoad
SETUPS = [
    ("ngsim_like", 100, [0.0025, 0.005, 0.01, 0.02]),
    ("portotaxi_like", 50, [0.005, 0.01, 0.02, 0.04]),
    ("road3d_like", 100, [0.02, 0.04, 0.08, 0.16]),
]


def run(n: int = 4096, quick: bool = False):
    setups = SETUPS[:1] if quick else SETUPS
    for dset, minpts, eps_list in setups:
        pts = pointclouds.load(dset, n)
        # the auto dispatcher amortizes its (eps-independent) plain-tree
        # index across the whole eps sweep — the plan-cache workload
        for eps in (eps_list[:2] if quick else eps_list):
            for name, fn in algorithms(include_gdbscan=(n <= 8192),
                                       include_auto=True).items():
                dt, res = time_fn(fn, pts, eps, minpts,
                                  warmup=1, repeat=1 if quick else 3)
                extra = f";backend={res.backend}" if name == "auto" else ""
                emit(f"eps/{dset}/e{eps}/{name}", dt * 1e6,
                     f"clusters={res.n_clusters}{extra}")


if __name__ == "__main__":
    run()
