"""Pallas tile-kernel microbench: per-call time + arithmetic intensity.

Wall time here is the *interpret-mode* (CPU) figure — meaningful only for
relative tracking. The derived column reports the kernel's FLOPs and the
VMEM tile-resident bytes/ratio used by the TPU roofline discussion in
DESIGN.md §3 (memory model).
"""
from __future__ import annotations

import numpy as np

from repro.kernels import pairwise_count, pairwise_minlabel
from repro.kernels.ref import pairwise_count_ref
from repro.data import pointclouds
from .common import emit, time_fn


def run(quick: bool = False):
    for n in ([1024] if quick else [1024, 4096]):
        pts = pointclouds.load("portotaxi_like", n)
        eps = 0.01
        # MXU form: n^2 x (2d for dot + 5 elementwise) flops
        flops = n * n * (2 * 2 + 5)
        tile_bytes = 128 * 2 * 4 + 128 * 2 * 4 + 128 * 128 * 4
        dt, _ = time_fn(pairwise_count, pts, pts, eps,
                        warmup=1, repeat=1 if quick else 3)
        emit(f"kernel/count/n{n}", dt * 1e6,
             f"flops={flops};tile_vmem_bytes={tile_bytes}")
        labels = np.arange(n, dtype=np.int32)
        mask = np.ones(n, bool)
        dt, _ = time_fn(pairwise_minlabel, pts, pts, labels, mask, eps,
                        warmup=1, repeat=1 if quick else 3)
        emit(f"kernel/minlabel/n{n}", dt * 1e6,
             f"flops={flops};tile_vmem_bytes={tile_bytes}")
        dt, _ = time_fn(pairwise_count_ref, pts, pts, eps,
                        warmup=1, repeat=1 if quick else 3)
        emit(f"kernel/count-jnp-ref/n{n}", dt * 1e6, "reference")


if __name__ == "__main__":
    run()
