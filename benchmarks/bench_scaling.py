"""Paper Fig. 4(g)(h)(i): scaling with dataset size (log-log).

Reproduces the paper's memory finding: the adjacency-materializing
G-DBSCAN baseline falls over (quadratic memory) where the on-the-fly
tree algorithms keep scaling.
"""
from __future__ import annotations

from repro.data import pointclouds
from .common import algorithms, emit, time_fn

SETUPS = [
    ("ngsim_like", 500, 0.0025),
    ("portotaxi_like", 100, 0.05),   # paper uses 1000; surrogate density
    ("road3d_like", 100, 0.01),
]


def run(sizes=(1024, 2048, 4096, 8192), quick: bool = False):
    setups = SETUPS[:1] if quick else SETUPS
    sizes = sizes[:2] if quick else sizes
    for dset, minpts, eps in setups:
        for n in sizes:
            pts = pointclouds.load(dset, n)
            algos = algorithms(include_gdbscan=(n <= 4096))
            for name, fn in algos.items():
                dt, res = time_fn(fn, pts, eps, minpts,
                                  warmup=1, repeat=1 if quick else 3)
                emit(f"scaling/{dset}/n{n}/{name}", dt * 1e6,
                     f"clusters={res.n_clusters}")


if __name__ == "__main__":
    run()
