"""Streaming vs full-recluster: insert throughput + query latency.

The ISSUE-3 acceptance claim: ingesting a 1% micro-batch into a live
``StreamingDBSCAN`` handle (bidirectional count update + incremental label
repair, eps-local work) must beat re-running batch ``dbscan`` on the union
by >= 5x wall clock at n=32768. The full-recluster baseline goes through
the unified dispatcher with the plan cache cleared per repetition — a new
point set genuinely pays the index rebuild — while its jitted programs
stay warm (shape-for-shape the same), so the comparison is compile-free on
both sides. Emits ``BENCH_stream.json``.

    PYTHONPATH=src python -m benchmarks.bench_stream [--n 32768]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

EPS, MINPTS = 0.02, 10          # taxi regime, same as bench_distributed
REQUIRED_SPEEDUP = 5.0


def _median_time(fn, repeat=3):
    times = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def run(n: int = 32768, quick: bool = False,
        json_out: str = "BENCH_stream.json"):
    from repro.core import dispatch
    from repro.core.validate import check_component_identical
    from repro.data import pointclouds
    from .common import emit

    b = max(1, n // 100)                      # the 1% micro-batch
    pts = pointclouds.taxi_2d(n + b)
    initial, batch = pts[:n], pts[n:]
    union = pts

    # ---- warm every shape once (compiles excluded from timings) --------
    dispatch.clear_cache()
    h = dispatch.stream_handle(initial, EPS, MINPTS)
    h.insert(batch)
    h.query(batch)
    snap_stream = h.snapshot()

    # ---- streaming insert: fresh handle per rep (cached index -> cheap
    # bootstrap), timing only the insert itself --------------------------
    def one_insert():
        hh = dispatch.stream_handle(initial, EPS, MINPTS)
        t0 = time.perf_counter()
        hh.insert(batch)
        return time.perf_counter() - t0
    insert_s = float(np.median([one_insert() for _ in range(3)]))

    # ---- query latency over the live two-level handle ------------------
    query_s, _ = _median_time(lambda: h.query(batch), repeat=5)

    # ---- full-recluster baseline on the union --------------------------
    dispatch.clear_cache()
    ref = dispatch.dbscan(union, EPS, MINPTS)         # warm the programs

    def one_full():
        dispatch.clear_cache()                        # honest index rebuild
        return dispatch.dbscan(union, EPS, MINPTS)
    full_s, ref = _median_time(one_full, repeat=3)

    # ---- equivalence spot check ----------------------------------------
    check_component_identical(snap_stream.labels, snap_stream.core_mask,
                              ref.labels, ref.core_mask)

    speedup = full_s / insert_s
    rec = {
        "n": n, "batch": b, "eps": EPS, "minpts": MINPTS,
        "backend_full": ref.backend,
        "insert_wall_s": insert_s,
        "insert_pts_per_s": b / insert_s,
        "query_wall_s": query_s,
        "query_pts_per_s": b / query_s,
        "full_recluster_wall_s": full_s,
        "speedup_vs_full": speedup,
        "required_speedup": REQUIRED_SPEEDUP,
        "meets_requirement": bool(speedup >= REQUIRED_SPEEDUP),
        "n_clusters": ref.n_clusters,
        "repair_sweeps": h.n_repair_sweeps,
        "quick": quick,
    }
    with open(json_out, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    emit(f"stream_insert_n{n}", insert_s * 1e6,
         f"{b / insert_s:.0f} pts/s")
    emit(f"stream_query_n{n}", query_s * 1e6,
         f"{b / query_s:.0f} probes/s")
    emit(f"stream_full_recluster_n{n}", full_s * 1e6,
         f"speedup {speedup:.1f}x (need >= {REQUIRED_SPEEDUP:.0f}x)")
    assert rec["meets_requirement"], (
        f"streaming insert only {speedup:.1f}x faster than full recluster "
        f"(required {REQUIRED_SPEEDUP}x)")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32768)
    ap.add_argument("--json-out", default="BENCH_stream.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rec = run(n=args.n, quick=args.n < 32768, json_out=args.json_out)
    print(f"# speedup {rec['speedup_vs_full']:.1f}x "
          f"({'PASS' if rec['meets_requirement'] else 'FAIL'})")
