"""Streaming benchmarks: insert vs full-recluster, and the fully dynamic
mixed workload (inserts + deletes + sliding window).

Two records, both emitted into ``BENCH_stream.json``:

* ``insert_vs_full`` — the ISSUE-3 acceptance claim: ingesting a 1%
  micro-batch into a live ``StreamingDBSCAN`` handle (bidirectional count
  update + incremental label repair, eps-local work) must beat re-running
  batch ``dbscan`` on the union by >= 5x wall clock at n=32768. The
  full-recluster baseline goes through the unified dispatcher with the
  plan cache cleared per repetition — a new point set genuinely pays the
  index rebuild — while its jitted programs stay warm (shape-for-shape
  the same), so the comparison is compile-free on both sides.

* ``mixed`` — a deterministic sliding-window serving trace (DESIGN.md
  §11): bootstrap half the stream under ``window=W``, then drain the rest
  in fixed micro-batches with a seeded 5%-of-survivors delete every third
  step; every insert auto-expires the window overflow, and tiered
  compaction churns underneath.  Wall-clock numbers are reported but the
  *deterministic* counters (repair sweeps, compactions, merges, final
  active/tombstoned sizes) are what ``benchmarks.run --check`` gates —
  they measure how much repair work the dynamic index does, and cannot
  drift with machine load.  The final snapshot is verified
  component-identical to batch dbscan on exactly the surviving points.

    PYTHONPATH=src python -m benchmarks.bench_stream [--n 32768]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import time_fn, time_once

EPS, MINPTS = 0.02, 10          # taxi regime, same as bench_distributed
REQUIRED_SPEEDUP = 5.0

# the deterministic mixed workload (the --check gate re-runs exactly this)
MIXED = {
    "n": 4096, "window": 1536, "batch": 256, "seed": 0,
    "buffer_max": 192,       # < batch: every insert seals a tier, so the
                             # cascade counters actually exercise the LSM
    "delete_every": 3, "delete_frac": 0.05,
}


def insert_vs_full(n: int = 32768, quick: bool = False) -> dict:
    from repro.core import dispatch
    from repro.core.validate import check_component_identical
    from repro.data import pointclouds

    b = max(1, n // 100)                      # the 1% micro-batch
    pts = pointclouds.taxi_2d(n + b)
    initial, batch = pts[:n], pts[n:]
    union = pts

    # ---- warm every shape once (compiles excluded from timings) --------
    dispatch.clear_cache()
    h = dispatch.stream_handle(initial, EPS, MINPTS)
    h.insert(batch)
    h.query(batch)
    snap_stream = h.snapshot()

    # ---- streaming insert: fresh handle per rep (cached index -> cheap
    # bootstrap), timing only the insert itself --------------------------
    def one_insert():
        hh = dispatch.stream_handle(initial, EPS, MINPTS)
        dt, _ = time_once(hh.insert, batch, label="stream/insert")
        return dt
    insert_s = float(np.median([one_insert() for _ in range(3)]))

    # ---- query latency over the live tiered handle ---------------------
    query_s, _ = time_fn(h.query, batch, warmup=0, repeat=5,
                         label="stream/query")

    # ---- full-recluster baseline on the union --------------------------
    dispatch.clear_cache()
    ref = dispatch.dbscan(union, EPS, MINPTS)         # warm the programs

    def one_full():
        dispatch.clear_cache()                        # honest index rebuild
        return dispatch.dbscan(union, EPS, MINPTS)
    full_s, ref = time_fn(one_full, warmup=0, repeat=3,
                          label="stream/full_recluster")

    # ---- equivalence spot check ----------------------------------------
    check_component_identical(snap_stream.labels, snap_stream.core_mask,
                              ref.labels, ref.core_mask)

    speedup = full_s / insert_s
    rec = {
        "n": n, "batch": b, "eps": EPS, "minpts": MINPTS,
        "backend_full": ref.backend,
        "insert_wall_s": insert_s,
        "insert_pts_per_s": b / insert_s,
        "query_wall_s": query_s,
        "query_pts_per_s": b / query_s,
        "full_recluster_wall_s": full_s,
        "speedup_vs_full": speedup,
        "required_speedup": REQUIRED_SPEEDUP,
        "meets_requirement": bool(speedup >= REQUIRED_SPEEDUP),
        "n_clusters": ref.n_clusters,
        "repair_sweeps": h.n_repair_sweeps,
        "quick": quick,
    }
    return rec


def _mixed_trace(pts, cfg):
    """One full run of the deterministic insert/delete/window trace;
    returns (handle, bootstrap_s, insert_times, delete_times)."""
    from repro.stream import StreamingDBSCAN

    n, W, B = cfg["n"], cfg["window"], cfg["batch"]
    rng = np.random.default_rng(cfg["seed"])
    n0 = n // 2
    boot_s, h = time_once(StreamingDBSCAN, pts[:n0], EPS, MINPTS, window=W,
                          buffer_max=cfg["buffer_max"],
                          label="stream/mixed_bootstrap")
    insert_times, delete_times = [], []
    step = 0
    for lo in range(n0, n, B):
        dt, _ = time_once(h.insert, pts[lo:lo + B],
                          label="stream/mixed_insert")
        insert_times.append(dt)
        step += 1
        if step % cfg["delete_every"] == 0:
            alive = h.active_gids
            k = max(1, int(len(alive) * cfg["delete_frac"]))
            gids = np.sort(rng.choice(alive, size=k, replace=False))
            dt, _ = time_once(h.delete, gids, label="stream/mixed_delete")
            delete_times.append(dt)
    return h, boot_s, insert_times, delete_times


def mixed_workload(cfg=MIXED, validate: bool = True) -> dict:
    """The deterministic insert/delete/window trace; returns wall times
    plus the exact dynamic-work counters the regression gate pins.

    The trace runs **twice** with the same seed: the stream grows through
    a fresh padded level shape every few batches, so a single cold pass
    charges one jit compile to an unlucky subset of inserts (p50 in the
    hundreds of ms — a compile artifact, not serving cost).  Pass 1 warms
    every (shape, program) pair and is reported separately as
    ``warmup_wall_s``; pass 2 replays the identical trace compile-free
    and is what the latency fields measure.  The deterministic counters
    are identical in both passes.
    """
    from repro.core import dispatch
    from repro.core.validate import check_component_identical
    from repro.data import pointclouds

    n, W, B = cfg["n"], cfg["window"], cfg["batch"]
    pts = pointclouds.taxi_2d(n)

    t0 = time.perf_counter()
    _mixed_trace(pts, cfg)                       # pass 1: compile warmup
    warmup_s = time.perf_counter() - t0

    h, boot_s, insert_times, delete_times = _mixed_trace(pts, cfg)

    snap_s, snap = time_once(h.snapshot, label="stream/mixed_snapshot")

    if validate:
        surv = pts[h.active_gids]
        ref = dispatch.dbscan(surv, EPS, MINPTS, algorithm="fdbscan")
        check_component_identical(snap.labels, snap.core_mask,
                                  ref.labels, ref.core_mask)

    return {
        "n": n, "window": W, "batch": B, "eps": EPS, "minpts": MINPTS,
        "seed": cfg["seed"], "buffer_max": cfg["buffer_max"],
        "delete_every": cfg["delete_every"],
        "delete_frac": cfg["delete_frac"],
        "warmup_wall_s": warmup_s,          # pass 1: compiles + first run
        "bootstrap_wall_s": boot_s,         # everything below: steady state
        "insert_p50_ms": float(np.median(insert_times)) * 1e3,
        "insert_p99_ms": float(np.quantile(insert_times, 0.99)) * 1e3,
        "delete_p50_ms": (float(np.median(delete_times)) * 1e3
                          if delete_times else float("nan")),
        "snapshot_wall_s": snap_s,
        "n_clusters": snap.n_clusters,
        # deterministic counters — the regression gate pins these
        "n_active": h.n_active,
        "n_tombstoned": h.n_tombstoned,
        "n_deletes": h.n_deletes,
        "n_merges": h.n_merges,
        "n_compactions": h.n_compactions,
        "repair_sweeps": h.n_repair_sweeps,
    }


def run(n: int = 32768, quick: bool = False,
        json_out: str = "BENCH_stream.json"):
    from .common import emit

    rec = insert_vs_full(n=n, quick=quick)
    mixed = mixed_workload()
    out = {"insert_vs_full": rec, "mixed": mixed}
    with open(json_out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)

    b = rec["batch"]
    emit(f"stream_insert_n{n}", rec["insert_wall_s"] * 1e6,
         f"{b / rec['insert_wall_s']:.0f} pts/s")
    emit(f"stream_query_n{n}", rec["query_wall_s"] * 1e6,
         f"{b / rec['query_wall_s']:.0f} probes/s")
    emit(f"stream_full_recluster_n{n}", rec["full_recluster_wall_s"] * 1e6,
         f"speedup {rec['speedup_vs_full']:.1f}x "
         f"(need >= {REQUIRED_SPEEDUP:.0f}x)")
    emit(f"stream_mixed_n{mixed['n']}w{mixed['window']}",
         mixed["insert_p50_ms"] * 1e3,
         f"{mixed['repair_sweeps']} sweeps, {mixed['n_compactions']} "
         f"compactions, {mixed['n_active']} active")
    assert rec["meets_requirement"], (
        f"streaming insert only {rec['speedup_vs_full']:.1f}x faster than "
        f"full recluster (required {REQUIRED_SPEEDUP}x)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32768)
    ap.add_argument("--json-out", default="BENCH_stream.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    out = run(n=args.n, quick=args.n < 32768, json_out=args.json_out)
    rec = out["insert_vs_full"]
    print(f"# speedup {rec['speedup_vs_full']:.1f}x "
          f"({'PASS' if rec['meets_requirement'] else 'FAIL'})")
