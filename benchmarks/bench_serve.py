"""Serving-plane benchmarks (DESIGN.md §13): snapshot query speedup,
sustained multi-tenant throughput, and the jit-stability witness.

Three records, all emitted into ``BENCH_serve.json``:

* ``snapshot_vs_handle`` — the headline claim: a frozen
  :class:`repro.serve.IndexSnapshot` (cell-summary pass + exact pass on
  the flagged residue, pure host numpy) must answer probe batches
  >= ``REQUIRED_SPEEDUP``x faster than the live
  ``StreamingDBSCAN.query`` traversal path, bit-identically.  Both sides
  are measured **interleaved** on the same probes (one call of each per
  round), so the committed speedup is a drift-free ratio-of-ratios —
  ``--check`` re-measures the ``_check`` scenario and gates the ratio,
  never either absolute time.

* ``open_loop`` — sustained aggregate throughput through the whole
  server: T tenants over one shared index, a closed submission window of
  in-flight query futures, and a couple of insert batches (applied +
  republished mid-run) to prove writes don't stall the query plane.  The
  aggregate probes/s across tenants is the ``>= REQUIRED_AGG`` serving
  claim.  Jit warmup (the insert path's compiles) happens before the
  timed window and is reported separately as ``warmup_wall_s``.

* ``recompiles`` — the steady-state jit witness: after one warm query
  per bucket, further queries at *any* size inside the bucket must
  launch zero new traversal programs
  (``stream_query_recompiles_total`` delta == 0; gated exactly).

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from collections import deque

import numpy as np

from .common import emit

EPS, MINPTS = 0.02, 10          # taxi regime, same as bench_stream
REQUIRED_SPEEDUP = 50.0         # snapshot.query over StreamingDBSCAN.query
REQUIRED_AGG = 180_000.0        # sustained aggregate probes/s (open loop)
CHECK_N = 8192                  # the --check re-measured scenario size

# the open-loop tenant set: one shared index, four (eps, min_pts) views
TENANTS = [("t0", 0.02, 10), ("t1", 0.03, 8),
           ("t2", 0.04, 8), ("t3", 0.05, 5)]


def _probes(pts, k, seed, eps=EPS):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(pts), k)
    jit = rng.normal(0.0, 0.2 * eps, (k, pts.shape[1])).astype(np.float32)
    return np.ascontiguousarray(pts[idx] + jit, np.float32)


def snapshot_vs_handle(n: int, batch: int = 1024, rounds: int = 5) -> dict:
    """Interleaved snapshot-vs-handle query timing on identical probes."""
    from repro.core import dispatch
    from repro.data import pointclouds
    from repro.serve import freeze

    pts = pointclouds.taxi_2d(n)
    h = dispatch.stream_handle(pts, EPS, MINPTS)
    snap = freeze(h, version=1)
    probes = _probes(pts, batch, seed=7)

    ref = h.query(probes)                       # also the jit warmup
    got = snap.query(probes)
    for f in ("labels", "counts", "would_be_core"):
        assert np.array_equal(getattr(ref, f), getattr(got, f)), \
            f"snapshot.query diverged from handle.query on {f}"

    ht, st = [], []
    for _ in range(rounds):                     # interleaved: drift-free
        t0 = time.perf_counter()
        h.query(probes)
        ht.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        snap.query(probes)
        st.append(time.perf_counter() - t0)
    handle_s = float(np.median(ht))
    snap_s = float(np.median(st))
    speedup = handle_s / snap_s
    return {
        "n": n, "batch": batch, "eps": EPS, "minpts": MINPTS,
        "handle_query_wall_s": handle_s,
        "handle_probes_per_s": batch / handle_s,
        "snapshot_query_wall_s": snap_s,
        "snapshot_probes_per_s": batch / snap_s,
        "speedup": speedup,
        "required_speedup": REQUIRED_SPEEDUP,
        "meets_requirement": bool(speedup >= REQUIRED_SPEEDUP),
        "snapshot_stats": snap.stats(),
    }


def recompile_steadystate() -> dict:
    """Warm one query per jit bucket, then hammer the bucket with other
    sizes: the recompile counter must not move (satellite witness)."""
    from repro.core import dispatch
    from repro.data import pointclouds
    from repro.obs import metrics as obs_metrics
    from repro.serve import bucket_size

    prev = obs_metrics.active()
    reg = obs_metrics.install(obs_metrics.Registry())
    try:
        pts = pointclouds.taxi_2d(2048)
        h = dispatch.stream_handle(pts, EPS, MINPTS)
        probes = _probes(pts, 256, seed=11)
        h.query(probes[:bucket_size(129)])      # warm the whole bucket

        def counter():
            c = reg.get("stream_query_recompiles_total")
            return float(c.value) if c is not None else 0.0

        c0 = counter()
        sizes = [k for k in (130, 150, 180, 200, 256)
                 if bucket_size(k) == bucket_size(129)]
        for k in sizes:
            h.query(probes[:k])
        delta = counter() - c0
    finally:
        obs_metrics.install(prev) if prev is not None \
            else obs_metrics.uninstall()
    return {"bucket": bucket_size(129), "sizes_tried": sizes,
            "new_programs_steady": int(delta)}


def open_loop(n: int, n_tenants: int = 4, duration_s: float = 10.0,
              request: int = 1024, inflight: int = 24) -> dict:
    """Sustained aggregate serving throughput across tenants.

    A fixed window of ``inflight`` outstanding query futures (round-robin
    over tenants) keeps the query plane saturated for ``duration_s``;
    two insert batches land inside the window to prove the write plane
    republishes without stalling queries.
    """
    from repro.data import pointclouds
    from repro.serve import Overloaded, Server, ServerConfig, TenantSpec

    specs = [TenantSpec(*t) for t in TENANTS[:n_tenants]]
    pts = pointclouds.taxi_2d(n + 256)
    initial, pool = pts[:n], pts[n:]
    cfg = ServerConfig(max_batch=4096, max_delay_s=0.005,
                       max_pending_requests=4 * inflight,
                       max_pending_points=8 * inflight * request,
                       max_pending_inserts=8)
    t0 = time.perf_counter()
    srv = Server(initial, specs, config=cfg)
    boot_s = time.perf_counter() - t0

    reqs = [_probes(initial, request, seed=100 + i) for i in range(32)]

    # warmup outside the timed window: the insert path's jit programs
    # (per tenant) and one query round per tenant
    t0 = time.perf_counter()
    srv.insert(pool[:64], timeout=600)
    for s in specs:
        srv.query(reqs[0], tenant=s.name, timeout=600)
    warm_s = time.perf_counter() - t0

    done_probes = 0
    n_shed = 0
    inserts_done = 0
    window: deque = deque()
    i = 0
    t0 = time.perf_counter()
    t_end = t0 + duration_s
    insert_at = [t0 + 0.3 * duration_s, t0 + 0.7 * duration_s]
    insert_futs = []
    now = t0
    while now < t_end:
        while len(window) < inflight:
            name = specs[i % len(specs)].name
            try:
                window.append(srv.submit_query(reqs[i % len(reqs)],
                                               tenant=name))
            except Overloaded:
                n_shed += 1
                break
            i += 1
        if insert_at and now >= insert_at[0]:
            insert_at.pop(0)
            try:
                insert_futs.append(srv.submit_insert(
                    pool[64 + 64 * inserts_done:128 + 64 * inserts_done]))
                inserts_done += 1
            except Overloaded:
                n_shed += 1
        window.popleft().result(timeout=600)
        done_probes += request
        now = time.perf_counter()
    for f in window:                    # drain the tail, still counted
        f.result(timeout=600)
        done_probes += request
    wall = time.perf_counter() - t0
    for f in insert_futs:
        f.result(timeout=600)
    st = srv.stats()
    srv.shutdown()
    agg = done_probes / wall
    return {
        "n": n, "tenants": [list(s) for s in specs],
        "eps": EPS, "minpts": MINPTS,
        "request_probes": request, "inflight": inflight,
        "duration_s": wall, "bootstrap_wall_s": boot_s,
        "warmup_wall_s": warm_s,        # jit compiles, outside the window
        "probes_served": done_probes,
        "aggregate_probes_per_s": agg,
        "required_aggregate_probes_per_s": REQUIRED_AGG,
        "meets_requirement": bool(agg >= REQUIRED_AGG),
        "insert_batches_mid_run": inserts_done,
        "query_p50_ms": st["query_p50_s"] * 1e3,
        "query_p99_ms": st["query_p99_s"] * 1e3,
        "insert_p50_ms": st["insert_p50_s"] * 1e3,
        "n_overloaded": n_shed,
        "final_versions": {t["name"]: t["version"] for t in st["tenants"]},
    }


def run(quick: bool = False, json_out: str = "BENCH_serve.json"):
    svh_check = snapshot_vs_handle(n=CHECK_N)
    if quick:
        svh = svh_check
        loop = open_loop(n=8192, n_tenants=2, duration_s=2.0)
    else:
        svh = snapshot_vs_handle(n=32768)
        loop = open_loop(n=32768, n_tenants=4, duration_s=10.0)
    rec = recompile_steadystate()
    out = {"snapshot_vs_handle": svh, "snapshot_vs_handle_check": svh_check,
           "open_loop": loop, "recompiles": rec, "quick": quick}
    with open(json_out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)

    emit(f"serve_snapshot_query_n{svh['n']}",
         svh["snapshot_query_wall_s"] * 1e6,
         f"{svh['snapshot_probes_per_s']:.0f} probes/s "
         f"(speedup {svh['speedup']:.1f}x, need >= "
         f"{REQUIRED_SPEEDUP:.0f}x)")
    emit(f"serve_open_loop_n{loop['n']}t{len(loop['tenants'])}",
         loop["duration_s"] * 1e6,
         f"{loop['aggregate_probes_per_s']:.0f} probes/s aggregate "
         f"(need >= {REQUIRED_AGG:.0f}), "
         f"{loop['insert_batches_mid_run']} inserts mid-run")
    emit("serve_recompiles_steady", 0.0,
         f"{rec['new_programs_steady']} new programs after warm "
         f"(bucket {rec['bucket']})")
    assert rec["new_programs_steady"] == 0, (
        f"{rec['new_programs_steady']} traversal programs compiled at "
        "steady state — probe padding broke")
    if not quick:
        # the >= 50x and >= 180k/s claims are at acceptance scale
        # (n=32768); at quick sizes the live handle is fast enough that
        # the ratio is smaller by construction, so quick runs only gate
        # the recompile witness and --check gates the committed ratios
        assert svh["meets_requirement"], (
            f"snapshot only {svh['speedup']:.1f}x over handle.query "
            f"(required {REQUIRED_SPEEDUP}x)")
        assert loop["meets_requirement"], (
            f"aggregate {loop['aggregate_probes_per_s']:.0f} probes/s "
            f"< required {REQUIRED_AGG:.0f}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json-out", default="BENCH_serve.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    out = run(quick=args.quick, json_out=args.json_out)
    svh, loop = out["snapshot_vs_handle"], out["open_loop"]
    verdict = ("PASS (quick: claims gated at full scale)" if args.quick
               else ("PASS" if svh["meets_requirement"]
                     and loop["meets_requirement"] else "FAIL"))
    print(f"# speedup {svh['speedup']:.1f}x, aggregate "
          f"{loop['aggregate_probes_per_s']:.0f} probes/s ({verdict})")
