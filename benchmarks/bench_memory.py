"""Paper §5.1 memory claim: O(n) on-the-fly vs O(E)/O(n^2) adjacency.

[32] measured G-DBSCAN at 166x CUDA-DClust's footprint; the paper's
framework never materializes neighbor lists. We account the live device
bytes of each backend's data structures analytically from their actual
array shapes (exact for both sides — no allocator noise).
"""
from __future__ import annotations

import numpy as np

from repro.core import grid, lbvh, morton
from repro.data import pointclouds
from .common import emit


def fdbscan_bytes(n: int, d: int, m: int | None = None) -> int:
    m = n if m is None else m
    pts = n * d * 4
    segs = 2 * m * 4 + n * 4 + m * 2 * d * 4 + m * 4 + n * 1 + m * 1
    tree = (m - 1) * 2 * 4 + (2 * m - 1) * (2 * 4 + 4) + (2 * m - 1) * 2 * d * 4
    labels = 2 * n * 4
    return pts + segs + tree + labels


def gdbscan_bytes(n: int, avg_degree: float) -> int:
    # edge list (CSR): offsets + neighbor indices, plus the points/labels
    return n * 4 + int(n * avg_degree) * 4 + n * 2 * 4 + n * 2 * 4


def run(quick: bool = False):
    for n in ([2048] if quick else [2048, 16384, 131072, 1048576]):
        pts = pointclouds.load("portotaxi_like", min(n, 16384))
        eps = 0.01
        # measure the average degree on a sample; extrapolate density
        sample = np.asarray(pts[:2048], np.float64)
        d2 = ((sample[:, None] - sample[None]) ** 2).sum(-1)
        deg = float((d2 <= eps * eps).sum(1).mean()) * (n / len(sample))
        fb = fdbscan_bytes(n, 2)
        gb = gdbscan_bytes(n, deg)
        emit(f"memory/n{n}/fdbscan", 0.0, f"bytes={fb};MB={fb/2**20:.1f}")
        emit(f"memory/n{n}/gdbscan-adjacency", 0.0,
             f"bytes={gb};MB={gb/2**20:.1f};ratio={gb/fb:.1f}x")


if __name__ == "__main__":
    run()
