"""Inject the roofline table + hillclimb numbers into EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.finalize_experiments
"""
from __future__ import annotations

import json
import glob
import os
import re

from .roofline_report import load, summary, table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fmt_cell(path, before=None):
    r = json.load(open(path))
    ro = r["roofline"]
    return (f"`t_comp {ro['t_compute_s']:.3f} s`, `t_mem "
            f"{ro['t_memory_s']:.3f} s`, `t_coll {ro['t_collective_s']:.4f} s`"
            f" (wire {ro['wire_bytes']/2**30:.2f} GiB), dominant "
            f"{ro['dominant']}, useful {ro.get('useful_flops_ratio') or 0:.3f}")


def main():
    md_path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(md_path).read()

    recs = load(os.path.join(ROOT, "results/dryrun"))
    tab = table(recs) + "\n\n```\n" + summary(recs) + "\n```"
    md = md.replace("<!-- ROOFLINE_TABLE -->", tab)

    # iteration 1 after numbers (grouped MoE dispatch)
    it1 = []
    for cell in ("mixtral-8x7b__train_4k", "mixtral-8x7b__prefill_32k",
                 "jamba-v0.1-52b__train_4k"):
        p = os.path.join(ROOT, "results/dryrun", f"{cell}.json")
        if os.path.exists(p):
            r = json.load(open(p))
            if r.get("roofline"):
                ro = r["roofline"]
                it1.append(f"{r['arch']}/{r['shape']}: t_comp "
                           f"{ro['t_compute_s']:.2f} s, useful "
                           f"{ro.get('useful_flops_ratio') or 0:.3f}")
    if it1:
        md = md.replace("<!-- IT1_AFTER -->", "; ".join(it1) + ".")
        md = md.replace(
            "<!-- IT1_VERDICT -->",
            "**confirmed** — mixtral train t_comp 957.3 -> 8.2 s (116x), "
            "useful 0.0017 -> 0.195; prefill 468.0 -> 2.8 s (167x); "
            "jamba train t_comp 55.1 -> 2.5 s (22x), useful 0.027 -> "
            "0.598. Residual mixtral gap vs dense archs: ~12% dispatch "
            "+ capacity-padded slots computing for dropped tokens.")

    it2 = []
    for a, before in (("chatglm3-6b", (0.2958, 13.79)),
                      ("gemma2-2b", (0.8640, 40.24)),
                      ("llava-next-mistral-7b", (1.3693, 63.77)),
                      ("qwen1.5-4b", (2.0262, 94.3))):
        p = os.path.join(ROOT, "results/hillclimb",
                         f"{a}__decode_32k__seq.json")
        if os.path.exists(p):
            ro = json.load(open(p))["roofline"]
            ro0 = {"chatglm3-6b": 0.296, "gemma2-2b": 0.864,
                   "llava-next-mistral-7b": 1.369,
                   "qwen1.5-4b": 2.026}[a]
            it2.append(f"{a}: wire {before[1]:.1f} -> "
                       f"{ro['wire_bytes']/2**30:.3f} GiB, t_coll "
                       f"{before[0]:.3f} -> {ro['t_collective_s']:.4f} s, "
                       f"bound {ro0:.3f}"
                       f" -> {ro['bound_time_s']:.3f} s")
    if it2:
        md = md.replace("<!-- IT2_AFTER -->", "; ".join(it2) + ".")
        md = md.replace(
            "<!-- IT2_VERDICT -->",
            "**confirmed, stronger than predicted** (530-2500x wire "
            "reduction; every cell flips to memory-dominant). `seq` is now "
            "the deployable default (`kv_policy=auto`).")

    open(md_path, "w").write(md)
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
