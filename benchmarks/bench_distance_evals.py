"""Distance-evaluation counts: the paper's central work metric.

FDBSCAN's traversal mask/early-exit and DenseBox's dense cells exist to
"reduce the number of distance calculations used by the algorithm in the
dense regions" (paper abstract). We count them exactly (the traversal's
member-step counter) and compare against brute force's n^2.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import grid, lbvh, traversal
from repro.data import pointclouds
from .common import emit


def run(n: int = 4096, quick: bool = False):
    cases = [("ngsim_like", 0.02, 10), ("hacc_like", 0.03, 5)]
    for dset, eps, minpts in (cases[:1] if quick else cases):
        pts = jnp.asarray(pointclouds.load(dset, n))
        for algo, build in (("fdbscan", grid.build_segments_fdbscan),
                            ("fdbscan-densebox",
                             lambda p: grid.build_segments_densebox(p, eps,
                                                                    minpts))):
            segs = build(pts)
            tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
            dense_skip = segs.dense_pt  # dense members skip preprocessing
            _, work = traversal.count_neighbors_with_work(
                tree, segs, eps, cap=minpts, query_active=~dense_skip)
            evals = int(np.asarray(work).sum())
            emit(f"dist_evals/{dset}/preprocess/{algo}", 0.0,
                 f"evals={evals};brute={n*n};saving={n*n/max(evals,1):.1f}x")


if __name__ == "__main__":
    run()
