"""Paper Fig. 4(a)(b)(c): impact of minpts, eps and size fixed.

Datasets are the surrogate analogues of NGSIM / PortoTaxi / 3D Road
(DESIGN.md §8.5); per-dataset eps matches the paper's choices.
"""
from __future__ import annotations

import numpy as np

from repro.data import pointclouds
from .common import algorithms, emit, time_fn

# paper: eps = 0.005 / 0.01 / 0.08 (NGSIM, PortoTaxi, 3DRoad), n = 16384
SETUPS = [
    ("ngsim_like", 0.005, [50, 100, 500, 1000]),
    ("portotaxi_like", 0.01, [10, 50, 100, 500]),
    ("road3d_like", 0.08, [10, 50, 100, 500]),
]


def run(n: int = 4096, quick: bool = False):
    setups = SETUPS[:1] if quick else SETUPS
    for dset, eps, minpts_list in setups:
        pts = pointclouds.load(dset, n)
        for minpts in (minpts_list[:2] if quick else minpts_list):
            for name, fn in algorithms(include_gdbscan=(n <= 8192)).items():
                dt, res = time_fn(fn, pts, eps, minpts,
                                  warmup=1, repeat=1 if quick else 3)
                emit(f"minpts/{dset}/mp{minpts}/{name}", dt * 1e6,
                     f"clusters={res.n_clusters}")


if __name__ == "__main__":
    run()
