"""Halo finding on a 3-D cosmology-like volume (paper §5.2 analogue).

Reproduces the paper's qualitative findings on sparse 3-D data:
  * minpts=2 (friends-of-friends) skips preprocessing entirely,
  * at low minpts / large eps DenseBox wins (dense cells dominate),
  * at high minpts plain FDBSCAN wins (dense-cell bookkeeping is overhead).

    PYTHONPATH=src python examples/cluster_cosmology.py [-n 20000]
"""
import argparse
import time

import numpy as np

import repro
from repro.core.grid import build_segments_densebox
from repro.data import pointclouds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("-n", type=int, default=8000)
    args = ap.parse_args()

    pts = pointclouds.halos_3d(args.n, n_halos=60, seed=7)
    eps = 0.02

    print(f"halo volume: n={args.n}, eps={eps} (physics-motivated)")
    for min_pts in (2, 5, 20):
        segs = build_segments_densebox(np.asarray(pts), eps, min_pts)
        dense_frac = float(np.asarray(segs.dense_pt).mean())
        row = [f"minpts={min_pts:3d}  dense-cell pts {100*dense_frac:5.1f}%"]
        for algo in ("fdbscan", "fdbscan-densebox"):
            t0 = time.time()
            res = repro.dbscan(pts, eps, min_pts, algorithm=algo)
            dt = time.time() - t0
            row.append(f"{algo}: {res.n_clusters:4d} halos {dt:6.2f}s")
        print("  " + " | ".join(row))

    res = repro.dbscan(pts, eps, 2)
    labels = np.asarray(res.labels)
    sizes = np.bincount(labels[labels >= 0])
    print(f"FoF mass function (top 5 halos): {sorted(sizes)[-5:][::-1]}")


if __name__ == "__main__":
    main()
