"""End-to-end driver: train a ~100M-param LM with DBSCAN dedup inline.

The paper's technique sits in the data pipeline: every batch is embedded
(3-D bigram sketch), clustered with FDBSCAN-DenseBox, and near-duplicate
documents are thinned before the gradient step. The run compares loss
with/without dedup on a duplicate-heavy synthetic stream — dedup lifts the
effective data diversity per step.

Full scale (defaults): ~100M params (d_model=640, 10 layers, 50k vocab),
a few hundred steps. ``--quick`` runs a reduced config for CI.

    PYTHONPATH=src python examples/train_lm_dedup.py --steps 300
    PYTHONPATH=src python examples/train_lm_dedup.py --quick
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.data.dedup import dedup_batch
from repro.data.lm_data import SyntheticLM
from repro.models import model
from repro.train import step as step_lib
from repro.train.optimizer import adamw_init


def build_cfg(quick: bool):
    base = get("deepseek-7b")  # llama-style family
    if quick:
        return dataclasses.replace(base.reduced(), name="lm-quick")
    return dataclasses.replace(
        base, name="lm-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=10, head_dim=64, d_ff=2560, vocab_size=50304)


def run(cfg, steps, batch, seq, dedup, seed=0, log_every=20):
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    opt = adamw_init(params)
    step_fn = jax.jit(step_lib.make_train_step(cfg, lr=1e-3))
    data = SyntheticLM(cfg.vocab_size, seq, seed=seed, dup_frac=0.4)
    print(f"[{cfg.name}] {n_params/1e6:.1f}M params, dedup={dedup}")
    losses, kept = [], []
    t0 = time.time()
    for step in range(steps):
        raw = data.batch(step, batch)
        toks = raw["tokens"] % cfg.vocab_size
        if dedup:
            filtered, idx = dedup_batch({"tokens": toks}, pad_to=batch)
            kept.append(len(np.unique(idx)) / batch)
            toks = filtered["tokens"]
        params, opt, metrics = step_fn(params, opt,
                                       {"tokens": jnp.asarray(toks)})
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            k = f" kept={np.mean(kept[-log_every:]):.2f}" if kept else ""
            print(f"  step {step:4d} loss={losses[-1]:.4f}{k}", flush=True)
    dt = time.time() - t0
    print(f"  {steps} steps in {dt:.1f}s ({steps*batch*seq/dt:.0f} tok/s)")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-baseline", action="store_true")
    args = ap.parse_args()
    cfg = build_cfg(args.quick)
    if args.quick:
        args.steps, args.batch, args.seq = min(args.steps, 40), 8, 64

    dedup_losses = run(cfg, args.steps, args.batch, args.seq, dedup=True)
    if not args.no_baseline:
        base_losses = run(cfg, args.steps, args.batch, args.seq, dedup=False)
        n = max(1, args.steps // 5)
        print(f"final-fifth mean loss: dedup={np.mean(dedup_losses[-n:]):.4f}"
              f" baseline={np.mean(base_losses[-n:]):.4f}")


if __name__ == "__main__":
    main()
