"""Quickstart: cluster 2-D points with the paper's two algorithms.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dbscan, dbscan_bruteforce_np
from repro.core.validate import check_dbscan, same_partition
from repro.data import pointclouds


def main():
    pts = pointclouds.blobs(2000, k=6, seed=42)
    eps, min_pts = 0.04, 8

    for algo in ("fdbscan", "fdbscan-densebox"):
        res = dbscan(pts, eps, min_pts, algorithm=algo)
        noise = int((np.asarray(res.labels) == -1).sum())
        print(f"{algo:18s}: {res.n_clusters} clusters, {noise} noise pts, "
              f"{res.n_sweeps} union-find sweeps")
        # validate against the DBSCAN axioms (oracle-backed)
        check_dbscan(pts, eps, min_pts, res.labels, res.core_mask)

    # the MXU tile backend (Pallas kernels, interpret mode on CPU)
    from repro.kernels import dbscan_tiled
    res_t = dbscan_tiled(pts, eps, min_pts)
    print(f"{'tiled (Pallas)':18s}: {res_t.n_clusters} clusters")

    # brute-force oracle agreement on the core partition
    ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, min_pts)
    for res in (dbscan(pts, eps, min_pts),):
        assert (np.asarray(res.core_mask) == ref_core).all()
        assert same_partition(np.asarray(res.labels)[ref_core],
                              ref_labels[ref_core])
    print("all backends agree with the brute-force oracle ✓")

    # --- streaming: online inserts + probe queries over a live index ---
    from repro.core import dispatch
    stream = dispatch.stream_handle(pts[:1500], eps, min_pts)
    stream.insert(pts[1500:1750])           # two micro-batches arrive...
    stream.insert(pts[1750:])
    probes = stream.query(pts[:5])          # read-only cluster assignment
    print(f"{'streaming':18s}: {stream.n_points} pts "
          f"({stream.n_delta} in the delta tree), probe labels "
          f"{probes.labels.tolist()}")
    snap = stream.snapshot()                # ≡ batch dbscan on the union
    batch = dbscan(pts, eps, min_pts, algorithm="fdbscan")
    from repro.core.validate import check_component_identical
    check_component_identical(snap.labels, snap.core_mask,
                              batch.labels, batch.core_mask)
    print("streaming snapshot matches batch dbscan ✓")


if __name__ == "__main__":
    main()
