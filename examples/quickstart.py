"""Quickstart: the stable top-level ``repro`` surface.

    PYTHONPATH=src python examples/quickstart.py

Everything here uses only the package root's exports (``repro.dbscan``,
``repro.plan``, ``repro.stream_handle``, ``repro.neighbors``,
``repro.DBSCANResult``) — the API contract DESIGN.md §8.1 documents.
"""
import numpy as np

import repro
from repro.core.validate import (check_component_identical, check_dbscan,
                                 same_partition)
from repro.core import dbscan_bruteforce_np
from repro.data import pointclouds


def main():
    pts = pointclouds.blobs(2000, k=6, seed=42)
    eps, min_pts = 0.04, 8

    for algo in ("fdbscan", "fdbscan-densebox", "tiled", "pallas-tree"):
        res = repro.dbscan(pts, eps, min_pts, algorithm=algo)
        assert isinstance(res, repro.DBSCANResult)
        noise = int((np.asarray(res.labels) == -1).sum())
        print(f"{algo:18s}: {res.n_clusters} clusters, {noise} noise pts, "
              f"{res.n_sweeps} union-find sweeps")
        # validate against the DBSCAN axioms (oracle-backed)
        check_dbscan(pts, eps, min_pts, res.labels, res.core_mask)

    # parameter sweeps reuse one cached eps-independent index via plan()
    p = repro.plan(pts, eps, min_pts, algorithm="fdbscan")
    res = repro.dbscan(pts, eps, min_pts, query_plan=p)
    print(f"{'planned (cached)':18s}: backend={res.backend}")

    # brute-force oracle agreement on the core partition
    ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, min_pts)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])
    print("all backends agree with the brute-force oracle ✓")

    # --- streaming: online inserts + probe queries over a live index ---
    stream = repro.stream_handle(pts[:1500], eps, min_pts)
    stream.insert(pts[1500:1750])           # two micro-batches arrive...
    stream.insert(pts[1750:])
    probes = stream.query(pts[:5])          # read-only cluster assignment
    print(f"{'streaming':18s}: {stream.n_points} pts "
          f"({stream.n_delta} in the delta tree), probe labels "
          f"{probes.labels.tolist()}")
    snap = stream.snapshot()                # ≡ batch dbscan on the union
    batch = repro.dbscan(pts, eps, min_pts, algorithm="fdbscan")
    check_component_identical(snap.labels, snap.core_mask,
                              batch.labels, batch.core_mask)
    print("streaming snapshot matches batch dbscan ✓")

    # --- neighbor queries over the same shared index (DESIGN.md §8) ---
    counts = repro.neighbors.neighbor_count(pts, eps)
    nn = repro.neighbors.knn(pts, k=min_pts)
    kth = np.asarray(nn.distances)[:, -1]
    print(f"{'neighbors':18s}: mean |N_eps| = "
          f"{float(np.asarray(counts).mean()):.1f}, "
          f"median {min_pts}-NN radius = {float(np.median(kth)):.4f} "
          f"(eps = {eps})")


if __name__ == "__main__":
    main()
