"""The fused single-pass engine (DESIGN.md §4) vs the separate-pass path.

Covers: fused count+minlabel == separate count / minlabel traversals;
frontier-restricted sweeps are label-identical and do bounded work vs full
sweeps; the unrolled loop body is result-invariant; the per-run traversal
budget is `n_sweeps + 1`.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dbscan, dbscan_bruteforce_np, fdbscan, grid, lbvh, traversal
from repro.core.validate import check_dbscan, same_partition

from conftest import separated_points

INT_MAX = traversal.INT_MAX


def _index(pts, algo="fdbscan", eps=0.1, mp=5):
    pts = jnp.asarray(pts)
    if algo == "fdbscan-densebox":
        segs = grid.build_segments_densebox(pts, eps, mp)
    else:
        segs = grid.build_segments_fdbscan(pts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return segs, tree


def test_fused_matches_separate_passes_fdbscan():
    # Singleton segments: no dense short-circuit anywhere, so the fused
    # pass must agree elementwise with the two separate traversals.
    pts = separated_points(200, 2, eps=0.1, seed=1)
    segs, tree = _index(pts)
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    fused = traversal.fused_count_minlabel(tree, segs, 0.1, vals)
    counts, evals = traversal.count_neighbors_with_work(tree, segs, 0.1,
                                                        cap=INT_MAX)
    minlab, matched = traversal.minlabel_sweep(tree, segs, 0.1, vals,
                                               gather_mask=jnp.ones(n, bool),
                                               query_active=jnp.ones(n, bool))
    np.testing.assert_array_equal(np.asarray(fused.hits) + 1,
                                  np.asarray(counts))
    np.testing.assert_array_equal(np.asarray(fused.acc), np.asarray(minlab))
    np.testing.assert_array_equal(np.asarray(fused.evals), np.asarray(evals))


@pytest.mark.parametrize("algo", ["fdbscan", "fdbscan-densebox"])
@pytest.mark.parametrize("mp", [2, 5, 20])
def test_fused_core_matches_preprocess(algo, mp):
    pts = separated_points(300, 2, eps=0.08, seed=mp)
    segs, tree = _index(pts, algo, eps=0.08, mp=mp)
    core_fused = fdbscan._fused_first_pass(tree, segs, 0.08, mp)[0]
    core_ref = fdbscan._preprocess(tree, segs, 0.08, mp)
    np.testing.assert_array_equal(np.asarray(core_fused),
                                  np.asarray(core_ref))


@pytest.mark.parametrize("algo", ["fdbscan", "fdbscan-densebox"])
def test_frontier_identical_labels_and_bounded_work(algo):
    pts = separated_points(400, 2, eps=0.06, seed=7)
    segs, tree = _index(pts, algo, eps=0.06, mp=5)
    res_f, st_f = fdbscan.cluster_from_index(segs, tree, 0.06, 5,
                                             with_stats=True)
    res_u, st_u = fdbscan.cluster_from_index(segs, tree, 0.06, 5,
                                             frontier=False, with_stats=True)
    np.testing.assert_array_equal(np.asarray(res_f.labels),
                                  np.asarray(res_u.labels))
    np.testing.assert_array_equal(np.asarray(res_f.core_mask),
                                  np.asarray(res_u.core_mask))
    # gather-mask frontier is exact: same fixpoint in the same sweep count
    assert res_f.n_sweeps == res_u.n_sweeps
    # ... with no more (strictly less, past sweep one) traversal work
    assert sum(st_f["evals_per_sweep"]) <= sum(st_u["evals_per_sweep"])
    assert sum(st_f["iters_per_sweep"]) <= sum(st_u["iters_per_sweep"])
    # restricted sweeps never gather from more points than the full set
    assert all(f <= st_u["frontier_per_sweep"][0]
               for f in st_f["frontier_per_sweep"])


def test_frontier_matches_oracle_end_to_end():
    pts = separated_points(350, 2, eps=0.07, seed=11)
    ref_labels, ref_core = dbscan_bruteforce_np(pts, 0.07, 4)
    for frontier in (True, False):
        res = dbscan(pts, 0.07, 4, algorithm="fdbscan", frontier=frontier)
        assert (np.asarray(res.core_mask) == ref_core).all()
        assert same_partition(np.asarray(res.labels)[ref_core],
                              ref_labels[ref_core])
        check_dbscan(pts, 0.07, 4, res.labels, res.core_mask)


def _visitor(kind, n, cap=6):
    vals = jnp.arange(n, dtype=jnp.int32)
    mask = jnp.ones(n, bool)
    return {"count": traversal.CountVisitor(cap=cap),
            "minlabel": traversal.MinLabelVisitor(vals, mask),
            "count_minlabel": traversal.CountMinLabelVisitor(vals, mask,
                                                             cap=cap),
            }[kind]


@pytest.mark.parametrize("kind", ["count", "minlabel", "count_minlabel"])
def test_unroll_invariance(kind):
    pts = separated_points(150, 2, eps=0.12, seed=3)
    segs, tree = _index(pts, "fdbscan-densebox", eps=0.12, mp=4)
    n = segs.n_points
    pred = traversal.intersects(traversal.sphere(0.12))
    outs = [traversal.traverse(tree, segs, pred, _visitor(kind, n),
                               unroll=u) for u in (1, 4, 7)]
    for other in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0].acc),
                                      np.asarray(other.acc))
        np.testing.assert_array_equal(np.asarray(outs[0].hits),
                                      np.asarray(other.hits))
        np.testing.assert_array_equal(np.asarray(outs[0].evals),
                                      np.asarray(other.evals))
    # unrolling shrinks loop trips ~unroll-fold
    assert int(outs[1].iters.sum()) < int(outs[0].iters.sum())


@pytest.mark.parametrize("kind", ["count", "minlabel", "count_minlabel",
                                  "nearest"])
def test_external_queries_match_resident(kind):
    # an external predicate batch at the resident coordinates must see the
    # same neighborhoods (modulo self-identity, which externals lack)
    pts = separated_points(160, 2, eps=0.1, seed=8)
    segs, tree = _index(pts)
    n = segs.n_points
    if kind == "nearest":
        cb = traversal.KNNVisitor(4)
        res = traversal.traverse(tree, segs, traversal.nearest(4), cb)
        ext = traversal.traverse(tree, segs,
                                 traversal.nearest(4, pts=segs.pts), cb)
        np.testing.assert_array_equal(np.asarray(res.carry.ids),
                                      np.asarray(ext.carry.ids))
        np.testing.assert_array_equal(np.asarray(res.carry.d2),
                                      np.asarray(ext.carry.d2))
        return
    cb = _visitor(kind, n, cap=traversal.INT_MAX)
    res = traversal.traverse(tree, segs,
                             traversal.intersects(traversal.sphere(0.1)), cb)
    ext = traversal.traverse(
        tree, segs,
        traversal.intersects(traversal.sphere(0.1), pts=segs.pts), cb,
        carry=(None if kind == "count"
               else traversal.AccHits(acc=jnp.arange(n, dtype=jnp.int32),
                                      hits=jnp.zeros(n, jnp.int32))))
    np.testing.assert_array_equal(np.asarray(res.acc), np.asarray(ext.acc))
    # externals have no self to exclude: exactly one extra hit per lane
    np.testing.assert_array_equal(np.asarray(res.hits) + 1,
                                  np.asarray(ext.hits))


@pytest.mark.parametrize("cap", [1, 3, 7])
def test_count_early_exit_saturates_exactly(cap):
    pts = separated_points(180, 2, eps=0.15, seed=cap)
    segs, tree = _index(pts)
    full = traversal.count_neighbors(tree, segs, 0.15, cap=INT_MAX)
    capped = traversal.count_neighbors(tree, segs, 0.15, cap=cap)
    np.testing.assert_array_equal(np.asarray(capped),
                                  np.minimum(np.asarray(full), cap))


def test_node_mask_all_true_is_noop():
    pts = separated_points(120, 2, eps=0.1, seed=9)
    segs, tree = _index(pts)
    n = segs.n_points
    pred = traversal.intersects(traversal.sphere(0.1))
    cb = _visitor("minlabel", n)
    a = traversal.traverse(tree, segs, pred, cb)
    b = traversal.traverse(tree, segs, pred, cb,
                           node_mask=jnp.ones(2 * segs.n_segments - 1, bool))
    np.testing.assert_array_equal(np.asarray(a.acc), np.asarray(b.acc))
    np.testing.assert_array_equal(np.asarray(a.hits), np.asarray(b.hits))


@pytest.mark.parametrize("algo", ["fdbscan", "fdbscan-densebox"])
def test_traversal_budget_is_sweeps_plus_one(algo):
    # The paper-fusion acceptance bound: seed spent n_sweeps + 2 walks.
    pts = separated_points(250, 2, eps=0.07, seed=2)
    res = dbscan(pts, 0.07, 5, algorithm=algo)
    assert res.n_traversals == res.n_sweeps + 1
    star = dbscan(pts, 0.07, 5, algorithm=algo, star=True)
    assert star.n_traversals == star.n_sweeps  # no border gather


def test_minpts2_uses_fused_pass():
    # minpts == 2 is no longer special-cased: the fused count covers it.
    pts = separated_points(200, 2, eps=0.05, seed=4)
    res = dbscan(pts, 0.05, 2, algorithm="fdbscan")
    check_dbscan(pts, 0.05, 2, res.labels, res.core_mask)
    assert res.n_traversals == res.n_sweeps + 1
