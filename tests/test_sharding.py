"""Sharding-resolver unit tests (pure spec logic, fake mesh)."""
from types import SimpleNamespace

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def fake_mesh(data=16, model=16):
    return SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((data, model)))


MESH = fake_mesh()


def _spec(name, shape, in_blocks=False):
    path = tuple(SimpleNamespace(key=k)
                 for k in ((["blocks"] if in_blocks else []) + [name]))
    return shd.param_spec(path, shape, MESH)


def test_embedding_vocab_parallel():
    assert _spec("embed", (151936, 2560)) == P("model", None)
    assert _spec("unembed", (2560, 151936)) == P(None, "model")


def test_attention_projections():
    assert _spec("wq", (4096, 4096)) == P(None, "model")
    assert _spec("wo", (4096, 4096)) == P("model", None)
    # stacked superblock axis shifts dims
    assert _spec("wq", (8, 4096, 4096), in_blocks=True) == P(None, None, "model")


def test_divisibility_fallback_replicates():
    # gemma2: 8 heads x 256 = 2048 cols; 2048 % 16 == 0 -> sharded
    assert _spec("wq", (2304, 2048)) == P(None, "model")
    # a 9-wide dim cannot shard over 16 -> replicated
    assert _spec("wq", (2304, 9)) == P(None, None)


def test_moe_expert_parallel_and_fallback():
    # 64 experts % 16 == 0 -> expert parallel
    assert _spec("w_gate", (6, 64, 2048, 1408), in_blocks=True) == \
        P(None, "model", None, None)
    # 8 experts % 16 != 0 -> shard d_ff instead (mixtral)
    assert _spec("w_gate", (4, 8, 4096, 14336), in_blocks=True) == \
        P(None, None, None, "model")
    assert _spec("w_down", (4, 8, 14336, 4096), in_blocks=True) == \
        P(None, None, "model", None)


def test_norms_replicated():
    assert _spec("scale", (4096,)) == P(None)
    assert _spec("router", (4096, 8)) == P(None, None)


def test_zero1_adds_data_on_largest_free_dim():
    s = shd.zero1_spec(P(None, "model"), (4096, 11008), MESH)
    assert s == P("data", "model")
    # model-sharded dim is taken; largest free divisible dim gets data
    s = shd.zero1_spec(P("model", None), (11008, 4096), MESH)
    assert s == P("model", "data")
    # nothing divisible -> unchanged
    s = shd.zero1_spec(P(None,), (7,), MESH)
    assert s == P(None)


def test_mamba_rules():
    assert _spec("in_proj", (4, 4096, 16384), in_blocks=True) == \
        P(None, None, "model")
    assert _spec("A_log", (4, 8192, 16), in_blocks=True) == \
        P(None, "model", None)
