"""Per-kernel allclose vs the pure-jnp oracles, across shapes and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import pairwise_count, pairwise_minlabel, dbscan_tiled
from repro.kernels.ref import pairwise_count_ref, pairwise_minlabel_ref
from repro.core import dbscan
from repro.core.validate import check_dbscan, same_partition

from conftest import separated_points

SHAPES = [(7, 5), (128, 128), (130, 257), (64, 300), (1, 1), (200, 3)]


@pytest.mark.parametrize("nq,nr", SHAPES)
@pytest.mark.parametrize("d", [2, 3])
def test_count_matches_ref(nq, nr, d):
    pts = separated_points(nq + nr, d, eps=0.2, seed=nq + nr + d)
    q, r = pts[:nq], pts[nq:]
    out = pairwise_count(q, r, 0.2)
    ref = pairwise_count_ref(q, r, 0.2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("cap", [1, 3, 2**31 - 1])
def test_count_saturates(cap):
    pts = separated_points(150, 2, eps=0.3, seed=9)
    out = pairwise_count(pts, pts, 0.3, cap=cap)
    ref = pairwise_count_ref(pts, pts, 0.3, cap=cap)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(out.max()) <= cap


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16])
def test_count_dtypes(dtype):
    pts = separated_points(100, 2, eps=0.25, seed=3).astype(dtype)
    out = pairwise_count(pts, pts, 0.25)
    ref = pairwise_count_ref(pts, pts, 0.25)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("tile", [128, 256])
def test_count_tile_sizes(tile):
    pts = separated_points(300, 2, eps=0.15, seed=5)
    out = pairwise_count(pts, pts, 0.15, tile_q=tile, tile_r=tile)
    ref = pairwise_count_ref(pts, pts, 0.15)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("nq,nr", SHAPES)
def test_minlabel_matches_ref(nq, nr):
    rng = np.random.default_rng(nq * 7 + nr)
    pts = separated_points(nq + nr, 2, eps=0.2, seed=nq + 31 * nr)
    q, r = pts[:nq], pts[nq:]
    labels = rng.integers(0, max(nr, 1), size=nr).astype(np.int32)
    mask = rng.random(nr) > 0.4
    out_l, out_c = pairwise_minlabel(q, r, labels, mask, 0.2)
    ref_l, ref_c = pairwise_minlabel_ref(q, r, jnp.asarray(labels),
                                         jnp.asarray(mask), 0.2)
    np.testing.assert_array_equal(np.asarray(out_l), np.asarray(ref_l))
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(ref_c))


def test_minlabel_all_masked():
    pts = separated_points(90, 2, eps=0.2, seed=11)
    labels = np.arange(90, dtype=np.int32)
    out_l, out_c = pairwise_minlabel(pts, pts, labels, np.zeros(90, bool), 0.2)
    assert (np.asarray(out_l) == np.iinfo(np.int32).max).all()
    assert (np.asarray(out_c) == 0).all()


@pytest.mark.parametrize("n,eps,mp", [(256, 0.08, 5), (400, 0.05, 2),
                                      (333, 0.1, 20)])
def test_tiled_dbscan_agrees_with_tree_backends(n, eps, mp):
    pts = separated_points(n, 2, eps=eps, seed=n)
    r_tile = dbscan_tiled(pts, eps, mp)
    check_dbscan(pts, eps, mp, r_tile.labels, r_tile.core_mask)
    r_tree = dbscan(pts, eps, mp, algorithm="fdbscan")
    assert (np.asarray(r_tile.core_mask) == np.asarray(r_tree.core_mask)).all()
    assert r_tile.n_clusters == r_tree.n_clusters
