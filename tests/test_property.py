"""Property-based tests (hypothesis) on exact integer-grid geometry.

Coordinates are small integers and eps^2 is chosen strictly between integer
values, so d2 comparisons are exact in float32 — every backend must agree
*exactly* with the brute-force oracle, including at cluster merges.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import dbscan, dbscan_bruteforce_np
from repro.core.validate import check_dbscan, same_partition
from repro.kernels import dbscan_tiled

N = 48  # fixed size => jit caches are reused across examples

points_strategy = st.lists(
    st.tuples(st.integers(0, 12), st.integers(0, 12)),
    min_size=N, max_size=N).map(
        lambda l: np.asarray(l, np.float32))

eps_strategy = st.sampled_from([1.4, 2.2, 3.1])   # eps^2 never integral
minpts_strategy = st.sampled_from([2, 3, 5])

# every dispatchable backend, including the multi-device tree path (which
# degenerates to a single shard here but still runs the full halo protocol)
BACKENDS = ("fdbscan", "fdbscan-densebox", "tiled", "auto", "sharded")
backend_strategy = st.sampled_from(BACKENDS)


@settings(max_examples=20, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy)
def test_fdbscan_axioms(pts, eps, mp):
    res = dbscan(pts, eps, mp, algorithm="fdbscan")
    check_dbscan(pts, eps, mp, res.labels, res.core_mask)


@settings(max_examples=20, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy)
def test_densebox_matches_oracle(pts, eps, mp):
    res = dbscan(pts, eps, mp, algorithm="fdbscan-densebox")
    ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, mp)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])
    check_dbscan(pts, eps, mp, res.labels, res.core_mask)


@settings(max_examples=10, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy)
def test_tiled_kernel_backend_matches_oracle(pts, eps, mp):
    res = dbscan_tiled(pts, eps, mp)
    ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, mp)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])


@settings(max_examples=10, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy,
       seed=st.integers(0, 2**31 - 1))
def test_backends_agree_under_permutation(pts, eps, mp, seed):
    perm = np.random.default_rng(seed).permutation(N)
    a = dbscan(pts, eps, mp, algorithm="fdbscan")
    b = dbscan(pts[perm], eps, mp, algorithm="fdbscan-densebox")
    core = np.asarray(a.core_mask)
    assert (core[perm] == np.asarray(b.core_mask)).all()
    assert same_partition(np.asarray(a.labels)[perm][np.asarray(b.core_mask)],
                          np.asarray(b.labels)[np.asarray(b.core_mask)])


@settings(max_examples=25, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy,
       algo=backend_strategy)
def test_every_backend_satisfies_axioms(pts, eps, mp, algo):
    """A1-A5 hold for every backend the dispatcher can resolve."""
    res = dbscan(pts, eps, mp, algorithm=algo)
    check_dbscan(pts, eps, mp, res.labels, res.core_mask)


@settings(max_examples=25, deadline=None)
@given(pts=points_strategy, eps=eps_strategy, mp=minpts_strategy,
       algo=backend_strategy, seed=st.integers(0, 2**31 - 1))
def test_core_partition_permutation_invariant(pts, eps, mp, algo, seed):
    """Shuffling the input must not change the core mask or the core
    partition, for any backend (labels may renumber; ``same_partition``
    compares the induced partitions)."""
    perm = np.random.default_rng(seed).permutation(N)
    a = dbscan(pts, eps, mp, algorithm=algo)
    b = dbscan(pts[perm], eps, mp, algorithm=algo)
    core_a = np.asarray(a.core_mask)
    core_b = np.asarray(b.core_mask)
    assert (core_a[perm] == core_b).all()
    assert same_partition(np.asarray(a.labels)[perm][core_b],
                          np.asarray(b.labels)[core_b])
