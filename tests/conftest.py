import numpy as np
import pytest


def separated_points(n: int, d: int, eps: float, seed: int,
                     band: float = 2e-3) -> np.ndarray:
    """Random points with no pair within a relative band of eps^2.

    DBSCAN is discontinuous at dist == eps: different (equally valid)
    float summation orders flip pairs sitting exactly on the boundary.
    Tests that compare two backends exactly use boundary-separated data;
    boundary behaviour itself is covered by the integer-grid property tests
    (where d2 is exact).
    """
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    e2 = eps * eps
    while True:
        d2 = ((pts[:, None, :].astype(np.float64)
               - pts[None, :, :].astype(np.float64)) ** 2).sum(-1)
        offending = np.abs(d2 - e2) < band * e2
        np.fill_diagonal(offending, False)
        bad = np.unique(np.nonzero(offending)[0])
        if len(bad) == 0:
            return pts
        repl = rng.uniform(0, 1, size=(len(bad), d)).astype(np.float32)
        pts[bad] = repl


@pytest.fixture
def rng():
    return np.random.default_rng(0)
