"""Shared fixtures + the ``fast`` marker.

Tier-1 iteration: ``pytest -m fast`` (or ``make test-fast``) runs the quick
algorithmic subset — core DBSCAN correctness, the traversal engine, the
dispatcher, morton/LBVH — in seconds instead of the ~6-minute full suite.
Modules listed in ``FAST_MODULES`` are auto-marked; individual tests can
also opt in with ``@pytest.mark.fast``.
"""
import numpy as np
import pytest

FAST_MODULES = {
    "test_morton",
    "test_lbvh",
    "test_dbscan",
    "test_traversal_fused",
    "test_dispatch",
    "test_neighbors",
    "test_pallas_tree",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "fast: quick tier-1 subset (run with `pytest -m fast`)")
    config.addinivalue_line(
        "markers", "fault: subprocess kill-based crash/recovery tests for "
        "the streaming durability layer (run with `pytest -m fault`)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in FAST_MODULES:
            item.add_marker(pytest.mark.fast)


def separated_points(n: int, d: int, eps: float, seed: int,
                     band: float = 2e-3) -> np.ndarray:
    """Random points with no pair within a relative band of eps^2.

    DBSCAN is discontinuous at dist == eps: different (equally valid)
    float summation orders flip pairs sitting exactly on the boundary.
    Tests that compare two backends exactly use boundary-separated data;
    boundary behaviour itself is covered by the integer-grid property tests
    (where d2 is exact).
    """
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    e2 = eps * eps
    while True:
        d2 = ((pts[:, None, :].astype(np.float64)
               - pts[None, :, :].astype(np.float64)) ** 2).sum(-1)
        offending = np.abs(d2 - e2) < band * e2
        np.fill_diagonal(offending, False)
        bad = np.unique(np.nonzero(offending)[0])
        if len(bad) == 0:
            return pts
        repl = rng.uniform(0, 1, size=(len(bad), d)).astype(np.float32)
        pts[bad] = repl


@pytest.fixture
def rng():
    return np.random.default_rng(0)
