"""End-to-end DBSCAN correctness vs the brute-force oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dbscan, dbscan_bruteforce_np, gdbscan
from repro.core.validate import check_dbscan, same_partition
from repro.data import pointclouds

from conftest import separated_points

ALGOS = ["fdbscan", "fdbscan-densebox"]


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("name,n,eps,mp", [
    ("blobs", 400, 0.05, 10),
    ("ngsim_like", 500, 0.01, 8),
    ("portotaxi_like", 400, 0.02, 6),
    ("road3d_like", 400, 0.01, 4),
    ("hacc_like", 500, 0.03, 5),
])
def test_matches_oracle_partition(algo, name, n, eps, mp):
    pts = pointclouds.load(name, n)
    res = dbscan(pts, eps, mp, algorithm=algo)
    check_dbscan(pts, eps, mp, res.labels, res.core_mask)
    ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, mp)
    assert (np.asarray(res.core_mask) == ref_core).all()
    # core partitions must match exactly (borders may differ validly)
    core = ref_core
    assert same_partition(np.asarray(res.labels)[core], ref_labels[core])


@pytest.mark.parametrize("algo", ALGOS)
def test_minpts2_friends_of_friends(algo):
    pts = separated_points(300, 2, eps=0.04, seed=0)
    res = dbscan(pts, 0.04, 2, algorithm=algo)
    check_dbscan(pts, 0.04, 2, res.labels, res.core_mask)
    # minpts=2: no border points — every labeled point is core
    labs = np.asarray(res.labels)
    core = np.asarray(res.core_mask)
    assert (labs[~core] == -1).all() and (labs[core] >= 0).all()


@pytest.mark.parametrize("algo", ALGOS)
def test_dbscan_star_no_borders(algo):
    pts = pointclouds.blobs(300, seed=5)
    res = dbscan(pts, 0.05, 10, algorithm=algo, star=True)
    labs = np.asarray(res.labels)
    core = np.asarray(res.core_mask)
    assert (labs[~core] == -1).all()
    full = dbscan(pts, 0.05, 10, algorithm=algo)
    # core labeling identical to full DBSCAN
    assert same_partition(labs[core], np.asarray(full.labels)[core])


def test_gdbscan_baseline_agrees():
    pts = separated_points(300, 2, eps=0.06, seed=2)
    a = gdbscan(pts, 0.06, 8)
    b = dbscan(pts, 0.06, 8, algorithm="fdbscan")
    assert (np.asarray(a.core_mask) == np.asarray(b.core_mask)).all()
    core = np.asarray(a.core_mask)
    assert same_partition(np.asarray(a.labels)[core], np.asarray(b.labels)[core])


@pytest.mark.parametrize("algo", ALGOS)
def test_permutation_invariance(algo):
    pts = separated_points(200, 2, eps=0.07, seed=3)
    perm = np.random.default_rng(0).permutation(200)
    r1 = dbscan(pts, 0.07, 5, algorithm=algo)
    r2 = dbscan(pts[perm], 0.07, 5, algorithm=algo)
    assert (np.asarray(r1.core_mask)[perm] == np.asarray(r2.core_mask)).all()
    assert same_partition(np.asarray(r1.labels)[perm], np.asarray(r2.labels))


def test_eps_monotonicity():
    # growing eps can only merge/grow clusters: core points stay core
    pts = separated_points(250, 2, eps=0.05, seed=4)
    prev_core = None
    for eps in [0.03, 0.06, 0.12]:
        res = dbscan(pts, eps, 5)
        core = np.asarray(res.core_mask)
        if prev_core is not None:
            assert (core | ~prev_core).all()  # prev_core implies core
        prev_core = core


def test_minpts_monotonicity():
    pts = separated_points(250, 2, eps=0.08, seed=6)
    prev_core = None
    for mp in [20, 10, 5, 2]:
        res = dbscan(pts, 0.08, mp)
        core = np.asarray(res.core_mask)
        if prev_core is not None:
            assert (core | ~prev_core).all()
        prev_core = core


@pytest.mark.parametrize("algo", ALGOS)
def test_all_points_identical(algo):
    pts = np.zeros((64, 2), np.float32)
    res = dbscan(pts, 0.1, 5, algorithm=algo)
    assert res.n_clusters == 1
    assert (np.asarray(res.labels) == 0).all()


def test_two_clusters_bridged_by_border():
    # classic bridging scenario: a single non-core point within eps of two
    # separate clusters must NOT merge them (the paper's critical section)
    ring = np.array([[0.0, 0.0], [0.1, 0.0], [0.05, 0.05], [0.05, -0.05],
                     [-0.05, 0.0], [0.0, 0.05], [0.0, -0.05], [0.05, 0.0]])
    a = ring
    b = ring + np.array([2.0, 0.0])
    bridge = np.array([[1.0, 0.0]])  # reaches only the closest edge points
    pts = np.concatenate([a, b, bridge]).astype(np.float32)
    eps, mp = 0.92, 4
    res = dbscan(pts, eps=eps, min_pts=mp)
    labs = np.asarray(res.labels)
    core = np.asarray(res.core_mask)
    assert not core[16], "bridge must be non-core"
    assert core[:16].all()
    assert labs[0] != labs[8], "bridging occurred"
    assert labs[16] in (labs[0], labs[8])  # border joined exactly one side
    check_dbscan(pts, eps, mp, res.labels, res.core_mask)


# --------------------------------------------------------------------- #
# degenerate-parameter matrix (ISSUE: robustness)                        #
#                                                                        #
# Every backend must return *well-defined* labels on parameter regimes   #
# that skip the interesting code paths entirely — min_pts larger than    #
# the whole dataset (all noise), eps swallowing the bounding box (one    #
# cluster), a single point, and all-duplicate inputs. These are exactly  #
# the inputs a serving path sees from misconfigured clients.             #
# --------------------------------------------------------------------- #

ALL_BACKENDS = ["fdbscan", "fdbscan-densebox", "tiled", "pallas-tree",
                "stream"]


def _degenerate_cases():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (60, 2)).astype(np.float32)
    one = pts[:1]
    dup = np.tile(pts[:1], (20, 1))
    # (name, points, eps, min_pts, expected clusters: 0 = all noise)
    return [
        ("minpts_gt_n", pts, 0.1, len(pts) + 40, 0),
        ("eps_gt_bbox", pts, 50.0, 5, 1),
        ("n1_minpts1", one, 0.1, 1, 1),
        ("n1_minpts2", one, 0.1, 2, 0),
        ("all_dup", dup, 0.1, 5, 1),
        ("all_dup_minpts_gt_n", dup, 0.1, len(dup) + 1, 0),
    ]


@pytest.mark.parametrize("algo", ALL_BACKENDS)
@pytest.mark.parametrize(
    "name,pts,eps,mp,want",
    _degenerate_cases(), ids=[c[0] for c in _degenerate_cases()])
def test_degenerate_parameters(algo, name, pts, eps, mp, want):
    from repro.core import dispatch
    res = dispatch.dbscan(pts, eps, mp, algorithm=algo)
    labs = np.asarray(res.labels)
    core = np.asarray(res.core_mask)
    assert res.n_clusters == want
    assert labs.shape == (len(pts),) and core.shape == (len(pts),)
    if want == 0:                      # all noise: nothing core, all -1
        assert (labs == -1).all() and not core.any()
    else:                              # single cluster: everything core
        assert (labs == 0).all() and core.all()
    check_dbscan(pts, eps, mp, labs, core)


def test_sweep_count_is_small():
    # hook+jump converges in a handful of sweeps even on adversarial chains
    line = np.stack([np.linspace(0, 1, 512), np.zeros(512)], -1).astype(np.float32)
    res = dbscan(line, eps=0.003, min_pts=2, algorithm="fdbscan")
    assert res.n_clusters == 1
    assert res.n_sweeps <= 12  # ~log2(512) + margin
