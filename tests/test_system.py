"""End-to-end behaviour tests for the paper's system claims."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dbscan, fdbscan, grid, lbvh, traversal
from repro.data import pointclouds

from conftest import separated_points


def test_on_the_fly_memory_no_neighbor_lists():
    """The paper's O(n) claim: no structure in the pipeline may scale with
    the edge count. We run a dense instance (avg degree ~n/4) and assert
    every array allocated by the phases is O(n + m)."""
    pts = jnp.asarray(separated_points(512, 2, eps=0.5, seed=0))
    eps, minpts = 0.5, 4  # extremely dense: ~85k edges for 512 points
    segs = grid.build_segments_densebox(pts, eps, minpts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    n, m = segs.n_points, segs.n_segments
    bound = 4 * (2 * m - 1 + 2 * n)  # nodes + per-point arrays, elements
    for leaf in list(tree) + list(segs):
        assert leaf.size <= bound, f"edge-scaled allocation: {leaf.shape}"
    core = fdbscan._preprocess(tree, segs, eps, minpts)
    labels, sweeps = fdbscan._main_phase(tree, segs, eps, core)
    assert labels.size == n and core.size == n


def test_early_exit_count_saturates():
    pts = jnp.asarray(separated_points(256, 2, eps=0.4, seed=1))
    segs = grid.build_segments_fdbscan(pts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    counts = traversal.count_neighbors(tree, segs, 0.4, cap=5)
    assert int(counts.max()) <= 5  # early exit: no count beyond minpts


def test_densebox_eliminates_distance_work():
    """>=90% of points in dense cells (paper's 2D road-data regime)."""
    pts = pointclouds.trajectories_2d(8000)
    eps = 0.02
    segs = grid.build_segments_densebox(jnp.asarray(pts), eps, 5)
    dense_frac = float(np.asarray(segs.dense_pt).mean())
    assert dense_frac > 0.9
    # all dense members are core without any traversal
    core = fdbscan._preprocess(
        lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi),
        segs, eps, 5)
    assert bool(np.asarray(core)[np.asarray(segs.dense_pt)].all())


def test_sparse_3d_disables_dense_cells():
    """Paper Fig. 6: at high minpts no cells are dense (cosmology)."""
    pts = pointclouds.halos_3d(4000, seed=7)
    segs = grid.build_segments_densebox(jnp.asarray(pts), 0.02, 100)
    assert float(np.asarray(segs.dense_pt).mean()) < 0.05


def test_minpts2_equals_connected_components():
    """minpts=2 == friends-of-friends == CC of the eps-graph."""
    pts = separated_points(300, 2, eps=0.06, seed=5)
    res = dbscan(pts, 0.06, 2)
    d2 = ((pts[:, None].astype(np.float64) - pts[None]) ** 2).sum(-1)
    adj = d2 <= 0.06 * 0.06
    n = len(pts)
    lab = np.arange(n)
    while True:  # min-label propagation to fixpoint = CC
        new = np.min(np.where(adj, lab[None, :], n), axis=1)
        new = np.minimum(lab, new)
        if (new == lab).all():
            break
        lab = new
    comp_sizes = np.bincount(lab, minlength=n)
    singles = comp_sizes[lab] == 1
    ours = np.asarray(res.labels)
    assert ((ours == -1) == singles).all()
    # same partition on non-noise
    from repro.core.validate import same_partition
    assert same_partition(ours[~singles], lab[~singles])


def test_sweep_convergence_bound():
    """Hook+jump sweep count stays logarithmic on adversarial chains."""
    for n in (128, 512):
        line = np.stack([np.linspace(0, 1, n), np.zeros(n)], -1).astype(np.float32)
        res = dbscan(line, eps=1.5 / n, min_pts=2, algorithm="fdbscan")
        assert res.n_clusters == 1
        assert res.n_sweeps <= int(np.log2(n)) + 4
