"""Kill-based crash/recovery tests for the streaming durability layer.

Each test spawns the deterministic child driver (tests/faults.py), which
arms exactly one crash point (``durability.FAULT_POINTS``) and dies there
with ``os._exit(137)`` — no cleanup, no flushing: the in-process stand-in
for ``kill -9``.  The parent then recovers from the checkpoint + WAL left
behind and asserts the durability contract (DESIGN.md §10):

  * recovery never raises on a torn or corrupt WAL tail;
  * the recovered point count sits on an insert-batch boundary — a batch
    is never half-applied;
  * every *acknowledged* batch (``insert`` returned before the kill) is
    present — acknowledged-durable data is never lost;
  * ``snapshot()`` of the recovered handle is component-identical to
    batch ``dbscan`` on exactly the recovered prefix, and stays so after
    the rest of the stream is inserted into the recovered handle.

The child's schedule (6 batches of 40, a forced merge every 2 inserts,
auto-checkpoint on every merge) drives every barrier: merges fire at
batches 2 and 4, checkpoints right after each merge, and the WAL holds
the not-yet-checkpointed suffix in between.
"""
import numpy as np
import pytest

import faults
from faults import CONFIG, CRASH_EXIT

pytestmark = pytest.mark.fault


# (crash point, occurrence) — chosen so each kill lands where the durable
# state is most interesting: mid-stream, with a checkpoint behind and
# un-checkpointed WAL records in front.
KILL_MATRIX = [
    ("pre-insert", 3),       # batch 3 never became durable: not recovered
    ("wal-durable", 3),      # batch 3 durable but unapplied: replay applies
    ("post-insert", 3),      # applied but never acknowledged: replay is
                             # idempotent (re-applies from the WAL)
    ("mid-merge", 2),        # merge in flight: in-memory only, no damage
    ("mid-checkpoint", 1),   # first checkpoint torn: WAL-only recovery
    ("mid-checkpoint", 2),   # later checkpoint torn: previous one + WAL
    ("mid-wal-append", 3),   # torn record on disk: truncated, not applied
]


@pytest.mark.parametrize("point,at", KILL_MATRIX,
                         ids=[f"{p}@{a}" for p, a in KILL_MATRIX])
def test_kill_and_recover(tmp_path, point, at):
    proc = faults.run_child(tmp_path, crash_point=point, crash_at=at)
    assert proc.returncode == CRASH_EXIT, (
        f"child did not die at the armed barrier {point}@{at}:\n"
        f"rc={proc.returncode}\nstdout={proc.stdout}\nstderr={proc.stderr}")
    h = faults.recover_and_check(tmp_path)
    faults.finish_stream(h)


def test_clean_run_then_restore(tmp_path):
    """No crash at all: restore of the final durable state is the whole
    stream, and the acks file covers every batch."""
    proc = faults.run_child(tmp_path, crash_point=None)
    assert proc.returncode == 0, proc.stderr
    acks = faults.read_acks(tmp_path)
    assert acks[-1] == CONFIG["n"] and len(acks) == CONFIG["batches"]
    h = faults.recover_and_check(tmp_path)
    assert h.n_points == CONFIG["n"]


@pytest.mark.parametrize("tail", [
    b"\x52\x45\x43\x57" + b"\x00" * 9,      # torn mid-header
    b"\x52\x45\x43\x57" + b"\x00" * 40,     # full header, torn payload
    b"not-a-record-at-all",                 # corrupt garbage tail
], ids=["torn-header", "torn-payload", "garbage"])
def test_torn_final_record(tmp_path, tail):
    """A crash mid-append leaves a partial final record: recovery must
    truncate it silently and keep everything acknowledged before it."""
    # die right before batch 6: batches 1-5 acked, WAL holds batch 5
    proc = faults.run_child(tmp_path, crash_point="pre-insert", crash_at=6)
    assert proc.returncode == CRASH_EXIT, proc.stderr
    _, wal, _ = faults.paths(tmp_path)
    with open(wal, "ab") as f:
        f.write(tail)
    h = faults.recover_and_check(tmp_path)
    assert h.n_points == max(faults.read_acks(tmp_path))
    faults.finish_stream(h)


def test_recovered_handle_is_durable_again(tmp_path):
    """Crash, recover, crash the *recovered* state's files again by hand
    (torn tail), recover again — durability survives repeated cycles."""
    proc = faults.run_child(tmp_path, crash_point="wal-durable", crash_at=2)
    assert proc.returncode == CRASH_EXIT, proc.stderr
    h = faults.recover_and_check(tmp_path)
    pts, batches = faults.stream_points()
    boundaries = np.cumsum([0] + [len(b) for b in batches])
    k = int(np.searchsorted(boundaries, h.n_points))
    h.insert(pts[batches[k]])               # re-attached WAL logs this
    _, wal, _ = faults.paths(tmp_path)
    with open(wal, "ab") as f:
        f.write(b"\x52\x45\x43\x57 torn again")
    h2 = faults.recover_and_check(tmp_path)
    assert h2.n_points >= h.n_points
