"""Kill-based crash/recovery tests for the streaming durability layer.

Each test spawns the deterministic child driver (tests/faults.py), which
arms exactly one crash point (``durability.FAULT_POINTS``) and dies there
with ``os._exit(137)`` — no cleanup, no flushing: the in-process stand-in
for ``kill -9``.  The parent then recovers from the checkpoint + WAL left
behind and asserts the durability contract (DESIGN.md §10, §11):

  * recovery never raises on a torn or corrupt WAL tail;
  * the recovered ``(n_points, active-gid set)`` equals the state after
    some *op prefix* of the schedule — an insert, delete, or expiry is
    never half-applied;
  * every *acknowledged* op (the call returned before the kill) is
    present — acknowledged-durable data is never lost;
  * ``snapshot()`` of the recovered handle is component-identical to
    batch ``dbscan`` on exactly the surviving points of that prefix, and
    stays so after the rest of the schedule runs in the recovered handle.

The child's schedule (6 insert batches of 40 with deletes after batches
2 and 5 and an expiry after batch 4; a forced merge every 3 inserts;
auto-checkpoint on every merge; buffer_max=48 so tier seals and cascade
merges fire mid-schedule) drives every barrier: insert, delete/expire
WAL appends, merges, checkpoints, and tiered compaction.
"""
import numpy as np
import pytest

import faults
from faults import CONFIG, CRASH_EXIT

pytestmark = pytest.mark.fault


# (crash point, occurrence) — chosen so each kill lands where the durable
# state is most interesting: mid-stream, with a checkpoint behind and
# un-checkpointed WAL records in front.
KILL_MATRIX = [
    ("pre-insert", 3),       # batch 3 never became durable: not recovered
    ("wal-durable", 3),      # batch 3 durable but unapplied: replay applies
    ("post-insert", 3),      # applied but never acknowledged: replay is
                             # idempotent (re-applies from the WAL)
    ("mid-merge", 2),        # merge in flight: in-memory only, no damage
    ("mid-checkpoint", 1),   # first checkpoint torn: WAL-only recovery
    ("mid-checkpoint", 2),   # later checkpoint torn: previous one + WAL
    ("mid-wal-append", 3),   # torn record on disk: truncated, not applied
    ("pre-delete", 1),       # first delete never durable: survivors keep
                             # the doomed gids until the schedule reruns
    ("wal-durable-delete", 1),   # delete durable but unapplied: replay
                                 # tombstones + repairs demotions
    ("wal-durable-delete", 2),   # the *expiry* record (2nd typed append):
                                 # window semantics survive the kill
    ("mid-compaction", 1),   # cascade tier-merge in flight (insert 5,
                             # checkpoint behind, WAL records in front):
                             # tiers are rebuilt in memory only, the
                             # durable state is undamaged
]


@pytest.mark.parametrize("point,at", KILL_MATRIX,
                         ids=[f"{p}@{a}" for p, a in KILL_MATRIX])
def test_kill_and_recover(tmp_path, point, at):
    proc = faults.run_child(tmp_path, crash_point=point, crash_at=at)
    assert proc.returncode == CRASH_EXIT, (
        f"child did not die at the armed barrier {point}@{at}:\n"
        f"rc={proc.returncode}\nstdout={proc.stdout}\nstderr={proc.stderr}")
    h = faults.recover_and_check(tmp_path)
    faults.finish_stream(h)


def test_clean_run_then_restore(tmp_path):
    """No crash at all: restore of the final durable state is the whole
    schedule, and the acks file covers every op."""
    proc = faults.run_child(tmp_path, crash_point=None)
    assert proc.returncode == 0, proc.stderr
    ops = faults.op_schedule()
    acks = faults.read_acks(tmp_path)
    assert len(acks) == len(ops)
    assert acks[-1][1] == CONFIG["n"]
    n_final, alive_final = faults.expected_states()[-1]
    assert acks[-1][2] == len(alive_final)
    h = faults.recover_and_check(tmp_path)
    assert h.n_points == CONFIG["n"]
    assert frozenset(int(g) for g in h.active_gids) == alive_final


@pytest.mark.parametrize("tail", [
    b"\x52\x45\x43\x57" + b"\x00" * 9,      # torn mid-header
    b"\x52\x45\x43\x57" + b"\x00" * 40,     # full header, torn payload
    b"not-a-record-at-all",                 # corrupt garbage tail
], ids=["torn-header", "torn-payload", "garbage"])
def test_torn_final_record(tmp_path, tail):
    """A crash mid-append leaves a partial final record: recovery must
    truncate it silently and keep everything acknowledged before it."""
    # die right before batch 6: all earlier ops acked, WAL holds the
    # un-checkpointed suffix (insert 5 + the expiry)
    proc = faults.run_child(tmp_path, crash_point="pre-insert", crash_at=6)
    assert proc.returncode == CRASH_EXIT, proc.stderr
    _, wal, _ = faults.paths(tmp_path)
    with open(wal, "ab") as f:
        f.write(tail)
    h = faults.recover_and_check(tmp_path)
    assert h.n_points == max(a[1] for a in faults.read_acks(tmp_path))
    faults.finish_stream(h)


def test_recovered_handle_is_durable_again(tmp_path):
    """Crash, recover, crash the *recovered* state's files again by hand
    (torn tail), recover again — durability survives repeated cycles."""
    proc = faults.run_child(tmp_path, crash_point="wal-durable", crash_at=2)
    assert proc.returncode == CRASH_EXIT, proc.stderr
    h = faults.recover_and_check(tmp_path)
    pts, _ = faults.stream_points()
    ops = faults.op_schedule()
    k = faults._match_prefix(h, CONFIG)
    kind, arg = ops[k]
    if kind == "insert":                    # re-attached WAL logs this
        h.insert(pts[arg])
    elif kind == "delete":
        h.delete(arg)
    else:
        h.expire(arg)
    _, wal, _ = faults.paths(tmp_path)
    with open(wal, "ab") as f:
        f.write(b"\x52\x45\x43\x57 torn again")
    h2 = faults.recover_and_check(tmp_path)
    assert h2.n_points >= h.n_points
    assert faults._match_prefix(h2, CONFIG) >= k + 1
