"""The neighbor-query workloads over the predicate/callback engine.

knn / neighbor_count vs NumPy brute force — including exact-tie groups at
the k-th radius (integer coordinates: d2 is exact, so ties are real),
k > n, radius caps, external query batches, and a custom visitor through
``radius_visit`` (the engine's extensibility contract).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro.core import dispatch, neighbors, traversal
from repro.data import pointclouds

from conftest import separated_points


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_cache()
    yield
    dispatch.clear_cache()


def _brute_knn(pts, q, k, radius=None):
    pts = np.asarray(pts, np.float32)
    q = np.asarray(q, np.float32)
    d2 = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    if radius is not None:
        d2 = np.where(d2 <= np.float32(radius) ** 2, d2, np.inf)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    dd = np.take_along_axis(d2, idx, axis=1)
    idx = np.where(np.isinf(dd), -1, idx)
    return idx, np.sqrt(dd)


def _check_knn(pts, k, query_pts=None, radius=None):
    res = neighbors.knn(pts, k, query_pts=query_pts, radius=radius)
    q = pts if query_pts is None else query_pts
    ref_i, ref_d = _brute_knn(pts, q, min(k, len(np.asarray(pts))),
                              radius=radius)
    got_i = np.asarray(res.indices)[:, :ref_i.shape[1]]
    got_d = np.asarray(res.distances)[:, :ref_i.shape[1]]
    np.testing.assert_array_equal(got_i, ref_i)
    np.testing.assert_allclose(got_d, ref_d, rtol=1e-6)
    # slots beyond n are padding
    assert (np.asarray(res.indices)[:, ref_i.shape[1]:] == -1).all()


@pytest.mark.parametrize("dset,n", [("blobs", 700), ("hacc_like", 600)])
def test_knn_matches_bruteforce(dset, n):
    pts = pointclouds.load(dset, n)
    _check_knn(pts, 5)
    # a resident query's nearest neighbor is itself at distance 0
    res = neighbors.knn(pts, 1)
    np.testing.assert_array_equal(np.asarray(res.indices)[:, 0],
                                  np.arange(n))
    np.testing.assert_array_equal(np.asarray(res.distances)[:, 0],
                                  np.zeros(n, np.float32))


def test_knn_external_queries():
    pts = pointclouds.blobs(500, seed=3)
    rng = np.random.default_rng(0)
    q = rng.uniform(-0.1, 1.1, size=(64, 2)).astype(np.float32)
    _check_knn(pts, 4, query_pts=q)


def test_knn_ties_at_radius_resolve_by_index():
    # integer lattice: d2 is exact, so equidistant rings are true ties.
    # k cuts *inside* a tie group — selection must match the stable
    # brute-force argsort (smallest original index wins).
    xy = np.stack(np.meshgrid(np.arange(7.0), np.arange(7.0)), -1)
    pts = xy.reshape(-1, 2).astype(np.float32)
    rng = np.random.default_rng(1)
    pts = pts[rng.permutation(len(pts))]          # ids decoupled from geometry
    for k in (2, 3, 4, 6):   # cuts a 4-point unit ring at various depths
        _check_knn(pts, k)
    q = np.array([[3.0, 3.0]], np.float32)        # center: 4-way ties
    _check_knn(pts, 3, query_pts=q)


def test_knn_k_exceeds_n():
    pts = pointclouds.blobs(40, seed=5)
    res = neighbors.knn(pts, 64)
    _check_knn(pts, 64)
    assert (np.asarray(res.indices)[:, 40:] == -1).all()
    assert np.isinf(np.asarray(res.distances)[:, 40:]).all()


def test_knn_radius_capped():
    pts = separated_points(400, 2, eps=0.05, seed=2)
    _check_knn(pts, 8, radius=0.05)


def test_knn_degenerate_inputs():
    one = np.zeros((1, 2), np.float32)
    res = neighbors.knn(one, 3)
    assert np.asarray(res.indices).tolist() == [[0, -1, -1]]
    with pytest.raises(ValueError):
        neighbors.knn(one, 0)
    # d outside the Morton range takes the exact brute fallback
    pts5 = np.random.default_rng(4).normal(size=(50, 5)).astype(np.float32)
    _check_knn(pts5, 4)


def test_neighbor_count_matches_bruteforce():
    pts = pointclouds.blobs(600, seed=7)
    r = 0.05
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    ref = (d2 <= np.float32(r) ** 2).sum(1)
    np.testing.assert_array_equal(np.asarray(neighbors.neighbor_count(pts, r)),
                                  ref)
    # saturating cap (the DBSCAN early exit)
    np.testing.assert_array_equal(
        np.asarray(neighbors.neighbor_count(pts, r, cap=5)),
        np.minimum(ref, 5))
    # external probes count every resident match
    q = pts[:32] + np.float32(1e-3)
    d2q = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(
        np.asarray(neighbors.neighbor_count(pts, r, query_pts=q)),
        (d2q <= np.float32(r) ** 2).sum(1))


def test_neighbors_share_the_dispatch_index():
    # knn, neighbor_count, and dbscan runs on the same point set must hit
    # one cached eps-independent index build
    pts = separated_points(1500, 2, eps=0.05, seed=9)
    p0 = dispatch.plan(pts, 0.05, 5, algorithm="fdbscan")
    neighbors.knn(pts, 3)
    neighbors.neighbor_count(pts, 0.02)
    p1 = dispatch.plan(pts, 0.09, 3, algorithm="fdbscan")
    assert p0.segs is p1.segs and p0.tree is p1.tree


@jax.tree_util.register_pytree_node_class
class _WeightSumVisitor(traversal.Visitor):
    """Test double: accumulates sum(weights[j]) over in-radius neighbors —
    a workload none of the built-in visitors cover."""

    def __init__(self, weights):
        self.weights = weights

    def tree_flatten(self):
        return (self.weights,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def init_carry(self, ids, external, segs):
        return jnp.zeros(ids.shape, self.weights.dtype)

    def visit(self, carry, j, d2, hit, ctx):
        return carry + jnp.where(hit, self.weights[j], 0), hit


def test_radius_visit_custom_callback():
    # the extensibility contract: an arbitrary accumulator pytree driven
    # by the same engine, validated against a dense oracle
    pts = separated_points(300, 2, eps=0.07, seed=11)
    w = np.random.default_rng(3).integers(1, 10, size=300).astype(np.int32)
    p = dispatch.plan(pts, 0.07, 5, algorithm="fdbscan")
    w_sorted = jnp.asarray(w)[p.segs.order]
    tr = neighbors.radius_visit(pts, 0.07, _WeightSumVisitor(w_sorted))
    got = np.zeros(300, np.int32)
    got[np.asarray(p.segs.order)] = np.asarray(tr.carry)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    ref = np.where(d2 <= np.float32(0.07) ** 2, w[None, :], 0).sum(1)
    np.testing.assert_array_equal(got, ref)


def test_top_level_exports():
    # the stable public surface (ISSUE 4): everything an application needs
    assert set(repro.__all__) == {"DBSCANResult", "dbscan", "plan",
                                  "stream_handle", "neighbors",
                                  "__version__"}
    pts = pointclouds.blobs(300, seed=1)
    res = repro.dbscan(pts, 0.05, 5)
    assert isinstance(res, repro.DBSCANResult)
    p = repro.plan(pts, 0.05, 5)
    assert repro.dbscan(pts, 0.05, 5, query_plan=p).backend == p.backend
    h = repro.stream_handle(pts, 0.05, 5)
    assert h.n_points == 300
    assert repro.neighbors.knn(pts, 2).indices.shape == (300, 2)