"""Golden equivalence: the predicate/callback engine vs the pre-redesign
``mode=`` enum engine, pinned bit-for-bit.

``tests/golden/golden.npz`` was generated at the last pre-redesign commit
(see tests/golden/make_golden.py); these tests re-run every backend on the
five scenario datasets and assert byte equality on labels, core masks,
neighbor counts, and sweep counts — including the external-query/halo
path (stream's chained two-tree reads, sharded's traveling slabs) and the
frontier-compacted sweep path (the tree backends' default).
"""
import os

import numpy as np
import pytest

from repro.core import dbscan, stream_handle, traversal
from repro.core.dispatch import plan
from repro.data import pointclouds

from test_ring_tree import run_with_devices

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = np.load(os.path.join(HERE, "golden", "golden.npz"))

# (dataset, n, eps, min_pts) — must match tests/golden/make_golden.py
SCENARIOS = [
    ("ngsim_like", 800, 0.01, 5),
    ("portotaxi_like", 800, 0.02, 5),
    ("road3d_like", 800, 0.01, 5),
    ("hacc_like", 800, 0.05, 5),
    ("blobs", 800, 0.05, 8),
]
SHARDED = ["portotaxi_like", "hacc_like"]


def _case(dset):
    return next(c for c in SCENARIOS if c[0] == dset)


def _assert_result(dset, backend, res):
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  GOLDEN[f"{dset}/{backend}/labels"])
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  GOLDEN[f"{dset}/{backend}/core"])
    assert res.n_clusters == int(GOLDEN[f"{dset}/{backend}/n_clusters"])


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
@pytest.mark.parametrize("backend", ["fdbscan", "fdbscan-densebox"])
def test_tree_backends_bit_identical(dset, backend):
    # default frontier=True: the compacted/pruned sweep path is on
    dset, n, eps, mp = _case(dset)
    res = dbscan(pointclouds.load(dset, n), eps, mp, algorithm=backend)
    _assert_result(dset, backend, res)
    # the fused-pass traversal budget survives the callback engine
    assert res.n_sweeps == int(GOLDEN[f"{dset}/{backend}/n_sweeps"])
    assert res.n_traversals == res.n_sweeps + 1


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
def test_pallas_tree_backend_bit_identical(dset):
    # the Pallas traversal kernel (interpret mode on CPU CI) drives every
    # walk over the same plain-fdbscan index, so its labels, core masks,
    # and sweep counts must match the fdbscan goldens byte-for-byte
    dset, n, eps, mp = _case(dset)
    res = dbscan(pointclouds.load(dset, n), eps, mp, algorithm="pallas-tree")
    assert res.backend == "pallas-tree"
    _assert_result(dset, "fdbscan", res)
    assert res.n_sweeps == int(GOLDEN[f"{dset}/fdbscan/n_sweeps"])
    assert res.n_traversals == res.n_sweeps + 1


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
def test_pallas_engine_counts_bit_identical(dset):
    # kernel-level golden: exact uncapped neighbor counts out of the
    # Pallas walk (original point order), plus eval-counter parity with
    # the reference engine on the same walk
    from repro.kernels import traverse as pallas_traverse
    dset, n, eps, mp = _case(dset)
    pts = pointclouds.load(dset, n)
    p = plan(pts, eps, mp, algorithm="fdbscan")
    pred = traversal.intersects(traversal.sphere(eps))
    cb = traversal.CountVisitor(cap=traversal.INT_MAX)
    tr = pallas_traverse.traverse(p.tree, p.segs, pred, cb)
    counts = np.zeros(n, np.int64)
    counts[np.asarray(p.segs.order)] = np.asarray(tr.acc)
    np.testing.assert_array_equal(counts, GOLDEN[f"{dset}/counts"])
    ref = traversal.traverse(p.tree, p.segs, pred, cb)
    np.testing.assert_array_equal(np.asarray(ref.evals), np.asarray(tr.evals))


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
def test_tiled_backend_bit_identical(dset):
    dset, n, eps, mp = _case(dset)
    res = dbscan(pointclouds.load(dset, n), eps, mp, algorithm="tiled")
    _assert_result(dset, "tiled", res)


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
def test_stream_backend_bit_identical(dset):
    # bootstrap + two micro-batches + forced merge: the chained two-tree
    # external-query path, exactly as the goldens were generated
    dset, n, eps, mp = _case(dset)
    pts = pointclouds.load(dset, n)
    cut = n * 5 // 8
    h = stream_handle(pts[:cut], eps, mp)
    h.insert(pts[cut:cut + (n - cut) // 2])
    h.insert(pts[cut + (n - cut) // 2:])
    h.merge()
    res = h.snapshot()
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  GOLDEN[f"{dset}/stream/labels"])
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  GOLDEN[f"{dset}/stream/core"])
    assert res.n_clusters == int(GOLDEN[f"{dset}/stream/n_clusters"])


@pytest.mark.parametrize("dset", [c[0] for c in SCENARIOS])
def test_engine_counts_bit_identical(dset):
    # engine-level golden: exact uncapped neighbor counts over the plain
    # tree index (original point order)
    dset, n, eps, mp = _case(dset)
    pts = pointclouds.load(dset, n)
    p = plan(pts, eps, mp, algorithm="fdbscan")
    counts_sorted = np.asarray(traversal.count_neighbors(
        p.tree, p.segs, eps, cap=traversal.INT_MAX))
    counts = np.zeros(n, np.int64)
    counts[np.asarray(p.segs.order)] = counts_sorted
    np.testing.assert_array_equal(counts, GOLDEN[f"{dset}/counts"])


# --------------------------------------------------------------------- #
# observer effect (DESIGN.md §12): instrumentation changes nothing      #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", ["fdbscan", "fdbscan-densebox",
                                     "tiled", "pallas-tree"])
def test_observer_effect_batch_bit_identical(backend):
    # the same golden assertions as above, but with a live registry and
    # a sync tracer installed: results must stay byte-identical, and the
    # collectors must actually have seen the run (a silently-dead
    # instrumentation path would also pass the equality half)
    from repro import obs
    dset, n, eps, mp = _case("portotaxi_like")
    pts = pointclouds.load(dset, n)
    with obs.instrumented(sync=True) as (reg, tr):
        res = dbscan(pts, eps, mp, algorithm=backend)
    golden = "fdbscan" if backend == "pallas-tree" else backend
    _assert_result(dset, golden, res)
    if backend in ("fdbscan", "fdbscan-densebox", "pallas-tree"):
        assert res.n_sweeps == int(GOLDEN[f"{dset}/{golden}/n_sweeps"])
    assert reg.get("dbscan_runs_total", backend=res.backend).value == 1
    spans = {e["name"] for e in tr.events}
    if backend != "tiled":      # tree backends expose the phase spans
        assert {"plan", "dbscan", "traverse", "sweep"} <= spans
    if backend == "pallas-tree":
        fam = reg._families.get("pallas_kernel_launches_total")
        assert fam is not None
        assert sum(c.value for c in fam._children.values()) >= 1


def test_observer_effect_stream_bit_identical():
    from repro import obs
    dset, n, eps, mp = _case("blobs")
    pts = pointclouds.load(dset, n)
    cut = n * 5 // 8
    with obs.instrumented(sync=True) as (reg, tr):
        h = stream_handle(pts[:cut], eps, mp)
        h.insert(pts[cut:cut + (n - cut) // 2])
        h.insert(pts[cut + (n - cut) // 2:])
        h.merge()
        res = h.snapshot()
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  GOLDEN[f"{dset}/stream/labels"])
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  GOLDEN[f"{dset}/stream/core"])
    assert res.n_clusters == int(GOLDEN[f"{dset}/stream/n_clusters"])
    assert reg.get("stream_inserts_total").value == 2
    assert reg.get("stream_merges_total").value >= 1
    assert {"stream.insert", "stream.merge", "stream.snapshot"} <= \
        {e["name"] for e in tr.events}


@pytest.mark.parametrize("dset", SHARDED)
def test_sharded_backend_bit_identical(dset):
    # the eps-halo external-query path, under 8 forced host devices
    dset, n, eps, mp = _case(dset)
    run_with_devices(f"""
    import numpy as np
    from repro.core import dbscan
    from repro.data import pointclouds
    z = np.load({os.path.join(HERE, 'golden', 'golden.npz')!r})
    pts = pointclouds.load({dset!r}, {n})
    res = dbscan(pts, {eps}, {mp}, algorithm="sharded")
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  z[{dset!r} + "/sharded/labels"])
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  z[{dset!r} + "/sharded/core"])
    assert res.n_sweeps == int(z[{dset!r} + "/sharded/n_sweeps"])
    """)
