import numpy as np
import jax.numpy as jnp

from repro.core import morton


def test_expand_bits_2d_known_values():
    v = jnp.asarray([0b1011], dtype=jnp.uint32)
    out = int(morton._expand_bits_2d(v)[0])
    assert out == 0b1000101  # 1 0 1 1 -> 1 _0 0_ 1 ... interleaved gaps


def test_encode_2d_orders_quadrants():
    pts = np.array([[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]], np.float32)
    codes = np.asarray(morton.morton_encode(jnp.asarray(pts)))
    # x is the high interleave bit: (0,0) < (0,1) < (1,0) < (1,1)
    assert codes[0] < codes[1] < codes[2] < codes[3]


def test_encode_injective_on_grid_2d():
    g = np.stack(np.meshgrid(np.arange(32), np.arange(32)), -1).reshape(-1, 2)
    pts = (g / 31.0).astype(np.float32)
    codes = np.asarray(morton.morton_encode(jnp.asarray(pts)))
    assert len(np.unique(codes)) == len(codes)


def test_encode_injective_on_grid_3d():
    g = np.stack(np.meshgrid(*[np.arange(8)] * 3), -1).reshape(-1, 3)
    pts = (g / 7.0).astype(np.float32)
    codes = np.asarray(morton.morton_encode(jnp.asarray(pts)))
    assert len(np.unique(codes)) == len(codes)


def test_quantize_range():
    pts = np.random.default_rng(0).normal(size=(100, 3)).astype(np.float32)
    q = np.asarray(morton.quantize(jnp.asarray(pts), 10))
    assert q.min() >= 0 and q.max() <= 1023


def test_sort_locality():
    # Z-order locality: consecutive codes should usually be spatial neighbors
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, size=(512, 2)).astype(np.float32)
    spts, order, codes = morton.morton_sort(jnp.asarray(pts))
    spts = np.asarray(spts)
    assert (np.diff(np.asarray(codes).astype(np.int64)) >= 0).all()
    hops = np.linalg.norm(np.diff(spts, axis=0), axis=1)
    rand_hops = np.linalg.norm(np.diff(pts, axis=0), axis=1)
    assert np.median(hops) < 0.5 * np.median(rand_hops)
    assert (np.sort(np.asarray(order)) == np.arange(512)).all()
