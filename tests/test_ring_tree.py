"""Sharded tree-path conformance: 8 virtual devices, exact agreement.

The sharded backend (shard-local LBVH traversal + eps-halo exchange,
DESIGN.md §6) must reproduce the single-device partition *exactly*: both
paths Morton-sort with the same global quantization and compute the same
float32 d2 per pair, and both assign min-representative labels, so even
border ties resolve identically — the tests assert equality, not merely
axiom conformance.

Run in subprocesses (like test_distributed) so the main pytest process
keeps its single real device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCENARIOS = [
    # (dataset, n, eps, min_pts) — all five pointclouds regimes
    ("ngsim_like", 1600, 0.01, 5),
    ("portotaxi_like", 1600, 0.02, 5),
    ("road3d_like", 1600, 0.01, 5),
    ("hacc_like", 1600, 0.05, 5),
    ("blobs", 1600, 0.05, 8),
]


def run_with_devices(body: str, n_devices: int = 8, timeout: int = 900):
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.path.join(REPO, "tests"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("dset,n,eps,minpts", SCENARIOS,
                         ids=[s[0] for s in SCENARIOS])
def test_sharded_tree_matches_single_device(dset, n, eps, minpts):
    run_with_devices(f"""
    import numpy as np
    from repro.core import dbscan
    from repro.core.validate import same_partition
    from repro.data import pointclouds
    from repro.distributed.ring_dbscan import tree_dbscan_sharded

    pts = pointclouds.load({dset!r}, {n})
    r, st = tree_dbscan_sharded(pts, {eps}, {minpts}, with_stats=True)
    assert st['ndev'] == 8, st
    s = dbscan(pts, {eps}, {minpts}, algorithm='fdbscan')
    core_r = np.asarray(r.core_mask); core_s = np.asarray(s.core_mask)
    assert (core_r == core_s).all(), 'core mask differs'
    lr = np.asarray(r.labels); ls = np.asarray(s.labels)
    assert same_partition(lr, ls), 'full partition differs'
    assert same_partition(lr[core_s], ls[core_s]), 'core partition differs'
    assert r.n_clusters == s.n_clusters
    # the tree path must beat the dense ring's work by a wide margin
    assert st['distance_evals'] * 5 < st['ring_distance_evals'], st
    print({dset!r}, 'ok', r.n_clusters, 'clusters', st['distance_evals'],
          'evals')
    """)


def test_sharded_tree_cluster_straddles_many_shards():
    """Adversarial: one thin dense strip whose single cluster crosses >= 3
    shard boundaries of the Morton-contiguous slab partition."""
    run_with_devices("""
    import numpy as np
    from repro.core import dbscan, morton
    from repro.core.validate import same_partition
    from repro.distributed.ring_dbscan import tree_dbscan_sharded

    rng = np.random.default_rng(0)
    eps, minpts = 0.01, 4
    # strip along x: spacing well under eps -> one density-connected chain
    xs = np.linspace(0.0, 1.0, 800).astype(np.float32)
    strip = np.stack([xs, 0.5 + 1e-3 * np.sin(37.0 * xs)], -1)
    # distant compact blob (y ~ 0.9) + sparse noise band (y in [0.05,
    # 0.12]) — both many eps away from the strip at y ~ 0.5, so there is
    # no eps-boundary ambiguity between groups
    blob = rng.uniform(0.0, 0.05, size=(120, 2)).astype(np.float32) \\
        + np.asarray([0.1, 0.9], np.float32)
    noise = np.stack([rng.uniform(0, 1, 80),
                      rng.uniform(0.05, 0.12, 80)], -1).astype(np.float32)
    pts = np.concatenate([strip, blob, noise])

    # the strip must occupy >= 4 distinct shards of the slab partition
    _, order, _ = morton.morton_sort(pts)
    pos = np.empty(len(pts), np.int64)
    pos[np.asarray(order)] = np.arange(len(pts))
    n_loc = -(-len(pts) // 8)
    strip_shards = np.unique(pos[:len(strip)] // n_loc)
    assert len(strip_shards) >= 4, strip_shards

    r = tree_dbscan_sharded(pts, eps, minpts)
    s = dbscan(pts, eps, minpts, algorithm='fdbscan')
    assert (np.asarray(r.core_mask) == np.asarray(s.core_mask)).all()
    assert same_partition(np.asarray(r.labels), np.asarray(s.labels))
    # the strip is one cluster despite the shard cuts
    strip_labels = np.unique(np.asarray(r.labels)[:len(strip)])
    assert len(strip_labels) == 1 and strip_labels[0] >= 0, strip_labels
    print('straddle ok: shards', strip_shards, 'clusters', r.n_clusters)
    """)


def test_sharded_auto_dispatch_under_mesh():
    """dispatch.plan picks the sharded backend when a mesh is active, and
    the unified entry point returns the identical partition."""
    run_with_devices("""
    import numpy as np, jax
    from repro.core import dbscan, dispatch
    from repro.core.validate import same_partition
    from conftest import separated_points

    pts = separated_points(1200, 2, eps=0.05, seed=4)
    mesh = jax.make_mesh((8,), ('data',))
    p = dispatch.plan(pts, 0.05, 6, mesh=mesh)
    assert p.backend == 'sharded', p
    assert p.stats['ndev'] == 8
    res = dbscan(pts, 0.05, 6, algorithm='auto', mesh=mesh)
    assert res.backend == 'sharded'
    ref = dbscan(pts, 0.05, 6, algorithm='fdbscan')
    assert (np.asarray(res.core_mask) == np.asarray(ref.core_mask)).all()
    assert same_partition(np.asarray(res.labels), np.asarray(ref.labels))
    print('auto mesh ok')
    """)
