"""Unit tests for the observability layer (DESIGN.md §12): registry
semantics, sketch accuracy and memory bounds, trace export and sync
marking, the disabled fast path, schema stability, artifact validation,
and a traced serve smoke run."""
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics, trace
from repro.obs import validate as obs_validate


# --------------------------------------------------------------------- #
# registry semantics                                                    #
# --------------------------------------------------------------------- #

def test_counter_monotone_and_labels():
    reg = metrics.Registry()
    fam = reg.counter("requests_total", labels=("kind",))
    fam.labels(kind="insert").inc()
    fam.labels(kind="insert").inc(2.5)
    fam.labels(kind="query").inc()
    assert fam.labels(kind="insert").value == 3.5
    assert fam.labels(kind="query").value == 1.0
    with pytest.raises(ValueError):
        fam.labels(kind="insert").inc(-1)
    # typo'd label names must raise, not fork a parallel series
    with pytest.raises(ValueError):
        fam.labels(kinds="insert")


def test_family_conflicts_raise():
    reg = metrics.Registry()
    reg.counter("x", labels=("a",))
    with pytest.raises(ValueError):
        reg.gauge("x", labels=("a",))          # kind conflict
    with pytest.raises(ValueError):
        reg.counter("x", labels=("b",))        # label-set conflict


def test_registry_get_never_creates():
    reg = metrics.Registry()
    assert reg.get("absent") is None
    reg.counter("c", labels=("k",)).labels(k="v").inc()
    assert reg.get("c", k="v").value == 1.0
    assert reg.get("c", k="other") is None
    assert len(reg._families["c"]._children) == 1


# --------------------------------------------------------------------- #
# histogram sketch: accuracy, memory bound, zero bucket                 #
# --------------------------------------------------------------------- #

def test_histogram_quantiles_within_relative_accuracy():
    rng = np.random.default_rng(0)
    vals = np.exp(rng.normal(-7.0, 1.5, size=20_000))   # latency-like
    h = metrics.Histogram()
    for v in vals:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(vals, q))
        est = h.quantile(q)
        # DDSketch guarantee is rel error <= a on the value; allow 3a for
        # rank-interpolation differences vs numpy at finite sample size
        assert abs(est - exact) / exact <= 3 * metrics.REL_ACCURACY, \
            f"q={q}: {est} vs {exact}"
    assert h.count == len(vals)
    assert math.isclose(h.sum, float(vals.sum()), rel_tol=1e-9)
    assert h.min == float(vals.min()) and h.max == float(vals.max())


def test_histogram_memory_flat_in_sample_count():
    h = metrics.Histogram()
    lo, hi = 1e-4, 1e-1
    # memory is bounded by the data's dynamic range, never by the count:
    # the sketch can use at most one bucket per log-gamma step across
    # [lo, hi] (+1 for the boundary), however many samples arrive
    range_buckets = math.ceil(math.log(hi / lo) / h._log_gamma) + 1
    rng = np.random.default_rng(1)
    for v in rng.uniform(lo, hi, size=50_000):
        h.observe(float(v))
    assert h.bucket_count() <= range_buckets
    for v in rng.uniform(lo, hi, size=50_000):
        h.observe(float(v))
    assert h.bucket_count() <= range_buckets < 400
    assert h.count == 100_000


def test_histogram_bucket_cap_collapses():
    h = metrics.Histogram()
    # one observation per sketch bucket across a huge dynamic range:
    # blows straight past MAX_BUCKETS unless the lowest buckets collapse
    step = h._log_gamma * 1.01
    for i in range(metrics.MAX_BUCKETS + 200):
        h.observe(math.exp((i - 100) * step))
    assert h.bucket_count() <= metrics.MAX_BUCKETS
    assert h.count == metrics.MAX_BUCKETS + 200


def test_histogram_zero_bucket_and_empty():
    h = metrics.Histogram()
    assert math.isnan(h.quantile(0.5))
    for v in (0.0, -1.0, 0.0, 5.0):
        h.observe(v)
    assert h.quantile(0.25) == 0.0            # the three non-positives
    # the top quantile lands in 5.0's bucket (midpoint within rel error)
    assert abs(h.quantile(1.0) - 5.0) / 5.0 <= metrics.REL_ACCURACY
    with pytest.raises(ValueError):
        h.quantile(1.5)


# --------------------------------------------------------------------- #
# schema stability + validation                                         #
# --------------------------------------------------------------------- #

def test_snapshot_schema_pinned():
    # the exact document layout is a compatibility surface: CI tooling
    # and dashboards parse it, so a change here is a schema migration
    assert metrics.SCHEMA == "repro.obs/v1"
    assert trace.TRACE_SCHEMA == "repro.obs.trace/v1"
    reg = metrics.Registry()
    reg.counter("c", help="h", labels=("k",)).labels(k="v").inc(2)
    reg.gauge("g").labels().set(1.5)
    reg.histogram("lat", labels=("op",)).labels(op="q").observe(0.25)
    doc = reg.snapshot()
    metrics.validate_snapshot(doc)
    assert sorted(doc) == ["metrics", "schema"]
    assert [m["name"] for m in doc["metrics"]] == ["c", "g", "lat"]
    c, g, lat = doc["metrics"]
    assert sorted(c) == ["help", "kind", "label_names", "name", "series"]
    assert c["series"] == [{"labels": {"k": "v"}, "value": 2.0}]
    assert g["series"] == [{"labels": {}, "value": 1.5}]
    s = lat["series"][0]
    assert sorted(s) == ["count", "labels", "max", "min", "p50", "p95",
                         "p99", "sum"]
    assert s["count"] == 1 and s["sum"] == 0.25
    # round-trips through JSON unchanged
    assert json.loads(json.dumps(doc)) == doc


def test_validate_snapshot_rejections():
    good = {"schema": metrics.SCHEMA, "metrics": []}
    metrics.validate_snapshot(good)
    with pytest.raises(ValueError):
        metrics.validate_snapshot({"schema": "nope", "metrics": []})
    with pytest.raises(ValueError):
        metrics.validate_snapshot({"schema": metrics.SCHEMA,
                                   "metrics": {}})
    dup = {"schema": metrics.SCHEMA, "metrics": [
        {"name": "x", "kind": "counter", "label_names": [], "series": []},
        {"name": "x", "kind": "counter", "label_names": [], "series": []}]}
    with pytest.raises(ValueError):
        metrics.validate_snapshot(dup)
    bad_hist = {"schema": metrics.SCHEMA, "metrics": [
        {"name": "h", "kind": "histogram", "label_names": [],
         "series": [{"labels": {}, "count": 1}]}]}
    with pytest.raises(ValueError):
        metrics.validate_snapshot(bad_hist)


def test_validate_chrome_trace_rejections():
    tr = trace.Tracer(sync=False, annotate=False)
    with tr.span("a"):
        with tr.span("b", i=1):
            pass
    doc = tr.to_dict()
    trace.validate_chrome_trace(doc)
    with pytest.raises(ValueError):
        trace.validate_chrome_trace({"traceEvents": []})   # no schema tag
    bad = json.loads(json.dumps(doc))
    del bad["traceEvents"][0]["dur"]
    with pytest.raises(ValueError):
        trace.validate_chrome_trace(bad)


# --------------------------------------------------------------------- #
# tracer: nesting, sync marking, export                                 #
# --------------------------------------------------------------------- #

def test_trace_nesting_and_attrs(tmp_path):
    tr = trace.Tracer(sync=False, annotate=False)
    with tr.span("outer", backend="fdbscan"):
        with tr.span("inner", i=2):
            pass
    # children close (and record) before parents
    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    inner, outer = tr.events
    assert outer["args"]["backend"] == "fdbscan"
    assert inner["args"]["i"] == 2
    assert outer["dur"] >= inner["dur"]
    p = tmp_path / "t.json"
    doc = tr.export(str(p))
    trace.validate_chrome_trace(json.loads(p.read_text()))
    assert doc["otherData"]["dropped_events"] == 0


def test_trace_sync_marking():
    import jax.numpy as jnp
    tr = trace.Tracer(sync=True, annotate=False)
    with tr.span("synced") as sp:
        sp.watch(jnp.arange(8) * 2)
    with tr.span("unsynced"):
        pass
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["synced"]["args"]["sync"] == "blocked"
    assert by_name["unsynced"]["args"]["sync"] == "none"
    # no-sync tracer never blocks, even with watches registered
    tr2 = trace.Tracer(sync=False, annotate=False)
    with tr2.span("s") as sp:
        sp.watch(jnp.arange(4))
    assert tr2.events[0]["args"]["sync"] == "none"


def test_trace_event_cap():
    tr = trace.Tracer(sync=False, annotate=False, max_events=3)
    for i in range(5):
        with tr.span("s", i=i):
            pass
    assert len(tr.events) == 3
    assert tr.to_dict()["otherData"]["dropped_events"] == 2


# --------------------------------------------------------------------- #
# disabled fast path + scoped installation                              #
# --------------------------------------------------------------------- #

def test_disabled_fast_path_is_noop():
    assert metrics.active() is None and trace.active() is None
    # module helpers must not allocate registries as a side effect
    metrics.inc("nope")
    metrics.observe("nope", 1.0)
    metrics.set_gauge("nope", 1.0)
    assert metrics.active() is None
    # span() hands back the one shared no-op object
    assert trace.span("a") is trace.span("b")
    with trace.span("a") as sp:
        sp.watch(object())
    trace.watch(object())                     # outside any span: no-op


def test_instrumented_scopes_and_restores():
    outer_reg = metrics.install(metrics.Registry())
    try:
        with obs.instrumented(sync=True) as (reg, tr):
            assert metrics.active() is reg and trace.active() is tr
            assert reg is not outer_reg
            metrics.inc("inside")
            with trace.span("s"):
                pass
        assert metrics.active() is outer_reg
        assert trace.active() is None
        assert outer_reg.get("inside") is None
    finally:
        metrics.uninstall()


# --------------------------------------------------------------------- #
# validator CLI + traced serve smoke (artifact end-to-end)              #
# --------------------------------------------------------------------- #

def test_validator_cli(tmp_path):
    reg = metrics.Registry()
    reg.counter("c").labels().inc()
    mpath = tmp_path / "m.json"
    reg.write_json(str(mpath))
    tr = trace.Tracer(sync=False, annotate=False)
    with tr.span("phase"):
        pass
    tpath = tmp_path / "t.json"
    tr.export(str(tpath))
    assert obs_validate.main(["--metrics", str(mpath), "--trace",
                              str(tpath), "--require-span", "phase",
                              "--require-metric", "c"]) == 0
    assert obs_validate.main(["--trace", str(tpath),
                              "--require-span", "absent"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert obs_validate.main(["--metrics", str(bad)]) == 1


def test_serve_emits_valid_artifacts(tmp_path):
    from repro.launch import serve
    mpath, tpath = tmp_path / "m.json", tmp_path / "t.json"
    stats = serve.main([
        "--dataset", "blobs", "--n", "512", "--warm-frac", "0.5",
        "--eps", "0.05", "--min-pts", "8", "--batch", "64",
        "--steps", "4", "--insert-frac", "1.0", "--seed", "3",
        "--metrics-json", str(mpath), "--trace", str(tpath),
        "--trace-sync"])
    assert obs_validate.main([
        "--metrics", str(mpath), "--trace", str(tpath),
        "--require-span", "serve.request", "--require-span",
        "stream.insert", "--require-metric", "serve_insert_seconds"]) == 0
    # serving latency lives in bounded sketches, not unbounded lists
    assert stats["latency_sketch_buckets"] < metrics.MAX_BUCKETS
    assert stats["insert_p50_ms"] > 0
    # collectors installed by serve.main must not leak into the session
    assert metrics.active() is None and trace.active() is None
