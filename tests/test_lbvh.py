import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import grid, lbvh, morton


def _build(n, d=2, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, size=(n, d)).astype(np.float32)
    spts, order, codes = morton.morton_sort(jnp.asarray(pts))
    tree = lbvh.build_tree(codes, spts, spts)
    return np.asarray(spts), tree


@pytest.mark.parametrize("n", [2, 3, 5, 17, 64, 257, 1024])
def test_topology_invariants(n):
    pts, tree = _build(n)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)
    parent = np.asarray(tree.parent)
    n_nodes = 2 * n - 1
    # every node except root has exactly one parent; children consistent
    assert parent[0] == -1
    seen = np.zeros(n_nodes, int)
    for i in range(n - 1):
        for c in (left[i], right[i]):
            assert 0 < c < n_nodes
            assert parent[c] == i
            seen[c] += 1
    assert (seen[1:] == 1).all() and seen[0] == 0


@pytest.mark.parametrize("n", [2, 5, 64, 257])
def test_rope_traversal_visits_all_leaves_in_order(n):
    pts, tree = _build(n, seed=1)
    left = np.asarray(tree.left)
    miss = np.asarray(tree.miss)
    node, visited = 0 if n > 1 else (n - 1), []
    # full DFS: always descend; at leaves follow the rope
    while node != -1:
        if node >= n - 1:
            visited.append(node - (n - 1))
            node = miss[node]
        else:
            node = left[node]
    assert visited == list(range(n))


@pytest.mark.parametrize("n,d", [(64, 2), (64, 3), (500, 2)])
def test_aabb_contains_descendants(n, d):
    pts, tree = _build(n, d=d, seed=2)
    lo = np.asarray(tree.box_lo)
    hi = np.asarray(tree.box_hi)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)
    for i in range(n - 1):
        for c in (left[i], right[i]):
            assert (lo[i] <= lo[c] + 1e-7).all()
            assert (hi[i] >= hi[c] - 1e-7).all()
    # leaves tight on their point
    leaf = np.arange(n) + n - 1
    assert np.allclose(lo[leaf], pts) and np.allclose(hi[leaf], pts)


@pytest.mark.parametrize("n", [2, 5, 64, 257])
def test_range_r_is_max_leaf_under_node(n):
    pts, tree = _build(n, seed=3)
    left = np.asarray(tree.left)
    right = np.asarray(tree.right)
    range_r = np.asarray(tree.range_r)

    def max_leaf(node):
        if node >= n - 1:
            return node - (n - 1)
        return max(max_leaf(left[node]), max_leaf(right[node]))

    import sys
    sys.setrecursionlimit(10000)
    for i in range(2 * n - 1):
        assert range_r[i] == max_leaf(i)


def test_duplicate_codes_tiebreak():
    # all identical points -> all codes equal; construction must still work
    pts = np.zeros((33, 2), np.float32)
    spts, order, codes = morton.morton_sort(jnp.asarray(pts))
    tree = lbvh.build_tree(codes, spts, spts)
    miss = np.asarray(tree.miss)
    left = np.asarray(tree.left)
    node, count = 0, 0
    while node != -1:
        if node >= 32:
            count += 1
            node = miss[node]
        else:
            node = left[node]
    assert count == 33


def test_densebox_segments_partition():
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 1, size=(400, 2)).astype(np.float32)
    segs = grid.build_segments_densebox(jnp.asarray(pts), eps=0.08, min_pts=5)
    start = np.asarray(segs.seg_start)
    end = np.asarray(segs.seg_end)
    sop = np.asarray(segs.seg_of_point)
    assert start[0] == 0 and end[-1] == 400
    assert (start[1:] == end[:-1]).all()          # contiguous partition
    for s in range(segs.n_segments):
        assert (sop[start[s]:end[s]] == s).all()
    dense_seg = np.asarray(segs.dense_seg)
    # dense segments have >= minpts members; loose are singletons
    sizes = end - start
    assert ((sizes >= 5) == dense_seg).all()
    assert (sizes[~dense_seg] == 1).all()
    # tight AABBs
    spts = np.asarray(segs.pts)
    for s in range(segs.n_segments):
        mem = spts[start[s]:end[s]]
        assert np.allclose(np.asarray(segs.prim_lo)[s], mem.min(0))
        assert np.allclose(np.asarray(segs.prim_hi)[s], mem.max(0))
    # dense cells geometrically valid: diameter <= eps
    diam = np.linalg.norm(np.asarray(segs.prim_hi) - np.asarray(segs.prim_lo),
                          axis=1)
    assert (diam[dense_seg] <= 0.08 + 1e-6).all()
