"""Durability-format stability + input hardening (DESIGN.md §10).

Two halves of the robustness contract that need no subprocess kills
(those live in test_faults.py):

* **Format stability** — the golden fixtures under ``tests/golden/``
  pin the on-disk layout.  The current-format pair
  (``stream_ckpt_v2.npz``, ``stream_wal_v2.bin`` — tombstone mask, typed
  insert/delete/expire WAL records) must restore *and* re-serialize byte
  for byte; the frozen version-1 pair (``stream_ckpt_v1.npz``,
  ``stream_wal_v1.bin``) must still load and replay (migration
  readability), though re-serializing it upgrades to the current
  version.  Damaged or future-versioned files must be rejected loudly
  (CheckpointError / WALError), never silently restored.

* **Input hardening** — every public surface (``dispatch.plan/dbscan``,
  ``StreamingDBSCAN.insert/query``, ``neighbors.*``) routes through
  ``core.validate.check_points`` and rejects NaN/Inf coordinates, empty
  point sets, and non-numeric dtypes with a clear ``ValueError`` instead
  of feeding garbage to the Morton encoder.
"""
import io
import json
import os

import numpy as np
import pytest

from repro.core import dispatch, neighbors
from repro.core.validate import check_component_identical, check_points
from repro.data import pointclouds
from repro.stream import StreamingDBSCAN, durability

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_CKPT = os.path.join(GOLDEN, "stream_ckpt_v1.npz")
GOLDEN_WAL = os.path.join(GOLDEN, "stream_wal_v1.bin")
GOLDEN_CKPT_V2 = os.path.join(GOLDEN, "stream_ckpt_v2.npz")
GOLDEN_WAL_V2 = os.path.join(GOLDEN, "stream_wal_v2.bin")

# must mirror tests/golden/make_stream_golden.py
G_EPS, G_MIN_PTS = 0.05, 6
G_N_CKPT, G_N_TOTAL = 80, 100
G2_DELETE_GIDS = (5, 17, 33, 85)
G2_EXPIRE_WM = 8


def golden_stream():
    return pointclouds.blobs(G_N_TOTAL, k=3, seed=7)


# --------------------------------------------------------------------- #
# checkpoint / restore roundtrip                                        #
# --------------------------------------------------------------------- #

@pytest.mark.fast
def test_checkpoint_restore_roundtrip(tmp_path):
    pts = pointclouds.blobs(200, k=3, seed=3)
    ck = str(tmp_path / "ck.npz")
    h = StreamingDBSCAN(pts[:150], 0.05, 6)
    h.insert(pts[150:])
    h.delete(np.arange(40, 60))      # tombstones roundtrip too
    h.checkpoint(ck)
    r = StreamingDBSCAN.restore(ck)
    assert r.n_points == h.n_points and r.n_active == h.n_active
    assert (r.active_gids == h.active_gids).all()
    assert (r.points == h.points).all()
    a, b = h.snapshot(), r.snapshot()
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    assert (np.asarray(a.core_mask) == np.asarray(b.core_mask)).all()
    # a restored handle keeps serving: inserts and queries still work
    r.insert(pts[:10] + 0.003)
    assert r.n_points == h.n_points + 10
    # re-serialization is byte-identical (np.savez is deterministic)
    ck2 = str(tmp_path / "ck2.npz")
    h.checkpoint(ck2)
    assert open(ck, "rb").read() == open(ck2, "rb").read()


@pytest.mark.fast
def test_checkpoint_without_path_raises():
    h = StreamingDBSCAN(pointclouds.blobs(50, seed=0), 0.05, 5)
    with pytest.raises(ValueError, match="checkpoint path"):
        h.checkpoint()


@pytest.mark.fast
def test_restore_nothing_to_recover(tmp_path):
    with pytest.raises(ValueError, match="nothing to recover"):
        StreamingDBSCAN.restore(str(tmp_path / "absent.npz"),
                                wal=str(tmp_path / "absent.wal"))


# --------------------------------------------------------------------- #
# golden fixtures: v2 is stable byte-for-byte, v1 stays readable        #
# --------------------------------------------------------------------- #

def test_golden_v1_checkpoint_still_loads(tmp_path):
    """Version-1 checkpoints (no tombstone array) predate deletes; they
    must restore with an all-alive tombstone mask, and re-serializing
    upgrades them to the current format (which must then roundtrip)."""
    h = StreamingDBSCAN.restore(GOLDEN_CKPT)
    assert h.n_points == G_N_CKPT and h.n_active == G_N_CKPT
    assert h.n_tombstoned == 0
    assert h.eps == G_EPS and h.min_pts == G_MIN_PTS
    out = str(tmp_path / "upgraded.npz")
    h.checkpoint(out)
    state = durability.load_checkpoint(out)
    assert state["manifest"]["version"] == durability.CHECKPOINT_VERSION
    r = StreamingDBSCAN.restore(out)
    a, b = h.snapshot(), r.snapshot()
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()


def test_golden_wal_replays_past_watermark():
    h = StreamingDBSCAN.restore(GOLDEN_CKPT, wal=GOLDEN_WAL)
    pts = golden_stream()
    assert h.n_points == G_N_TOTAL
    assert np.allclose(h.points, pts)
    ref = dispatch.dbscan(pts, G_EPS, G_MIN_PTS, algorithm="fdbscan")
    snap = h.snapshot()
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)


@pytest.mark.fast
def test_golden_wal_scan_shape():
    header, ops, valid_end = durability.scan_wal(GOLDEN_WAL)
    assert header == {"version": 1, "d": 2, "eps": G_EPS,
                      "min_pts": G_MIN_PTS}
    assert [op[0] for op in ops] == ["insert", "insert"]
    assert [op[1] for op in ops] == [80, 90]
    assert all(op[2].shape == (10, 2) for op in ops)
    assert valid_end == os.path.getsize(GOLDEN_WAL)


def test_golden_v2_checkpoint_restores_byte_for_byte(tmp_path):
    h = StreamingDBSCAN.restore(GOLDEN_CKPT_V2)
    assert h.n_points == G_N_CKPT
    assert h.eps == G_EPS and h.min_pts == G_MIN_PTS
    out = str(tmp_path / "rewrite.npz")
    h.checkpoint(out)
    golden = open(GOLDEN_CKPT_V2, "rb").read()
    assert open(out, "rb").read() == golden, (
        "re-serializing a restored v2 checkpoint changed its bytes — the "
        "on-disk format drifted; bump CHECKPOINT_VERSION and regenerate "
        "the fixture (tests/golden/make_stream_golden.py)")


@pytest.mark.fast
def test_golden_v2_wal_scan_shape():
    """Pins the typed-record framing: insert/delete/expire tags, their
    argument fields, and payload shapes."""
    header, ops, valid_end = durability.scan_wal(GOLDEN_WAL_V2)
    assert header == {"version": 2, "d": 2, "eps": G_EPS,
                      "min_pts": G_MIN_PTS}
    assert [op[0] for op in ops] == ["insert", "delete", "expire",
                                    "insert"]
    assert ops[0][1] == 80 and ops[0][2].shape == (10, 2)
    assert ops[1][1] == 90                       # n_points at delete time
    assert ops[1][2].dtype == np.int64
    assert list(ops[1][2]) == list(G2_DELETE_GIDS)
    assert ops[2][1] == G2_EXPIRE_WM and ops[2][2] is None
    assert ops[3][1] == 90 and ops[3][2].shape == (10, 2)
    assert valid_end == os.path.getsize(GOLDEN_WAL_V2)


def test_golden_v2_wal_replays_deletes_and_expiry():
    """Checkpoint + v2 WAL replay must reproduce the exact surviving set
    and a snapshot component-identical to batch dbscan on it."""
    h = StreamingDBSCAN.restore(GOLDEN_CKPT_V2, wal=GOLDEN_WAL_V2)
    pts = golden_stream()
    assert h.n_points == G_N_TOTAL
    dead = set(G2_DELETE_GIDS) | set(range(G2_EXPIRE_WM))
    alive = np.array([g for g in range(G_N_TOTAL) if g not in dead])
    assert (h.active_gids == alive).all()
    ref = dispatch.dbscan(pts[alive], G_EPS, G_MIN_PTS,
                          algorithm="fdbscan")
    snap = h.snapshot()
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)


# --------------------------------------------------------------------- #
# rejection: damaged / future-versioned files fail loudly               #
# --------------------------------------------------------------------- #

def _rewrite_checkpoint(out_path, *, version=None, corrupt=None):
    """Copy the golden checkpoint, optionally stamping a new manifest
    version or flipping bits in one array (without fixing the checksum)."""
    with np.load(GOLDEN_CKPT) as z:
        arrays = {k: z[k] for k in z.files}
    manifest = json.loads(bytes(arrays["manifest"]).decode())
    if version is not None:
        manifest["version"] = version
    if corrupt is not None:
        arr = arrays[corrupt].copy()
        arr.flat[0] += 1
        arrays[corrupt] = arr
    arrays["manifest"] = np.frombuffer(
        json.dumps(manifest, sort_keys=True).encode(), np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    with open(out_path, "wb") as f:
        f.write(buf.getvalue())


@pytest.mark.fast
def test_rejects_future_format_version(tmp_path):
    p = str(tmp_path / "future.npz")
    _rewrite_checkpoint(p, version=durability.CHECKPOINT_VERSION + 41)
    with pytest.raises(durability.CheckpointError,
                       match="unsupported checkpoint format version"):
        StreamingDBSCAN.restore(p)


@pytest.mark.fast
def test_rejects_checksum_mismatch(tmp_path):
    p = str(tmp_path / "bitrot.npz")
    _rewrite_checkpoint(p, corrupt="counts")
    with pytest.raises(durability.CheckpointError,
                       match="checksum mismatch"):
        StreamingDBSCAN.restore(p)


@pytest.mark.fast
def test_rejects_foreign_npz(tmp_path):
    p = str(tmp_path / "foreign.npz")
    np.savez(p, something=np.arange(4))
    with pytest.raises(durability.CheckpointError, match="no manifest"):
        durability.load_checkpoint(p)


@pytest.mark.fast
def test_rejects_truncated_npz(tmp_path):
    p = str(tmp_path / "torn.npz")
    with open(GOLDEN_CKPT, "rb") as f:
        blob = f.read()
    with open(p, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(durability.CheckpointError, match="unreadable"):
        durability.load_checkpoint(p)


@pytest.mark.fast
def test_wal_rejects_bad_magic(tmp_path):
    p = str(tmp_path / "bad.wal")
    with open(p, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 32)
    with pytest.raises(durability.WALError, match="bad magic"):
        durability.scan_wal(p)


@pytest.mark.fast
def test_wal_rejects_parameter_mismatch(tmp_path):
    p = str(tmp_path / "mismatch.wal")
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append(np.zeros((3, 2), np.float32), 0)
    w.close()
    w2 = durability.WriteAheadLog(p, eps=0.2, min_pts=4)
    with pytest.raises(durability.WALError, match="do not match"):
        w2.append(np.ones((3, 2), np.float32), 3)


@pytest.mark.fast
def test_wal_truncates_torn_tail_and_appends(tmp_path):
    p = str(tmp_path / "torn.wal")
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append(np.zeros((3, 2), np.float32), 0)
    w.append(np.ones((4, 2), np.float32), 3)
    w.close()
    with open(p, "ab") as f:                 # torn third record
        f.write(b"\x52\x45\x43\x57" + b"\x00" * 9)
    header, records, valid_end = durability.scan_wal(p)
    assert len(records) == 2 and valid_end < os.path.getsize(p)
    # reopening for append drops the torn tail, then extends cleanly
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append(np.full((2, 2), 2, np.float32), 7)
    w.close()
    _, records, valid_end = durability.scan_wal(p)
    assert [r[1] for r in records] == [0, 3, 7]
    assert valid_end == os.path.getsize(p)


@pytest.mark.fast
def test_wal_delete_expire_roundtrip(tmp_path):
    """Typed v2 records survive a close/scan cycle with exact payloads."""
    p = str(tmp_path / "typed.wal")
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append(np.zeros((6, 2), np.float32), 0)
    w.append_delete(np.array([1, 4], np.int64), 6, d=2)
    w.append_expire(3, d=2)
    w.close()
    header, ops, valid_end = durability.scan_wal(p)
    assert header["version"] == durability.WAL_VERSION
    assert [op[0] for op in ops] == ["insert", "delete", "expire"]
    assert list(ops[1][2]) == [1, 4] and ops[1][1] == 6
    assert ops[2][1] == 3 and ops[2][2] is None
    assert valid_end == os.path.getsize(p)


@pytest.mark.fast
def test_wal_truncates_torn_delete_record(tmp_path):
    """A torn delete payload must be dropped on scan like a torn insert."""
    p = str(tmp_path / "torn_del.wal")
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append(np.zeros((3, 2), np.float32), 0)
    w.append_delete(np.array([0, 2], np.int64), 3, d=2)
    w.close()
    size = os.path.getsize(p)
    with open(p, "r+b") as f:                # tear off half the payload
        f.truncate(size - 8)
    _, ops, valid_end = durability.scan_wal(p)
    assert [op[0] for op in ops] == ["insert"]
    assert valid_end < os.path.getsize(p)
    # reopening for append truncates to the valid prefix and extends
    w = durability.WriteAheadLog(p, eps=0.1, min_pts=4)
    w.append_expire(1, d=2)
    w.close()
    _, ops, valid_end = durability.scan_wal(p)
    assert [op[0] for op in ops] == ["insert", "expire"]
    assert valid_end == os.path.getsize(p)


@pytest.mark.fast
def test_v1_wal_refuses_delete_append_until_reset(tmp_path):
    """Appending typed records to a frozen v1 log would make it unreadable
    to v1 code without any version bump — refuse, and let checkpoint's
    reset() upgrade the header instead."""
    import shutil
    p = str(tmp_path / "old.wal")
    shutil.copy(GOLDEN_WAL, p)
    w = durability.WriteAheadLog(p, eps=G_EPS, min_pts=G_MIN_PTS)
    with pytest.raises(durability.WALError, match="version-1"):
        w.append_delete(np.array([0], np.int64), 100, d=2)
    with pytest.raises(durability.WALError, match="version-1"):
        w.append_expire(5, d=2)
    w.reset()                                # checkpoint truncation path
    w.append_delete(np.array([0], np.int64), 100, d=2)
    w.close()
    header, ops, _ = durability.scan_wal(p)
    assert header["version"] == durability.WAL_VERSION
    assert [op[0] for op in ops] == ["delete"]


@pytest.mark.fast
def test_wal_rejects_future_version(tmp_path):
    p = str(tmp_path / "future.wal")
    import shutil
    shutil.copy(GOLDEN_WAL_V2, p)
    with open(p, "r+b") as f:                # bump the header version
        f.seek(4)
        f.write((durability.WAL_VERSION + 9).to_bytes(2, "little"))
    with pytest.raises(durability.WALError, match="version"):
        durability.scan_wal(p)


@pytest.mark.fast
def test_wal_only_bootstrap_recovers(tmp_path):
    """WAL without a checkpoint_path: the bootstrap set is logged as the
    gid-0 record, so WAL-only recovery keeps every acknowledged insert
    (it used to come back empty — silent loss of acknowledged data)."""
    p = str(tmp_path / "only.wal")
    pts = pointclouds.blobs(120, k=3, seed=4)
    h = StreamingDBSCAN(pts[:80], 0.05, 6, wal=p)
    h.insert(pts[80:])
    r = StreamingDBSCAN.restore(wal=p)
    assert r.n_points == 120
    assert (r.points == h.points).all()
    snap = r.snapshot()
    ref = dispatch.dbscan(pts, 0.05, 6, algorithm="fdbscan")
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)


@pytest.mark.fast
def test_recover_raises_on_gapped_wal(tmp_path):
    """A WAL whose first unapplied record starts past the recovered
    watermark is missing its prefix: recovery must fail loudly, not
    return a handle that silently dropped acknowledged records."""
    p = str(tmp_path / "gap.wal")
    w = durability.WriteAheadLog(p, eps=0.05, min_pts=5)
    w.append(np.zeros((4, 2), np.float32), 80)   # prefix 0..80 is absent
    w.close()
    with pytest.raises(durability.WALError, match="gap"):
        StreamingDBSCAN.restore(wal=p)


@pytest.mark.fast
def test_side_checkpoint_keeps_wal(tmp_path):
    """checkpoint(path) to a path other than the configured one must not
    truncate the WAL — restore(configured_path) still needs the records."""
    ck = str(tmp_path / "ck.npz")
    side = str(tmp_path / "side.npz")
    wl = str(tmp_path / "w.wal")
    pts = pointclouds.blobs(120, k=3, seed=5)
    h = StreamingDBSCAN(pts[:80], 0.05, 6, wal=wl, checkpoint_path=ck)
    h.insert(pts[80:])              # WAL holds the un-checkpointed tail
    h.checkpoint(side)              # ad-hoc side copy: WAL untouched
    _, records, _ = durability.scan_wal(wl)
    assert [r[1] for r in records] == [80]
    r = StreamingDBSCAN.restore(ck, wal=wl)
    assert r.n_points == 120
    h.checkpoint()                  # configured path: *now* it truncates
    _, records, _ = durability.scan_wal(wl)
    assert records == []


@pytest.mark.fast
def test_recover_rejects_wal_checkpoint_param_mismatch(tmp_path):
    """A WAL from a different parameter run than the checkpoint must be
    refused at recovery, not silently replayed into a mismatched handle."""
    ck = str(tmp_path / "ck.npz")
    wl = str(tmp_path / "w.wal")
    StreamingDBSCAN(pointclouds.blobs(60, seed=6), 0.05, 6,
                    checkpoint_path=ck)
    w = durability.WriteAheadLog(wl, eps=0.1, min_pts=6)  # wrong eps
    w.append(np.zeros((3, 2), np.float32), 60)
    w.close()
    with pytest.raises(durability.WALError, match="manifest"):
        StreamingDBSCAN.restore(ck, wal=wl)


@pytest.mark.fast
def test_handle_refuses_dirty_wal(tmp_path):
    """A fresh (non-restore) handle must not silently shadow unreplayed
    WAL records — that would drop durable, acknowledged data."""
    p = str(tmp_path / "dirty.wal")
    w = durability.WriteAheadLog(p, eps=0.05, min_pts=5)
    w.append(np.zeros((3, 2), np.float32), 0)
    w.close()
    with pytest.raises(durability.WALError, match="recover"):
        StreamingDBSCAN(pointclouds.blobs(50, seed=0), 0.05, 5, wal=p)


# --------------------------------------------------------------------- #
# input hardening: check_points at every public surface                 #
# --------------------------------------------------------------------- #

def _nan_pts():
    pts = pointclouds.blobs(40, seed=1).copy()
    pts[7] = np.nan
    return pts


def _inf_pts():
    pts = pointclouds.blobs(40, seed=1).copy()
    pts[3, 0] = np.inf
    return pts


BAD_INPUTS = [
    ("nan", _nan_pts(), "non-finite"),
    ("inf", _inf_pts(), "non-finite"),
    ("empty", np.empty((0, 2), np.float32), "empty"),
    ("flat", np.zeros(8, np.float32), r"\(n, d\)"),
    ("bool", np.zeros((8, 2), bool), "dtype"),
    ("complex", np.zeros((8, 2), complex), "dtype"),
    ("strings", np.array([["a", "b"], ["c", "d"]]), "dtype"),
]
BAD_IDS = [b[0] for b in BAD_INPUTS]


@pytest.mark.fast
@pytest.mark.parametrize("name,bad,msg", BAD_INPUTS, ids=BAD_IDS)
def test_check_points_rejects(name, bad, msg):
    with pytest.raises(ValueError, match=msg):
        check_points(bad)


@pytest.mark.fast
def test_check_points_accepts_int_grid():
    out = check_points(np.arange(12).reshape(6, 2))
    assert out.shape == (6, 2)


@pytest.mark.fast
@pytest.mark.parametrize("name,bad,msg", BAD_INPUTS, ids=BAD_IDS)
def test_dispatch_surfaces_reject(name, bad, msg):
    with pytest.raises(ValueError, match=msg):
        dispatch.plan(bad, 0.05, 5)
    with pytest.raises(ValueError, match=msg):
        dispatch.dbscan(bad, 0.05, 5)


@pytest.mark.fast
def test_stream_surfaces_reject():
    pts = pointclouds.blobs(60, seed=2)
    h = StreamingDBSCAN(pts, 0.05, 5)
    for bad in (_nan_pts(), np.empty((0, 2), np.float32)):
        with pytest.raises(ValueError):
            h.insert(bad)
    with pytest.raises(ValueError, match="non-finite"):
        h.query(_nan_pts())
    with pytest.raises(ValueError, match="non-finite"):
        StreamingDBSCAN(_nan_pts(), 0.05, 5)
    assert h.n_points == 60              # rejected requests left no trace


@pytest.mark.fast
def test_stream_query_allows_empty_batch():
    """An empty *probe* batch is a valid request (mirroring neighbors.*):
    empty QueryResult, no error — only inserts reject emptiness."""
    h = StreamingDBSCAN(pointclouds.blobs(60, seed=2), 0.05, 5)
    out = h.query(np.empty((0, 2), np.float32))
    assert out.labels.shape == (0,)
    assert out.counts.shape == (0,)
    assert out.would_be_core.shape == (0,)


@pytest.mark.fast
def test_neighbors_surfaces_reject():
    pts = pointclouds.blobs(60, seed=2)
    bad = _nan_pts()
    for fn in (lambda p: neighbors.neighbor_count(p, 0.05),
               lambda p: neighbors.knn(p, 3),
               lambda p: neighbors.neighbor_count(pts, 0.05, query_pts=p)):
        with pytest.raises(ValueError, match="non-finite"):
            fn(bad)
    with pytest.raises(ValueError, match="empty"):
        neighbors.knn(np.empty((0, 2), np.float32), 3)
    # an *empty query batch* is a valid request: empty result, no error
    out = neighbors.neighbor_count(pts, 0.05,
                                   query_pts=np.empty((0, 2), np.float32))
    assert out.shape == (0,)
