"""Multi-device tests (8 fake host devices, run in subprocesses so the
main pytest process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 8, timeout: int = 900):
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.path.join(REPO, "tests"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ring_dbscan_matches_single_device():
    run_with_devices("""
    import numpy as np, jax
    from repro.core import dbscan
    from repro.core.validate import check_dbscan, same_partition
    from repro.distributed.ring_dbscan import ring_dbscan
    from conftest import separated_points

    pts = separated_points(1000, 2, eps=0.05, seed=1)
    r = ring_dbscan(pts, 0.05, 8)
    check_dbscan(pts, 0.05, 8, r.labels, r.core_mask)
    s = dbscan(pts, 0.05, 8, algorithm='fdbscan')
    assert (np.asarray(r.core_mask) == np.asarray(s.core_mask)).all()
    core = np.asarray(s.core_mask)
    assert same_partition(np.asarray(r.labels)[core], np.asarray(s.labels)[core])
    assert r.n_clusters == s.n_clusters
    print('ring ok', r.n_clusters)
    """)


def test_ring_dbscan_pallas_kernels_inside_shard_map():
    """The Pallas tile kernels (interpret mode) drive the ring epilogues."""
    run_with_devices("""
    import numpy as np
    from repro.core.validate import check_dbscan
    from repro.distributed.ring_dbscan import ring_dbscan
    from conftest import separated_points

    pts = separated_points(512, 2, eps=0.06, seed=2)
    a = ring_dbscan(pts, 0.06, 5, use_pallas=True)
    check_dbscan(pts, 0.06, 5, a.labels, a.core_mask)
    b = ring_dbscan(pts, 0.06, 5, use_pallas=False)
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    print('pallas ring ok')
    """, n_devices=4)


def test_ring_dbscan_minpts2_and_3d():
    run_with_devices("""
    import numpy as np
    from repro.core.validate import check_dbscan
    from repro.distributed.ring_dbscan import ring_dbscan
    from repro.data import pointclouds
    from conftest import separated_points

    pts = separated_points(500, 3, eps=0.1, seed=3)
    r = ring_dbscan(pts, 0.1, 2)
    check_dbscan(pts, 0.1, 2, r.labels, r.core_mask)
    print('3d ok', r.n_clusters)
    """)
