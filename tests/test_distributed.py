"""Multi-device tests (8 fake host devices, run in subprocesses so the
main pytest process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n_devices: int = 8, timeout: int = 900):
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.path.join(REPO, "tests"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ring_dbscan_matches_single_device():
    run_with_devices("""
    import numpy as np, jax
    from repro.core import dbscan
    from repro.core.validate import check_dbscan, same_partition
    from repro.distributed.ring_dbscan import ring_dbscan
    from conftest import separated_points

    pts = separated_points(1000, 2, eps=0.05, seed=1)
    r = ring_dbscan(pts, 0.05, 8)
    check_dbscan(pts, 0.05, 8, r.labels, r.core_mask)
    s = dbscan(pts, 0.05, 8, algorithm='fdbscan')
    assert (np.asarray(r.core_mask) == np.asarray(s.core_mask)).all()
    core = np.asarray(s.core_mask)
    assert same_partition(np.asarray(r.labels)[core], np.asarray(s.labels)[core])
    assert r.n_clusters == s.n_clusters
    print('ring ok', r.n_clusters)
    """)


def test_ring_dbscan_pallas_kernels_inside_shard_map():
    """The Pallas tile kernels (interpret mode) drive the ring epilogues."""
    run_with_devices("""
    import numpy as np
    from repro.core.validate import check_dbscan
    from repro.distributed.ring_dbscan import ring_dbscan
    from conftest import separated_points

    pts = separated_points(512, 2, eps=0.06, seed=2)
    a = ring_dbscan(pts, 0.06, 5, use_pallas=True)
    check_dbscan(pts, 0.06, 5, a.labels, a.core_mask)
    b = ring_dbscan(pts, 0.06, 5, use_pallas=False)
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    print('pallas ring ok')
    """, n_devices=4)


def test_ring_dbscan_minpts2_and_3d():
    run_with_devices("""
    import numpy as np
    from repro.core.validate import check_dbscan
    from repro.distributed.ring_dbscan import ring_dbscan
    from repro.data import pointclouds
    from conftest import separated_points

    pts = separated_points(500, 3, eps=0.1, seed=3)
    r = ring_dbscan(pts, 0.1, 2)
    check_dbscan(pts, 0.1, 2, r.labels, r.core_mask)
    print('3d ok', r.n_clusters)
    """)


def test_compressed_gradient_allreduce():
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import make_dp_grad_fn

    mesh = jax.make_mesh((8,), ('data',))
    w = jnp.asarray(np.random.default_rng(0).normal(size=(16, 4)), jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 16)), jnp.float32)

    def loss(w, xb):
        return jnp.mean((xb @ w) ** 2)

    exact = jax.grad(loss)(w, x)
    for method, tol in [('none', 1e-6), ('bf16', 2e-2), ('int8', 3e-2)]:
        fn = jax.jit(make_dp_grad_fn(loss, mesh, method=method))
        l, g = fn(w, x)
        err = float(jnp.max(jnp.abs(g - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
        assert err < tol, (method, err)
        print(method, 'rel err', err)
    """)


def test_gpipe_matches_sequential():
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.train.pipeline import gpipe, gpipe_bubble

    mesh = jax.make_mesh((8,), ('pod',))
    S, M, B, D = 8, 16, 4, 32
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(S, D, D)) / np.sqrt(D), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(M, B, D)), jnp.float32)

    def stage(w, x):
        return jnp.tanh(x @ w)

    piped = jax.jit(gpipe(stage, mesh, axis='pod'))(Ws, xs)
    ref = xs
    for s in range(S):
        ref = jax.vmap(lambda x: stage(Ws[s], x))(ref)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(gpipe_bubble(16, 8) - 7/23) < 1e-9
    print('gpipe ok')
    """)


def test_elastic_checkpoint_reshard():
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.checkpoint import CheckpointManager

    mesh8 = jax.make_mesh((8,), ('data',))
    tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            'b': jnp.ones((8,), jnp.float32)}
    tree = jax.device_put(tree, NamedSharding(mesh8, P('data')))
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(5, tree)
        # "restart" with a different mesh shape: 4-way (elastic shrink)
        mesh4 = jax.make_mesh((4, 2), ('data', 'model'))
        sh = {'w': NamedSharding(mesh4, P('data', 'model')),
              'b': NamedSharding(mesh4, P(None))}
        restored, step = ckpt.restore(tree, shardings=sh)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored['w']),
                                      np.asarray(tree['w']))
        assert restored['w'].sharding.spec == P('data', 'model')
    print('elastic ok')
    """)


def test_sharded_train_step_on_8_devices():
    """End-to-end: the production train step lowered on a real 4x2 mesh."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get
    from repro.launch import specs
    from repro.models import model
    from repro.train.optimizer import adamw_init

    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    cfg = get('qwen1.5-4b').reduced()
    import dataclasses
    from repro.launch.specs import Cell
    fn, args, in_sh, out_sh, meta = None, None, None, None, None

    from repro.train import step as step_lib
    from repro.distributed import sharding as shd
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    params_sh = shd.params_shardings(params, mesh)
    params = jax.device_put(params, params_sh)
    opt = adamw_init(params)
    opt_sh = shd.opt_shardings(opt, params_sh, mesh, zero1=True)
    opt = jax.device_put(opt, opt_sh)
    batch = {'tokens': jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 64)),
        jnp.int32)}
    bsh = shd.batch_shardings(batch, mesh, ('data',))
    batch = jax.device_put(batch, bsh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    metrics_sh = {'ce': repl, 'aux': repl, 'loss': repl, 'step': repl}
    step = jax.jit(step_lib.make_train_step(cfg, n_micro=2),
                   in_shardings=(params_sh, opt_sh, bsh),
                   out_shardings=(params_sh, opt_sh, metrics_sh))
    losses = []
    for i in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics['loss']))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0]
    print('sharded step ok', losses)
    """)
