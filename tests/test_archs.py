"""Per-architecture smoke tests on reduced configs (CPU, one step each)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, names
from repro.models import model

ALL = names()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, model.VISION_EMBED_DIM)),
            jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg = get(name).reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, aux, _, n_prefix = model.forward(cfg, params, batch)
    S_total = 32 + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert x.shape == (2, S_total, cfg.d_model)
    assert bool(jnp.isfinite(x).all())
    logits = model.logits_from_hidden(cfg, params, x[:, -1:])
    assert logits.shape == (2, 1, cfg.vocab_size)
    if cfg.final_softcap:
        assert float(jnp.abs(logits).max()) <= cfg.final_softcap + 1e-3


@pytest.mark.parametrize("name", ALL)
def test_one_train_step_reduces_loss_no_nans(name):
    from repro.train.optimizer import adamw_init, adamw_update
    cfg = get(name).reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr=1e-2)
        return params, opt, loss

    opt = adamw_init(params)
    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt)
        assert bool(jnp.isfinite(loss)), name
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{name}: loss did not decrease {losses}"


@pytest.mark.parametrize("name", ALL)
def test_prefill_decode_consistency(name):
    """decode_step after prefill must match the full-sequence forward."""
    cfg = get(name).reduced()
    if cfg.frontend == "vision":
        pytest.skip("prefix semantics covered by dense backbone variants")
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, seed=3)

    # full forward over S tokens -> logits at the last position
    x, _, _, _ = model.forward(cfg, params, batch, remat=False)
    ref = model.logits_from_hidden(cfg, params, x[:, -1:])

    # prefill on the first S-1 tokens, then decode token S-1
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    _, cache = model.prefill(cfg, params, pre)
    cache = jax.tree.map(jnp.asarray, cache)
    cache = _grow_cache(cfg, cache, S)
    logits, _ = model.decode_step(cfg, params, cache,
                                  batch["tokens"][:, -1:], S - 1)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(logits),
                               rtol=2e-3, atol=2e-3)


def _grow_cache(cfg, cache, S):
    """Pad prefill kv caches (length S-1) to decode size S."""
    def grow(entry):
        out = dict(entry)
        for key in ("k", "v"):
            if key in entry and entry[key].shape[2] < S:
                pad = S - entry[key].shape[2]
                out[key] = jnp.pad(entry[key],
                                   ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return out
    return tuple(grow(e) for e in cache)


@pytest.mark.parametrize("name", ["gemma2-2b", "mixtral-8x7b"])
def test_sliding_window_masks_far_context(name):
    """A token beyond every window/global reach must not affect local attn."""
    cfg = get(name).reduced()  # window = 16
    assert cfg.sliding_window == 16
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    S = 40
    batch = _batch(cfg, B=1, S=S, seed=5)
    x1, _, _, _ = model.forward(cfg, params, batch, remat=False)
    if name == "mixtral-8x7b":  # all layers local: early token can't leak
        t2 = batch["tokens"].at[0, 0].set((int(batch["tokens"][0, 0]) + 1)
                                          % cfg.vocab_size)
        x2, _, _, _ = model.forward(cfg, params, {"tokens": t2}, remat=False)
        depth_reach = cfg.n_layers * (cfg.sliding_window - 1)
        if depth_reach < S - 1:
            np.testing.assert_allclose(np.asarray(x1[0, -1]),
                                       np.asarray(x2[0, -1]), atol=1e-5)


def test_moe_capacity_and_aux_loss():
    cfg = get("mixtral-8x7b").reduced()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    _, metrics = model.loss_fn(cfg, params, batch)
    assert float(metrics["aux"]) > 0.0  # load-balance loss present
