"""Streaming DBSCAN equivalence: any interleaving of inserts, deletes,
expiries, merges, and tiered compactions must leave ``snapshot()``
component-identical to batch ``dbscan`` on exactly the surviving point
set (DESIGN.md §7, §11).

Component identity is the contract the repo's oracle philosophy defines
(validate.py): exact core mask, exact noise set, identical partition of
the core points. Border points may legitimately attach to any adjacent
cluster, so full label arrays are compared via the axiom checker, not
elementwise.
"""
import numpy as np
import pytest

from repro.core import dbscan, dispatch
from repro.core.validate import (check_component_identical, check_dbscan,
                                 same_partition)
from repro.data import pointclouds
from repro.stream import StreamingDBSCAN

SCENARIOS = [
    # (dataset, n, eps, min_pts) — all five pointclouds regimes
    ("ngsim_like", 360, 0.01, 5),
    ("portotaxi_like", 360, 0.02, 5),
    ("road3d_like", 360, 0.01, 5),
    ("hacc_like", 360, 0.05, 5),
    ("blobs", 360, 0.05, 8),
]


def assert_component_identical(stream_res, pts, eps, min_pts, ref=None):
    """snapshot() ≡ batch dbscan: core mask, noise set, core partition."""
    if ref is None:
        ref = dbscan(pts, eps, min_pts, algorithm="fdbscan")
    check_component_identical(stream_res.labels, stream_res.core_mask,
                              ref.labels, ref.core_mask)
    assert stream_res.n_clusters == ref.n_clusters
    return ref


def random_schedule(n, seed):
    """A randomized insert schedule: 1..8 shuffled micro-batches plus a
    merge decision per boundary."""
    rng = np.random.default_rng(seed)
    nb = int(rng.integers(1, 9))
    cuts = (np.sort(rng.choice(np.arange(1, n), size=nb - 1, replace=False))
            if nb > 1 else np.array([], int))
    parts = [p for p in np.split(np.arange(n), cuts)]
    rng.shuffle(parts)
    merges = rng.integers(0, 2, size=len(parts)).astype(bool)
    return parts, merges


@pytest.mark.parametrize("dset,n,eps,minpts", SCENARIOS,
                         ids=[s[0] for s in SCENARIOS])
def test_randomized_schedules_match_batch(dset, n, eps, minpts):
    pts = pointclouds.load(dset, n)
    for seed in (0, 1):
        parts, merges = random_schedule(n, seed)
        h = StreamingDBSCAN(pts[parts[0]], eps, minpts)
        acc = pts[parts[0]]
        for part, force_merge in zip(parts[1:], merges[1:]):
            h.insert(pts[part])
            acc = np.concatenate([acc, pts[part]])
            if force_merge:
                h.merge()
        assert_component_identical(h.snapshot(), acc, eps, minpts)
        # the axiom oracle validates the border assignments too
        snap = h.snapshot()
        check_dbscan(acc, eps, minpts, np.asarray(snap.labels),
                     np.asarray(snap.core_mask))


def test_forced_merge_at_every_boundary():
    """Merges are index-only: forcing one after every insert must not
    perturb the labels at any intermediate state."""
    pts = pointclouds.blobs(360, k=5, seed=3)
    eps, minpts = 0.05, 8
    parts, _ = random_schedule(len(pts), seed=7)
    h = StreamingDBSCAN(pts[parts[0]], eps, minpts)
    acc = pts[parts[0]]
    for part in parts[1:]:
        h.insert(pts[part])
        acc = np.concatenate([acc, pts[part]])
        before = h.snapshot()
        h.merge()
        assert h.n_delta == 0
        after = h.snapshot()
        assert (np.asarray(before.labels) == np.asarray(after.labels)).all()
        assert_component_identical(after, acc, eps, minpts)


@pytest.mark.fast
def test_empty_start_matches_batch():
    pts = pointclouds.blobs(240, k=4, seed=5)
    eps, minpts = 0.05, 6
    h = StreamingDBSCAN(None, eps, minpts)
    for lo in range(0, len(pts), 80):
        h.insert(pts[lo:lo + 80])
    assert h.n_points == len(pts)
    assert_component_identical(h.snapshot(), pts, eps, minpts)


@pytest.mark.fast
def test_border_promotion_regression():
    """An insert that turns an existing *noise* point into core: the
    bidirectional count update must promote it and repair its labels."""
    eps, minpts = 0.1, 4
    # three points in an eps-chain: each sees at most 3 neighbors
    # (incl. self) < min_pts, so the whole set starts as noise
    base = np.array([[0.0, 0.0], [0.07, 0.0], [0.14, 0.0]], np.float32)
    h = StreamingDBSCAN(base, eps, minpts)
    s0 = h.snapshot()
    assert not np.asarray(s0.core_mask).any()
    assert (np.asarray(s0.labels) == -1).all()
    # one new point within eps of all three: the middle ones reach 4
    # neighbors -> core (promotion of existing noise), one cluster forms
    h.insert(np.array([[0.07, 0.05]], np.float32))
    pts = np.concatenate([base, [[0.07, 0.05]]]).astype(np.float32)
    ref = assert_component_identical(h.snapshot(), pts, eps, minpts)
    assert np.asarray(ref.core_mask).any()          # promotion happened
    assert h.snapshot().n_clusters == 1


@pytest.mark.fast
def test_promotion_bridges_two_clusters():
    """Promoted points can merge two previously separate clusters — the
    repair pass must propagate the union beyond the inserted batch."""
    eps, minpts = 0.1, 4
    blob = np.array([[0.0, 0.0], [0.03, 0.0], [-0.03, 0.0], [0.0, 0.03]],
                    np.float32)
    left, right = blob, blob + np.float32(0.6) * np.array([1, 0], np.float32)
    # a sparse chain between the blobs: interior links see only 2 neighbors
    # + self < min_pts, so the chain starts broken (two clusters)
    chain = np.array([[x, 0.0] for x in
                      (0.09, 0.18, 0.27, 0.36, 0.45, 0.54)], np.float32)
    h = StreamingDBSCAN(np.concatenate([left, right, chain]), eps, minpts)
    assert h.snapshot().n_clusters == 2
    # thicken the interior: each link gains a neighbor, promotes to core,
    # and the promoted chain density-connects left and right
    thick = np.array([[x, 0.05] for x in (0.18, 0.27, 0.36, 0.45)],
                     np.float32)
    h.insert(thick)
    pts = np.concatenate([left, right, chain, thick]).astype(np.float32)
    ref = assert_component_identical(h.snapshot(), pts, eps, minpts)
    assert ref.n_clusters == 1


@pytest.mark.fast
def test_query_is_read_only_and_consistent():
    pts = pointclouds.blobs(300, k=3, seed=11)
    eps, minpts = 0.05, 6
    h = StreamingDBSCAN(pts[:200], eps, minpts)
    h.insert(pts[200:])
    before = np.asarray(h.snapshot().labels)
    core = np.asarray(h.snapshot().core_mask)
    # probing resident core points returns their own component
    probe_idx = np.flatnonzero(core)[:8]
    q = h.query(pts[probe_idx])
    assert (q.labels >= 0).all()
    assert q.would_be_core.all()
    # the probe's rep matches the resident point's rep
    assert (q.labels == h._labels[probe_idx]).all()
    # a far-away probe is noise
    far = h.query(np.full((1, 2), 50.0, np.float32))
    assert far.labels[0] == -1 and not far.would_be_core[0]
    # nothing moved
    after = np.asarray(h.snapshot().labels)
    assert (before == after).all()


@pytest.mark.fast
def test_dispatch_stream_plan_and_index_reuse():
    pts = pointclouds.blobs(500, k=4, seed=2)
    dispatch.clear_cache()
    p1 = dispatch.plan(pts, 0.05, 8, algorithm="stream")
    assert p1.backend == "stream"
    assert p1.segs is not None
    # a different (eps, min_pts) shares the same cached eps-independent
    # index object — no rebuild across parameter sweeps
    p2 = dispatch.plan(pts, 0.08, 4, algorithm="stream")
    assert p2.segs is p1.segs and p2.tree is p1.tree
    # ...and so does the plain fdbscan plan
    p3 = dispatch.plan(pts, 0.05, 8, algorithm="fdbscan")
    assert p3.segs is p1.segs
    # one-shot execution through the unified entry point
    res = dbscan(pts, 0.05, 8, algorithm="stream")
    assert res.backend == "stream"
    assert_component_identical(res, pts, 0.05, 8)
    # handle construction reuses the cache too
    h = dispatch.stream_handle(pts, 0.05, 8)
    assert h._main.segs is p1.segs
    assert_component_identical(h.snapshot(), pts, 0.05, 8)


@pytest.mark.fast
def test_auto_merge_policy():
    pts = pointclouds.blobs(800, k=4, seed=9)
    eps, minpts = 0.05, 8
    h = StreamingDBSCAN(pts[:300], eps, minpts, merge_ratio=0.25)
    # push the delta well past max(MERGE_MIN, 0.25 * 300): auto-merge fires
    h.insert(pts[300:700])
    assert h.n_merges == 1 and h.n_delta == 0 and h.n_main == 700
    h.insert(pts[700:])                      # small delta: no merge
    assert h.n_merges == 1 and h.n_delta == 100
    assert_component_identical(h.snapshot(), pts, eps, minpts)


@pytest.mark.fast
def test_snapshot_star_mode():
    pts = pointclouds.blobs(300, k=3, seed=4)
    eps, minpts = 0.05, 8
    h = StreamingDBSCAN(pts[:250], eps, minpts)
    h.insert(pts[250:])
    ref = dbscan(pts, eps, minpts, algorithm="fdbscan", star=True)
    snap = h.snapshot(star=True)
    core = np.asarray(ref.core_mask)
    ls, lb = np.asarray(snap.labels), np.asarray(ref.labels)
    assert (np.asarray(snap.core_mask) == core).all()
    assert (ls[~core] == -1).all() and (lb[~core] == -1).all()
    assert same_partition(ls[core], lb[core])


def test_serve_loop_smoke():
    """The serving loop runs end to end on a tiny stream and validates
    its final snapshot against batch dbscan."""
    from repro.launch import serve
    stats = serve.main(["--dataset", "blobs", "--n", "600",
                        "--warm-frac", "0.5", "--eps", "0.05",
                        "--min-pts", "8", "--batch", "64", "--steps", "8",
                        "--insert-frac", "0.5", "--validate"])
    assert stats["n_points"] >= 300
    assert stats["n_queried"] > 0


# --------------------------------------------------------------------- #
# fully dynamic: deletes, expiry, sliding windows (DESIGN.md §11)       #
# --------------------------------------------------------------------- #

def assert_matches_batch_on_survivors(h, all_pts, alive, eps, minpts):
    """snapshot() over the active set ≡ batch dbscan on exactly the
    surviving points, in insertion order."""
    alive = np.asarray(sorted(alive))
    assert (h.active_gids == alive).all()
    assert h.n_active == len(alive)
    surv = all_pts[alive]
    snap = h.snapshot()
    ref = dbscan(surv, eps, minpts, algorithm="fdbscan")
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)
    check_dbscan(surv, eps, minpts, np.asarray(snap.labels),
                 np.asarray(snap.core_mask))


def dynamic_schedule(n, seed):
    """A randomized interleaving of insert / delete / expire / merge /
    compact steps over an n-point stream.  Inserts arrive in shuffled
    micro-batch sizes; delete picks a random subset of the current
    survivors; expire advances the insert-order watermark."""
    rng = np.random.default_rng(seed)
    nb = int(rng.integers(4, 8))
    cuts = np.sort(rng.choice(np.arange(1, n), size=nb - 1, replace=False))
    batches = np.split(np.arange(n), cuts)
    ops = []
    for b in batches:
        ops.append(("insert", b))
        r = rng.random()
        if r < 0.45:
            ops.append(("delete", rng))
        elif r < 0.65:
            ops.append(("expire", rng))
        r = rng.random()
        if r < 0.25:
            ops.append(("merge", None))
        elif r < 0.5:
            ops.append(("compact", None))
    return ops


@pytest.mark.parametrize("dset,n,eps,minpts", SCENARIOS,
                         ids=[s[0] for s in SCENARIOS])
def test_randomized_dynamic_interleavings(dset, n, eps, minpts):
    """The hard contract: after ANY interleaving of inserts, deletes,
    expiries, merges, and tiered compactions, the snapshot is
    component-identical to batch dbscan on exactly the survivors.  A
    small buffer_max forces tier seals and cascade merges inside the
    schedule, so compaction boundaries are crossed mid-stream."""
    pts = pointclouds.load(dset, n)
    for seed in (0, 1):
        ops = dynamic_schedule(n, seed)
        first = ops[0][1]
        h = StreamingDBSCAN(pts[first], eps, minpts,
                            buffer_max=64, growth=4)
        alive = set(int(g) for g in range(len(first)))
        watermark = 0
        for kind, arg in ops[1:]:
            if kind == "insert":
                h.insert(pts[arg])
                alive |= set(range(h.n_points - len(arg), h.n_points))
            elif kind == "delete" and alive:
                srt = sorted(alive)
                take = arg.choice(len(srt),
                                  size=max(1, len(srt) // 8),
                                  replace=False)
                gids = np.asarray(srt)[take]
                assert h.delete(gids) == len(gids)
                alive -= set(int(g) for g in gids)
            elif kind == "expire":
                watermark = min(h.n_points,
                                watermark + int(arg.integers(1, n // 6)))
                h.expire(watermark)
                alive -= set(range(watermark))
            elif kind == "merge":
                h.merge()
                assert h.n_delta == 0
            elif kind == "compact":
                h.compact()
        assert_matches_batch_on_survivors(h, pts, alive, eps, minpts)


def test_delete_is_idempotent_and_checked():
    pts = pointclouds.blobs(200, k=3, seed=13)
    h = StreamingDBSCAN(pts, 0.05, 6)
    assert h.delete(np.array([5, 9, 5, 9])) == 2   # dups collapse
    assert h.delete(np.array([5, 9])) == 0         # idempotent
    assert h.n_active == 198 and h.n_tombstoned == 2
    with pytest.raises(ValueError):
        h.delete(np.array([500]))                  # out of range
    with pytest.raises(ValueError):
        h.expire(1000)                             # past the watermark
    assert h.expire(0) == 0                        # no-op watermark


@pytest.mark.fast
def test_delete_bridge_core_splits_cluster():
    """Demotion hazard #1: deleting a bridge core must split the cluster
    it merged — min-label propagation alone can never split, so the
    repair pass has to reset the affected component (DESIGN.md §11)."""
    eps, minpts = 0.1, 4
    blob = np.array([[0.0, 0.0], [0.03, 0.0], [-0.03, 0.0], [0.0, 0.03]],
                    np.float32)
    left = blob
    right = blob + np.array([0.18, 0.0], np.float32)
    bridge = np.array([[0.09, 0.0]], np.float32)
    pts = np.concatenate([left, right, bridge]).astype(np.float32)
    h = StreamingDBSCAN(pts, eps, minpts)
    assert h.snapshot().n_clusters == 1            # bridge joins the blobs
    h.delete(np.array([len(pts) - 1]))             # kill the bridge core
    alive = set(range(len(pts) - 1))
    assert_matches_batch_on_survivors(h, pts, alive, eps, minpts)
    assert h.snapshot().n_clusters == 2            # the cluster split


@pytest.mark.fast
def test_delete_neighbor_demotes_core_to_noise():
    """Demotion hazard #2: deleting a *neighbor* of a still-present core
    drops its count below min_pts; points that were reachable only
    through it must relabel to noise while unrelated clusters stand."""
    eps, minpts = 0.1, 4
    # C at the origin with exactly 3 satellites: count 4 = min_pts, so C
    # is core and the satellites are its borders (each sees only C+self)
    fragile = np.array([[0.0, 0.0], [0.08, 0.0], [-0.08, 0.0],
                        [0.0, 0.08]], np.float32)
    sturdy = np.array([[1.0, 1.0], [1.03, 1.0], [0.97, 1.0], [1.0, 1.03]],
                      np.float32)
    pts = np.concatenate([fragile, sturdy]).astype(np.float32)
    h = StreamingDBSCAN(pts, eps, minpts)
    s0 = h.snapshot()
    assert s0.n_clusters == 2
    assert np.asarray(s0.core_mask)[0]             # C is core
    h.delete(np.array([3]))                        # kill one satellite
    alive = set(range(len(pts))) - {3}
    assert_matches_batch_on_survivors(h, pts, alive, eps, minpts)
    s1 = h.snapshot()
    assert s1.n_clusters == 1                      # only the sturdy blob
    labels = np.asarray(s1.labels)
    assert (labels[:3] == -1).all()                # demoted C + ex-borders
    assert not np.asarray(s1.core_mask)[:3].any()


def test_sliding_window_matches_batch():
    """window=W: every insert auto-expires all but the W most recent
    points; the handle must track batch dbscan over exactly that tail,
    including at bootstrap when the seed set already overflows W."""
    pts = pointclouds.blobs(600, k=4, seed=17)
    eps, minpts = 0.05, 6
    h = StreamingDBSCAN(pts[:300], eps, minpts, window=200, buffer_max=64)
    assert h.n_active == 200                       # bootstrap overflow
    assert_matches_batch_on_survivors(h, pts, set(range(100, 300)),
                                      eps, minpts)
    for lo in range(300, 600, 50):
        h.insert(pts[lo:lo + 50])
        assert h.n_active == 200
    assert_matches_batch_on_survivors(h, pts, set(range(400, 600)),
                                      eps, minpts)
    # dispatch plumbs the window through to the handle
    h2 = dispatch.stream_handle(pts[:300], eps, minpts, window=120)
    assert h2.window == 120 and h2.n_active == 120


@pytest.mark.fast
def test_counters_and_compaction_stats():
    pts = pointclouds.blobs(400, k=3, seed=19)
    h = StreamingDBSCAN(pts[:200], 0.05, 6, buffer_max=64)
    assert h.n_active == 200 and h.n_tombstoned == 0
    h.delete(np.arange(10, 40))
    assert h.n_active == 170 and h.n_tombstoned == 30
    assert h.n_deletes == 1
    h.expire(10)
    assert h.n_active == 160 and h.n_tombstoned == 40
    h.insert(pts[200:])
    assert h.n_active == 360 and h.n_points == 400
    before = h.n_compactions
    h.compact()
    assert h.n_compactions >= before
    # full merge folds everything into one clean tier over the survivors
    h.merge()
    assert h.n_tiers == 1 and h.n_delta == 0
    assert h.n_main == h.n_active == 360
    alive = set(range(40, 400))
    assert_matches_batch_on_survivors(h, pts, alive, 0.05, 6)
