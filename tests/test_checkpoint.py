"""Checkpointing + fault-tolerance behaviour (single device)."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault_tolerance import (HeartbeatBoard,
                                               StragglerMonitor,
                                               run_resilient)


def _tree(x=0.0):
    return {"a": jnp.full((4, 4), x, jnp.float32),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)},
            "scalar": jnp.asarray(x)}


def test_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(1, _tree(1.0))
        ckpt.save(7, _tree(7.0))
        assert ckpt.latest_step() == 7
        restored, step = ckpt.restore(_tree())
        assert step == 7
        assert float(restored["a"][0, 0]) == 7.0
        restored, step = ckpt.restore(_tree(), step=1)
        assert float(restored["a"][0, 0]) == 1.0


def test_retention_gc():
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            ckpt.save(s, _tree(float(s)))
        assert ckpt.all_steps() == [3, 4]


def test_async_save():
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(3, _tree(3.0), blocking=False)
        ckpt.wait()
        assert ckpt.latest_step() == 3


def test_atomicity_no_partial_visible():
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(5, _tree(5.0))
        # a stale tmp dir from a crashed writer must be invisible
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ckpt.latest_step() == 5


def test_run_resilient_restores_after_failure():
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 5 and calls["n"] < 8:  # fail once at step 5
            raise RuntimeError("injected")
        return {"w": state["w"] + 1.0}, {"loss": float(step)}

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        state, step, report = run_resilient(
            step_fn, {"w": jnp.zeros(())}, 10, ckpt=ckpt, ckpt_every=2)
        assert step == 10
        assert report.failures == 1
        assert report.restores >= 1
        # w counts exactly the committed steps (restart replays from ckpt)
        assert float(state["w"]) == 10.0


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=20, threshold=5.0)
    for i in range(15):
        assert not mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.record(15, 1.5)  # 15x median
    assert len(mon.flagged) == 1


def test_heartbeat_dead_worker():
    hb = HeartbeatBoard(timeout=0.05)
    hb.beat("w0")
    hb.beat("w1")
    time.sleep(0.08)
    hb.beat("w1")
    assert hb.dead_workers() == ["w0"]


def test_trainer_cli_resumes(tmp_path):
    """Smoke the actual CLI path incl. injected failure + resume."""
    from repro.launch.train import main
    loss = main(["--arch", "qwen1.5-4b", "--reduced", "--steps", "12",
                 "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
                 "--ckpt-every", "5", "--fail-at-step", "7",
                 "--log-every", "100"])
    assert np.isfinite(loss)
    assert os.path.exists(tmp_path / "step_00000012")
