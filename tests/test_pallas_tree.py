"""The Pallas traversal kernel backend (kernels/traverse.py, DESIGN.md §9).

Every test pins the kernel (interpret mode on CPU) against the vmapped
reference engine on identical inputs — acc/hits/evals must be *equal*,
not close: both engines trace the same ``traversal.make_step`` op
sequence, so any drift is a bug in the lane tiling, the padding, or the
visitor inlining, never float noise.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dbscan, dispatch, grid, lbvh, traversal
from repro.data import pointclouds
from repro.kernels import traverse as kt

EPS, MINPTS = 0.05, 8


@pytest.fixture(scope="module")
def index():
    pts = jnp.asarray(pointclouds.load("portotaxi_like", 600))
    segs = grid.build_segments_densebox(pts, EPS, MINPTS)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return segs, tree


def _assert_trace_equal(ref, pal, iters_too=False):
    np.testing.assert_array_equal(np.asarray(ref.acc), np.asarray(pal.acc))
    np.testing.assert_array_equal(np.asarray(ref.hits), np.asarray(pal.hits))
    np.testing.assert_array_equal(np.asarray(ref.evals),
                                  np.asarray(pal.evals))
    if iters_too:
        np.testing.assert_array_equal(np.asarray(ref.iters),
                                      np.asarray(pal.iters))


def test_count_visitor_matches_engine(index):
    segs, tree = index
    pred = traversal.intersects(traversal.sphere(EPS))
    cb = traversal.CountVisitor(cap=MINPTS)
    _assert_trace_equal(traversal.traverse(tree, segs, pred, cb),
                        kt.traverse(tree, segs, pred, cb))


def test_iters_counter_matches_engine_at_same_unroll(index):
    # at matching unroll the per-lane loop-trip counters are identical —
    # the counter surface benchmarks/run.py --check gates
    segs, tree = index
    pred = traversal.intersects(traversal.sphere(EPS))
    cb = traversal.CountVisitor(cap=MINPTS)
    _assert_trace_equal(traversal.traverse(tree, segs, pred, cb, unroll=4),
                        kt.traverse(tree, segs, pred, cb, unroll=4),
                        iters_too=True)


def test_minlabel_with_node_mask_and_compacted_ids(index):
    segs, tree = index
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)
    # compacted active-lane batch with -1 padding (the frontier shape)
    ids = np.full(256, -1, np.int32)
    ids[:200] = np.random.default_rng(0).choice(n, 200, replace=False)
    ids = jnp.asarray(ids)
    nm = lbvh.propagate_leaf_flags(
        tree, jnp.asarray(np.arange(segs.n_segments) % 3 != 0))
    pred = traversal.intersects(traversal.sphere(EPS), ids=ids)
    cb = traversal.MinLabelVisitor(vals, mask)
    _assert_trace_equal(
        traversal.traverse(tree, segs, pred, cb, node_mask=nm),
        kt.traverse(tree, segs, pred, cb, node_mask=nm))


def test_dual_mask_wide_lanes(index):
    # the split first sweep: per-lane choice of gather mask AND node mask
    segs, tree = index
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    narrow = jnp.asarray(np.arange(n) % 4 == 0)
    wide_m = jnp.ones(n, bool)
    nm_n = lbvh.propagate_leaf_flags(
        tree, jnp.asarray(np.arange(segs.n_segments) % 2 == 0))
    nm_w = jnp.ones(2 * segs.n_segments - 1, bool)
    lanes_wide = jnp.asarray(np.arange(n) % 5 == 0)
    pred = traversal.intersects(traversal.sphere(EPS))
    cb = traversal.MinLabelVisitor(vals, narrow, mask_wide=wide_m)
    kw = dict(node_mask=nm_n, node_mask_wide=nm_w, wide_lanes=lanes_wide)
    _assert_trace_equal(traversal.traverse(tree, segs, pred, cb, **kw),
                        kt.traverse(tree, segs, pred, cb, **kw))


def test_minlabel_float_vals(index):
    # the gathered values' dtype rides the carry: float32 vals must flow
    # through the kernel's acc output unchanged
    segs, tree = index
    n = segs.n_points
    vals = jnp.asarray(np.random.default_rng(2).uniform(0, 1, n)
                       .astype(np.float32))
    cb = traversal.MinLabelVisitor(vals, jnp.ones(n, bool))
    pred = traversal.intersects(traversal.sphere(EPS))
    ref = traversal.traverse(tree, segs, pred, cb)
    pal = kt.traverse(tree, segs, pred, cb)
    assert pal.acc.dtype == ref.acc.dtype == jnp.float32
    _assert_trace_equal(ref, pal)


def test_countminlabel_fused_pass(index):
    segs, tree = index
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    cb = traversal.CountMinLabelVisitor(vals, jnp.ones(n, bool),
                                        cap=MINPTS - 1)
    pred = traversal.intersects(traversal.sphere(EPS))
    _assert_trace_equal(traversal.traverse(tree, segs, pred, cb),
                        kt.traverse(tree, segs, pred, cb))


def test_external_queries_and_seeded_carry(index):
    # external predicate batch + chained carry (the stream/halo shape)
    segs, tree = index
    rng = np.random.default_rng(1)
    qpts = jnp.asarray(rng.uniform(0, 1, (137, 2)).astype(np.float32))
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    cb = traversal.MinLabelVisitor(vals, jnp.ones(n, bool))
    pred = traversal.intersects(traversal.sphere(3 * EPS), pts=qpts)
    ref1 = traversal.traverse(tree, segs, pred, cb)
    pal1 = kt.traverse(tree, segs, pred, cb)
    _assert_trace_equal(ref1, pal1)
    # chain: seed the second walk with the first walk's carry
    ref2 = traversal.traverse(tree, segs, pred, cb, carry=ref1.carry)
    pal2 = kt.traverse(tree, segs, pred, cb, carry=pal1.carry)
    _assert_trace_equal(ref2, pal2)


def test_use_range_mask(index):
    segs, tree = index
    pred = traversal.intersects(traversal.sphere(EPS))
    cb = traversal.CountVisitor(cap=traversal.INT_MAX)
    _assert_trace_equal(
        traversal.traverse(tree, segs, pred, cb, use_range_mask=True),
        kt.traverse(tree, segs, pred, cb, use_range_mask=True))


def test_nearest_predicate_falls_back_to_engine(index):
    # k-NN is not fusible: the kernel path must hand off transparently
    segs, tree = index
    pred = traversal.nearest(4)
    cb = traversal.KNNVisitor(4)
    ref = traversal.traverse(tree, segs, pred, cb)
    pal = kt.traverse(tree, segs, pred, cb)
    assert not kt.fusible(pred, cb)
    np.testing.assert_array_equal(np.asarray(ref.carry.ids),
                                  np.asarray(pal.carry.ids))
    np.testing.assert_array_equal(np.asarray(ref.carry.d2),
                                  np.asarray(pal.carry.d2))


def test_custom_visitor_falls_back_to_engine(index):
    segs, tree = index

    class SumD2(traversal.Visitor):
        def init_carry(self, ids, external, segs):
            z = jnp.zeros(ids.shape, jnp.int32)
            return traversal.AccHits(acc=z, hits=z)

        def visit(self, carry, j, d2, hit, ctx):
            return traversal.AccHits(
                acc=carry.acc + jnp.where(hit, j, 0),
                hits=carry.hits + jnp.where(hit, 1, 0)), hit

    import jax
    jax.tree_util.register_pytree_node(
        SumD2, lambda v: ((), None), lambda aux, ch: SumD2())
    pred = traversal.intersects(traversal.sphere(EPS))
    assert not kt.fusible(pred, SumD2())
    _assert_trace_equal(traversal.traverse(tree, segs, pred, SumD2()),
                        kt.traverse(tree, segs, pred, SumD2()))


def test_dispatch_explicit_backend():
    pts = pointclouds.load("blobs", 500)
    p = dispatch.plan(pts, EPS, MINPTS, algorithm="pallas-tree")
    assert p.backend == "pallas-tree"
    assert p.tree is not None           # rides the cached fdbscan index
    a = dbscan(pts, EPS, MINPTS, algorithm="fdbscan")
    b = dbscan(pts, EPS, MINPTS, algorithm="pallas-tree")
    assert b.backend == "pallas-tree"
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    np.testing.assert_array_equal(np.asarray(a.core_mask),
                                  np.asarray(b.core_mask))
    assert (a.n_clusters, a.n_sweeps) == (b.n_clusters, b.n_sweeps)


def test_dispatch_auto_upgrades_on_accelerator(monkeypatch):
    # auto dispatch picks the kernel engine whenever jit runs on TPU;
    # pin the probe (CPU CI) and check only the *plan* — the kernel still
    # runs in interpret mode here
    pts = pointclouds.load("blobs", 2000)   # > TILED_MAX_POINTS
    dispatch.clear_cache()
    ref = dispatch.dbscan(pts, EPS, MINPTS, algorithm="auto")  # CPU: tree
    assert ref.backend != "pallas-tree"
    monkeypatch.setattr(dispatch, "_accel", lambda: True)
    dispatch.clear_cache()
    p = dispatch.plan(pts, EPS, MINPTS, algorithm="auto")
    assert p.backend == "pallas-tree"
    assert "pallas" in p.stats["reason"]
    # same auto decision, same index, upgraded engine: identical labels
    res = dispatch.dbscan(pts, EPS, MINPTS, query_plan=p)
    assert res.backend == "pallas-tree"
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels))
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  np.asarray(ref.core_mask))


def test_auto_upgrade_respects_vmem_budget(monkeypatch):
    # past the kernel's VMEM residency budget auto dispatch must keep the
    # reference engine (a compile failure is worse than a slower walk);
    # an explicit pallas-tree request still bypasses the guard
    monkeypatch.setattr(dispatch, "_accel", lambda: True)
    monkeypatch.setattr(dispatch, "PALLAS_MAX_INDEX_BYTES", 1024)
    dispatch.clear_cache()
    pts = pointclouds.load("blobs", 2000)
    p = dispatch.plan(pts, EPS, MINPTS, algorithm="auto")
    assert p.backend != "pallas-tree"
    p2 = dispatch.plan(pts, EPS, MINPTS, algorithm="pallas-tree")
    assert p2.backend == "pallas-tree"
    dispatch.clear_cache()


def test_dispatch_rejects_mesh():
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="mesh"):
        dispatch.plan(pointclouds.load("blobs", 300), EPS, MINPTS,
                      algorithm="pallas-tree", mesh=mesh)


def test_lane_tile_boundaries(index):
    # lane counts straddling the tile size: padding lanes must stay inert
    segs, tree = index
    cb = traversal.CountVisitor(cap=traversal.INT_MAX)
    for k in (1, kt.LANE_TILE - 1, kt.LANE_TILE, kt.LANE_TILE + 1):
        ids = jnp.arange(k, dtype=jnp.int32)
        pred = traversal.intersects(traversal.sphere(EPS), ids=ids)
        _assert_trace_equal(traversal.traverse(tree, segs, pred, cb),
                            kt.traverse(tree, segs, pred, cb))
