"""Divergence-aware lane reordering (DESIGN.md §9): the permutation
contract, pinned bit-for-bit.

The Pallas kernel may permute lanes before the walk — Morton order so a
tile visits correlated subtrees, or measured-depth order from a prior
pass — and must apply the inverse permutation to every per-lane output
on exit. The contract under test: *any* query permutation composed with
*any* reorder policy is bit-identical to the unpermuted reference
engine, for every batch shape the pipeline produces (resident full
batches, frontier-compacted id batches with dead-lane padding,
external/halo point batches) and every fusible visitor. The end-to-end
half pins the tuned pipeline (heuristic mode: reorder on, calibrated
depth oracle on the second run) against the golden fixtures.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dispatch, grid, lbvh, traversal
from repro.data import pointclouds
from repro.kernels import traverse as kt

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = np.load(os.path.join(HERE, "golden", "golden.npz"))

# (eps, min_pts) per scenario dataset; small n — the kernel runs in
# interpret mode on CPU and every test below walks the tree many times
SCENARIOS = {
    "ngsim_like": (0.02, 5),
    "portotaxi_like": (0.04, 5),
    "road3d_like": (0.03, 5),
    "hacc_like": (0.08, 5),
    "blobs": (0.08, 8),
}
N = 300

# must match tests/golden/make_golden.py (same as test_golden.SCENARIOS)
GOLDEN_SCENARIOS = [
    ("ngsim_like", 800, 0.01, 5),
    ("portotaxi_like", 800, 0.02, 5),
    ("road3d_like", 800, 0.01, 5),
    ("hacc_like", 800, 0.05, 5),
    ("blobs", 800, 0.05, 8),
]

VISITORS = ["count", "minlabel", "countminlabel"]
BATCHES = ["resident", "compacted", "external"]
POLICIES = ["morton", "depth"]


@pytest.fixture(scope="module", params=sorted(SCENARIOS))
def case(request):
    dset = request.param
    eps, mp = SCENARIOS[dset]
    pts = jnp.asarray(pointclouds.load(dset, N))
    segs = grid.build_segments_fdbscan(pts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    # depth oracle exactly as the tuner calibrates it: per-query loop
    # trips of a full pass over the same index, indexed by sorted id
    rank = traversal.traverse(
        tree, segs, traversal.intersects(traversal.sphere(eps)),
        traversal.CountVisitor(cap=traversal.INT_MAX)).iters
    return segs, tree, eps, mp, rank


def _visitor(name, segs, mp):
    n = segs.n_points
    vals = jnp.arange(n, dtype=jnp.int32)
    mask = jnp.asarray(np.arange(n) % 2 == 0)
    if name == "count":
        return traversal.CountVisitor(cap=mp)
    if name == "minlabel":
        return traversal.MinLabelVisitor(vals, mask)
    return traversal.CountMinLabelVisitor(vals, mask, cap=mp - 1)


def _batch(name, segs, tree, eps):
    """(predicate, extra-kwargs) for one batch shape."""
    rng = np.random.default_rng(3)
    if name == "resident":
        return traversal.intersects(traversal.sphere(eps)), {}
    if name == "compacted":
        # frontier shape: compacted ids with -1 dead-lane padding plus a
        # descent-pruning node mask (the sweep's frontier restriction)
        n = segs.n_points
        ids = np.full(192, -1, np.int32)
        ids[:160] = rng.choice(n, 160, replace=False)
        nm = lbvh.propagate_leaf_flags(
            tree, jnp.asarray(np.arange(segs.n_segments) % 3 != 0))
        return (traversal.intersects(traversal.sphere(eps),
                                     ids=jnp.asarray(ids)),
                {"node_mask": nm})
    # external/halo: queries not resident in the tree (stream/sharded)
    d = segs.pts.shape[1]
    qpts = jnp.asarray(rng.uniform(0, 1, (117, d)).astype(np.float32))
    return traversal.intersects(traversal.sphere(2 * eps), pts=qpts), {}


def _assert_equal(ref, pal, iters_too=False):
    np.testing.assert_array_equal(np.asarray(ref.acc), np.asarray(pal.acc))
    np.testing.assert_array_equal(np.asarray(ref.hits), np.asarray(pal.hits))
    np.testing.assert_array_equal(np.asarray(ref.evals),
                                  np.asarray(pal.evals))
    if iters_too:
        np.testing.assert_array_equal(np.asarray(ref.iters),
                                      np.asarray(pal.iters))


@pytest.mark.parametrize("visitor", VISITORS)
@pytest.mark.parametrize("batch", BATCHES)
def test_reorder_bit_identical(case, batch, visitor):
    # every policy vs the reference engine (acc/hits/evals exact) AND vs
    # the unreordered kernel with per-lane iters exact: reordering only
    # changes the schedule, never any lane-intrinsic output
    segs, tree, eps, mp, rank = case
    pred, kw = _batch(batch, segs, tree, eps)
    cb = _visitor(visitor, segs, mp)
    ref = traversal.traverse(tree, segs, pred, cb, **kw)
    base = kt.traverse(tree, segs, pred, cb, reorder="none", **kw)
    _assert_equal(ref, base)
    for policy in POLICIES:
        pal = kt.traverse(tree, segs, pred, cb, reorder=policy,
                          depth_rank=rank, **kw)
        _assert_equal(ref, pal)
        _assert_equal(base, pal, iters_too=True)


def test_depth_without_rank_is_identity_for_resident(case):
    # uncalibrated depth reorder (first run of a plan): resident batches
    # fall back to identity, external batches to Morton — both exact
    segs, tree, eps, mp, rank = case
    cb = traversal.CountVisitor(cap=mp)
    for batch in ("resident", "external"):
        pred, kw = _batch(batch, segs, tree, eps)
        ref = traversal.traverse(tree, segs, pred, cb, **kw)
        pal = kt.traverse(tree, segs, pred, cb, reorder="depth",
                          depth_rank=None, **kw)
        _assert_equal(ref, pal)


@pytest.mark.parametrize("policy", ["none"] + POLICIES)
def test_query_permutation_composes(case, policy):
    # permuting the lane batch commutes with the reorder: lane i of the
    # output always belongs to query i of the (permuted) batch
    segs, tree, eps, mp, rank = case
    n = segs.n_points
    rng = np.random.default_rng(11)
    live = rng.choice(n, 160, replace=False).astype(np.int32)
    cb = traversal.MinLabelVisitor(jnp.arange(n, dtype=jnp.int32),
                                   jnp.asarray(np.arange(n) % 2 == 0))
    ref = traversal.traverse(
        tree, segs,
        traversal.intersects(traversal.sphere(eps), ids=jnp.asarray(live)),
        cb)
    for trial in range(2):
        perm = rng.permutation(live.shape[0])
        pal = kt.traverse(
            tree, segs,
            traversal.intersects(traversal.sphere(eps),
                                 ids=jnp.asarray(live[perm])),
            cb, reorder=policy, depth_rank=rank)
        np.testing.assert_array_equal(np.asarray(pal.acc),
                                      np.asarray(ref.acc)[perm])
        np.testing.assert_array_equal(np.asarray(pal.hits),
                                      np.asarray(ref.hits)[perm])
        np.testing.assert_array_equal(np.asarray(pal.evals),
                                      np.asarray(ref.evals)[perm])


def test_external_permutation_composes(case):
    # same composition law for external/halo batches (Morton key path)
    segs, tree, eps, mp, rank = case
    d = segs.pts.shape[1]
    rng = np.random.default_rng(5)
    qpts = rng.uniform(0, 1, (117, d)).astype(np.float32)
    cb = traversal.CountVisitor(cap=traversal.INT_MAX)
    ref = kt.traverse(tree, segs,
                      traversal.intersects(traversal.sphere(2 * eps),
                                           pts=jnp.asarray(qpts)),
                      cb, reorder="none")
    perm = rng.permutation(qpts.shape[0])
    pal = kt.traverse(tree, segs,
                      traversal.intersects(traversal.sphere(2 * eps),
                                           pts=jnp.asarray(qpts[perm])),
                      cb, reorder="morton")
    np.testing.assert_array_equal(np.asarray(pal.acc),
                                  np.asarray(ref.acc)[perm])
    np.testing.assert_array_equal(np.asarray(pal.hits),
                                  np.asarray(ref.hits)[perm])


def test_bad_policy_rejected(case):
    segs, tree, eps, mp, _ = case
    with pytest.raises(ValueError, match="reorder"):
        kt.traverse(tree, segs,
                    traversal.intersects(traversal.sphere(eps)),
                    traversal.CountVisitor(cap=mp), reorder="zorder")


# --------------------------------------------------------------------- #
# end-to-end: the tuned pipeline (reorder on) vs the golden fixtures    #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("dset", [c[0] for c in GOLDEN_SCENARIOS])
def test_e2e_tuned_reorder_golden(dset, monkeypatch):
    # heuristic mode turns reordering on (depth, Morton fallback) and the
    # small-frontier reference fallback; run the same plan twice so both
    # the uncalibrated first run and the calibrated second run (depth
    # oracle live) are pinned against the goldens
    monkeypatch.setenv("REPRO_TUNE", "heuristic")
    dset, n, eps, mp = next(c for c in GOLDEN_SCENARIOS if c[0] == dset)
    pts = pointclouds.load(dset, n)
    dispatch.clear_cache()
    try:
        p = dispatch.plan(pts, eps, mp, algorithm="pallas-tree")
        assert p.tune is not None
        assert p.tune.config.source == "heuristic"
        assert p.stats["tuned_config"]["source"] == "heuristic"
        for run in range(2):
            res = dispatch.dbscan(pts, eps, mp, query_plan=p)
            np.testing.assert_array_equal(np.asarray(res.labels),
                                          GOLDEN[f"{dset}/fdbscan/labels"])
            np.testing.assert_array_equal(np.asarray(res.core_mask),
                                          GOLDEN[f"{dset}/fdbscan/core"])
            assert res.n_clusters == int(
                GOLDEN[f"{dset}/fdbscan/n_clusters"])
            assert res.n_sweeps == int(GOLDEN[f"{dset}/fdbscan/n_sweeps"])
        assert p.tune.depth_rank is not None    # calibration happened
    finally:
        dispatch.clear_cache()
