"""Autotuner conformance (repro.core.tune, DESIGN.md §9).

Tuning changes only the *schedule* — engine choice, lane tile, unroll,
lane order — so every point of the config space must be bit-identical.
The grid test sweeps the full (LANE_TILE, K) candidate grid through the
end-to-end pipeline against the golden fixtures; the pin tests check
that ``REPRO_TUNE=off`` reproduces today's (128, 4) kernel behavior
*exactly* (down to jit-cache function identity); the unit tests cover
mode parsing, the stats-bucketed search cache key, and the per-phase
engine fallbacks.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dispatch, fdbscan, grid, lbvh, traversal
from repro.core import tune as tune_mod
from repro.data import pointclouds
from repro.kernels import traverse as kt

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = np.load(os.path.join(HERE, "golden", "golden.npz"))

# the portotaxi golden scenario (tests/golden/make_golden.py)
DSET, N, EPS, MINPTS = "portotaxi_like", 800, 0.02, 5


@pytest.fixture(scope="module")
def index():
    pts = jnp.asarray(pointclouds.load(DSET, N))
    segs = grid.build_segments_fdbscan(pts)
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return segs, tree


def _forced(lane_tile, unroll):
    """A TuneState running every phase at one (lane_tile, unroll)."""
    fp = tune_mod.PhaseConfig("pallas", lane_tile, unroll, "morton")
    sw = tune_mod.PhaseConfig("pallas", lane_tile, unroll, "depth")
    bd = tune_mod.PhaseConfig("pallas", lane_tile, unroll, "none")
    return tune_mod.TuneState(tune_mod.TunedConfig(
        first_pass=fp, sweep=sw, border=bd,
        min_lanes=0, border_min_frac=0.0, source="grid"))


@pytest.mark.parametrize("unroll", tune_mod.TUNE_UNROLLS)
@pytest.mark.parametrize("lane_tile", tune_mod.TUNE_LANE_TILES)
def test_config_grid_bit_identical(index, lane_tile, unroll):
    # the full candidate grid, end to end: labels, core mask, cluster and
    # sweep counts byte-equal to the goldens at every (LANE_TILE, K) —
    # with reordering on (morton first pass, calibrated depth sweeps)
    segs, tree = index
    res = fdbscan.cluster_from_index(segs, tree, EPS, MINPTS,
                                     backend="pallas-tree",
                                     tune=_forced(lane_tile, unroll))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  GOLDEN[f"{DSET}/fdbscan/labels"])
    np.testing.assert_array_equal(np.asarray(res.core_mask),
                                  GOLDEN[f"{DSET}/fdbscan/core"])
    assert res.n_clusters == int(GOLDEN[f"{DSET}/fdbscan/n_clusters"])
    assert res.n_sweeps == int(GOLDEN[f"{DSET}/fdbscan/n_sweeps"])


@pytest.mark.parametrize("lane_tile", tune_mod.TUNE_LANE_TILES)
def test_config_grid_counts_bit_identical(index, lane_tile):
    # kernel-level half: exact uncapped neighbor counts at every lane
    # tile (unroll sweeps ride the e2e grid test above)
    segs, tree = index
    pred = traversal.intersects(traversal.sphere(EPS))
    cb = traversal.CountVisitor(cap=traversal.INT_MAX)
    tr = kt.traverse(tree, segs, pred, cb, lane_tile=lane_tile,
                     reorder="morton")
    counts = np.zeros(N, np.int64)
    counts[np.asarray(segs.order)] = np.asarray(tr.acc)
    np.testing.assert_array_equal(counts, GOLDEN[f"{DSET}/counts"])


# --------------------------------------------------------------------- #
# REPRO_TUNE=off: the deterministic pin                                 #
# --------------------------------------------------------------------- #

def test_off_pin_is_todays_kernel_identity():
    # the pinned default config must resolve to the *same function
    # object* as the bare kernel entry — same jit static-arg identity,
    # same compile cache entries as before the tuner existed
    assert tune_mod.PINNED.first_pass == tune_mod.PhaseConfig(
        "pallas", 128, 4, "none")
    assert tune_mod.engine_fn(tune_mod.PhaseConfig()) is kt.traverse
    assert tune_mod.engine_fn(
        tune_mod.PhaseConfig("reference")) is traversal.traverse


def test_off_pin_e2e_golden(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "off")
    pts = pointclouds.load(DSET, N)
    dispatch.clear_cache()
    try:
        p = dispatch.plan(pts, EPS, MINPTS, algorithm="pallas-tree")
        assert p.tune is not None
        assert p.tune.config == tune_mod.PINNED
        assert p.stats["tuned_config"]["source"] == "pinned"
        res = dispatch.dbscan(pts, EPS, MINPTS, query_plan=p)
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      GOLDEN[f"{DSET}/fdbscan/labels"])
        np.testing.assert_array_equal(np.asarray(res.core_mask),
                                      GOLDEN[f"{DSET}/fdbscan/core"])
        assert res.n_sweeps == int(GOLDEN[f"{DSET}/fdbscan/n_sweeps"])
        # pinned mode never calibrates: no oracle, no reordering, ever
        assert p.tune.depth_rank is None
    finally:
        dispatch.clear_cache()


def test_mode_parsing(monkeypatch):
    for raw, want in [("off", "off"), ("0", "off"), ("none", "off"),
                      ("pinned", "off"), ("OFF", "off"),
                      ("search", "search"), ("heuristic", "heuristic"),
                      ("banana", "heuristic")]:
        monkeypatch.setenv("REPRO_TUNE", raw)
        assert tune_mod.mode() == want
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    assert tune_mod.mode() == "heuristic"


# --------------------------------------------------------------------- #
# unit: stats key, budget cap, per-phase fallbacks                      #
# --------------------------------------------------------------------- #

def test_stats_key_buckets(index):
    segs, _ = index
    k1 = tune_mod.stats_key(segs, EPS, MINPTS)
    assert k1 == tune_mod.stats_key(segs, EPS, MINPTS)
    assert all(isinstance(v, int) for v in k1)
    assert k1 != tune_mod.stats_key(segs, EPS, MINPTS + 1)
    small = jnp.asarray(pointclouds.load(DSET, 100))
    segs_small = grid.build_segments_fdbscan(small)
    assert tune_mod.stats_key(segs_small, EPS, MINPTS) != k1


def test_lane_tiles_within_budget():
    assert tune_mod.lane_tiles_within_budget(0) == tune_mod.TUNE_LANE_TILES
    # an index filling the whole budget still yields one candidate
    assert tune_mod.lane_tiles_within_budget(
        tune_mod.VMEM_BUDGET_BYTES * 2) == tune_mod.TUNE_LANE_TILES[:1]


def test_phase_fallbacks():
    st = tune_mod.TuneState(tune_mod.TunedConfig(
        first_pass=tune_mod.PhaseConfig("pallas", 256, 1, "depth"),
        sweep=tune_mod.PhaseConfig("pallas", 256, 1, "depth"),
        border=tune_mod.PhaseConfig("auto", 256, 1, "none"),
        min_lanes=256, border_min_frac=0.9, source="heuristic"))
    # small compacted frontiers drop to the reference engine
    assert st.phase("sweep", n_lanes=64).engine == "reference"
    assert st.phase("sweep", n_lanes=512).engine == "pallas"
    # auto border: kernel only when most lanes are live
    assert st.phase("border", n_lanes=100, n=1000).engine == "reference"
    assert st.phase("border", n_lanes=950, n=1000).engine == "pallas"
    # the depth oracle is handed out only to depth-reordering kernels
    assert st.rank_for(st.phase("sweep", n_lanes=512)) is None
    st.calibrate(jnp.arange(4))
    assert st.rank_for(st.phase("sweep", n_lanes=512)) is not None
    assert st.rank_for(st.phase("border", n_lanes=950, n=1000)) is None
    d = st.describe()
    assert d["source"] == "heuristic" and d["calibrated"]
    assert d["sweep"]["lane_tile"] == 256


def test_pinned_never_calibrates():
    st = tune_mod.TuneState(tune_mod.PINNED)
    st.calibrate(jnp.arange(4))
    assert st.depth_rank is None


# --------------------------------------------------------------------- #
# measured search: smoke + stats-key cache                              #
# --------------------------------------------------------------------- #

def test_search_mode_cached_and_bit_identical(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "search")
    calls = []
    orig = tune_mod.search

    def counting_search(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(tune_mod, "search", counting_search)
    pts = pointclouds.load("blobs", 300)
    dispatch.clear_cache()
    try:
        ref = dispatch.dbscan(pts, 0.05, 8, algorithm="fdbscan")
        p = dispatch.plan(pts, 0.05, 8, algorithm="pallas-tree")
        assert p.tune.config.source == "search"
        assert "timings" in p.tune.info and "mean_hits" in p.tune.info
        assert len(calls) == 1
        res = dispatch.dbscan(pts, 0.05, 8, query_plan=p)
        np.testing.assert_array_equal(np.asarray(res.labels),
                                      np.asarray(ref.labels))
        np.testing.assert_array_equal(np.asarray(res.core_mask),
                                      np.asarray(ref.core_mask))
        assert (res.n_clusters, res.n_sweeps) == (ref.n_clusters,
                                                  ref.n_sweeps)
        # a permuted copy of the same point set has identical index
        # stats: the plan is new, but the search result is reused
        p2 = dispatch.plan(pts[::-1].copy(), 0.05, 8,
                           algorithm="pallas-tree")
        assert p2.tune.config == p.tune.config
        assert len(calls) == 1
    finally:
        dispatch.clear_cache()
