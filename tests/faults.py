"""Fault-injection harness for the streaming durability layer.

Two halves:

  * **Child driver** (``python tests/faults.py --workdir D --crash-point P
    --crash-at K ...``): runs a deterministic **op schedule** — inserts
    interleaved with deletes and insert-order expiry — against a
    ``StreamingDBSCAN`` handle with a WAL + auto-checkpoints, arming one
    named crash point (``repro.stream.durability.FAULT_POINTS``).  The
    armed barrier terminates the process with ``os._exit(137)`` — the
    in-process equivalent of ``kill -9``: no cleanup, no flushing, no
    atexit.  After every *acknowledged* op (the call returned) the driver
    appends ``op_idx n_points n_active`` to ``D/acks.txt`` with fsync, so
    the parent knows exactly which ops the client was told are durable.

  * **Parent helpers** (imported by tests/test_faults.py): spawn the
    child, then recover from ``D`` and assert the durability contract —
    the recovered ``(n_points, active-gid set)`` matches the state after
    some *op prefix* of the schedule (no op half-applied), that prefix
    covers every acknowledged op (no acknowledged op lost), and
    ``snapshot()`` is component-identical to batch ``dbscan`` on exactly
    the surviving points of that prefix.  Recovery must never raise on a
    torn/corrupt WAL tail.

The schedule is deterministic (dataset, seed, batch split, delete gid
choices, and expire watermarks are all derived from the config and
regenerated identically on both sides), so every kill point is
reproducible bit-for-bit.  A small ``buffer_max`` forces tier seals and
cascade merges mid-schedule, putting real tiered-compaction work behind
the ``mid-compaction`` barrier.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# One deterministic serving scenario shared by child and parent.
CONFIG = {
    "dataset": "blobs",
    "n": 240,
    "seed": 0,
    "eps": 0.05,
    "min_pts": 6,
    "batches": 6,
    "merge_every": 3,        # force a merge (and auto-checkpoint) every 3
    "checkpoint_every": 1,   # ... inserts, so every barrier is exercised.
                             # 3, not 2: with buffer_max=48 every *even*
                             # insert already compacts the buffer into a
                             # single clean tier, which makes merge() a
                             # no-op — merging after odd inserts keeps
                             # both merge and compaction barriers live
    "buffer_max": 48,        # < 2 batches: tier seals and cascade merges
                             # fire organically mid-schedule
}

CRASH_EXIT = 137


def stream_points(cfg=CONFIG):
    """The deterministic point stream, split into insert batches."""
    from repro.data import pointclouds
    pts = pointclouds.load(cfg["dataset"], cfg["n"], seed=cfg["seed"])
    return pts, np.array_split(np.arange(cfg["n"]), cfg["batches"])


def op_schedule(cfg=CONFIG):
    """The deterministic op list both sides regenerate identically.

    Inserts carry the batch's index array; deletes carry the exact gid
    array (chosen by a seeded rng from the survivors at that point of the
    schedule); expire carries the watermark.  Deletes land after batches
    2 and 5 and the expiry after batch 4, so kills at the delete barriers
    always have a checkpoint behind them and WAL records in front.
    """
    _, batches = stream_points(cfg)
    rng = np.random.default_rng(cfg["seed"] + 1)
    ops, n, alive = [], 0, set()
    for i, b in enumerate(batches):
        ops.append(("insert", b))
        alive |= set(range(n, n + len(b)))
        n += len(b)
        if i in (1, 4):
            srt = np.array(sorted(alive))
            gids = np.sort(rng.choice(srt, size=12, replace=False))
            ops.append(("delete", gids))
            alive -= set(int(g) for g in gids)
        elif i == 3:
            wm = int(len(batches[0]))             # expire the first batch
            ops.append(("expire", wm))
            alive -= set(range(wm))
    return ops


def expected_states(cfg=CONFIG):
    """``(n_points, frozenset(active gids))`` after each op prefix;
    index 0 is the empty pre-stream state."""
    states = [(0, frozenset())]
    n, alive = 0, set()
    for kind, arg in op_schedule(cfg):
        if kind == "insert":
            alive |= set(range(n, n + len(arg)))
            n += len(arg)
        elif kind == "delete":
            alive -= set(int(g) for g in arg)
        else:
            alive -= set(range(arg))
        states.append((n, frozenset(alive)))
    return states


def paths(workdir):
    return (os.path.join(workdir, "ckpt.npz"),
            os.path.join(workdir, "wal.bin"),
            os.path.join(workdir, "acks.txt"))


def run_child(workdir, crash_point=None, crash_at=1, cfg=CONFIG,
              timeout=300):
    """Run the driver as a subprocess; returns its CompletedProcess."""
    cmd = [sys.executable, os.path.abspath(__file__),
           "--workdir", str(workdir)]
    if crash_point is not None:
        cmd += ["--crash-point", crash_point, "--crash-at", str(crash_at)]
    for k, v in cfg.items():
        cmd += [f"--{k.replace('_', '-')}", str(v)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # every child is a fresh process: share one persistent jit cache so
    # the kill matrix doesn't recompile the traversal programs per spawn
    cache = os.path.join(tempfile.gettempdir(), "repro-faults-jit-cache")
    env.setdefault("JAX_COMPILATION_CACHE_DIR", cache)
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
    return subprocess.run(cmd, cwd=REPO, env=env, timeout=timeout,
                          capture_output=True, text=True)


def read_acks(workdir):
    """Acknowledged ops: list of (op_idx, n_points, n_active) tuples."""
    _, _, ack_path = paths(workdir)
    if not os.path.exists(ack_path):
        return []
    out = []
    with open(ack_path) as f:
        for line in f:
            i, np_, na = line.split()
            out.append((int(i), int(np_), int(na)))
    return out


def _match_prefix(h, cfg):
    """The op-prefix index whose expected state equals the handle's."""
    states = expected_states(cfg)
    got = (h.n_points, frozenset(int(g) for g in h.active_gids))
    for k, s in enumerate(states):
        if s == got:
            return k
    raise AssertionError(
        f"recovered state (n_points={got[0]}, n_active={len(got[1])}) "
        f"matches no op prefix of the schedule — an op was half-applied "
        f"or the active set drifted")


def recover_and_check(workdir, cfg=CONFIG):
    """Recover from ``workdir`` and assert the full durability contract.

    Returns the recovered handle (still live: the caller can run the rest
    of the schedule into it and re-verify, see :func:`finish_stream`).
    """
    from repro.core import dispatch
    from repro.core.validate import check_component_identical
    from repro.stream import StreamingDBSCAN

    ckpt, wal, _ = paths(workdir)
    pts, _ = stream_points(cfg)
    acked = read_acks(workdir)

    h = StreamingDBSCAN.restore(ckpt, wal=wal,
                                checkpoint_every=cfg["checkpoint_every"])
    k = _match_prefix(h, cfg)
    n_acked = len(acked)
    assert k >= n_acked, (
        f"recovered only the first {k} ops but {n_acked} were acknowledged "
        "as durable: an acknowledged op was lost")
    states = expected_states(cfg)
    for i, np_, na in acked:            # acks themselves must be coherent
        exp_np, exp_alive = states[i + 1]
        assert (np_, na) == (exp_np, len(exp_alive))
    if h.n_active:
        alive = np.asarray(sorted(int(g) for g in h.active_gids))
        snap = h.snapshot()
        ref = dispatch.dbscan(pts[alive], cfg["eps"], cfg["min_pts"],
                              algorithm="fdbscan")
        check_component_identical(snap.labels, snap.core_mask,
                                  ref.labels, ref.core_mask)
    return h


def finish_stream(h, cfg=CONFIG):
    """Run whatever the crash cut off and verify final equivalence on the
    final surviving set."""
    from repro.core import dispatch
    from repro.core.validate import check_component_identical

    pts, _ = stream_points(cfg)
    ops = op_schedule(cfg)
    k = _match_prefix(h, cfg)
    for kind, arg in ops[k:]:
        if kind == "insert":
            h.insert(pts[arg])
        elif kind == "delete":
            h.delete(arg)
        else:
            h.expire(arg)
    assert h.n_points == cfg["n"]
    _, final_alive = expected_states(cfg)[-1]
    assert frozenset(int(g) for g in h.active_gids) == final_alive
    alive = np.asarray(sorted(final_alive))
    snap = h.snapshot()
    ref = dispatch.dbscan(pts[alive], cfg["eps"], cfg["min_pts"],
                          algorithm="fdbscan")
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)
    return h


# ---------------------------------------------------------------------- #
# child driver                                                           #
# ---------------------------------------------------------------------- #

def _child_main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="fault-injection child driver")
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--crash-point", default=None)
    ap.add_argument("--crash-at", type=int, default=1)
    ap.add_argument("--dataset", default=CONFIG["dataset"])
    ap.add_argument("--n", type=int, default=CONFIG["n"])
    ap.add_argument("--seed", type=int, default=CONFIG["seed"])
    ap.add_argument("--eps", type=float, default=CONFIG["eps"])
    ap.add_argument("--min-pts", type=int, default=CONFIG["min_pts"])
    ap.add_argument("--batches", type=int, default=CONFIG["batches"])
    ap.add_argument("--merge-every", type=int, default=CONFIG["merge_every"])
    ap.add_argument("--checkpoint-every", type=int,
                    default=CONFIG["checkpoint_every"])
    ap.add_argument("--buffer-max", type=int, default=CONFIG["buffer_max"])
    args = ap.parse_args(argv)

    from repro.stream import StreamingDBSCAN, durability

    cfg = {"dataset": args.dataset, "n": args.n, "seed": args.seed,
           "eps": args.eps, "min_pts": args.min_pts,
           "batches": args.batches, "merge_every": args.merge_every,
           "checkpoint_every": args.checkpoint_every,
           "buffer_max": args.buffer_max}
    pts, _ = stream_points(cfg)
    ckpt, wal, ack_path = paths(args.workdir)

    h = StreamingDBSCAN(None, args.eps, args.min_pts, wal=wal,
                        checkpoint_path=ckpt,
                        checkpoint_every=args.checkpoint_every,
                        buffer_max=args.buffer_max)
    durability.arm_fault(args.crash_point, at=args.crash_at)
    ack_f = open(ack_path, "a")
    n_inserts = 0
    for i, (kind, arg) in enumerate(op_schedule(cfg)):
        if kind == "insert":                # each may os._exit(137) at an
            h.insert(pts[arg])              # armed barrier
            n_inserts += 1
        elif kind == "delete":
            h.delete(arg)
        else:
            h.expire(arg)
        ack_f.write(f"{i} {h.n_points} {h.n_active}\n")
        ack_f.flush()
        os.fsync(ack_f.fileno())
        if (kind == "insert" and args.merge_every
                and n_inserts % args.merge_every == 0):
            h.merge()               # forces the merge/checkpoint barriers
    durability.arm_fault(None)
    print(f"child done: n={h.n_points} active={h.n_active} "
          f"merges={h.n_merges} compactions={h.n_compactions}")
    return 0


if __name__ == "__main__":
    sys.exit(_child_main())
