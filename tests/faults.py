"""Fault-injection harness for the streaming durability layer.

Two halves:

  * **Child driver** (``python tests/faults.py --workdir D --crash-point P
    --crash-at K ...``): runs a deterministic insert stream against a
    ``StreamingDBSCAN`` handle with a WAL + auto-checkpoints, arming one
    named crash point (``repro.stream.durability.FAULT_POINTS``).  The
    armed barrier terminates the process with ``os._exit(137)`` — the
    in-process equivalent of ``kill -9``: no cleanup, no flushing, no
    atexit.  After every *acknowledged* insert (i.e. ``insert`` returned)
    the driver appends the new watermark to ``D/acks.txt`` with fsync, so
    the parent knows exactly which batches the client was told are
    durable.

  * **Parent helpers** (imported by tests/test_faults.py): spawn the
    child, then recover from ``D`` and assert the durability contract —
    the recovered point count sits on a batch boundary (no half-applied
    batch), covers every acknowledged watermark (no lost acknowledged
    batch), and ``snapshot()`` is component-identical to batch ``dbscan``
    on exactly the recovered prefix.  Recovery must never raise on a
    torn/corrupt WAL tail.

The stream itself is deterministic (dataset, seed, and batch split are
part of the config and regenerated identically on both sides), so every
kill point is reproducible bit-for-bit.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# One deterministic serving scenario shared by child and parent.
CONFIG = {
    "dataset": "blobs",
    "n": 240,
    "seed": 0,
    "eps": 0.05,
    "min_pts": 6,
    "batches": 6,
    "merge_every": 2,        # force a merge (and auto-checkpoint) every 2
    "checkpoint_every": 1,   # ... inserts, so every barrier is exercised
}

CRASH_EXIT = 137


def stream_points(cfg=CONFIG):
    """The deterministic point stream, split into insert batches."""
    from repro.data import pointclouds
    pts = pointclouds.load(cfg["dataset"], cfg["n"], seed=cfg["seed"])
    return pts, np.array_split(np.arange(cfg["n"]), cfg["batches"])


def paths(workdir):
    return (os.path.join(workdir, "ckpt.npz"),
            os.path.join(workdir, "wal.bin"),
            os.path.join(workdir, "acks.txt"))


def run_child(workdir, crash_point=None, crash_at=1, cfg=CONFIG,
              timeout=300):
    """Run the driver as a subprocess; returns its CompletedProcess."""
    cmd = [sys.executable, os.path.abspath(__file__),
           "--workdir", str(workdir)]
    if crash_point is not None:
        cmd += ["--crash-point", crash_point, "--crash-at", str(crash_at)]
    for k, v in cfg.items():
        cmd += [f"--{k.replace('_', '-')}", str(v)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # every child is a fresh process: share one persistent jit cache so
    # the kill matrix doesn't recompile the traversal programs per spawn
    cache = os.path.join(tempfile.gettempdir(), "repro-faults-jit-cache")
    env.setdefault("JAX_COMPILATION_CACHE_DIR", cache)
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
    return subprocess.run(cmd, cwd=REPO, env=env, timeout=timeout,
                          capture_output=True, text=True)


def read_acks(workdir):
    """Acknowledged watermarks (handle.n_points after each acked insert)."""
    _, _, ack_path = paths(workdir)
    if not os.path.exists(ack_path):
        return []
    with open(ack_path) as f:
        return [int(line) for line in f.read().split()]


def recover_and_check(workdir, cfg=CONFIG):
    """Recover from ``workdir`` and assert the full durability contract.

    Returns the recovered handle (still live: the caller can insert the
    rest of the stream into it and re-verify).
    """
    from repro.core import dispatch
    from repro.core.validate import check_component_identical
    from repro.stream import StreamingDBSCAN

    ckpt, wal, _ = paths(workdir)
    pts, batches = stream_points(cfg)
    boundaries = np.cumsum([0] + [len(b) for b in batches])
    acked = read_acks(workdir)

    h = StreamingDBSCAN.restore(ckpt, wal=wal,
                                checkpoint_every=cfg["checkpoint_every"])
    n_rec = h.n_points
    assert n_rec in boundaries, (
        f"recovered {n_rec} points — not a batch boundary {boundaries}: "
        "a batch was half-applied")
    assert n_rec >= (max(acked) if acked else 0), (
        f"recovered {n_rec} points but {max(acked)} were acknowledged "
        "as durable: an acknowledged batch was lost")
    if n_rec:
        snap = h.snapshot()
        ref = dispatch.dbscan(pts[:n_rec], cfg["eps"], cfg["min_pts"],
                              algorithm="fdbscan")
        check_component_identical(snap.labels, snap.core_mask,
                                  ref.labels, ref.core_mask)
    return h


def finish_stream(h, cfg=CONFIG):
    """Insert whatever the crash cut off and verify final equivalence."""
    from repro.core import dispatch
    from repro.core.validate import check_component_identical

    pts, batches = stream_points(cfg)
    boundaries = np.cumsum([0] + [len(b) for b in batches])
    k = int(np.searchsorted(boundaries, h.n_points))
    for b in batches[k:]:
        h.insert(pts[b])
    assert h.n_points == cfg["n"]
    snap = h.snapshot()
    ref = dispatch.dbscan(pts, cfg["eps"], cfg["min_pts"],
                          algorithm="fdbscan")
    check_component_identical(snap.labels, snap.core_mask,
                              ref.labels, ref.core_mask)
    return h


# ---------------------------------------------------------------------- #
# child driver                                                           #
# ---------------------------------------------------------------------- #

def _child_main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="fault-injection child driver")
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--crash-point", default=None)
    ap.add_argument("--crash-at", type=int, default=1)
    ap.add_argument("--dataset", default=CONFIG["dataset"])
    ap.add_argument("--n", type=int, default=CONFIG["n"])
    ap.add_argument("--seed", type=int, default=CONFIG["seed"])
    ap.add_argument("--eps", type=float, default=CONFIG["eps"])
    ap.add_argument("--min-pts", type=int, default=CONFIG["min_pts"])
    ap.add_argument("--batches", type=int, default=CONFIG["batches"])
    ap.add_argument("--merge-every", type=int, default=CONFIG["merge_every"])
    ap.add_argument("--checkpoint-every", type=int,
                    default=CONFIG["checkpoint_every"])
    args = ap.parse_args(argv)

    from repro.stream import StreamingDBSCAN, durability

    cfg = {"dataset": args.dataset, "n": args.n, "seed": args.seed,
           "eps": args.eps, "min_pts": args.min_pts,
           "batches": args.batches, "merge_every": args.merge_every,
           "checkpoint_every": args.checkpoint_every}
    pts, batches = stream_points(cfg)
    ckpt, wal, ack_path = paths(args.workdir)

    h = StreamingDBSCAN(None, args.eps, args.min_pts, wal=wal,
                        checkpoint_path=ckpt,
                        checkpoint_every=args.checkpoint_every)
    durability.arm_fault(args.crash_point, at=args.crash_at)
    ack_f = open(ack_path, "a")
    for i, b in enumerate(batches):
        h.insert(pts[b])            # may os._exit(137) at an armed barrier
        ack_f.write(f"{h.n_points}\n")
        ack_f.flush()
        os.fsync(ack_f.fileno())
        if args.merge_every and (i + 1) % args.merge_every == 0:
            h.merge()               # forces the merge/checkpoint barriers
    durability.arm_fault(None)
    print(f"child done: n={h.n_points} merges={h.n_merges}")
    return 0


if __name__ == "__main__":
    sys.exit(_child_main())
