"""Linearizability of the snapshot publish/read path (DESIGN.md §13).

The serving contract: every query executes against exactly one
*published* snapshot version — never a torn intermediate — and the
version sequence any single client observes is monotonic.  Two attack
angles:

  * **randomized interleaving** — reader threads hammer
    ``SnapshotStore.current`` while a writer publishes a known sequence
    of versions; every answer must be bit-identical to the reference
    computed for the version the reader saw *before* it was published,
    and per-reader versions never go backwards;
  * **kill mid-publish** — a subprocess arms the ``mid-publish``
    durability barrier (between snapshot build and the atomic swap) and
    dies there with ``os._exit(137)``.  The WAL-durable insert that
    triggered the publish must survive recovery; the never-swapped
    snapshot must leave no trace.
"""
import os
import subprocess
import sys
import tempfile
import threading

import numpy as np
import pytest

from repro.core import dispatch
from repro.data import pointclouds
from repro.serve import Server, SnapshotStore, freeze

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EPS, MINPTS = 0.05, 6
CRASH_EXIT = 137


def test_interleaved_readers_always_see_a_published_version():
    pts = pointclouds.load("blobs", 500, seed=30)
    h = dispatch.stream_handle(pts[:200], EPS, MINPTS)
    store = SnapshotStore(keep=32)
    probes = np.ascontiguousarray(pts[::7][:64], np.float32)

    refs = {}                       # version -> reference QueryResult,
                                    # filled BEFORE the version publishes
    snap0 = freeze(h, version=0)
    refs[0] = snap0.query(probes)
    store.publish(snap0)

    stop = threading.Event()
    errors: list = []
    observed = [0, 0]

    def reader(slot):
        last = -1
        try:
            while not stop.is_set():
                snap = store.current()
                v = snap.version
                assert v >= last, f"reader saw v{v} after v{last}"
                last = v
                res = snap.query(probes)
                ref = refs[v]       # publish ordering guarantees presence
                for f in ("labels", "counts", "would_be_core"):
                    np.testing.assert_array_equal(
                        getattr(ref, f), getattr(res, f),
                        err_msg=f"v{v}: {f} diverged under interleaving")
                observed[slot] += 1
        except Exception as e:      # pragma: no cover — failure path
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    try:
        for v in range(1, 10):      # writer: publish a known sequence
            h.insert(pts[200 + 30 * (v - 1):200 + 30 * v])
            snap = freeze(h, version=v)
            refs[v] = snap.query(probes)
            store.publish(snap)
    finally:
        stop.set()
        for t in threads:
            t.join(60)
    assert not errors, errors
    assert store.version == 9
    assert min(observed) > 0        # both readers actually raced the writer
    # the retained history is exactly the published snapshots
    for v in range(10):
        kept = store.get(v)
        assert kept is not None and kept.version == v


_CHILD = r"""
import sys, time
import numpy as np
from repro.data import pointclouds
from repro.serve import Server
from repro.stream import durability

workdir = sys.argv[1]
pts = pointclouds.load("blobs", 300, seed=40)
srv = Server(pts[:200], [("t", 0.05, 6)], durability_dir=workdir,
             checkpoint_every=1)
durability.arm_fault("mid-publish", at=1)   # armed AFTER the bootstrap
fut = srv.submit_insert(pts[200:260])       # publish dies at the barrier
time.sleep(60)                              # the writer thread kills us
sys.exit(1)                                 # survived: the test fails
"""


@pytest.mark.fault
def test_kill_mid_publish_recovers_old_view_plus_durable_insert(tmp_path):
    """Crash between snapshot build and swap: the insert is already
    WAL-durable (the handle logged it before the freeze), the new
    snapshot never published.  Recovery must serve the full durable
    stream — acknowledged-durable data survives, the torn publish
    leaves nothing behind."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cache = os.path.join(tempfile.gettempdir(), "repro-faults-jit-cache")
    env.setdefault("JAX_COMPILATION_CACHE_DIR", cache)
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    env.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
    proc = subprocess.run([sys.executable, "-c", _CHILD, str(tmp_path)],
                          cwd=REPO, env=env, timeout=600,
                          capture_output=True, text=True)
    assert proc.returncode == CRASH_EXIT, (
        f"child did not die at the mid-publish barrier:\n"
        f"rc={proc.returncode}\nstdout={proc.stdout}\nstderr={proc.stderr}")

    pts = pointclouds.load("blobs", 300, seed=40)
    srv = Server.restore([("t", 0.05, 6)], durability_dir=str(tmp_path),
                         checkpoint_every=1)
    try:
        view = srv._views[0]
        # the insert hit the WAL before the publish barrier: recovered
        # state is the whole 260-point stream, not just the bootstrap
        assert view.handle.n_points == 260
        assert view.store.version == 0      # fresh publish, old counter
        probes = np.ascontiguousarray(pts[::5][:64], np.float32)
        ref_h = dispatch.stream_handle(pts[:200], EPS, MINPTS)
        ref_h.insert(pts[200:260])
        ref = ref_h.query(probes)
        got = srv.query(probes, timeout=120)
        for f in ("labels", "counts", "would_be_core"):
            np.testing.assert_array_equal(
                getattr(ref, f), getattr(got, f),
                err_msg=f"post-recovery {f} diverged")
    finally:
        srv.shutdown(final_checkpoint=False)
