"""Data pipeline + DBSCAN dedup integration tests."""
import numpy as np

from repro.data.dedup import dedup_batch, dedup_indices
from repro.data.lm_data import SyntheticLM, doc_embedding


def test_stream_determinism():
    a = SyntheticLM(512, 64, seed=3).batch(10, 8)
    b = SyntheticLM(512, 64, seed=3).batch(10, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(512, 64, seed=4).batch(10, 8)
    assert (a["tokens"] != c["tokens"]).any()


def test_dedup_collapses_duplicates_keeps_fresh():
    data = SyntheticLM(512, 64, seed=0, dup_frac=0.5, n_templates=8)
    b = data.batch(0, 64)
    idx = dedup_indices(b["tokens"])
    dup = b["is_dup"]
    kept_dup = dup[idx].sum()
    kept_fresh = (~dup[idx]).sum()
    assert kept_fresh == (~dup).sum(), "no fresh doc may be dropped"
    assert kept_dup <= 10, f"duplicates not collapsed: {kept_dup}"
    assert kept_dup >= 1


def test_dedup_batch_padding_keeps_shape():
    data = SyntheticLM(512, 64, seed=1, dup_frac=0.6)
    b = data.batch(2, 32)
    out, idx = dedup_batch({"tokens": b["tokens"]}, pad_to=32)
    assert out["tokens"].shape == (32, 64)


def test_doc_embedding_near_duplicates_close():
    data = SyntheticLM(512, 64, seed=2, dup_frac=1.0, n_templates=2)
    b = data.batch(0, 16)
    emb = doc_embedding(b["tokens"])
    d = np.linalg.norm(emb[:, None] - emb[None], axis=-1)
    # two templates -> within-template distances tiny, cross larger
    close = (d < 0.15).sum() - 16
    assert close >= 16 * 3  # each doc has several near-copies
