"""The unified auto-dispatching backend (DESIGN.md §5)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dbscan, dbscan_bruteforce_np, dispatch
from repro.core.validate import check_dbscan, same_partition
from repro.data import pointclouds

from conftest import separated_points


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_cache()
    yield
    dispatch.clear_cache()


def test_auto_small_n_dispatches_tiled():
    pts = separated_points(300, 2, eps=0.1, seed=0)
    p = dispatch.plan(pts, 0.1, 5)
    assert p.backend == "tiled"
    res = dbscan(pts, 0.1, 5, algorithm="auto")
    assert res.backend == "tiled"
    ref_labels, ref_core = dbscan_bruteforce_np(pts, 0.1, 5)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])


def test_auto_dense_2d_dispatches_densebox():
    pts = pointclouds.trajectories_2d(3000)
    p = dispatch.plan(pts, 0.02, 5)
    assert p.backend == "fdbscan-densebox"
    assert p.stats["dense_fraction"] >= dispatch.DENSE_FRACTION_MIN
    res = dispatch.dbscan(pts, 0.02, 5, query_plan=p)
    assert res.backend == "fdbscan-densebox"
    assert res.n_clusters >= 1


def test_auto_sparse_3d_dispatches_plain_tree():
    pts = pointclouds.halos_3d(4000, seed=7)
    p = dispatch.plan(pts, 0.02, 100)
    assert p.backend == "fdbscan"
    assert p.stats["dense_fraction"] < dispatch.DENSE_FRACTION_MIN


def test_fdbscan_plan_reused_across_eps_and_minpts():
    # The plain-tree index is eps-independent: a parameter sweep must hit
    # the same cached Segments/Tree objects (identity, not just equality).
    pts = separated_points(1500, 2, eps=0.05, seed=3)
    p1 = dispatch.plan(pts, 0.03, 5, algorithm="fdbscan")
    p2 = dispatch.plan(pts, 0.09, 20, algorithm="fdbscan")
    assert p1.segs is p2.segs and p1.tree is p2.tree
    r1 = dispatch.dbscan(pts, 0.03, 5, query_plan=p1)
    r2 = dispatch.dbscan(pts, 0.09, 20, query_plan=p2)
    for res, eps, mp in ((r1, 0.03, 5), (r2, 0.09, 20)):
        ref_labels, ref_core = dbscan_bruteforce_np(pts, eps, mp)
        assert (np.asarray(res.core_mask) == ref_core).all()
        assert same_partition(np.asarray(res.labels)[ref_core],
                              ref_labels[ref_core])


def test_plan_cache_hit_returns_same_plan():
    pts = separated_points(200, 2, eps=0.1, seed=5)
    assert dispatch.plan(pts, 0.1, 5) is dispatch.plan(pts, 0.1, 5)


@pytest.mark.parametrize("algo", ["fdbscan", "fdbscan-densebox", "tiled",
                                  "auto"])
def test_all_backends_agree_with_oracle(algo):
    pts = separated_points(280, 2, eps=0.08, seed=8)
    res = dbscan(pts, 0.08, 6, algorithm=algo)
    ref_labels, ref_core = dbscan_bruteforce_np(pts, 0.08, 6)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])
    check_dbscan(pts, 0.08, 6, res.labels, res.core_mask)


def test_tiled_star_no_borders():
    pts = separated_points(220, 2, eps=0.09, seed=2)
    res = dbscan(pts, 0.09, 8, algorithm="tiled", star=True)
    labs = np.asarray(res.labels)
    core = np.asarray(res.core_mask)
    assert (labs[~core] == -1).all()
    full = dbscan(pts, 0.09, 8, algorithm="tiled")
    assert same_partition(labs[core], np.asarray(full.labels)[core])


def test_sharded_backend_matches_oracle():
    # explicit sharded dispatch on whatever devices exist (1 locally, 8 in
    # CI via XLA_FLAGS): the halo protocol must reproduce the oracle either
    # way, and the plan must record the decision without building an index
    pts = separated_points(280, 2, eps=0.08, seed=8)
    p = dispatch.plan(pts, 0.08, 6, algorithm="sharded")
    assert p.backend == "sharded" and p.segs is None and p.tree is None
    res = dbscan(pts, 0.08, 6, algorithm="sharded")
    assert res.backend == "sharded"
    ref_labels, ref_core = dbscan_bruteforce_np(pts, 0.08, 6)
    assert (np.asarray(res.core_mask) == ref_core).all()
    assert same_partition(np.asarray(res.labels)[ref_core],
                          ref_labels[ref_core])
    check_dbscan(pts, 0.08, 6, res.labels, res.core_mask)


def test_unknown_algorithm_raises():
    with pytest.raises(ValueError):
        dbscan(separated_points(50, 2, eps=0.1, seed=0), 0.1, 5,
               algorithm="nope")
    with pytest.raises(ValueError):
        dispatch.plan(separated_points(50, 2, eps=0.1, seed=0), 0.1, 5,
                      algorithm="nope")


def test_mesh_with_single_device_backend_raises():
    # these backends are single-device: a mesh= would silently be ignored
    import jax
    pts = separated_points(120, 2, eps=0.1, seed=4)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for algo in ("stream", "tiled", "fdbscan", "fdbscan-densebox"):
        with pytest.raises(ValueError, match="mesh"):
            dbscan(pts, 0.1, 5, algorithm=algo, mesh=mesh)
        with pytest.raises(ValueError, match="mesh"):
            dispatch.plan(pts, 0.1, 5, algorithm=algo, mesh=mesh)


def test_frontier_with_non_tree_backend_raises():
    # frontier restriction only exists on the single-device tree-sweep
    # backends; everywhere else the kwarg would silently be ignored
    pts = separated_points(120, 2, eps=0.1, seed=5)
    with pytest.raises(ValueError, match="frontier"):
        dbscan(pts, 0.1, 5, algorithm="tiled", frontier=False)
    with pytest.raises(ValueError, match="frontier"):
        dbscan(pts, 0.1, 5, frontier=False)  # auto resolves to tiled here
    with pytest.raises(ValueError, match="frontier"):
        dbscan(pts, 0.1, 5, algorithm="stream", frontier=False)
    with pytest.raises(ValueError, match="frontier"):
        dbscan(pts, 0.1, 5, algorithm="sharded", frontier=False)
    # the tree backends accept it, through auto dispatch too
    big = separated_points(1100, 2, eps=0.05, seed=6)
    assert dbscan(big, 0.05, 5, frontier=False).backend in (
        "fdbscan", "fdbscan-densebox")
