"""Regenerate the golden durability fixtures (format v1).

Writes ``stream_ckpt_v1.npz`` (a version-1 checkpoint at watermark 80)
and ``stream_wal_v1.bin`` (a WAL holding two 10-point insert records past
that watermark) from a deterministic point stream. The fixtures pin the
**on-disk format**: `tests/test_durability.py` restores them and asserts
the re-serialized checkpoint is byte-for-byte identical, so any change to
the npz layout, manifest fields, or WAL framing that silently breaks old
files fails loudly. Bump ``CHECKPOINT_VERSION``/``_WAL_VERSION`` and
regenerate (``PYTHONPATH=src python tests/golden/make_stream_golden.py``)
only with an explicit migration story.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))

from repro.data import pointclouds           # noqa: E402
from repro.stream import StreamingDBSCAN     # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
CKPT = os.path.join(HERE, "stream_ckpt_v1.npz")
WAL = os.path.join(HERE, "stream_wal_v1.bin")

EPS, MIN_PTS = 0.05, 6
N_CKPT, N_WAL_BATCHES, BATCH = 80, 2, 10


def stream():
    return pointclouds.blobs(N_CKPT + N_WAL_BATCHES * BATCH, k=3, seed=7)


def main():
    pts = stream()
    for p in (CKPT, WAL):
        if os.path.exists(p):
            os.remove(p)
    # bootstrap + attach both files: __init__ writes the watermark-80
    # checkpoint, the two inserts append WAL records past it
    h = StreamingDBSCAN(pts[:N_CKPT], EPS, MIN_PTS,
                        wal=WAL, checkpoint_path=CKPT)
    for b in range(N_WAL_BATCHES):
        lo = N_CKPT + b * BATCH
        h.insert(pts[lo:lo + BATCH])
    h._wal.close()
    print(f"wrote {CKPT} ({os.path.getsize(CKPT)} bytes, watermark "
          f"{N_CKPT}) and {WAL} ({os.path.getsize(WAL)} bytes, "
          f"{N_WAL_BATCHES} records)")


if __name__ == "__main__":
    main()
