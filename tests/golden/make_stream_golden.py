"""Regenerate the golden durability fixtures (current format: v2).

Writes ``stream_ckpt_v2.npz`` (a version-2 checkpoint at watermark 80
with a tombstone mask) and ``stream_wal_v2.bin`` (a WAL holding insert,
delete, and expire records past that watermark) from a deterministic
point stream. The fixtures pin the **on-disk format**:
`tests/test_durability.py` restores them and asserts the re-serialized
checkpoint is byte-for-byte identical, so any change to the npz layout,
manifest fields, or WAL framing that silently breaks old files fails
loudly. Bump ``CHECKPOINT_VERSION``/``WAL_VERSION`` and regenerate
(``PYTHONPATH=src python tests/golden/make_stream_golden.py``) only with
an explicit migration story.

The version-1 fixtures (``stream_ckpt_v1.npz``, ``stream_wal_v1.bin``)
are *frozen* — they were written by the version-1 code and pin backward
readability; this script never touches them.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))

from repro.data import pointclouds           # noqa: E402
from repro.stream import StreamingDBSCAN     # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
CKPT = os.path.join(HERE, "stream_ckpt_v2.npz")
WAL = os.path.join(HERE, "stream_wal_v2.bin")

EPS, MIN_PTS = 0.05, 6
N_CKPT, BATCH = 80, 10
# deterministic post-checkpoint tail: insert 10, delete 4 fixed gids,
# expire everything below 8, insert 10 more
DELETE_GIDS = (5, 17, 33, 85)
EXPIRE_WM = 8


def stream():
    return pointclouds.blobs(N_CKPT + 2 * BATCH, k=3, seed=7)


def main():
    pts = stream()
    for p in (CKPT, WAL):
        if os.path.exists(p):
            os.remove(p)
    # bootstrap + attach both files: __init__ writes the watermark-80
    # checkpoint, then the tail appends one record per operation
    h = StreamingDBSCAN(pts[:N_CKPT], EPS, MIN_PTS,
                        wal=WAL, checkpoint_path=CKPT)
    h.insert(pts[N_CKPT:N_CKPT + BATCH])
    h.delete(np.array(DELETE_GIDS))
    h.expire(EXPIRE_WM)
    h.insert(pts[N_CKPT + BATCH:N_CKPT + 2 * BATCH])
    h._wal.close()
    print(f"wrote {CKPT} ({os.path.getsize(CKPT)} bytes, watermark "
          f"{N_CKPT}) and {WAL} ({os.path.getsize(WAL)} bytes, "
          f"4 records: insert/delete/expire/insert)")


if __name__ == "__main__":
    main()
