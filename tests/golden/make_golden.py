"""Generate the golden equivalence fixtures (tests/golden/golden.npz).

The fixtures were produced at the pre-redesign commit (the last `mode=`
string-enum traversal engine) and pin the exact outputs — labels, core
mask, neighbor counts, sweep counts — that the predicate/callback engine
must reproduce bit-for-bit on every backend (tests/test_golden.py).

Uses only surfaces that are stable across the redesign (the top-level
``dbscan`` / ``stream_handle`` entry points and the ``count_neighbors``
helper), so re-running it at any later commit must regenerate an
identical file:

    PYTHONPATH=src:tests python tests/golden/make_golden.py
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(HERE))
OUT = os.path.join(HERE, "golden.npz")

# (dataset, n, eps, min_pts) — the five pointclouds scenario regimes
SCENARIOS = [
    ("ngsim_like", 800, 0.01, 5),
    ("portotaxi_like", 800, 0.02, 5),
    ("road3d_like", 800, 0.01, 5),
    ("hacc_like", 800, 0.05, 5),
    ("blobs", 800, 0.05, 8),
]

# sharded runs in a subprocess with 8 forced host devices (XLA_FLAGS must
# precede jax import); two regimes bound the runtime while still covering
# both dimensionalities of the halo exchange
SHARDED = {"portotaxi_like", "hacc_like"}

_SHARDED_BODY = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import numpy as np
from repro.core import dbscan
from repro.data import pointclouds
pts = pointclouds.load({dset!r}, {n})
res = dbscan(pts, {eps}, {mp}, algorithm="sharded")
np.savez({out!r}, labels=np.asarray(res.labels),
         core=np.asarray(res.core_mask),
         n_clusters=np.int32(res.n_clusters),
         n_sweeps=np.int32(res.n_sweeps))
"""


def _in_process_cases(dset, n, eps, mp):
    from repro.core import dbscan, stream_handle, traversal
    from repro.core.dispatch import plan
    from repro.data import pointclouds

    pts = pointclouds.load(dset, n)
    out = {}
    for algo in ("fdbscan", "fdbscan-densebox", "tiled"):
        res = dbscan(pts, eps, mp, algorithm=algo)
        out[f"{dset}/{algo}/labels"] = np.asarray(res.labels)
        out[f"{dset}/{algo}/core"] = np.asarray(res.core_mask)
        out[f"{dset}/{algo}/n_clusters"] = np.int32(res.n_clusters)
        out[f"{dset}/{algo}/n_sweeps"] = np.int32(res.n_sweeps)

    # streaming: bootstrap + two micro-batches + forced merge — the
    # external-query (query_pts/query_init chained two-tree) path
    cut = n * 5 // 8
    h = stream_handle(pts[:cut], eps, mp)
    h.insert(pts[cut:cut + (n - cut) // 2])
    h.insert(pts[cut + (n - cut) // 2:])
    h.merge()
    res = h.snapshot()
    out[f"{dset}/stream/labels"] = np.asarray(res.labels)
    out[f"{dset}/stream/core"] = np.asarray(res.core_mask)
    out[f"{dset}/stream/n_clusters"] = np.int32(res.n_clusters)

    # engine-level golden: exact (uncapped) neighbor counts over the plain
    # tree index, in original point order
    p = plan(pts, eps, mp, algorithm="fdbscan")
    counts_sorted = np.asarray(traversal.count_neighbors(
        p.tree, p.segs, eps, cap=traversal.INT_MAX))
    counts = np.zeros(n, np.int64)
    counts[np.asarray(p.segs.order)] = counts_sorted
    out[f"{dset}/counts"] = counts
    return out


def _sharded_case(dset, n, eps, mp):
    tmp = os.path.join(HERE, f"_sharded_{dset}.npz")
    code = textwrap.dedent(_SHARDED_BODY).format(dset=dset, n=n, eps=eps,
                                                 mp=mp, out=tmp)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"sharded golden for {dset} failed:\n{r.stderr}")
    with np.load(tmp) as z:
        out = {f"{dset}/sharded/{k}": z[k] for k in z.files}
    os.remove(tmp)
    return out


def main():
    out = {}
    for dset, n, eps, mp in SCENARIOS:
        print(f"[golden] {dset} n={n} eps={eps} mp={mp}", flush=True)
        out.update(_in_process_cases(dset, n, eps, mp))
        if dset in SHARDED:
            out.update(_sharded_case(dset, n, eps, mp))
    np.savez_compressed(OUT, **out)
    print(f"[golden] wrote {OUT} ({len(out)} arrays)")


if __name__ == "__main__":
    main()
