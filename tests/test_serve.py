"""Serving-subsystem tests (DESIGN.md §13).

Four layers, mirroring ``src/repro/serve``:

  * snapshot exactness — ``IndexSnapshot.query`` must be bit-identical to
    ``StreamingDBSCAN.query`` on the frozen state, on every dataset /
    dimensionality / eps the suite runs, including far out-of-range
    probes and exact duplicates of residents (the conservative cell
    margins demote every boundary-ambiguous cell to exact point tests);
  * micro-batching — the passive deadline-or-full batcher is driven with
    explicit ``now`` values, so flush reasons, request atomicity, and
    the adaptive target are all deterministic;
  * admission — typed ``Overloaded`` with the right budget/reason, and
    release symmetry;
  * the server — multi-tenant end-to-end: one shared index build, per
    tenant answers bit-identical to that tenant's own handle, insert
    acknowledgement implies visibility, graceful shutdown, and the
    query plane staying live (and version-monotonic) under concurrent
    writes.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import dispatch
from repro.core.fdbscan import _pad_size
from repro.data import pointclouds
from repro.obs import metrics as obs_metrics
from repro.serve import (AdmissionController, IndexSnapshot, MicroBatcher,
                         Overloaded, Server, ServerConfig, SnapshotStore,
                         TenantSpec, bucket_size, freeze)
from repro.serve.batching import Request
from repro.serve.tenants import build_views, check_specs

EPS, MINPTS = 0.05, 6


def _handle(pts, eps=EPS, min_pts=MINPTS, **kw):
    return dispatch.stream_handle(pts, eps, min_pts, **kw)


def _probe_mix(pts, k, seed, eps=EPS):
    """Jittered resident samples + exact duplicates + far out-of-range."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(pts), k)
    jit = rng.normal(0.0, 0.5 * eps, (k, pts.shape[1])).astype(np.float32)
    probes = pts[idx] + jit
    probes[: k // 4] = pts[rng.integers(0, len(pts), k // 4)]  # exact dups
    far = np.full((4, pts.shape[1]), 1e6, np.float32)
    far[1] *= -1.0
    far[2, 0] = -1e6
    far[3] = np.nextafter(np.float32(pts.max()), np.float32(np.inf)) + 50.0
    return np.ascontiguousarray(np.concatenate([probes, far]), np.float32)


def _assert_same(ref, got, ctx=""):
    for f in ("labels", "counts", "would_be_core"):
        np.testing.assert_array_equal(getattr(ref, f), getattr(got, f),
                                      err_msg=f"{ctx}: {f} diverged")


# ---------------------------------------------------------------------- #
# snapshot exactness                                                     #
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("dataset,eps,min_pts", [
    ("portotaxi_like", 0.02, 10),
    ("blobs", 0.05, 6),
    ("hacc_like", 0.05, 8),         # 3-d: 125-cell neighborhood path
])
def test_snapshot_bitidentical_to_handle(dataset, eps, min_pts):
    pts = pointclouds.load(dataset, 1500, seed=3)
    h = _handle(pts, eps, min_pts)
    snap = freeze(h, version=1)
    probes = _probe_mix(pts, 400, seed=5, eps=eps)
    _assert_same(h.query(probes), snap.query(probes), f"{dataset} frozen")
    assert snap.version == 1
    assert snap.watermark == h.n_points

    # mutate the handle: the old snapshot must keep answering for the old
    # state while a re-freeze matches the new one
    more = pointclouds.load(dataset, 1700, seed=3)[1500:]
    old = snap.query(probes)
    h.insert(more)
    _assert_same(old, snap.query(probes), f"{dataset} immutable")
    _assert_same(h.query(probes), freeze(h, version=2).query(probes),
                 f"{dataset} refrozen")


def test_snapshot_empty_and_edge_cases():
    empty = IndexSnapshot(np.zeros((0, 2), np.float32),
                          np.zeros(0, np.int64), EPS, MINPTS)
    res = empty.query(np.zeros((3, 2), np.float32))
    assert np.all(res.labels == -1) and np.all(res.counts == 0)
    assert not res.would_be_core.any()

    # min_pts == 1: an inserted probe is always its own core point
    lone = IndexSnapshot(np.zeros((0, 2), np.float32),
                         np.zeros(0, np.int64), EPS, 1)
    assert lone.query(np.zeros((2, 2), np.float32)).would_be_core.all()

    pts = pointclouds.load("blobs", 300, seed=0)
    snap = freeze(_handle(pts))
    res = snap.query(np.zeros((0, 2), np.float32))      # empty probe batch
    assert res.labels.shape == (0,)

    with pytest.raises(ValueError, match="eps"):
        IndexSnapshot(pts, np.zeros(len(pts), np.int64), 0.0, MINPTS)
    with pytest.raises(ValueError, match="min_pts"):
        IndexSnapshot(pts, np.zeros(len(pts), np.int64), EPS, 0)
    with pytest.raises(ValueError, match="mismatch"):
        IndexSnapshot(pts, np.zeros(7, np.int64), EPS, MINPTS)
    with pytest.raises(ValueError, match="dimensionality"):
        snap.query(np.zeros((4, 3), np.float32))
    with pytest.raises(ValueError, match="finite"):
        snap.query(np.full((4, 2), np.nan, np.float32))


def test_snapshot_store_versioning():
    pts = pointclouds.load("blobs", 200, seed=1)
    h = _handle(pts)
    store = SnapshotStore(keep=3)
    assert store.version == -1 and store.current() is None
    for v in (0, 1, 2, 3):
        store.publish(freeze(h, version=v))
    assert store.version == 3
    assert store.current().version == 3
    assert store.get(0) is None                 # evicted (keep=3)
    assert store.get(1).version == 1
    with pytest.raises(ValueError, match="monotonic"):
        store.publish(freeze(h, version=3))     # stale writer
    assert store.version == 3                   # rejected publish: no swap


# ---------------------------------------------------------------------- #
# micro-batching                                                         #
# ---------------------------------------------------------------------- #

def _req(k, now, d=2):
    return Request(np.zeros((k, d), np.float32), None, now)


def test_batcher_full_and_deadline_flush():
    b = MicroBatcher(max_batch=8, max_delay_s=0.01, adaptive=False)
    assert b.ready(now=0.0) is None             # nothing pending
    assert not b.add(_req(3, now=0.0))
    assert b.next_deadline(0.0) == pytest.approx(0.01)
    assert b.ready(now=0.001) is None           # neither full nor due
    assert b.add(_req(3, now=0.002)) is False
    assert b.add(_req(3, now=0.003)) is True    # 9 >= 8: full
    fl = b.ready(now=0.004)
    assert fl.reason == "full"
    # whole requests only, capped at max_batch: 3 + 3 fit, 9 would not
    assert len(fl.requests) == 2 and len(fl.pts) == 6
    assert b.pending_points == 3
    assert b.ready(now=0.005) is None
    fl = b.ready(now=0.0031 + 0.01)             # oldest remaining is due
    assert fl.reason == "deadline" and len(fl.pts) == 3
    assert b.pending_points == 0


def test_batcher_drain_and_atomicity():
    b = MicroBatcher(max_batch=4, max_delay_s=10.0, adaptive=False)
    for i in range(3):
        b.add(_req(3, now=float(i)))
    flushes = list(b.drain(now=100.0))
    # 3-pt requests against max_batch=4: one whole request per flush,
    # never split
    assert [len(f.pts) for f in flushes] == [3, 3, 3]
    assert all(f.reason in ("full", "deadline", "drain") for f in flushes)
    assert b.pending_points == 0


def test_batcher_adaptive_target_tracks_rate():
    b = MicroBatcher(max_batch=4096, max_delay_s=0.002, adaptive=True)
    assert b.target_points() == 64              # cold: the floor
    now = 0.0
    for _ in range(50):                         # ~1e6 pts/s arrival rate
        b.add(_req(256, now))
        now += 256e-6
        b.ready(now)                            # keep the queue small
    hot = b.target_points()
    assert hot > 64                             # grew toward max_batch
    for _ in range(50):                         # rate collapses
        b.add(_req(1, now))
        now += 1.0
        b.ready(now, drain=True)
    assert b.target_points() == 64              # back at the floor
    assert b.target_points() <= b.max_batch


def test_bucket_ladder_is_the_jit_ladder():
    for k in (1, 63, 64, 65, 100, 129, 256, 1000, 4097):
        assert bucket_size(k) == _pad_size(k)
        assert bucket_size(k) >= k
    # padded probe sizes inside one bucket share one compiled shape
    assert bucket_size(130) == bucket_size(bucket_size(130))


# ---------------------------------------------------------------------- #
# admission control                                                      #
# ---------------------------------------------------------------------- #

def test_admission_budgets_and_release():
    a = AdmissionController(max_pending_requests=2, max_pending_points=100,
                            max_pending_inserts=1, retry_after_s=0.25)
    a.admit_query(40)
    a.admit_query(40)
    with pytest.raises(Overloaded) as ei:
        a.admit_query(1)
    assert (ei.value.kind, ei.value.reason) == ("query", "requests")
    assert ei.value.depth == 2 and ei.value.limit == 2
    assert ei.value.retry_after_s == 0.25
    a.release_query(40)
    with pytest.raises(Overloaded) as ei:
        a.admit_query(80)                       # 40 + 80 > 100
    assert ei.value.reason == "points"
    a.admit_query(50)

    a.admit_insert()
    with pytest.raises(Overloaded) as ei:
        a.admit_insert()
    assert (ei.value.kind, ei.value.reason) == ("insert", "inserts")
    a.release_insert()
    a.admit_insert()

    st = a.stats()
    assert st["shed"] == {"query": 2, "insert": 1}
    assert st["pending_requests"] == 2 and st["pending_inserts"] == 1

    a.close()
    for call in (lambda: a.admit_query(1), a.admit_insert):
        with pytest.raises(Overloaded) as ei:
            call()
        assert ei.value.reason == "shutdown"


def test_admission_slo_quantiles_need_no_collector():
    assert obs_metrics.active() is None         # the point of the test
    a = AdmissionController()
    for ms in (1, 2, 3, 50):
        a.observe("query", ms * 1e-3, tenant="t0")
    st = a.stats(tenants=("t0",))
    assert 0 < st["query_p50_s"] < st["query_p99_s"]
    assert st["completed"]["query"] == 4
    assert np.isnan(st["insert_p50_s"])         # nothing observed


# ---------------------------------------------------------------------- #
# tenants                                                                #
# ---------------------------------------------------------------------- #

def test_check_specs_validation():
    ok = check_specs([("a", 0.1, 5), TenantSpec("b", 0.2, 3)])
    assert [s.name for s in ok] == ["a", "b"]
    for bad, msg in [
        ([], "at least one"),
        ([("a/b", 0.1, 5)], "must match"),
        ([("a", 0.1, 5), ("a", 0.2, 3)], "duplicate"),
        ([("a", 0.0, 5)], "eps"),
        ([("a", 0.1, 0)], "min_pts"),
    ]:
        with pytest.raises(ValueError, match=msg):
            check_specs(bad)


def test_tenants_share_one_index_build():
    pts = pointclouds.load("blobs", 600, seed=2)
    prev = obs_metrics.active()
    reg = obs_metrics.install(obs_metrics.Registry())
    try:
        dispatch.clear_cache()
        views = build_views(pts, [("tight", 0.03, 8), ("loose", 0.08, 4)])
        c = reg.get("dispatch_index_builds_total", index="fdbscan")
        assert c is not None and c.value == 1.0     # N tenants, one build
    finally:
        obs_metrics.install(prev) if prev is not None \
            else obs_metrics.uninstall()
    probes = _probe_mix(pts, 200, seed=9)
    for v in views:
        # each tenant's snapshot answers for its OWN (eps, min_pts)
        _assert_same(v.handle.query(probes), v.store.current().query(probes),
                     v.name)
    tight, loose = views
    # monotonicity across views: anything clustered at (eps=0.03, mp=8)
    # is clustered at (eps=0.08, mp=4) — neighbors only grow with eps and
    # the core threshold only drops (counts themselves saturate at each
    # tenant's own min_pts, so they are not comparable across tenants)
    t_lab = tight.store.current().query(probes).labels
    l_lab = loose.store.current().query(probes).labels
    assert np.all((t_lab == -1) | (l_lab != -1))


# ---------------------------------------------------------------------- #
# server end-to-end                                                      #
# ---------------------------------------------------------------------- #

SPECS = [("tight", 0.03, 8), ("loose", 0.08, 4)]
FAST_CFG = ServerConfig(max_batch=512, max_delay_s=0.001)


@pytest.fixture(scope="module")
def served():
    pts = pointclouds.load("blobs", 500, seed=4)
    srv = Server(pts[:400], SPECS, config=FAST_CFG)
    yield srv, pts
    srv.shutdown()


def test_server_query_matches_tenant_handles(served):
    srv, pts = served
    probes = _probe_mix(pts[:400], 150, seed=11)
    for v in srv._views:
        reply = srv.query(probes, tenant=v.name, timeout=60)
        _assert_same(v.handle.query(probes), reply, v.name)
        assert reply.tenant == v.name
        assert reply.version == v.store.version


def test_server_insert_ack_implies_visibility(served):
    srv, pts = served
    before = {v.name: v.store.version for v in srv._views}
    rep = srv.insert(pts[400:450], timeout=60)
    assert rep.watermark == 450
    for v in srv._views:
        assert rep.versions[v.name] > before[v.name]
    # acknowledged -> the very next query answers from the new state
    probes = _probe_mix(pts, 100, seed=13)
    for v in srv._views:
        _assert_same(v.handle.query(probes),
                     srv.query(probes, tenant=v.name, timeout=60), v.name)


def test_server_rejects_malformed_requests(served):
    srv, pts = served
    with pytest.raises(ValueError, match="unknown tenant"):
        srv.submit_query(pts[:4], tenant="nope")
    with pytest.raises(ValueError, match="pass tenant="):
        srv.submit_query(pts[:4])               # ambiguous: two tenants
    with pytest.raises(ValueError, match="finite"):
        srv.submit_query(np.full((4, 2), np.inf, np.float32),
                         tenant="tight")
    with pytest.raises(ValueError, match="max_batch"):
        srv.submit_query(np.zeros((FAST_CFG.max_batch + 1, 2), np.float32),
                         tenant="tight")
    with pytest.raises(ValueError, match="dimensionality"):
        srv.submit_query(np.zeros((4, 3), np.float32), tenant="tight")
    with pytest.raises(ValueError):
        srv.submit_insert(np.zeros((0, 2), np.float32))     # empty insert
    # a failed submit consumed no budget
    st = srv.stats()
    assert st["pending_requests"] == 0 and st["pending_inserts"] == 0


def test_server_empty_query_completes_inline(served):
    srv, _ = served
    rep = srv.query(np.zeros((0, 2), np.float32), tenant="tight",
                    timeout=5)
    assert rep.labels.shape == (0,) and rep.tenant == "tight"


def test_server_single_tenant_needs_no_name():
    pts = pointclouds.load("blobs", 300, seed=6)
    with Server(pts, [("only", EPS, MINPTS)], config=FAST_CFG) as srv:
        rep = srv.query(pts[:16], timeout=60)
        assert rep.tenant == "only"
        st = srv.stats()
        assert [t["name"] for t in st["tenants"]] == ["only"]
        assert st["tenants"][0]["version"] == 0
    assert srv.stats()["stopped"]


def test_server_queries_survive_concurrent_writes(served):
    """The acceptance property: the query plane never blocks behind the
    writer, answers stay exact for *some* published version, and the
    versions any single client observes never go backwards."""
    srv, pts = served
    probes = _probe_mix(pts, 64, seed=17)
    refs = {}                                   # version -> per-tenant ref

    def snapshot_refs():
        for v in srv._views:
            snap = v.store.current()
            refs.setdefault((v.name, snap.version), snap.query(probes))

    snapshot_refs()
    stop = threading.Event()
    errors: list = []

    def writer():
        rng = np.random.default_rng(23)
        try:
            while not stop.is_set():
                batch = pts[rng.integers(0, len(pts), 20)] \
                    + rng.normal(0, 0.01, (20, 2)).astype(np.float32)
                srv.insert(batch.astype(np.float32), timeout=60)
                snapshot_refs()
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=writer)
    t.start()
    try:
        last = {v.name: -1 for v in srv._views}
        deadline = time.monotonic() + 3.0
        n_done = 0
        while time.monotonic() < deadline:
            for v in srv._views:
                rep = srv.query(probes, tenant=v.name, timeout=60)
                assert rep.version >= last[v.name], "version went backwards"
                last[v.name] = rep.version
                ref = refs.get((v.name, rep.version))
                if ref is not None:             # raced publishes may skip
                    _assert_same(ref, rep, f"{v.name}@v{rep.version}")
                n_done += 1
    finally:
        stop.set()
        t.join(30)
    assert not errors, errors
    assert n_done > 10                          # the loop actually served


def test_server_shutdown_drains_and_sheds():
    pts = pointclouds.load("blobs", 300, seed=7)
    srv = Server(pts, [("t", EPS, MINPTS)], config=FAST_CFG)
    fut = srv.submit_query(pts[:32], tenant="t")
    srv.shutdown()
    rep = fut.result(timeout=10)                # admitted work drained
    assert rep.tenant == "t"
    with pytest.raises(Overloaded) as ei:       # new work shed, typed
        srv.submit_query(pts[:4], tenant="t")
    assert ei.value.reason == "shutdown"
    with pytest.raises(Overloaded):
        srv.submit_insert(pts[:4])
    srv.shutdown()                              # idempotent


def test_server_shutdown_without_drain_fails_pending():
    pts = pointclouds.load("blobs", 300, seed=8)
    srv = Server(pts, [("t", EPS, MINPTS)],
                 config=ServerConfig(max_batch=512, max_delay_s=5.0))
    fut = srv.submit_query(pts[:8], tenant="t")     # parked on deadline
    srv.shutdown(drain=False)
    with pytest.raises(RuntimeError, match="without drain"):
        fut.result(timeout=10)
    assert srv.stats()["pending_requests"] == 0     # budget released


# ---------------------------------------------------------------------- #
# jit-cache stability (the recompile witness)                            #
# ---------------------------------------------------------------------- #

def test_stream_query_recompiles_flat_at_steady_state():
    """Padded probe batches keep the jit cache warm: after one query per
    bucket, any probe count inside the bucket compiles nothing new."""
    pts = pointclouds.load("blobs", 600, seed=9)
    prev = obs_metrics.active()
    reg = obs_metrics.install(obs_metrics.Registry())
    try:
        h = _handle(pts)
        probes = _probe_mix(pts, 256, seed=19)
        h.query(probes[:bucket_size(65)])       # warm this bucket

        def recompiles():
            c = reg.get("stream_query_recompiles_total")
            return c.value if c is not None else 0.0

        c0 = recompiles()
        assert c0 >= 1.0                        # the warm call was counted
        for k in (65, 70, 90, bucket_size(65)):
            assert bucket_size(k) == bucket_size(65)
            h.query(probes[:k])
        assert recompiles() == c0               # same bucket: zero new
        h.query(probes[:256])                   # a NEW bucket does count
        assert recompiles() > c0
    finally:
        obs_metrics.install(prev) if prev is not None \
            else obs_metrics.uninstall()
