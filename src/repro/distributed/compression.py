"""Gradient-compression collectives (shard_map + psum demonstrations).

Two levels, both validated by subprocess multi-device tests:

* ``allreduce_bf16``   — genuine wire saving: grads cast to bf16 before the
  psum (half the bytes of f32 on the link), f32 accumulation after.
* ``allreduce_int8``   — 1-byte payload semantics: a globally agreed scale
  (pmax) quantizes to int8; the psum accumulates in int32 (XLA's collective
  payload here is int32 — true int8 transport needs a custom collective,
  noted honestly), dequantized afterwards. The *accuracy* contract of int8
  compression is what this validates; EXPERIMENTS.md quotes the wire-byte
  arithmetic for both.

``compressed_psum_tree`` applies either to a full gradient pytree inside a
shard_map'd data-parallel step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def allreduce_bf16(g, axis: str):
    return lax.psum(g.astype(jnp.bfloat16), axis).astype(jnp.float32)


def allreduce_int8(g, axis: str):
    amax = lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(grads, axis: str, method: str = "int8"):
    fn = {"int8": allreduce_int8, "bf16": allreduce_bf16,
          "none": lambda g, a: lax.psum(g, a)}[method]
    return jax.tree.map(lambda g: fn(g.astype(jnp.float32), axis), grads)


def make_dp_grad_fn(loss_fn, mesh, axis: str = "data", method: str = "int8"):
    """Data-parallel value+grad with compressed gradient all-reduce.

    ``loss_fn(params, batch) -> scalar``; params replicated, batch sharded
    on dim 0 over ``axis``. Returns (loss, grads) with grads averaged
    across the axis through the compressed collective.
    """
    from jax.sharding import PartitionSpec as P
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(params, batch):
        # mark params device-varying so the grads are the *local* (pre-
        # reduction) contributions — the compressed psum below is then the
        # one and only cross-replica reduction (VMA-aware AD would otherwise
        # insert its own full-precision psum for invariant params).
        from repro.distributed.sharding import vary
        params = jax.tree.map(lambda a: vary(a, axis), params)
        l, g = jax.value_and_grad(loss_fn)(params, batch)
        g = compressed_psum_tree(g, axis, method)
        g = jax.tree.map(lambda x: x / ndev, g)
        return lax.pmean(l, axis), g

    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(local, mesh, in_specs=(P(), P(axis)),
                            out_specs=(P(), P()))
