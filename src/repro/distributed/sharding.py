"""Per-tensor sharding resolution with divisibility checks.

Rules are by leaf name (the param dicts use stable names across archs);
every rule is validated against the actual dimension size and the mesh axis
size — a non-divisible dim falls back to replication, so *every* assigned
arch lowers on *every* mesh (e.g. gemma2's 8 heads on a model=16 axis
replicate heads and shard d_ff instead).

Layout summary (DESIGN.md §5):
  * tensor parallel ("model"): attention heads, FFN hidden, MoE experts
    (fallback: expert d_ff), vocab/embedding;
  * data parallel ("pod", "data"): batch dim of activations;
  * sequence parallel ("data"): KV-cache length for long-context decode;
  * ZeRO-1 ("data"): optimizer master/m/v sharded on the largest divisible
    dim on top of the param's model-axis sharding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> preferred dim to shard over the model axis, by ndim
# (negative dims are relative to the *unstacked* param; a leading superblock
# axis is detected via the "blocks" path component and offsets positive dims)
_MODEL_RULES: dict[str, dict[int, int]] = {
    "embed": {2: 0},        # vocab-parallel
    "unembed": {2: 1},
    "projector": {2: 1},
    "wq": {2: 1}, "wk": {2: 1}, "wv": {2: 1}, "wo": {2: 0},
    "bq": {1: 0}, "bk": {1: 0}, "bv": {1: 0},
    # dense mlp (2D) vs moe experts (3D): experts dim first, d_ff fallback
    "w_gate": {2: 1, 3: 0}, "w_up": {2: 1, 3: 0}, "w_down": {2: 0, 3: 0},
    "w_in": {2: 1}, "w_out": {2: 0}, "b_in": {1: 0},
    "router": {},
    # mamba
    "in_proj": {2: 1}, "out_proj": {2: 0}, "x_proj": {2: 0},
    "dt_proj": {2: 1}, "dt_bias": {1: 0}, "A_log": {2: 0}, "D": {1: 0},
    "conv_w": {2: 1}, "conv_b": {1: 0},
    # rwkv
    "wr": {2: 1}, "wg": {2: 1},
    "cm_wk": {2: 1}, "cm_wv": {2: 0}, "cm_wr": {2: 1},
}
_MOE_FALLBACK = {"w_gate": 2, "w_up": 2, "w_down": 1}  # shard d_ff instead


def vary(x, axis: str):
    """Mark ``x`` device-varying under shard_map's VMA typing.

    Version shim: newer jax spells this ``lax.pcast(..., to="varying")``
    (earlier ``lax.pvary``); on jax without VMA typing it is a no-op —
    replication is then governed by ``check_rep`` (see shard_map_compat).
    """
    pc = getattr(jax.lax, "pcast", None)
    if pc is not None:
        return pc(x, (axis,), to="varying")
    pv = getattr(jax.lax, "pvary", None)
    if pv is not None:
        return pv(x, (axis,))
    return x


def shard_map_compat(fn, mesh, in_specs, out_specs, check_vma: bool = True):
    """shard_map across the jax API renames.

    Newer jax: top-level ``jax.shard_map`` with ``check_vma``. Older jax:
    ``jax.experimental.shard_map.shard_map`` with ``check_rep`` — which we
    always disable there, since without VMA typing (``vary`` above being a
    no-op) its replication checker rejects valid loop-carried collectives.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def shard_bounds(pts: jax.Array, valid: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """AABB (lo, hi) of the *valid* resident points of a shard's slab.

    Padding sentinels (coordinates ~1e30 on the trailing shard) are masked
    out so they cannot stretch the box; a shard with no valid point (tiny
    ``n`` on a wide mesh) reports the unit box — visiting queries may pass
    that halo test, but its tree holds only sentinel primitives, so they
    die at the root box test having evaluated nothing.
    """
    big = jnp.asarray(jnp.inf, pts.dtype)
    lo = jnp.min(jnp.where(valid[:, None], pts, big), axis=0)
    hi = jnp.max(jnp.where(valid[:, None], pts, -big), axis=0)
    any_valid = jnp.any(valid)
    lo = jnp.where(any_valid, lo, jnp.zeros_like(lo))
    hi = jnp.where(any_valid, hi, jnp.ones_like(hi))
    return lo, hi


def halo_mask(q_pts: jax.Array, lo: jax.Array, hi: jax.Array,
              eps) -> jax.Array:
    """Which of ``q_pts`` lie in the eps-dilated slab of the AABB [lo, hi].

    This is the halo-exchange membership test (DESIGN.md §6): a traveling
    query farther than ``eps`` from a shard's resident bounding box cannot
    be within ``eps`` of any resident point, so its lane is marked inert
    before the local tree traversal — it is *not* part of that shard's halo.
    """
    from repro.core.lbvh import box_dist2
    d2 = box_dist2(q_pts, lo[None, :], hi[None, :])
    return d2 <= jnp.asarray(eps, q_pts.dtype) ** 2


def _axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)


def param_spec(path: tuple, shape: tuple, mesh: Mesh,
               model_axis: str = "model") -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    stacked = any(n in ("blocks", "enc_blocks") for n in names)
    off = 1 if stacked else 0
    base_ndim = len(shape) - off
    rule = _MODEL_RULES.get(leaf, {})
    dim = rule.get(base_ndim)
    msize = _axis_size(mesh, model_axis)
    spec = [None] * len(shape)
    if dim is not None and msize > 1:
        d = dim + off
        if shape[d] % msize == 0:
            spec[d] = model_axis
        elif base_ndim == 3 and leaf in _MOE_FALLBACK:
            d2 = _MOE_FALLBACK[leaf] + off
            if shape[d2] % msize == 0:
                spec[d2] = model_axis
    return P(*spec)


def params_shardings(params_shape, mesh: Mesh, model_axis: str = "model"):
    """NamedShardings for a params pytree (of arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf.shape, mesh, model_axis)),
        params_shape)


def zero1_spec(pspec: P, shape: tuple, mesh: Mesh,
               data_axis: str = "data") -> P:
    """Add ZeRO-1 data-axis sharding on the largest still-free dim."""
    dsize = _axis_size(mesh, data_axis)
    if dsize <= 1:
        return pspec
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    order = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in order:
        if spec[d] is None and shape[d] % dsize == 0:
            spec[d] = data_axis
            break
    return P(*spec)


def opt_shardings(opt_shape, params_shardings_tree, mesh: Mesh,
                  zero1: bool = True, data_axis: str = "data"):
    """Shardings for AdamWState: param sharding + optional ZeRO-1."""
    from repro.train.optimizer import AdamWState

    def like(tree_shape):
        return jax.tree.map(
            lambda leaf, ps: NamedSharding(
                mesh, zero1_spec(ps.spec, leaf.shape, mesh, data_axis)
                if zero1 else ps.spec),
            tree_shape, params_shardings_tree)

    return AdamWState(
        step=NamedSharding(mesh, P()),
        master=like(opt_shape.master),
        m=like(opt_shape.m),
        v=like(opt_shape.v))


def batch_shardings(batch_shape, mesh: Mesh, data_axes=("data",)):
    """Batch-dim sharding for input batches (dim 0), replicate if B=1."""
    axes = tuple(a for a in data_axes if _axis_size(mesh, a) > 1)
    total = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1

    def spec(leaf):
        if leaf.ndim >= 1 and total > 1 and leaf.shape[0] % total == 0:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, batch_shape)


def cache_shardings(cache_shape, mesh: Mesh, model_axis: str = "model",
                    data_axis: str = "data", batch: int = 1,
                    kv_policy: str = "auto"):
    """Decode-cache shardings.

    kv caches (NB, B, S, KV, hd): batch over data when divisible, else
    *sequence parallel* over data (long-context, B=1). The model axis goes
    by ``kv_policy``:
      * "heads":   kv heads over model (requires KV % model == 0),
      * "seq":     cache sequence over model — attention becomes seq-partial
                   reductions (small per-layer all-reduces) instead of
                   whole-cache all-gathers (EXPERIMENTS.md §Perf it. 2),
      * "headdim": head_dim over model (the naive fallback; measured to
                   force whole-cache all-gathers when KV % model != 0),
      * "auto":    heads if divisible else seq (the validated deployable
                   default after §Perf iteration 2; "headdim" reproduces
                   the recorded baseline).
    """
    msize = _axis_size(mesh, model_axis)
    dsize = _axis_size(mesh, data_axis)

    def spec(leaf):
        s = [None] * leaf.ndim
        if leaf.ndim >= 2 and dsize > 1:
            if leaf.shape[1] % dsize == 0:
                s[1] = data_axis                       # batch
            elif leaf.ndim >= 3 and leaf.shape[2] % dsize == 0:
                s[2] = data_axis                       # sequence (SP)
        if leaf.ndim >= 5 and msize > 1:
            heads_ok = leaf.shape[3] % msize == 0
            seq_ok = leaf.shape[2] % msize == 0 and s[2] is None
            policy = kv_policy
            if policy == "auto":
                policy = "heads" if heads_ok else ("seq" if seq_ok
                                                   else "headdim")
            if policy == "heads" and heads_ok:
                s[3] = model_axis                      # kv heads
            elif policy == "seq" and seq_ok:
                s[2] = model_axis                      # sequence over TP
            elif leaf.shape[4] % msize == 0:
                s[4] = model_axis                      # head_dim
        elif leaf.ndim == 4 and msize > 1 and leaf.shape[-2] % msize == 0:
            s[-2] = model_axis                         # mamba d_inner etc.
        elif leaf.ndim == 3 and msize > 1 and leaf.shape[-1] % msize == 0:
            s[-1] = model_axis
        return NamedSharding(mesh, P(*s))

    return jax.tree.map(spec, cache_shape)
