"""Mesh/shard_map utilities shared by the distributed DBSCAN paths.

Two kinds of helpers live here:

  * jax API compatibility shims (:func:`vary`, :func:`shard_map_compat`)
    so the collective programs lower across the ``shard_map`` /
    VMA-typing renames;
  * slab geometry for the sharded tree path (DESIGN.md §6):
    :func:`shard_bounds` fits a shard's resident AABB and
    :func:`halo_mask` is the eps-dilated membership test that decides
    which traveling queries must traverse a remote shard's tree at all.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh


def vary(x, axis: str):
    """Mark ``x`` device-varying under shard_map's VMA typing.

    Version shim: newer jax spells this ``lax.pcast(..., to="varying")``
    (earlier ``lax.pvary``); on jax without VMA typing it is a no-op —
    replication is then governed by ``check_rep`` (see shard_map_compat).
    """
    pc = getattr(jax.lax, "pcast", None)
    if pc is not None:
        return pc(x, (axis,), to="varying")
    pv = getattr(jax.lax, "pvary", None)
    if pv is not None:
        return pv(x, (axis,))
    return x


def shard_map_compat(fn, mesh, in_specs, out_specs, check_vma: bool = True):
    """shard_map across the jax API renames.

    Newer jax: top-level ``jax.shard_map`` with ``check_vma``. Older jax:
    ``jax.experimental.shard_map.shard_map`` with ``check_rep`` — which we
    always disable there, since without VMA typing (``vary`` above being a
    no-op) its replication checker rejects valid loop-carried collectives.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def shard_bounds(pts: jax.Array, valid: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """AABB (lo, hi) of the *valid* resident points of a shard's slab.

    Padding sentinels (coordinates ~1e30 on the trailing shard) are masked
    out so they cannot stretch the box; a shard with no valid point (tiny
    ``n`` on a wide mesh) reports the unit box — visiting queries may pass
    that halo test, but its tree holds only sentinel primitives, so they
    die at the root box test having evaluated nothing.
    """
    big = jnp.asarray(jnp.inf, pts.dtype)
    lo = jnp.min(jnp.where(valid[:, None], pts, big), axis=0)
    hi = jnp.max(jnp.where(valid[:, None], pts, -big), axis=0)
    any_valid = jnp.any(valid)
    lo = jnp.where(any_valid, lo, jnp.zeros_like(lo))
    hi = jnp.where(any_valid, hi, jnp.ones_like(hi))
    return lo, hi


def halo_mask(q_pts: jax.Array, lo: jax.Array, hi: jax.Array,
              eps) -> jax.Array:
    """Which of ``q_pts`` lie in the eps-dilated slab of the AABB [lo, hi].

    This is the halo-exchange membership test (DESIGN.md §6): a traveling
    query farther than ``eps`` from a shard's resident bounding box cannot
    be within ``eps`` of any resident point, so its lane is marked inert
    before the local tree traversal — it is *not* part of that shard's halo.
    """
    from repro.core.lbvh import box_dist2
    d2 = box_dist2(q_pts, lo[None, :], hi[None, :])
    return d2 <= jnp.asarray(eps, q_pts.dtype) ** 2


def _axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)
