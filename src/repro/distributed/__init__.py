from . import sharding

__all__ = ["sharding", "tree_dbscan_sharded"]


def __getattr__(name):
    # ring_dbscan imports repro.core (morton/fdbscan); keep that import
    # lazy so `repro.distributed.sharding` stays usable standalone.
    if name == "tree_dbscan_sharded":
        from .ring_dbscan import tree_dbscan_sharded
        return tree_dbscan_sharded
    raise AttributeError(name)
