"""Distributed DBSCAN: systolic ring over device shards (beyond-paper).

The paper's §6 lists distribution as future work; this is the TPU-native
extension (DESIGN.md §3). Points are Morton-sorted (spatial locality per
shard) and sharded over the mesh's data axis. Each phase is a *ring
systolic* pass: every device holds its resident block and a traveling
block; at each of the ``ndev`` steps it runs the dense pairwise tile
epilogue (neighbor count / min-label hook) between resident queries and the
traveling block, then rotates the traveling block with
``lax.ppermute`` — nearest-neighbor ICI traffic that overlaps with the tile
compute, exactly the collective/compute overlap pattern the MXU kernel
needs to stay fed.

Union-find across shards: labels are global indices; after each ring hook
sweep, labels are all-gathered (n x int32 — tiny next to the O(n^2/P)
distance work) and pointer jumping runs locally to a fixpoint. Sweeps
repeat until a global psum reports no change.

The per-tile epilogues default to the pure-jnp oracle (portable: CPU tests
run it under shard_map); on TPU the Pallas kernels in repro.kernels slot in
via ``use_pallas=True`` (same contract, validated against the same refs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import morton
from repro.core.fdbscan import DBSCANResult, _finalize
from repro.distributed import sharding

INT_MAX = jnp.iinfo(jnp.int32).max


def _vary(x, axis, enabled=True):
    """Mark a loop-carry init as device-varying (shard_map VMA typing)."""
    if not enabled:
        return x
    return sharding.vary(x, axis)


def _shard_map(fn, mesh, in_specs, out_specs, check_vma=True):
    # check_vma=False is required when pl.pallas_call runs inside the body
    # (its out_shape ShapeDtypeStructs carry no varying-axes typing).
    return sharding.shard_map_compat(fn, mesh, in_specs, out_specs,
                                     check_vma=check_vma)


def _count_tile(q, r, eps):
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    return jnp.sum(d2 <= eps * eps, axis=1).astype(jnp.int32)


def _minlabel_tile(q, r, labels_r, mask_r, eps):
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    ok = (d2 <= eps * eps) & mask_r[None, :]
    return jnp.min(jnp.where(ok, labels_r[None, :], INT_MAX), axis=1)


def _pallas_count(q, r, eps):
    from repro.kernels import pairwise_count
    return pairwise_count(q, r, eps, interpret=True)


def _pallas_minlabel(q, r, labels_r, mask_r, eps):
    from repro.kernels import pairwise_minlabel
    return pairwise_minlabel(q, r, labels_r, mask_r, eps, interpret=True)[0]


def ring_dbscan(points, eps: float, min_pts: int, mesh=None,
                axis: str = "data", use_pallas: bool = False,
                max_jump: int = 32) -> DBSCANResult:
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), (axis,))
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    pts_sorted, order, _ = morton.morton_sort(points)
    n_pad = ((n + ndev - 1) // ndev) * ndev
    pts_pad = jnp.pad(pts_sorted, ((0, n_pad - n), (0, 0)),
                      constant_values=1e30)  # sentinels never match
    n_loc = n_pad // ndev
    count_tile = _pallas_count if use_pallas else _count_tile
    minlabel_tile = _pallas_minlabel if use_pallas else _minlabel_tile
    check_vma = not use_pallas
    perm = [(i, (i + 1) % ndev) for i in range(ndev)]

    def kernel(local_pts):
        me = lax.axis_index(axis)
        offset = me.astype(jnp.int32) * n_loc
        gid = offset + jnp.arange(n_loc, dtype=jnp.int32)
        valid = gid < n

        # ---- phase 1 (preprocessing): ring neighbor count ----------------
        def count_body(i, carry):
            counts, block = carry
            counts = counts + count_tile(local_pts, block, eps)
            return counts, lax.ppermute(block, axis, perm)

        counts, _ = lax.fori_loop(
            0, ndev, count_body,
            (_vary(jnp.zeros(n_loc, jnp.int32), axis, check_vma), local_pts))
        core = (counts >= min_pts) & valid

        # ---- phase 2 (main): ring hook sweeps + global pointer jumping ---
        labels = jnp.where(core, gid, INT_MAX)

        def jump(labels):
            # collectives live in the body (not cond): the carry holds the
            # already-psum'd global change flag.
            def body(state):
                l, _ = state
                table = lax.all_gather(l, axis, tiled=True)   # (n_pad,)
                safe = jnp.where(l == INT_MAX, 0, l)
                nl = jnp.where(l == INT_MAX, l, table[safe])
                changed = lax.psum(jnp.any(nl != l).astype(jnp.int32), axis)
                return nl, _vary(changed > 0, axis, check_vma)

            labels, _ = lax.while_loop(lambda s: s[1], body,
                                       (labels, _vary(jnp.bool_(True), axis, check_vma)))
            return labels

        def sweep_body(state):
            labels, _ = state

            def ring(i, carry):
                best, blk_pts, blk_lab, blk_core = carry
                got = minlabel_tile(local_pts, blk_pts, blk_lab, blk_core, eps)
                best = jnp.minimum(best, got)
                return (best,
                        lax.ppermute(blk_pts, axis, perm),
                        lax.ppermute(blk_lab, axis, perm),
                        lax.ppermute(blk_core, axis, perm))

            best, _, _, _ = lax.fori_loop(
                0, ndev, ring,
                (_vary(jnp.full(n_loc, INT_MAX, jnp.int32), axis, check_vma),
                 local_pts, labels, core))
            new = jnp.where(core, jnp.minimum(labels, best), labels)
            new = jump(new)
            changed = lax.psum(jnp.any(new != labels).astype(jnp.int32), axis)
            return new, _vary(changed > 0, axis, check_vma)

        labels, _ = lax.while_loop(lambda s: s[1], sweep_body,
                                   (labels, _vary(jnp.bool_(True), axis, check_vma)))

        # ---- borders: one more ring pass over core roots ------------------
        def bring(i, carry):
            best, blk_pts, blk_lab, blk_core = carry
            got = minlabel_tile(local_pts, blk_pts, blk_lab, blk_core, eps)
            return (jnp.minimum(best, got),
                    lax.ppermute(blk_pts, axis, perm),
                    lax.ppermute(blk_lab, axis, perm),
                    lax.ppermute(blk_core, axis, perm))

        broot = jnp.where(core, labels, INT_MAX)
        best, _, _, _ = lax.fori_loop(
            0, ndev, bring,
            (_vary(jnp.full(n_loc, INT_MAX, jnp.int32), axis, check_vma),
             local_pts, broot, core))
        labels = jnp.where(core, labels, jnp.where(valid, best, INT_MAX))
        labels = jnp.where(labels == INT_MAX, jnp.int32(-1), labels)
        return labels, core

    fn = _shard_map(kernel, mesh, in_specs=P(axis),
                    out_specs=(P(axis), P(axis)), check_vma=check_vma)
    labels_pad, core_pad = jax.jit(fn)(pts_pad)
    labels_sorted = labels_pad[:n]   # -1 noise, else global sorted index
    core_sorted = core_pad[:n]
    labels, n_clusters = _finalize(labels_sorted, order, n)
    core_mask = jnp.zeros(n, bool).at[order].set(core_sorted)
    return DBSCANResult(labels=labels, core_mask=core_mask,
                        n_clusters=n_clusters, n_sweeps=-1)
