"""Distributed DBSCAN over device shards (beyond-paper).

The paper's §6 lists distribution as future work; this module carries two
multi-device strategies over the same outer protocol (global Morton sort,
contiguous slabs over the mesh's data axis, all-gather + pointer-jumping
label fixpoint):

* ``ring_dbscan`` — the dense *ring systolic* baseline: every phase rotates
  full point blocks and runs an O(n^2/P) pairwise tile per step. None of
  the tree machinery reaches it; it survives as the small-n fallback and
  the comparator for ``BENCH_distributed.json``.

* ``tree_dbscan_sharded`` — the tree-based path (DESIGN.md §6): each shard
  Morton-resorts its slab locally and builds a singleton-segment LBVH over
  it *inside* the jitted collective program; queries (not primitives)
  travel the ring, and at each stop only the **eps-halo** — traveling
  points within ``eps`` of the resident slab's AABB — traverses the local
  tree (``sharding.halo_mask``). Everything else dies before the root box
  test, so per-shard work collapses from the dense n^2/P tile to the
  sequential tree bound plus a boundary-slab term, while the label fixpoint
  (all-gather + pointer jumping) is unchanged.

Union-find across shards: labels are global (Morton-sorted) indices; after
each hook sweep, labels are all-gathered (n x int32 — tiny next to the
distance work) and pointer jumping runs locally to a fixpoint. Sweeps
repeat until a global psum reports no change.

The ring's per-tile epilogues default to the pure-jnp oracle (portable: CPU
tests run it under shard_map); on TPU the Pallas kernels in repro.kernels
slot in via ``use_pallas=True`` (same contract, validated against the same
refs).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import grid, lbvh, morton, traversal
from repro.core.fdbscan import DBSCANResult, _finalize
from repro.distributed import sharding

INT_MAX = jnp.iinfo(jnp.int32).max


def _vary(x, axis, enabled=True):
    """Mark a loop-carry init as device-varying (shard_map VMA typing)."""
    if not enabled:
        return x
    return sharding.vary(x, axis)


def _shard_map(fn, mesh, in_specs, out_specs, check_vma=True):
    # check_vma=False is required when pl.pallas_call runs inside the body
    # (its out_shape ShapeDtypeStructs carry no varying-axes typing).
    return sharding.shard_map_compat(fn, mesh, in_specs, out_specs,
                                     check_vma=check_vma)


def _count_tile(q, r, eps):
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    return jnp.sum(d2 <= eps * eps, axis=1).astype(jnp.int32)


def _minlabel_tile(q, r, labels_r, mask_r, eps):
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    ok = (d2 <= eps * eps) & mask_r[None, :]
    return jnp.min(jnp.where(ok, labels_r[None, :], INT_MAX), axis=1)


def _pallas_count(q, r, eps):
    from repro.kernels import pairwise_count
    return pairwise_count(q, r, eps, interpret=True)


def _pallas_minlabel(q, r, labels_r, mask_r, eps):
    from repro.kernels import pairwise_minlabel
    return pairwise_minlabel(q, r, labels_r, mask_r, eps, interpret=True)[0]


def ring_dbscan(points, eps: float, min_pts: int, mesh=None,
                axis: str = "data", use_pallas: bool = False,
                max_jump: int = 32) -> DBSCANResult:
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), (axis,))
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    pts_sorted, order, _ = morton.morton_sort(points)
    n_pad = ((n + ndev - 1) // ndev) * ndev
    pts_pad = jnp.pad(pts_sorted, ((0, n_pad - n), (0, 0)),
                      constant_values=1e30)  # sentinels never match
    n_loc = n_pad // ndev
    count_tile = _pallas_count if use_pallas else _count_tile
    minlabel_tile = _pallas_minlabel if use_pallas else _minlabel_tile
    check_vma = not use_pallas
    perm = [(i, (i + 1) % ndev) for i in range(ndev)]

    def kernel(local_pts):
        me = lax.axis_index(axis)
        offset = me.astype(jnp.int32) * n_loc
        gid = offset + jnp.arange(n_loc, dtype=jnp.int32)
        valid = gid < n

        # ---- phase 1 (preprocessing): ring neighbor count ----------------
        def count_body(i, carry):
            counts, block = carry
            counts = counts + count_tile(local_pts, block, eps)
            return counts, lax.ppermute(block, axis, perm)

        counts, _ = lax.fori_loop(
            0, ndev, count_body,
            (_vary(jnp.zeros(n_loc, jnp.int32), axis, check_vma), local_pts))
        core = (counts >= min_pts) & valid

        # ---- phase 2 (main): ring hook sweeps + global pointer jumping ---
        labels = jnp.where(core, gid, INT_MAX)

        def jump(labels):
            # collectives live in the body (not cond): the carry holds the
            # already-psum'd global change flag.
            def body(state):
                l, _ = state
                table = lax.all_gather(l, axis, tiled=True)   # (n_pad,)
                safe = jnp.where(l == INT_MAX, 0, l)
                nl = jnp.where(l == INT_MAX, l, table[safe])
                changed = lax.psum(jnp.any(nl != l).astype(jnp.int32), axis)
                return nl, _vary(changed > 0, axis, check_vma)

            labels, _ = lax.while_loop(lambda s: s[1], body,
                                       (labels, _vary(jnp.bool_(True), axis, check_vma)))
            return labels

        def sweep_body(state):
            labels, _, n_sw = state

            def ring(i, carry):
                best, blk_pts, blk_lab, blk_core = carry
                got = minlabel_tile(local_pts, blk_pts, blk_lab, blk_core, eps)
                best = jnp.minimum(best, got)
                return (best,
                        lax.ppermute(blk_pts, axis, perm),
                        lax.ppermute(blk_lab, axis, perm),
                        lax.ppermute(blk_core, axis, perm))

            best, _, _, _ = lax.fori_loop(
                0, ndev, ring,
                (_vary(jnp.full(n_loc, INT_MAX, jnp.int32), axis, check_vma),
                 local_pts, labels, core))
            new = jnp.where(core, jnp.minimum(labels, best), labels)
            new = jump(new)
            changed = lax.psum(jnp.any(new != labels).astype(jnp.int32), axis)
            return new, _vary(changed > 0, axis, check_vma), n_sw + 1

        labels, _, n_sweeps = lax.while_loop(
            lambda s: s[1], sweep_body,
            (labels, _vary(jnp.bool_(True), axis, check_vma),
             _vary(jnp.int32(0), axis, check_vma)))

        # ---- borders: one more ring pass over core roots ------------------
        def bring(i, carry):
            best, blk_pts, blk_lab, blk_core = carry
            got = minlabel_tile(local_pts, blk_pts, blk_lab, blk_core, eps)
            return (jnp.minimum(best, got),
                    lax.ppermute(blk_pts, axis, perm),
                    lax.ppermute(blk_lab, axis, perm),
                    lax.ppermute(blk_core, axis, perm))

        broot = jnp.where(core, labels, INT_MAX)
        best, _, _, _ = lax.fori_loop(
            0, ndev, bring,
            (_vary(jnp.full(n_loc, INT_MAX, jnp.int32), axis, check_vma),
             local_pts, broot, core))
        labels = jnp.where(core, labels, jnp.where(valid, best, INT_MAX))
        labels = jnp.where(labels == INT_MAX, jnp.int32(-1), labels)
        return labels, core, jnp.reshape(n_sweeps, (1,))

    fn = _shard_map(kernel, mesh, in_specs=P(axis),
                    out_specs=(P(axis), P(axis), P(axis)),
                    check_vma=check_vma)
    labels_pad, core_pad, sweeps_dev = jax.jit(fn)(pts_pad)
    labels_sorted = labels_pad[:n]   # -1 noise, else global sorted index
    core_sorted = core_pad[:n]
    labels, n_clusters = _finalize(labels_sorted, order, n)
    core_mask = jnp.zeros(n, bool).at[order].set(core_sorted)
    return DBSCANResult(labels=labels, core_mask=core_mask,
                        n_clusters=n_clusters,
                        n_sweeps=int(sweeps_dev[0]), backend="ring")



@lru_cache(maxsize=16)
def _sharded_programs(mesh, axis: str, n: int, n_pad: int, eps: float,
                      min_pts: int):
    """Compile (build, sweep, border) collective programs for one config.

    The host sweep loop calls the sweep program once per sweep; caching by
    (mesh, n, eps, min_pts) keeps repeat runs — parameter sweeps, property
    tests — from retracing three shard_map programs per call.
    """
    ndev = sharding._axis_size(mesh, axis)
    n_loc = n_pad // ndev
    perm = [(i, (i + 1) % ndev) for i in range(ndev)]

    def rotate(*xs):
        return tuple(lax.ppermute(x, axis, perm) for x in xs)

    def slab_ids():
        me = lax.axis_index(axis)
        gid = me.astype(jnp.int32) * n_loc + jnp.arange(n_loc,
                                                        dtype=jnp.int32)
        return gid, gid < n

    def halo_ids(idx, blk_pts, blk_on):
        # the eps-dilated boundary slab: a traveling query farther than
        # eps from the resident AABB cannot match any resident point
        active = blk_on & sharding.halo_mask(blk_pts, idx["lo"], idx["hi"],
                                             eps)
        return jnp.where(active, 0, jnp.int32(-1)), active

    def jump(labels):
        # all-gather + pointer jumping (labels are global sorted indices;
        # chains strictly decrease, so this terminates)
        def body(state):
            l, _ = state
            table = lax.all_gather(l, axis, tiled=True)   # (n_pad,)
            safe = jnp.where(l == INT_MAX, 0, l)
            nl = jnp.where(l == INT_MAX, l, table[safe])
            changed = lax.psum(jnp.any(nl != l).astype(jnp.int32), axis)
            return nl, changed > 0

        labels, _ = lax.while_loop(lambda s: s[1], body,
                                   (labels, jnp.bool_(True)))
        return labels

    def build_kernel(local_pts):
        """Per-shard index build + the traveling-query count phase."""
        gid, valid = slab_ids()

        lo, hi = sharding.shard_bounds(local_pts, valid)
        codes = morton.morton_encode(local_pts, lo=lo, hi=hi)
        codes = jnp.where(valid, codes, jnp.uint32(0xFFFFFFFF))
        lorder = jnp.argsort(codes)       # local sorted order of the slab
        lpts = local_pts[lorder]
        segs = grid.singleton_segments(lpts, lorder, codes[lorder])
        tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
        idx = {"tree": tree, "segs": segs, "lorder": lorder,
               "lvalid": valid[lorder], "lo": lo, "hi": hi}
        zero_i = jnp.zeros(n_loc, jnp.int32)

        def count_body(i, carry):
            blk_pts, blk_on, blk_cnt, blk_ev = carry
            qids, active = halo_ids(idx, blk_pts, blk_on)
            # the traveling slab is an external-predicate batch against the
            # resident tree; only halo lanes (qids >= 0) traverse
            tr = traversal.traverse_impl(
                tree, segs,
                traversal.intersects(traversal.sphere(eps), ids=qids,
                                     pts=blk_pts),
                traversal.CountVisitor(cap=min_pts))
            blk_cnt = blk_cnt + jnp.where(active, tr.acc, 0)
            return rotate(blk_pts, blk_on, blk_cnt, blk_ev + tr.evals)

        _, _, counts, evals = lax.fori_loop(
            0, ndev, count_body, (local_pts, valid, zero_i, zero_i))
        core = (counts >= min_pts) & valid
        labels0 = jnp.where(core, gid, INT_MAX)
        return idx, core, labels0, evals

    def minlabel_rotation(local_pts, idx, point_vals, gather_mask, blk_on,
                          acc0):
        """Rotate ``(queries, acc)`` around the full ring, gathering the
        min of ``point_vals`` over masked resident neighbors at each halo
        stop. Returns (best, evals) home-aligned — shared by the sweep and
        border phases (same protocol, different values/queries)."""
        def ring_step(i, carry):
            blk_pts, on, blk_acc, blk_ev = carry
            qids, active = halo_ids(idx, blk_pts, on)
            # seed the carry with the traveling partial min: a query chains
            # its running answer across successive shard visits this way
            tr = traversal.traverse_impl(
                idx["tree"], idx["segs"],
                traversal.intersects(traversal.sphere(eps), ids=qids,
                                     pts=blk_pts),
                traversal.MinLabelVisitor(point_vals, gather_mask),
                carry=traversal.AccHits(acc=blk_acc,
                                        hits=jnp.zeros_like(blk_acc)))
            blk_acc = jnp.where(active, tr.acc, blk_acc)
            return rotate(blk_pts, on, blk_acc, blk_ev + tr.evals)

        _, _, best, evals = lax.fori_loop(
            0, ndev, ring_step,
            (local_pts, blk_on, acc0, jnp.zeros(n_loc, jnp.int32)))
        return best, evals

    def sweep_kernel(local_pts, idx, core, labels):
        """One traveling min-label sweep + pointer jumping + change psum."""
        gather_core = core[idx["lorder"]] & idx["lvalid"]
        _, valid = slab_ids()
        best, evals = minlabel_rotation(local_pts, idx,
                                        labels[idx["lorder"]], gather_core,
                                        valid & core, labels)
        new = jnp.where(core, jnp.minimum(labels, best), labels)
        new = jump(new)
        changed = lax.psum(jnp.any(new != labels).astype(jnp.int32), axis)
        return new, jnp.reshape(changed > 0, (1,)), evals

    def border_kernel(local_pts, idx, core, labels):
        """Borders: one rotation of the non-core queries over core roots."""
        root_l = jnp.where(core[idx["lorder"]], labels[idx["lorder"]],
                           INT_MAX)
        gather_core = core[idx["lorder"]] & idx["lvalid"]
        _, valid = slab_ids()
        best, evals = minlabel_rotation(local_pts, idx, root_l, gather_core,
                                        valid & ~core,
                                        jnp.full(n_loc, INT_MAX, jnp.int32))
        labels = jnp.where(core, labels, jnp.where(valid, best, INT_MAX))
        return jnp.where(labels == INT_MAX, jnp.int32(-1), labels), evals

    # check_vma=False: the traversal engine's loop carries mix replicated
    # constants with device-varying state; its while_loops carry no
    # collectives, so the replication checker's complaint is spurious here.
    def smap(fn, n_in):
        return jax.jit(_shard_map(fn, mesh, in_specs=(P(axis),) * n_in,
                                  out_specs=P(axis), check_vma=False))
    return smap(build_kernel, 1), smap(sweep_kernel, 4), smap(border_kernel, 4)


def tree_dbscan_sharded(points, eps: float, min_pts: int, mesh=None,
                        axis: str = "data",
                        with_stats: bool = False):
    """Shard-local LBVH traversal + eps-halo exchange (DESIGN.md §6).

    Protocol per phase (count / sweep / border): the shard's slab of the
    globally Morton-sorted array travels the ring as *external queries*;
    at each of the ``ndev`` stops, the traveling points inside the resident
    shard's eps-dilated AABB (``sharding.halo_mask`` — the halo) traverse
    the resident tree, and the per-query partial result (count or running
    min label) travels on with the block. After a full rotation the block
    is home carrying its global answer. Exchanged points are *queries*, not
    tree primitives: no shard ever rebuilds its index for foreign points,
    and exactness needs no assumption that spatial neighbors land on
    Morton-adjacent shards (a query visits every shard and is simply inert
    wherever it is outside the halo).

    Per-visit neighbor counts saturate at ``min_pts``; the home-shard sum
    of the saturated per-visit counts crosses ``min_pts`` iff the true
    global count does, so the early exit survives distribution.

    The sweep fixpoint is driven from the host (one jitted collective
    program per sweep, like the single-device host loop): nesting the
    traversal's data-divergent ``while_loop`` inside a device-synchronized
    ``while_loop`` that carries collectives deadlocks the CPU backend's
    rendezvous, and a host loop also hands back per-sweep work stats for
    free. The per-shard index is built once and threaded through sharded
    outputs, so sweeps rebuild nothing.

    Returns a :class:`DBSCANResult` (labels/core identical to single-device
    ``dbscan``); with ``with_stats=True``, also a dict with the exact
    distance-evaluation count (the paper's work metric) and ring-equivalent
    comparators.
    """
    if eps < 0:
        raise ValueError(f"eps must be non-negative; got {eps}"
                         " (a negative eps would be squared away silently)")
    points = jnp.asarray(points)
    if not jnp.issubdtype(points.dtype, jnp.floating):
        points = points.astype(jnp.float32)
    n, d = points.shape
    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), (axis,))
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    ndev = sharding._axis_size(mesh, axis)

    pts_sorted, order, _ = morton.morton_sort(points)
    n_pad = ((n + ndev - 1) // ndev) * ndev
    n_loc = n_pad // ndev
    if n_loc < 2:
        # a shard-local LBVH needs >= 2 primitives; inputs this tiny take
        # the dense ring (whose cost is trivial at this scale) — stats keep
        # the normal shape, with the ring's work on both sides of the ratio
        res = ring_dbscan(points, eps, min_pts, mesh=mesh, axis=axis)
        ring_evals = (2 + res.n_sweeps) * n_pad * n_pad
        return (res, {"distance_evals": ring_evals,
                      "ring_distance_evals": ring_evals, "ndev": ndev,
                      "n_pad": n_pad,
                      "n_sweeps": res.n_sweeps}) if with_stats else res
    pts_pad = jnp.pad(pts_sorted, ((0, n_pad - n), (0, 0)),
                      constant_values=1e30)  # sentinels never match
    build_fn, sweep_fn, border_fn = _sharded_programs(
        mesh, axis, n, n_pad, float(eps), int(min_pts))

    idx, core_pad, labels_pad, evals = build_fn(pts_pad)
    total_evals = int(jnp.sum(evals))
    n_sweeps = 0
    while True:
        labels_pad, changed, evals = sweep_fn(pts_pad, idx, core_pad,
                                              labels_pad)
        n_sweeps += 1
        total_evals += int(jnp.sum(evals))
        if not bool(changed[0]):
            break
    labels_pad, evals = border_fn(pts_pad, idx, core_pad, labels_pad)
    total_evals += int(jnp.sum(evals))

    labels_sorted = labels_pad[:n]
    core_sorted = core_pad[:n]
    labels, n_clusters = _finalize(labels_sorted, order, n)
    core_mask = jnp.zeros(n, bool).at[order].set(core_sorted)
    res = DBSCANResult(labels=labels, core_mask=core_mask,
                       n_clusters=n_clusters, n_sweeps=n_sweeps,
                       backend="sharded")
    if not with_stats:
        return res
    # ring comparator: every dense phase is a full n_pad^2 pairwise pass
    # (count + n_sweeps sweep rotations + border)
    stats = {
        "distance_evals": total_evals,
        "ring_distance_evals": (2 + n_sweeps) * n_pad * n_pad,
        "ndev": ndev, "n_pad": n_pad, "n_sweeps": n_sweeps,
    }
    return res, stats
