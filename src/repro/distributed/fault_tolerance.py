"""Fleet-resilience utilities: straggler detection, heartbeats, restart.

On a 1000+ node fleet the three recurring events are (a) slow nodes
(stragglers), (b) dead nodes, (c) preemptions. The framework's answers:

* ``StragglerMonitor`` — robust z-score (median/MAD) over recent step
  times; a step beyond ``threshold`` MADs flags a straggler. On a real
  fleet the flag feeds the scheduler's replace/evict hook (``on_straggler``);
  the default hook just logs.
* ``HeartbeatBoard`` — per-worker heartbeat timestamps with a liveness
  sweep; workers silent for > ``timeout`` are declared dead (the trigger
  for checkpoint-restart with a shrunken mesh — the elastic path in
  ``checkpoint.restore(shardings=new_mesh_shardings)``).
* ``run_resilient`` — the supervisor loop used by launch/train.py: run
  steps, checkpoint every ``ckpt_every``, and on any step exception restore
  the latest checkpoint and continue (bounded retries).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 6.0,
                 on_straggler: Optional[Callable] = None):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler or (lambda *a: None)
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        import numpy as np
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            mad = float(np.median(np.abs(np.asarray(self.times) - med)))
            sigma = max(1.4826 * mad, 1e-6)
            if dt - med > self.threshold * sigma:
                self.flagged.append((step, dt, med))
                self.on_straggler(step, dt, med)
                self.times.append(dt)
                return True
        self.times.append(dt)
        return False


class HeartbeatBoard:
    def __init__(self, timeout: float = 60.0):
        self.timeout = timeout
        self.last = {}

    def beat(self, worker: str, t: float | None = None):
        self.last[worker] = time.time() if t is None else t

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = time.time() if now is None else now
        return [w for w, t in self.last.items() if now - t > self.timeout]


@dataclass
class ResilienceReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0
    history: list = field(default_factory=list)


def run_resilient(step_fn, state, n_steps: int, *, ckpt, ckpt_every: int = 10,
                  max_retries: int = 3, monitor: StragglerMonitor | None = None,
                  on_metrics: Optional[Callable] = None) -> tuple:
    """Supervisor loop: step, checkpoint, restore-on-failure.

    ``step_fn(state, step) -> (state, metrics)`` must be a pure step.
    ``state`` must match the checkpoint target structure.
    """
    report = ResilienceReport()
    monitor = monitor or StragglerMonitor()
    start = ckpt.latest_step()
    step = 0
    if start is not None:
        state, step = ckpt.restore(state)
        report.restores += 1
    retries = 0
    while step < n_steps:
        t0 = time.time()
        try:
            state, metrics = step_fn(state, step)
        except Exception:
            report.failures += 1
            retries += 1
            if retries > max_retries:
                raise
            latest = ckpt.latest_step()
            if latest is not None:
                state, step = ckpt.restore(state)
                report.restores += 1
            continue
        retries = 0
        dt = time.time() - t0
        if monitor.record(step, dt):
            report.stragglers += 1
        step += 1
        report.steps_run += 1
        report.history.append(metrics)
        if on_metrics:
            on_metrics(step, metrics)
        if step % ckpt_every == 0 or step == n_steps:
            ckpt.wait()
            ckpt.save(step, state, blocking=False)
    ckpt.wait()
    return state, step, report
