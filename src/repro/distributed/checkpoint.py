"""Fault-tolerant checkpointing: atomic, async, elastic.

* atomic: write into ``step_XXXX.tmp`` then ``os.replace`` -> a crash never
  leaves a half-written checkpoint visible;
* async: ``save(..., blocking=False)`` snapshots to host (device_get) and
  writes on a daemon thread, overlapping I/O with the next steps;
* elastic: ``restore(..., shardings=...)`` re-device_puts with the *target*
  shardings, so a checkpoint written on one mesh restores onto any other
  (mesh shape changes across restarts are the common elasticity event);
* retention: keeps the newest ``keep`` checkpoints.

Format: one .npz of flattened leaves (keys are joined tree paths) plus a
JSON manifest (step, leaf dtypes/shapes, mesh note). For multi-host fleets
each host would write its addressable shards; on this single-host container
the arrays are written whole — the layout and the restore path are the same.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

import jax
import numpy as np

_SEP = "||"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx",
                        getattr(k, "name", k)))) for k in path)
        out[key] = leaf
    return out


def _unflatten_into(target, arrays):
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx",
                        getattr(k, "name", k)))) for k in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{a.shape} vs {leaf.shape}")
        leaves.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree, blocking: bool = True, extra: dict | None = None):
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        manifest = {"step": int(step), "time": time.time(),
                    "leaves": {k: [str(v.dtype), list(v.shape)]
                               for k, v in host.items()},
                    "extra": extra or {}}
        if blocking:
            self._write(step, host, manifest)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, manifest), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host, manifest):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "manifest.json")) as f:
            f.fileno()  # ensure visible before rename
        if os.path.exists(final):
            return
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            name = os.path.join(self.dir, f"step_{s:08d}")
            for root, dirs, files in os.walk(name, topdown=False):
                for fn in files:
                    os.remove(os.path.join(root, fn))
                os.rmdir(root)

    # ---------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", n)
            if m and os.path.exists(os.path.join(self.dir, n, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: int | None = None, shardings=None):
        """Restore into the structure of ``target`` (arrays or SDS).

        ``shardings``: optional pytree of NamedShardings — the *elastic*
        path: leaves are device_put with the new mesh's shardings.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten_into(target, arrays)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree, step
