import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above precedes any jax
import so 512 placeholder host devices exist). Per cell, three compiles:

  1. multi-pod (2,16,16) mesh, scanned blocks  — proves the "pod" axis
     shards (deliverable e); cheap compile.
  2. single-pod (16,16) mesh, scanned blocks   — the deployable program;
     memory_analysis() proves per-device fit.
  3. single-pod, *unrolled* blocks             — XLA cost_analysis counts a
     while body once, so roofline FLOPs/bytes/collectives are extracted
     from a fully unrolled lowering (compile-heavy; roofline table is
     single-pod only, matching the assignment).

Sequential-scan caveat (DESIGN.md §6): the wkv/SSM *time* recurrences stay
`lax.scan` even when blocks are unrolled; their inner elementwise flops are
a low single-digit % of layer flops (projections/einsums sit outside the
scan) and are noted as an undercount in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  python -m repro.launch.dryrun --all --out-dir results/dryrun --resume
"""
import argparse
import json
import sys
import time
import traceback

import jax


def _memory_record(compiled):
    try:
        mem = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:
        return {"error": str(e)}


def _compile(arch, shape, mesh, unroll, **kw):
    from repro.launch import specs
    fn, args, in_sh, out_sh, meta = specs.build_cell(
        arch, shape, mesh, unroll=unroll, **kw)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    return compiled, meta


def run_cell(arch: str, shape: str, *, n_micro: int = 1, zero1: bool = True,
             remat: bool = True, phases=("multi", "fit", "roofline"),
             kv_policy: str = "auto", grad_rs: bool = False) -> dict:
    from repro.launch import mesh as mesh_lib
    from repro.launch import roofline, specs

    reason = specs.skip_reason(arch, shape)
    rec = {"arch": arch, "shape": shape}
    if reason:
        rec.update(status="SKIP", reason=reason)
        return rec
    kw = dict(n_micro=n_micro, zero1=zero1, remat=remat,
              kv_policy=kv_policy, grad_rs=grad_rs)

    if "multi" in phases:  # (2,16,16): the pod axis shards
        t0 = time.time()
        mesh = mesh_lib.make_production_mesh(multi_pod=True)
        compiled, _ = _compile(arch, shape, mesh, unroll=False, **kw)
        rec["multi_pod"] = {"mesh": "2x16x16", "status": "OK",
                            "compile_s": round(time.time() - t0, 1),
                            "memory": _memory_record(compiled)}
        del compiled

    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size

    if "fit" in phases:  # deployable scanned program: memory fit proof
        t0 = time.time()
        compiled, _ = _compile(arch, shape, mesh, unroll=False, **kw)
        rec["fit"] = {"mesh": "16x16", "status": "OK",
                      "compile_s": round(time.time() - t0, 1),
                      "memory": _memory_record(compiled),
                      "hbm_per_chip": mesh_lib.HBM_PER_CHIP}
        del compiled

    if "roofline" in phases:  # unrolled: accurate cost/collectives
        t0 = time.time()
        compiled, meta = _compile(arch, shape, mesh, unroll=True, **kw)
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
        wire = roofline.collective_wire_bytes(compiled.as_text(), n_dev)
        terms = roofline.roofline_terms(
            cost, wire, peak_flops=mesh_lib.PEAK_FLOPS_BF16,
            hbm_bw=mesh_lib.HBM_BW, ici_bw=mesh_lib.ICI_BW)
        n_active = meta["params_active"]
        if meta["kind"] == "train":
            model_flops = 6 * n_active * meta["batch"] * meta["seq"] / n_dev
        elif meta["kind"] == "prefill":
            model_flops = 2 * n_active * meta["batch"] * meta["seq"] / n_dev
        else:
            model_flops = 2 * n_active * meta["batch"] / n_dev
        rec["meta"] = meta
        rec["roofline"] = dict(
            terms, compile_s=round(time.time() - t0, 1),
            wire_by_kind={k: v for k, v in wire.items() if k != "counts"},
            collective_counts=wire["counts"],
            model_flops_per_dev=model_flops,
            useful_flops_ratio=(model_flops / terms["hlo_flops"]
                                if terms["hlo_flops"] else None))
    rec["status"] = "OK"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--phases", default="multi,fit,roofline")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-policy", default="auto",
                    choices=["auto", "heads", "seq", "headdim"])
    ap.add_argument("--grad-rs", action="store_true",
                    help="pin ZeRO-1 grad shardings (reduce-scatter)")
    args = ap.parse_args()

    from repro.configs import names
    from repro.launch.specs import CELLS

    archs = names() if args.all else [args.arch]
    shapes = list(CELLS) if (args.all or not args.shape) else [args.shape]
    phases = tuple(args.phases.split(","))

    results = []
    for a in archs:
        for s in shapes:
            fname = (os.path.join(args.out_dir, f"{a}__{s}.json")
                     if args.out_dir else None)
            if args.resume and fname and os.path.exists(fname):
                print(f"[dryrun] {a}/{s}: cached", flush=True)
                results.append(json.load(open(fname)))
                continue
            try:
                rec = run_cell(a, s, n_micro=args.n_micro,
                               zero1=not args.no_zero1,
                               remat=not args.no_remat, phases=phases,
                               kv_policy=args.kv_policy,
                               grad_rs=args.grad_rs)
            except Exception:
                rec = {"arch": a, "shape": s, "status": "FAIL",
                       "error": traceback.format_exc()}
            extra = ""
            if rec.get("roofline"):
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" bound={r['bound_time_s']:.4f}s")
            print(f"[dryrun] {a}/{s}: {rec['status']}{extra}", flush=True)
            results.append(rec)
            if fname:
                os.makedirs(args.out_dir, exist_ok=True)
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results if len(results) > 1 else results[0], f, indent=1)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"[dryrun] done: {len(results)} cells, {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
