"""End-to-end resilient trainer.

Wires together: arch configs, synthetic data + DBSCAN dedup, sharded
train step (DP x TP on whatever devices exist), AdamW, atomic/async
checkpointing with auto-resume, straggler monitoring, and an optional
injected failure (--fail-at-step) to exercise the restart path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 100 --batch 8 --seq 128 --dedup --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test scale config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--dedup", action="store_true",
                    help="DBSCAN near-duplicate filtering in the pipeline")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject one failure (tests checkpoint-restart)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs import get
    from repro.data.dedup import dedup_batch
    from repro.data.lm_data import SyntheticLM
    from repro.distributed import sharding as shd
    from repro.distributed.checkpoint import CheckpointManager
    from repro.distributed.fault_tolerance import StragglerMonitor
    from repro.launch.mesh import make_host_mesh
    from repro.models import model
    from repro.train import step as step_lib
    from repro.train.optimizer import adamw_init

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"[train] {cfg.name}: {cfg.params_total()/1e6:.1f}M params, "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    params_sh = shd.params_shardings(params, mesh)
    params = jax.device_put(params, params_sh)
    opt = adamw_init(params)
    opt_sh = shd.opt_shardings(opt, params_sh, mesh, zero1=True)
    opt = jax.device_put(opt, opt_sh)

    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    metrics_sh = {"ce": repl, "aux": repl, "loss": repl, "step": repl}
    bsh = shd.batch_shardings(
        {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)},
        mesh, ("data",))
    step_fn = jax.jit(
        step_lib.make_train_step(cfg, n_micro=args.n_micro, lr=args.lr),
        in_shardings=(params_sh, opt_sh, bsh),
        out_shardings=(params_sh, opt_sh, metrics_sh))

    data = SyntheticLM(cfg.vocab_size, args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    monitor = StragglerMonitor(
        on_straggler=lambda s, dt, med: print(
            f"[straggler] step {s}: {dt:.3f}s vs median {med:.3f}s"))

    start = 0
    if ckpt and ckpt.latest_step() is not None:
        (params, opt), start = ckpt.restore((params, opt),
                                            shardings=(params_sh, opt_sh))
        print(f"[train] resumed from step {start}")

    dedup_stats = []
    t_start = time.time()
    failed_once = [False]
    for step in range(start, args.steps):
        raw = data.batch(step, args.batch)
        if args.dedup:
            filtered, idx = dedup_batch({"tokens": raw["tokens"]},
                                        pad_to=args.batch, min_pts=2)
            dedup_stats.append(len(np.unique(idx)) / args.batch)
            tokens = filtered["tokens"]
        else:
            tokens = raw["tokens"]
        batch = {"tokens": jax.device_put(jnp.asarray(tokens), bsh["tokens"])}
        t0 = time.time()
        if (args.fail_at_step is not None and step == args.fail_at_step
                and not failed_once[0]):
            failed_once[0] = True
            print(f"[train] injected failure at step {step}; restarting")
            if ckpt and ckpt.latest_step() is not None:
                ckpt.wait()
                (params, opt), step0 = ckpt.restore(
                    (params, opt), shardings=(params_sh, opt_sh))
                print(f"[train] restored step {step0}")
            continue
        params, opt, metrics = step_fn(params, opt, batch)
        monitor.record(step, time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            extra = (f" kept={np.mean(dedup_stats[-args.log_every:]):.2f}"
                     if dedup_stats else "")
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f}"
                  f"{extra}", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.wait()
            ckpt.save(step + 1, (params, opt), blocking=False)
    if ckpt:
        ckpt.wait()
        ckpt.save(args.steps, (params, opt))
    dt = time.time() - t_start
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"[train] done: {args.steps} steps in {dt:.1f}s "
          f"({tok_s:.0f} tok/s); final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
