# NOTE: dryrun is intentionally not imported here — it sets XLA_FLAGS on
# import and must only run as its own process (python -m repro.launch.dryrun).
from . import mesh, roofline, specs

__all__ = ["mesh", "roofline", "specs"]
