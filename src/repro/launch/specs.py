"""(arch x input-shape) cell definitions for the dry-run & roofline matrix.

``build_cell`` returns everything needed to lower a cell with zero device
allocation: the step function, ShapeDtypeStruct stand-ins for every input
(params and optimizer state included, via ``jax.eval_shape`` over the init),
and NamedShardings resolved per tensor (repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get
from repro.distributed import sharding as shd
from repro.models import model
from repro.train import step as step_lib
from repro.train.optimizer import adamw_init


class Cell(NamedTuple):
    kind: str       # train | prefill | decode
    seq: int
    batch: int


CELLS = {
    "train_4k": Cell("train", 4096, 256),
    "prefill_32k": Cell("prefill", 32768, 32),
    "decode_32k": Cell("decode", 32768, 128),
    "long_500k": Cell("decode", 524288, 1),
}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get(arch)
    if shape == "long_500k" and not cfg.is_subquadratic:
        if cfg.is_encdec:
            return "enc-dec audio arch: decoder context is architecturally 448"
        if "attn" in cfg.layer_pattern and cfg.sliding_window:
            return "global full-attention layers dominate at 500k (gemma2)"
        return "pure full-attention arch: quadratic prefill / unbounded cache"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, cell: Cell, param_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for one input batch of this cell."""
    B, S = cell.batch, cell.seq
    if cell.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    batch = {}
    if cfg.is_encdec:
        batch["frames"] = _sds((B, S, cfg.d_model), param_dtype)
        batch["tokens"] = _sds((B, S), jnp.int32)
        return batch
    n_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    batch["tokens"] = _sds((B, n_text), jnp.int32)
    if cfg.frontend == "vision":
        batch["patches"] = _sds((B, cfg.n_frontend_tokens,
                                 model.VISION_EMBED_DIM), param_dtype)
    return batch


def build_cell(arch: str, shape: str, mesh, *, n_micro: int = 1,
               zero1: bool = True, param_dtype=jnp.bfloat16,
               remat: bool = True, data_axes=None, unroll: bool = True,
               kv_policy: str = "auto", grad_rs: bool = False):
    """Returns (fn, args, in_shardings, out_shardings, meta)."""
    cfg = get(arch)
    cell = CELLS[shape]
    if data_axes is None:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    params_sds = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0), param_dtype))
    params_sh = shd.params_shardings(params_sds, mesh)
    repl = NamedSharding(mesh, P())
    meta = {"arch": arch, "shape": shape, "kind": cell.kind,
            "batch": cell.batch, "seq": cell.seq,
            "params_total": cfg.params_total(),
            "params_active": cfg.params_per_token_active()}

    if cell.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        opt_sh = shd.opt_shardings(opt_sds, params_sh, mesh, zero1=zero1)
        bsds = batch_specs(cfg, cell, param_dtype)
        bsh = shd.batch_shardings(bsds, mesh, data_axes)
        fn = step_lib.make_train_step(
            cfg, n_micro=n_micro, unroll=unroll,
            grad_shardings=opt_sh.m if grad_rs else None)
        metrics_sh = {"ce": repl, "aux": repl, "loss": repl, "step": repl}
        return (fn, (params_sds, opt_sds, bsds),
                (params_sh, opt_sh, bsh),
                (params_sh, opt_sh, metrics_sh), meta)

    if cell.kind == "prefill":
        bsds = batch_specs(cfg, cell, param_dtype)
        bsh = shd.batch_shardings(bsds, mesh, data_axes)
        fn = step_lib.make_prefill_step(cfg, unroll=unroll)
        cache_sds = jax.eval_shape(fn, params_sds, bsds)[0]
        cache_sh = shd.cache_shardings(cache_sds, mesh, batch=cell.batch,
                                       kv_policy=kv_policy)
        nt_sh = shd.batch_shardings(
            jax.eval_shape(fn, params_sds, bsds)[1], mesh, data_axes)
        return (fn, (params_sds, bsds), (params_sh, bsh),
                (cache_sh, nt_sh), meta)

    # decode: one new token against a seq-long cache
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(cfg, cell.batch, cell.seq, param_dtype))
    cache_sh = shd.cache_shardings(cache_sds, mesh, batch=cell.batch,
                                   kv_policy=kv_policy)
    tok_sds = {"tokens": _sds((cell.batch, 1), jnp.int32)}
    tok_sh = shd.batch_shardings(tok_sds, mesh, data_axes)
    fn0 = step_lib.make_serve_step(cfg, unroll=unroll)
    pos = cell.seq - 1  # static: write slot for the new token

    def fn(params, cache, tokens):
        return fn0(params, cache, tokens, pos)

    nt_sds = jax.eval_shape(fn, params_sds, cache_sds, tok_sds["tokens"])[1]
    nt_sh = shd.batch_shardings(nt_sds, mesh, data_axes)
    return (fn, (params_sds, cache_sds, tok_sds["tokens"]),
            (params_sh, cache_sh, tok_sh["tokens"]),
            (cache_sh, nt_sh), meta)
