"""Clustering CLI — the paper's algorithms as a runnable tool.

  PYTHONPATH=src python -m repro.launch.cluster --data hacc_like -n 20000 \
      --eps 0.03 --minpts 5 --algorithm fdbscan-densebox

``--trace``/``--metrics-json`` record the run's phase spans (plan/build/
traverse/sweep/border, DESIGN.md §12) and metrics snapshot — the batch
analogue of the serving loop's observability artifacts.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="blobs",
                    help="dataset name (data/pointclouds.py) or .npy path")
    ap.add_argument("-n", type=int, default=10000)
    ap.add_argument("--eps", type=float, required=True)
    ap.add_argument("--minpts", type=int, required=True)
    ap.add_argument("--algorithm", default="auto",
                    choices=["auto", "fdbscan", "fdbscan-densebox", "tiled",
                             "pallas-tree", "gdbscan", "ring"])
    ap.add_argument("--star", action="store_true", help="DBSCAN* variant")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", help="write labels .npy")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics registry snapshot here at exit")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record phase spans; write Chrome trace JSON here")
    args = ap.parse_args(argv)

    prev_reg, prev_tr = obs_metrics.active(), obs_trace.active()
    reg = tracer = None
    if args.metrics_json:
        reg = obs_metrics.install(obs_metrics.Registry())
    if args.trace:
        tracer = obs_trace.install(sync=True)
    try:
        _run(args, reg, tracer)
    finally:
        obs_metrics.install(prev_reg) if prev_reg is not None \
            else obs_metrics.uninstall()
        obs_trace.install(prev_tr) if prev_tr is not None \
            else obs_trace.uninstall()


def _run(args, reg, tracer):
    from repro.data import pointclouds
    pts = pointclouds.load(args.data, args.n, seed=args.seed)
    print(f"[cluster] {args.data}: n={len(pts)} d={pts.shape[1]} "
          f"eps={args.eps} minpts={args.minpts} algo={args.algorithm}")

    t0 = time.time()
    if args.algorithm == "tiled":
        from repro.kernels import dbscan_tiled
        res = dbscan_tiled(pts, args.eps, args.minpts)
    elif args.algorithm == "gdbscan":
        from repro.core import gdbscan
        res = gdbscan(pts, args.eps, args.minpts)
    elif args.algorithm == "ring":
        from repro.distributed.ring_dbscan import ring_dbscan
        res = ring_dbscan(pts, args.eps, args.minpts)
    else:
        from repro.core import dbscan
        res = dbscan(pts, args.eps, args.minpts, algorithm=args.algorithm,
                     star=args.star)
    dt = time.time() - t0
    labels = np.asarray(res.labels)
    n_noise = int((labels == -1).sum())
    sizes = np.bincount(labels[labels >= 0]) if res.n_clusters else []
    print(f"[cluster] {res.n_clusters} clusters, {n_noise} noise "
          f"({100*n_noise/len(pts):.1f}%), "
          f"core={int(np.asarray(res.core_mask).sum())}, "
          f"sweeps={res.n_sweeps}, {dt:.2f}s (incl. compile)")
    if len(sizes):
        print(f"[cluster] largest clusters: {sorted(sizes)[-5:][::-1]}")
    if args.out:
        np.save(args.out, labels)
        print(f"[cluster] labels -> {args.out}")
    if reg is not None and args.metrics_json:
        obs_metrics.validate_snapshot(reg.write_json(args.metrics_json))
        print(f"[cluster] metrics snapshot -> {args.metrics_json}")
    if tracer is not None and args.trace:
        doc = tracer.export(args.trace)
        print(f"[cluster] Chrome trace ({len(doc['traceEvents'])} events) "
              f"-> {args.trace}")


if __name__ == "__main__":
    main()
