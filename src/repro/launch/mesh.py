"""Production mesh definitions.

Factory functions only — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; tests and benches see the real single device).

Topology: a TPU v5e pod of 256 chips is a 16x16 mesh (data, model); the
multi-pod configuration adds a leading "pod" axis (2 pods = 512 chips).
The sharded DBSCAN path (DESIGN.md §6) shards points over the data axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests: 1 CPU or 8 fake hosts)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (per direction)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
