"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / peak_FLOPs          (per chip: post-SPMD HLO)
memory term     = HLO_bytes / HBM_bw
collective term = wire_bytes / ICI_bw

``cost_analysis`` provides per-partition FLOPs and bytes accessed. Wire
bytes are parsed from the post-SPMD HLO text: for each collective op the
*result* buffer size R gives per-chip traffic via the op-specific ring cost
(all-reduce 2R, all-gather R, reduce-scatter R x group, all-to-all R,
collective-permute R). Group sizes come from the op's replica_groups.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_TILED_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _result_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_TILED_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_wire_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-chip wire bytes by collective kind, from post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        for kind in _COLLECTIVES:
            # match "<result type> all-reduce(" or "... all-reduce-start("
            if f" {kind}(" in rhs:
                token = f" {kind}("
            elif f" {kind}-start(" in rhs:
                token = f" {kind}-start("
            else:
                continue
            lhs = rhs.split(token, 1)[0]
            r = _result_bytes(lhs)
            if r == 0:
                continue
            g = _group_size(line, n_devices)
            if kind == "all-reduce":
                wire = 2 * r * (g - 1) // max(g, 1)
            elif kind == "all-gather":
                wire = r * (g - 1) // max(g, 1)
            elif kind == "reduce-scatter":
                wire = r * (g - 1)
            elif kind == "all-to-all":
                wire = r * (g - 1) // max(g, 1)
            else:  # collective-permute
                wire = r
            out[kind] += wire
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def roofline_terms(cost: dict, wire: dict, *, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / peak_flops
    t_memory = bytes_accessed / hbm_bw
    t_collective = wire["total"] / ici_bw
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "wire_bytes": wire["total"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_time_s": max(t_compute, t_memory, t_collective),
    }
