"""Streaming DBSCAN serving loop (DESIGN.md §7, durability §10, obs §12).

The serving path the ROADMAP's north star actually needs: a long-lived
``StreamingDBSCAN`` handle absorbing a mixed stream of *insert* and
*query* requests. Requests are drained in **fixed-size micro-batches**
(``--batch`` points per operation), so the jitted traversal programs see a
stable set of padded shapes and steady-state serving never recompiles.

Bootstrap routes through ``core.dispatch.dbscan`` (plan caching + backend
auto-selection), and the handle itself is built with
``dispatch.stream_handle`` so it reuses the very same cached
eps-independent index instead of rebuilding it.

Sliding windows: ``--window W`` keeps only the most recent W inserted
points live — every insert auto-expires the rest by insert-order
watermark (tombstones + demotion repair, DESIGN.md §11), the workload
the ngsim_like trajectory scenario actually needs.

Durability (DESIGN.md §10): ``--wal`` logs every insert/delete/expire
micro-batch before it is applied, ``--checkpoint`` +
``--checkpoint-every`` write atomic snapshots of the whole index, and
``--restore`` recovers the handle (checkpoint + WAL replay) after a
crash and keeps serving where the stream left off:

  PYTHONPATH=src python -m repro.launch.serve --dataset blobs --n 8192 \
      --eps 0.04 --min-pts 8 --batch 256 --steps 60 --insert-frac 0.3 \
      --wal /tmp/serve.wal --checkpoint /tmp/serve.npz --checkpoint-every 1
  # kill -9 it mid-run, then:
  PYTHONPATH=src python -m repro.launch.serve ... --restore

Observability (DESIGN.md §12): the loop always runs against a local
metrics registry — request latencies go into *bounded-memory* quantile
histograms (``serve_insert_seconds`` / ``serve_query_seconds`` /
``serve_snapshot_seconds``; the sketch size is bounded by the latency
range, never by the request count, so a long-lived server stays
memory-flat), and every handle counter (merges, compactions, repair
sweeps, WAL fsyncs) reports into the same registry.  ``--metrics-json``
writes the schema-stable snapshot at exit, ``--trace`` additionally
records phase spans and writes a Chrome trace (open in Perfetto /
``chrome://tracing``; pass ``--trace-sync`` to block on device values at
span close so spans measure compute, not dispatch), and
``--stats-every K`` prints registry-derived latency lines during the run.

The loop is defensive the way a serving process must be: an exhausted
insert pool degrades to query-only service (dropped insert requests are
counted, not fatal), malformed request batches (NaN/Inf coordinates) are
rejected by the validation gate and counted instead of corrupting the
index, and ``--validate`` failures exit non-zero with a readable error.

Multi-tenant server mode (DESIGN.md §13): ``--tenants name:eps:min_pts[,
...]`` swaps the bare handle for :class:`repro.serve.Server` — adaptive
micro-batching, immutable versioned snapshots, per-tenant views over one
shared index build, and admission control.  ``--durability-dir DIR``
gives every tenant its own WAL + checkpoint files there, and
``--restore`` recovers the whole server from them:

  PYTHONPATH=src python -m repro.launch.serve --dataset blobs --n 8192 \
      --tenants tight:0.02:10,coarse:0.05:5 --steps 40 \
      --durability-dir /tmp/serve-state

Graceful shutdown (both modes): SIGTERM or Ctrl-C finishes the request
in flight, drains everything already admitted, writes a final durable
checkpoint, prints the summary, and exits 0 — a supervisor's ``kill``
is a clean restart, never data loss.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _install_shutdown() -> threading.Event:
    """Route SIGTERM/SIGINT into a drain flag (main thread only; worker
    threads and embedded callers are unaffected)."""
    ev = threading.Event()

    def _handler(signum, frame):
        if ev.is_set():              # second signal: operator insists
            raise KeyboardInterrupt
        ev.set()
        print(f"[serve] caught {signal.Signals(signum).name}: draining, "
              "will checkpoint and exit 0", file=sys.stderr)

    try:
        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGINT, _handler)
    except ValueError:               # not the main thread (embedded use)
        pass
    return ev


def _parse_tenants(spec: str):
    """``name:eps:min_pts[,name:eps:min_pts...]`` -> TenantSpec list."""
    from repro.serve import TenantSpec
    out = []
    for part in spec.split(","):
        bits = part.strip().split(":")
        if len(bits) != 3:
            raise ValueError(f"bad tenant spec {part!r}: want "
                             "name:eps:min_pts")
        out.append(TenantSpec(bits[0], float(bits[1]), int(bits[2])))
    return out


def _q_ms(reg, name: str, q: float) -> float:
    """Quantile (in ms) of a registry latency histogram; NaN when empty."""
    h = reg.get(name)
    if h is None or h.count == 0:
        return float("nan")
    return h.quantile(q) * 1e3


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="blobs",
                    help="pointclouds dataset name or .npy path")
    ap.add_argument("--n", type=int, default=8192,
                    help="total points backing the request stream")
    ap.add_argument("--warm-frac", type=float, default=0.5,
                    help="fraction of points clustered at bootstrap")
    ap.add_argument("--eps", type=float, default=0.04)
    ap.add_argument("--min-pts", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256,
                    help="micro-batch size (fixed: stable jit shapes)")
    ap.add_argument("--steps", type=int, default=60,
                    help="number of micro-batches to serve")
    ap.add_argument("--insert-frac", type=float, default=0.3,
                    help="probability a step drains inserts (vs queries); "
                    "0 serves a query-only stream, 1 insert-only")
    ap.add_argument("--window", type=int, default=None, metavar="W",
                    help="sliding window: every insert auto-expires points "
                    "older than the last W inserted (tombstones + demotion "
                    "repair, DESIGN.md §11)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="materialize labels every K steps (0: only final)")
    ap.add_argument("--validate", action="store_true",
                    help="check the final snapshot against batch dbscan "
                    "(exits 1 with a readable error on mismatch)")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="write-ahead log: every insert batch is appended "
                    "+ fsynced here before it is applied")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="checkpoint .npz path (atomic tmp+fsync+rename)")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="auto-checkpoint every K merges (needs --checkpoint)")
    ap.add_argument("--restore", action="store_true",
                    help="recover from --checkpoint/--wal instead of a cold "
                    "bootstrap, then keep serving the rest of the stream")
    ap.add_argument("--poison-frac", type=float, default=0.0,
                    help="probability a request batch carries a NaN point "
                    "(exercises the validation gate; rejected + counted)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics registry snapshot "
                    "(repro.obs schema) here at exit")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record phase spans and write a Chrome trace-event "
                    "JSON here at exit (Perfetto / chrome://tracing)")
    ap.add_argument("--trace-sync", action="store_true",
                    help="block on watched device values at span close so "
                    "spans measure compute, not dispatch (observer cost is "
                    "marked in the trace); default: never block")
    ap.add_argument("--stats-every", type=int, default=0, metavar="K",
                    help="print registry-derived latency stats every K steps")
    ap.add_argument("--tenants", default=None, metavar="SPECS",
                    help="multi-tenant server mode: name:eps:min_pts[,...] "
                    "— serve every view over one shared index via "
                    "repro.serve.Server (ignores --eps/--min-pts)")
    ap.add_argument("--durability-dir", default=None, metavar="DIR",
                    help="server mode: per-tenant WAL + checkpoint files "
                    "live here (<name>.wal / <name>.npz)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="server mode: micro-batching deadline — the "
                    "longest a query may wait for co-travelers")
    args = ap.parse_args(argv)

    if args.tenants:
        if args.wal or args.checkpoint:
            ap.error("server mode persists per tenant: use "
                     "--durability-dir, not --wal/--checkpoint")
        if args.restore and not args.durability_dir:
            ap.error("--restore in server mode needs --durability-dir")
    else:
        if args.restore and not (args.checkpoint or args.wal):
            ap.error("--restore needs --checkpoint and/or --wal")
        if args.checkpoint_every and not args.checkpoint:
            ap.error("--checkpoint-every needs --checkpoint")

    # The serving loop always collects into its own registry (bounded
    # histograms replace the old unbounded all-time latency lists); the
    # tracer is only installed when a trace is requested.  Previous
    # collectors are restored on the way out, so embedding callers (the
    # tests) never see their instrumentation hijacked.
    prev_reg, prev_tr = obs_metrics.active(), obs_trace.active()
    reg = obs_metrics.install(obs_metrics.Registry())
    tracer = None
    if args.trace:
        tracer = obs_trace.install(sync=args.trace_sync)
    try:
        if args.tenants:
            return _serve_multi(args, reg, tracer)
        return _serve(args, reg, tracer)
    finally:
        obs_metrics.install(prev_reg) if prev_reg is not None \
            else obs_metrics.uninstall()
        obs_trace.install(prev_tr) if prev_tr is not None \
            else obs_trace.uninstall()


def _serve(args, reg, tracer):
    from repro.core import dispatch
    from repro.data import pointclouds
    from repro.stream import StreamingDBSCAN

    pts = pointclouds.load(args.dataset, args.n, seed=args.seed)
    n0 = max(2, int(args.n * args.warm_frac))
    initial, pool = pts[:n0], pts[n0:]
    rng = np.random.default_rng(args.seed)
    B, d = args.batch, pts.shape[1]

    t0 = time.perf_counter()
    if args.restore:
        # Crash recovery: latest valid checkpoint + WAL replay past its
        # watermark (DESIGN.md §10). The stream is deterministic (initial
        # prefix, then the pool in order), so the recovered watermark tells
        # us exactly where to resume draining the pool.
        with obs_trace.span("serve.restore"):
            handle = StreamingDBSCAN.restore(
                args.checkpoint, wal=args.wal, window=args.window,
                checkpoint_every=args.checkpoint_every)
            boot = handle.snapshot()
        t_boot = time.perf_counter() - t0
        pool_off = min(max(handle.n_points - n0, 0), len(pool))
        print(f"[serve] restored n={handle.n_points} "
              f"(watermark resumes pool at +{pool_off}): "
              f"{boot.n_clusters} clusters in {t_boot:.2f}s")
    else:
        # Bootstrap through the unified dispatcher: stream_handle plans via
        # dispatch (algorithm="stream"), so the handle's main tree is the
        # plan cache's eps-independent index — later batch dbscan calls or
        # handles at other eps/min_pts over the same points reuse it. The
        # handle's own bootstrap clustering doubles as the t0 snapshot.
        with obs_trace.span("serve.bootstrap", n=n0):
            handle = dispatch.stream_handle(
                initial, args.eps, args.min_pts, window=args.window,
                wal=args.wal, checkpoint_path=args.checkpoint,
                checkpoint_every=args.checkpoint_every)
            boot = handle.snapshot()
        t_boot = time.perf_counter() - t0
        pool_off = 0
        print(f"[serve] bootstrap n={n0} via backend={boot.backend!r}: "
              f"{boot.n_clusters} clusters in {t_boot:.2f}s "
              f"(index cached for reuse across parameter sweeps)")

    def query_batch():
        idx = rng.integers(0, len(pts), B)
        jitter = rng.normal(0.0, 0.2 * args.eps, (B, d)).astype(np.float32)
        return pts[idx] + jitter

    def poisoned(batch):
        if args.poison_frac and rng.random() < args.poison_frac:
            batch = batch.copy()
            batch[rng.integers(0, len(batch))] = np.nan
        return batch

    # shape warmup (compile once, outside the latency measurements)
    handle.query(query_batch())

    stop = _install_shutdown()
    n_ins = n_q = n_dropped = n_rejected = 0
    for step in range(args.steps):
        if stop.is_set():
            # graceful drain: stop taking new steps; the epilogue below
            # still checkpoints and reports, and we exit 0
            print(f"[serve] drained after {step} steps", file=sys.stderr)
            break
        want_insert = rng.random() < args.insert_frac
        if want_insert and pool_off >= len(pool):
            # Insert stream ran dry: a real server keeps answering queries.
            n_dropped += 1
            obs_metrics.inc("serve_dropped_total", kind="insert")
            want_insert = False
        if want_insert:
            take = poisoned(pool[pool_off:pool_off + B])
            t0 = time.perf_counter()
            try:
                with obs_trace.span("serve.request", kind="insert",
                                    step=step):
                    handle.insert(take)
            except ValueError as e:
                n_rejected += 1
                obs_metrics.inc("serve_rejected_total", kind="insert")
                print(f"[serve] step {step + 1}: insert rejected "
                      f"({str(e).splitlines()[0]})", file=sys.stderr)
            else:
                obs_metrics.observe("serve_insert_seconds",
                                    time.perf_counter() - t0)
                n_ins += len(take)
            # rejected or not, that slice of the stream is consumed
            pool_off += len(pool[pool_off:pool_off + B])
        else:
            qb = poisoned(query_batch())
            t0 = time.perf_counter()
            try:
                with obs_trace.span("serve.request", kind="query",
                                    step=step):
                    handle.query(qb)
            except ValueError as e:
                n_rejected += 1
                obs_metrics.inc("serve_rejected_total", kind="query")
                print(f"[serve] step {step + 1}: query rejected "
                      f"({str(e).splitlines()[0]})", file=sys.stderr)
            else:
                obs_metrics.observe("serve_query_seconds",
                                    time.perf_counter() - t0)
                n_q += B
        obs_metrics.set_gauge("serve_pool_remaining",
                              float(len(pool) - pool_off))
        if args.snapshot_every and (step + 1) % args.snapshot_every == 0:
            t0 = time.perf_counter()
            snap = handle.snapshot()
            dt = time.perf_counter() - t0
            obs_metrics.observe("serve_snapshot_seconds", dt)
            print(f"[serve] step {step + 1}: n={handle.n_points} "
                  f"(delta {handle.n_delta}), {snap.n_clusters} clusters, "
                  f"snapshot {dt * 1e3:.1f}ms")
        if args.stats_every and (step + 1) % args.stats_every == 0:
            print(f"[serve] step {step + 1}: "
                  f"insert p50 {_q_ms(reg, 'serve_insert_seconds', .5):.1f}ms "
                  f"query p50 {_q_ms(reg, 'serve_query_seconds', .5):.1f}ms "
                  f"(active {handle.n_active}, tiers {handle.n_tiers})")

    if args.checkpoint:
        handle.checkpoint()          # final durable state before reporting

    t0 = time.perf_counter()
    snap = handle.snapshot()
    t_snap = time.perf_counter() - t0
    obs_metrics.observe("serve_snapshot_seconds", t_snap)
    ins_h, q_h = reg.get("serve_insert_seconds"), reg.get("serve_query_seconds")
    stats = {
        "steps": args.steps, "batch": B,
        "n_points": handle.n_points, "n_inserted": n_ins, "n_queried": n_q,
        "n_dropped": n_dropped, "n_rejected": n_rejected,
        "n_active": handle.n_active, "n_tombstoned": handle.n_tombstoned,
        "n_merges": handle.n_merges,
        "n_compactions": handle.n_compactions,
        "n_deletes": handle.n_deletes,
        "repair_sweeps": handle.n_repair_sweeps,
        "insert_p50_ms": _q_ms(reg, "serve_insert_seconds", 0.50),
        "insert_p99_ms": _q_ms(reg, "serve_insert_seconds", 0.99),
        "insert_pts_per_s": (n_ins / ins_h.sum
                             if ins_h is not None and ins_h.sum > 0
                             else float("nan")),
        "query_p50_ms": _q_ms(reg, "serve_query_seconds", 0.50),
        "query_p99_ms": _q_ms(reg, "serve_query_seconds", 0.99),
        "snapshot_s": t_snap, "n_clusters": snap.n_clusters,
        # memory-flatness witness: sketch buckets, not sample counts
        "latency_sketch_buckets": ((ins_h.bucket_count() if ins_h else 0)
                                   + (q_h.bucket_count() if q_h else 0)),
    }
    print(f"[serve] {args.dataset}: served {args.steps} micro-batches "
          f"(B={B}) -> {stats['n_active']} active pts "
          f"(+{stats['n_tombstoned']} tombstoned), "
          f"{stats['n_clusters']} clusters, {stats['n_merges']} merges, "
          f"{stats['n_compactions']} compactions, "
          f"{n_dropped} dropped, {n_rejected} rejected")
    print(f"[serve] insert: p50 {stats['insert_p50_ms']:.1f}ms "
          f"p99 {stats['insert_p99_ms']:.1f}ms "
          f"({stats['insert_pts_per_s']:.0f} pts/s); "
          f"query: p50 {stats['query_p50_ms']:.1f}ms "
          f"p99 {stats['query_p99_ms']:.1f}ms; "
          f"snapshot {t_snap:.2f}s")

    if args.metrics_json:
        obs_metrics.validate_snapshot(reg.write_json(args.metrics_json))
        print(f"[serve] metrics snapshot -> {args.metrics_json}")
    if tracer is not None and args.trace:
        doc = tracer.export(args.trace)
        print(f"[serve] Chrome trace ({len(doc['traceEvents'])} events) "
              f"-> {args.trace}")

    if args.validate:
        from repro.core.validate import check_component_identical
        ref = dispatch.dbscan(handle.points, args.eps, args.min_pts,
                              algorithm="fdbscan")
        try:
            check_component_identical(snap.labels, snap.core_mask,
                                      ref.labels, ref.core_mask)
        except (AssertionError, ValueError) as e:
            print(f"[serve] validation FAILED: snapshot is not "
                  f"component-identical to batch dbscan on the same "
                  f"points — {e}", file=sys.stderr)
            raise SystemExit(1)
        print("[serve] validation against batch dbscan ✓")
    return stats


def _serve_multi(args, reg, tracer):
    """Multi-tenant server mode: drive a :class:`repro.serve.Server`.

    Each step fires a burst of query requests (round-robin over tenants,
    sized ``--batch`` split across 4 requests so the micro-batcher has
    something to coalesce) and with probability ``--insert-frac`` one
    insert batch.  SIGTERM/Ctrl-C drains admitted work, checkpoints every
    tenant, and exits 0.
    """
    from repro.data import pointclouds
    from repro.serve import Overloaded, Server, ServerConfig

    specs = _parse_tenants(args.tenants)
    pts = pointclouds.load(args.dataset, args.n, seed=args.seed)
    n0 = max(2, int(args.n * args.warm_frac))
    initial, pool = pts[:n0], pts[n0:]
    rng = np.random.default_rng(args.seed)
    B, d = args.batch, pts.shape[1]
    cfg = ServerConfig(max_batch=max(B, 64),
                       max_delay_s=args.max_delay_ms * 1e-3)

    t0 = time.perf_counter()
    if args.restore:
        srv = Server.restore(specs, durability_dir=args.durability_dir,
                             config=cfg, window=args.window,
                             checkpoint_every=args.checkpoint_every)
        pool_off = min(max(srv._views[0].handle.n_points - n0, 0), len(pool))
        print(f"[serve] restored {len(specs)} tenants at watermark "
              f"{srv._views[0].handle.n_points} in "
              f"{time.perf_counter() - t0:.2f}s")
    else:
        srv = Server(initial, specs, config=cfg,
                     durability_dir=args.durability_dir,
                     window=args.window,
                     checkpoint_every=args.checkpoint_every)
        pool_off = 0
        print(f"[serve] bootstrap n={n0}, {len(specs)} tenants over one "
              f"shared index in {time.perf_counter() - t0:.2f}s")

    def query_batch(k):
        idx = rng.integers(0, len(pts), k)
        eps0 = specs[0].eps
        jitter = rng.normal(0.0, 0.2 * eps0, (k, d)).astype(np.float32)
        return pts[idx] + jitter

    stop = _install_shutdown()
    n_q = n_ins = n_shed = 0
    steps = 0
    with srv:
        for step in range(args.steps):
            if stop.is_set():
                print(f"[serve] drained after {step} steps",
                      file=sys.stderr)
                break
            steps = step + 1
            futs = []
            per = max(B // 4, 1)
            for j in range(4):
                spec = specs[(step * 4 + j) % len(specs)]
                try:
                    futs.append(srv.submit_query(query_batch(per),
                                                 tenant=spec.name))
                except Overloaded:
                    n_shed += 1
            if rng.random() < args.insert_frac and pool_off < len(pool):
                take = pool[pool_off:pool_off + per]
                pool_off += len(take)
                try:
                    srv.insert(take, timeout=120)
                    n_ins += len(take)
                except Overloaded:
                    n_shed += 1
            for f in futs:
                f.result(timeout=120)
                n_q += per
            if args.stats_every and steps % args.stats_every == 0:
                st = srv.stats()
                print(f"[serve] step {steps}: query p50 "
                      f"{st['query_p50_s'] * 1e3:.1f}ms p99 "
                      f"{st['query_p99_s'] * 1e3:.1f}ms, shed {st['shed']}")
        stats = srv.stats()
        # context exit: admission closes, planes drain, final per-tenant
        # checkpoint through the durability path
    stats.update(steps=steps, n_queried=n_q, n_inserted=n_ins,
                 n_overloaded=n_shed)
    vers = {t["name"]: t["version"] for t in stats["tenants"]}
    print(f"[serve] served {steps} steps across {len(specs)} tenants: "
          f"{n_q} probes, {n_ins} inserts, {n_shed} shed; "
          f"versions {vers}")
    print(f"[serve] query p50 {stats['query_p50_s'] * 1e3:.1f}ms "
          f"p99 {stats['query_p99_s'] * 1e3:.1f}ms; "
          f"insert p50 {stats['insert_p50_s'] * 1e3:.1f}ms")

    if args.metrics_json:
        obs_metrics.validate_snapshot(reg.write_json(args.metrics_json))
        print(f"[serve] metrics snapshot -> {args.metrics_json}")
    if tracer is not None and args.trace:
        doc = tracer.export(args.trace)
        print(f"[serve] Chrome trace ({len(doc['traceEvents'])} events) "
              f"-> {args.trace}")
    return stats


if __name__ == "__main__":
    main()
