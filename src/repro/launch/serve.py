"""Batched serving driver: prefill + decode loop with the cached step.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get
    from repro.models import model
    from repro.train import step as step_lib

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)

    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens,
                             model.VISION_EMBED_DIM)), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, P, cfg.d_model)), jnp.float32) * 0.02

    t0 = time.time()
    logits, cache = model.prefill(cfg, params, batch)
    # pad kv caches from prompt length to the full decode budget
    def grow(entry):
        out = dict(entry)
        for key in ("k", "v"):
            if key in entry and entry[key].shape[2] < S_max:
                pad = S_max - entry[key].shape[2]
                out[key] = jnp.pad(entry[key],
                                   ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return out
    cache = tuple(grow(e) for e in cache)
    next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    serve_step = jax.jit(step_lib.make_serve_step(cfg))
    out_tokens = [next_tok]
    t0 = time.time()
    for i in range(G - 1):
        cache, nt = serve_step(params, cache, out_tokens[-1],
                               jnp.asarray(P + i, jnp.int32))
        out_tokens.append(nt[:, None])
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: prefill({B}x{P}) {t_prefill:.2f}s, "
          f"decode {G-1} steps {dt:.2f}s "
          f"({B*(G-1)/max(dt,1e-9):.1f} tok/s incl. compile)")
    print("[serve] sample continuations:")
    for b in range(min(B, 2)):
        print(f"  prompt[-5:]={np.asarray(prompts[b, -5:]).tolist()} "
              f"-> gen={gen[b, :10].tolist()}")
    return gen


if __name__ == "__main__":
    main()
