"""Continuous adaptive micro-batching for the serving plane (DESIGN.md §13).

The old serving loop drained requests in fixed-size ticks: every request
batch had to be exactly ``--batch`` points or it hit a fresh jit shape.
The micro-batcher instead coalesces arrivals *continuously*:

  * requests append to a pending queue with their arrival time;
  * a flush happens when the pending points reach the batch target
    (**full**), when the oldest pending request has waited ``max_delay_s``
    (**deadline** — bounds added latency under light load), or on drain
    (**drain** — graceful shutdown);
  * flushed probe arrays are concatenated and the executor pads the
    result to the shared bucket ladder (:func:`bucket_size` — the same
    quarter-power-of-two ladder ``StreamingDBSCAN`` pads its own probe
    batches to, so server traffic and direct handle callers hit one jit
    cache), keeping the set of compiled shapes bounded regardless of
    arrival sizes.

The **adaptive** part targets the classic batching tradeoff: under heavy
load a big batch amortizes per-call overhead, but under light load
waiting for one is pure added latency.  The batcher keeps an EWMA of the
arrival rate and shrinks the batch target to what can plausibly
accumulate within one deadline window — light traffic flushes small and
fast, heavy traffic fills full buckets, and the transition needs no
tuning.

The batcher is deliberately passive (no thread of its own): ``add`` /
``ready`` / ``next_deadline`` / ``drain`` are called by the server's
worker loop under its own condition variable, and every method takes an
explicit ``now`` so tests can drive time deterministically.
"""
from __future__ import annotations

import threading
from typing import NamedTuple

import numpy as np

from repro.core.fdbscan import _pad_size
from repro.obs import metrics as obs_metrics

# Floor of the adaptive batch target: below this, per-flush overhead
# dominates and shrinking further cannot help latency.
MIN_TARGET = 64

# EWMA smoothing for the arrival-rate estimate (per-request updates).
_RATE_ALPHA = 0.2


def bucket_size(k: int) -> int:
    """The serve bucket ladder: smallest padded size >= k.

    This is ``repro.core.fdbscan._pad_size`` — the quarter-power-of-two
    ladder every level build and probe batch in the streaming index
    already pads to — re-exported as the *one* ladder the serving plane
    uses, so coalesced server batches, direct ``StreamingDBSCAN.query``
    callers, and index rebuilds all share the same bounded set of
    compiled shapes.
    """
    return _pad_size(int(k))


class Request:
    """One admitted query request: probe points + its completion future."""

    __slots__ = ("pts", "future", "arrived_at")

    def __init__(self, pts: np.ndarray, future, arrived_at: float):
        self.pts = pts
        self.future = future
        self.arrived_at = float(arrived_at)


class Flush(NamedTuple):
    """One coalesced batch handed to the executor."""
    requests: list          # the Request objects, arrival order
    pts: np.ndarray         # concatenated probe points
    reason: str             # "full" | "deadline" | "drain"


class MicroBatcher:
    """Deadline-or-full request coalescing with an adaptive batch target.

    max_batch: hard cap on coalesced points per flush (whole requests —
        admission bounds a single request at ``max_batch`` points, so a
        request is never split).
    max_delay_s: longest a pending request may wait before a flush is
        forced (the latency bound).
    adaptive: shrink the batch target toward the points one deadline
        window can plausibly accumulate (EWMA arrival rate); ``False``
        always targets ``max_batch``.
    """

    def __init__(self, *, max_batch: int = 1024,
                 max_delay_s: float = 0.002, adaptive: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0; got {max_delay_s}")
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.adaptive = bool(adaptive)
        self._lock = threading.Lock()
        self._pending: list[Request] = []
        self._pending_pts = 0
        self._rate = 0.0                  # EWMA arrival rate, points/s
        self._last_add: float | None = None

    @property
    def pending_points(self) -> int:
        return self._pending_pts

    def target_points(self) -> int:
        """Current flush target: ``max_batch``, adaptively shrunk toward
        what one deadline window can accumulate under the observed rate."""
        if not self.adaptive:
            return self.max_batch
        reachable = self._rate * self.max_delay_s
        return int(min(self.max_batch,
                       max(MIN_TARGET, bucket_size(max(1, int(reachable))))))

    def add(self, req: Request) -> bool:
        """Queue one admitted request; True if the batch target is now
        reached (the caller should wake the executor immediately)."""
        with self._lock:
            if self._last_add is not None:
                dt = max(req.arrived_at - self._last_add, 1e-6)
                inst = len(req.pts) / dt
                self._rate += _RATE_ALPHA * (inst - self._rate)
            self._last_add = req.arrived_at
            self._pending.append(req)
            self._pending_pts += len(req.pts)
            return self._pending_pts >= self.target_points()

    def next_deadline(self, now: float) -> float | None:
        """Absolute time the oldest pending request must flush by; None
        when nothing is pending."""
        with self._lock:
            if not self._pending:
                return None
            return self._pending[0].arrived_at + self.max_delay_s

    def ready(self, now: float, *, drain: bool = False) -> Flush | None:
        """Pop one flush if due (full / deadline / drain); else None."""
        with self._lock:
            if not self._pending:
                return None
            full = self._pending_pts >= self.target_points()
            due = (now - self._pending[0].arrived_at) >= self.max_delay_s
            if not (full or due or drain):
                return None
            reason = "full" if full else ("deadline" if due else "drain")
            take, pts = [], 0
            while self._pending and (not take
                                     or pts + len(self._pending[0].pts)
                                     <= self.max_batch):
                r = self._pending.pop(0)
                take.append(r)
                pts += len(r.pts)
            self._pending_pts -= pts
        batch = (np.concatenate([r.pts for r in take])
                 if len(take) > 1 else take[0].pts)
        obs_metrics.inc("serve_flushes_total", reason=reason)
        obs_metrics.observe("serve_batch_probes", float(pts))
        return Flush(requests=take, pts=batch, reason=reason)

    def drain(self, now: float):
        """Flush everything pending (shutdown path); yields Flushes."""
        while True:
            fl = self.ready(now, drain=True)
            if fl is None:
                return
            yield fl
