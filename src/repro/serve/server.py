"""The serving subsystem's front door (DESIGN.md §13).

``Server`` turns one shared point stream plus N tenant ``(eps, min_pts)``
views into a long-lived service with two decoupled planes:

  * the **query plane** — requests are admitted (bounded queues, typed
    ``Overloaded`` shedding), coalesced by per-tenant adaptive
    micro-batchers, and executed by the query worker against each
    tenant's *published* :class:`~repro.serve.snapshot.IndexSnapshot`.
    Snapshots are immutable and swapped atomically, so a query batch is
    never blocked by — and can never observe a torn state from — a
    concurrent insert, merge, or compaction;
  * the **write plane** — a single writer thread applies admitted insert
    batches to every tenant's streaming handle in order (each handle's
    WAL/checkpoint durability applies unchanged, PR 6), then freezes and
    publishes each tenant's next snapshot version off-path.  An insert
    is acknowledged (its future resolves) only after every tenant has
    applied *and republished*, so an acknowledged write is visible to
    the very next admitted query.

Requests are asynchronous: ``submit_query`` / ``submit_insert`` return
``concurrent.futures.Future`` objects; ``query`` / ``insert`` are the
blocking conveniences.  Invalid input (NaN/Inf, wrong dimensionality,
oversized requests) fails synchronously with ``ValueError`` at submit
time — malformed data is the client's fault and must never consume
write-plane budget.

Graceful shutdown (:meth:`shutdown`, also wired to SIGTERM /
KeyboardInterrupt by the CLI): admission closes (new work sheds with
``Overloaded(reason="shutdown")``), both planes drain everything already
admitted, every tenant writes a final checkpoint through the durability
path, and the process can exit 0 with nothing acknowledged-but-unapplied
left behind.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import NamedTuple

import numpy as np

from repro.core.validate import check_points
from repro.obs import metrics as obs_metrics
from repro.obs import names as obs_names
from repro.obs import trace as obs_trace
from repro.serve import admission as admission_mod
from repro.serve import batching, tenants as tenants_mod
from repro.serve.admission import Overloaded

__all__ = ["Server", "ServerConfig", "QueryReply", "InsertReply",
           "Overloaded"]


class ServerConfig(NamedTuple):
    """Serving-plane knobs (see DESIGN.md §13 for the policy rationale).

    max_batch: probe points per coalesced flush (also the per-request
        size cap — a request is never split across flushes).
    max_delay_s: batching deadline — the longest a pending query may wait
        for co-travelers.
    adaptive: shrink the flush target under light load (see
        ``serve.batching.MicroBatcher``).
    max_pending_requests / max_pending_points / max_pending_inserts:
        admission budgets; overflow sheds with :class:`Overloaded`.
    publish_every: publish new tenant snapshots after every K applied
        insert batches (1 = every insert is immediately visible;
        higher trades freshness for writer throughput).
    drain_timeout_s: how long :meth:`shutdown` waits for the planes to
        drain before giving up (the threads are daemonic — a stuck drain
        cannot hang process exit).
    """
    max_batch: int = 1024
    max_delay_s: float = 0.002
    adaptive: bool = True
    max_pending_requests: int = 256
    max_pending_points: int = 65536
    max_pending_inserts: int = 8
    publish_every: int = 1
    drain_timeout_s: float = 30.0


class QueryReply(NamedTuple):
    """One query request's result, tagged with its consistency point."""
    labels: np.ndarray
    counts: np.ndarray
    would_be_core: np.ndarray
    version: int            # snapshot version the batch executed against
    tenant: str


class InsertReply(NamedTuple):
    """One acknowledged insert batch: durable and visible everywhere."""
    watermark: int                  # stream length after the batch
    versions: dict                  # tenant -> published snapshot version


class _InsertReq(NamedTuple):
    pts: np.ndarray
    future: Future
    arrived_at: float


class Server:
    """A multi-tenant streaming-DBSCAN server over one point stream.

    points: initial point set, bootstrap-clustered per tenant over one
        shared index build (ignored when recovering via :meth:`restore`).
    tenants: iterable of ``(name, eps, min_pts)`` (or
        :class:`~repro.serve.tenants.TenantSpec`).
    config: :class:`ServerConfig`.
    durability_dir: per-tenant WAL + checkpoint files live here
        (``<name>.wal`` / ``<name>.npz``); None disables durability.
    window / checkpoint_every / handle kwargs: forwarded to every
        tenant's ``StreamingDBSCAN``.
    keep_versions: snapshot history retained per tenant (>=1; the
        linearizability tests use a deeper history).
    """

    def __init__(self, points, tenants, *, config: ServerConfig | None = None,
                 durability_dir: str | None = None,
                 window: int | None = None, checkpoint_every: int = 0,
                 keep_versions: int = 1, _views=None, **handle_kwargs):
        self.config = config or ServerConfig()
        if self.config.max_batch < 1 or self.config.publish_every < 1:
            raise ValueError("max_batch and publish_every must be >= 1")
        self._durability_dir = durability_dir
        with obs_trace.span("serve.bootstrap"):
            if _views is not None:
                self._views = _views
            else:
                self._views = tenants_mod.build_views(
                    points, tenants, durability_dir=durability_dir,
                    window=window, checkpoint_every=checkpoint_every,
                    keep_versions=keep_versions, **handle_kwargs)
        self._by_name = {v.name: v for v in self._views}
        self.admission = admission_mod.AdmissionController(
            max_pending_requests=self.config.max_pending_requests,
            max_pending_points=self.config.max_pending_points,
            max_pending_inserts=self.config.max_pending_inserts,
            retry_after_s=self.config.max_delay_s)
        self._batchers = {
            v.name: batching.MicroBatcher(
                max_batch=self.config.max_batch,
                max_delay_s=self.config.max_delay_s,
                adaptive=self.config.adaptive)
            for v in self._views}
        self._qcond = threading.Condition()
        self._wcond = threading.Condition()
        self._inserts: list[_InsertReq] = []
        self._unpublished = 0           # applied batches since last publish
        self._draining = False
        self._stopped = False
        self._apply_failures = 0
        self._qthread = threading.Thread(target=self._query_loop,
                                         name="serve-query", daemon=True)
        self._wthread = threading.Thread(target=self._write_loop,
                                         name="serve-writer", daemon=True)
        self._qthread.start()
        self._wthread.start()

    # ------------------------------------------------------------------ #
    # construction / recovery                                            #
    # ------------------------------------------------------------------ #

    @classmethod
    def restore(cls, tenants, *, durability_dir: str,
                config: ServerConfig | None = None,
                window: int | None = None, checkpoint_every: int = 0,
                keep_versions: int = 1, **handle_kwargs) -> "Server":
        """Recover a server from its per-tenant durability files.

        Every tenant recovers independently (checkpoint + WAL replay);
        lagging replicas are topped up from the leader's point stream
        (see :func:`repro.serve.tenants.restore_views`), so serving
        resumes with all tenants at one watermark and fresh snapshots.
        """
        with obs_trace.span("serve.restore"):
            views = tenants_mod.restore_views(
                tenants, durability_dir=durability_dir, window=window,
                checkpoint_every=checkpoint_every,
                keep_versions=keep_versions, **handle_kwargs)
        return cls(None, tenants, config=config,
                   durability_dir=durability_dir, _views=views)

    # ------------------------------------------------------------------ #
    # public request surface                                             #
    # ------------------------------------------------------------------ #

    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(v.name for v in self._views)

    def _view(self, tenant: str | None) -> tenants_mod.TenantView:
        if tenant is None:
            if len(self._views) == 1:
                return self._views[0]
            raise ValueError(f"server has {len(self._views)} tenants "
                             f"{self.tenants}; pass tenant=")
        v = self._by_name.get(tenant)
        if v is None:
            raise ValueError(f"unknown tenant {tenant!r}; have "
                             f"{self.tenants}")
        return v

    def submit_query(self, pts, *, tenant: str | None = None) -> Future:
        """Admit one query request; resolves to a :class:`QueryReply`.

        Raises ValueError synchronously for malformed probes (NaN/Inf,
        wrong d, more than ``config.max_batch`` points) and
        :class:`Overloaded` when admission sheds it.
        """
        view = self._view(tenant)
        qb = np.ascontiguousarray(
            check_points(pts, name="probe points", dims=(2, 3),
                         allow_empty=True), np.float32)
        snap = view.store.current()
        if snap.n_points and qb.size and qb.shape[1] != snap.d:
            raise ValueError(f"dimensionality mismatch: tenant "
                             f"{view.name!r} serves {snap.d}-d, got "
                             f"{qb.shape[1]}-d probes")
        fut: Future = Future()
        if len(qb) == 0:                # trivially complete; skip queues
            fut.set_result(QueryReply(
                np.full(0, -1, np.int32), np.zeros(0, np.int32),
                np.zeros(0, bool), snap.version, view.name))
            return fut
        if len(qb) > self.config.max_batch:
            raise ValueError(f"request of {len(qb)} probes exceeds "
                             f"max_batch={self.config.max_batch}; split "
                             "it client-side")
        self.admission.admit_query(len(qb))
        obs_metrics.inc(obs_names.SERVE_REQUESTS, kind="query",
                        tenant=view.name)
        req = batching.Request(qb, fut, time.monotonic())
        hot = self._batchers[view.name].add(req)
        with self._qcond:
            self._qcond.notify()
        del hot                          # add() already queued; the wake
        return fut                       # covers full and deadline alike

    def query(self, pts, *, tenant: str | None = None,
              timeout: float | None = None) -> QueryReply:
        """Blocking convenience around :meth:`submit_query`."""
        return self.submit_query(pts, tenant=tenant).result(timeout)

    def submit_insert(self, pts) -> Future:
        """Admit one insert batch; resolves to an :class:`InsertReply`
        once **every** tenant has applied and republished.

        Raises ValueError synchronously for malformed batches and
        :class:`Overloaded` when the write queue is full.
        """
        batch = np.ascontiguousarray(
            check_points(pts, name="points", dims=(2, 3)), np.float32)
        self.admission.admit_insert()
        obs_metrics.inc(obs_names.SERVE_REQUESTS, kind="insert",
                        tenant="")
        fut: Future = Future()
        with self._wcond:
            self._inserts.append(_InsertReq(batch, fut, time.monotonic()))
            self._wcond.notify()
        return fut

    def insert(self, pts, *, timeout: float | None = None) -> InsertReply:
        """Blocking convenience around :meth:`submit_insert`."""
        return self.submit_insert(pts).result(timeout)

    def stats(self) -> dict:
        """Queue depths, shed counts, SLO quantiles, per-tenant state."""
        st = self.admission.stats(tenants=self.tenants + ("",))
        st["tenants"] = [v.stats() for v in self._views]
        st["apply_failures"] = self._apply_failures
        st["stopped"] = self._stopped
        return st

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #

    def shutdown(self, *, drain: bool = True,
                 final_checkpoint: bool = True) -> None:
        """Stop serving: close admission, drain (or abandon) queued work,
        write final checkpoints, join the planes.  Idempotent."""
        if self._stopped:
            return
        self.admission.close()
        if not drain:
            self._fail_pending(RuntimeError("server shut down "
                                            "without drain"))
        with self._qcond:
            self._draining = True
            self._qcond.notify_all()
        with self._wcond:
            self._wcond.notify_all()
        self._wthread.join(self.config.drain_timeout_s)
        self._qthread.join(self.config.drain_timeout_s)
        self._stopped = True
        if final_checkpoint and self._durability_dir is not None:
            for v in self._views:
                v.handle.checkpoint()
        obs_metrics.inc("serve_shutdowns_total")

    def _fail_pending(self, exc: Exception) -> None:
        with self._wcond:
            pending, self._inserts = self._inserts, []
        for req in pending:
            self.admission.release_insert()
            req.future.set_exception(exc)
        now = time.monotonic()
        for name, b in self._batchers.items():
            for fl in b.drain(now):
                for r in fl.requests:
                    self.admission.release_query(len(r.pts))
                    r.future.set_exception(exc)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------------ #
    # query plane                                                        #
    # ------------------------------------------------------------------ #

    def _pop_ready(self, now: float, drain: bool = False):
        for v in self._views:
            fl = self._batchers[v.name].ready(now, drain=drain)
            if fl is not None:
                return v, fl
        return None

    def _query_loop(self) -> None:
        while True:
            with self._qcond:
                while True:
                    now = time.monotonic()
                    item = self._pop_ready(now, drain=self._draining)
                    if item is not None:
                        break
                    if self._draining:
                        return
                    deadlines = [d for d in
                                 (b.next_deadline(now)
                                  for b in self._batchers.values())
                                 if d is not None]
                    if deadlines:
                        self._qcond.wait(max(min(deadlines) - now, 1e-4))
                    else:
                        self._qcond.wait()
            self._execute(*item)

    def _execute(self, view: tenants_mod.TenantView,
                 fl: batching.Flush) -> None:
        snap = view.store.current()     # one version for the whole flush
        try:
            res = snap.query(fl.pts)
        except Exception as e:          # pragma: no cover — defensive
            for r in fl.requests:
                self.admission.release_query(len(r.pts))
                r.future.set_exception(e)
            return
        done = time.monotonic()
        off = 0
        for r in fl.requests:
            k = len(r.pts)
            r.future.set_result(QueryReply(
                res.labels[off:off + k], res.counts[off:off + k],
                res.would_be_core[off:off + k], snap.version, view.name))
            off += k
            self.admission.release_query(k)
            self.admission.observe("query", done - r.arrived_at,
                                   tenant=view.name)

    # ------------------------------------------------------------------ #
    # write plane                                                        #
    # ------------------------------------------------------------------ #

    def _write_loop(self) -> None:
        while True:
            with self._wcond:
                while not self._inserts and not self._draining:
                    self._wcond.wait()
                if not self._inserts:
                    if self._unpublished:
                        self._publish_all()
                    return              # draining and empty: done
                req = self._inserts.pop(0)
            self._apply(req)

    def _apply(self, req: _InsertReq) -> None:
        try:
            with obs_trace.span("serve.apply", k=len(req.pts)):
                for v in self._views:
                    v.handle.insert(req.pts)
            self._unpublished += 1
            if self._unpublished >= self.config.publish_every:
                self._publish_all()
            versions = {v.name: v.store.version for v in self._views}
            watermark = self._views[0].handle.n_points
        except Exception as e:
            # the batch passed validation, so this is an internal error:
            # fail the future, keep the old snapshots serving (they were
            # never swapped), and keep answering queries
            self._apply_failures += 1
            obs_metrics.inc(obs_names.SERVE_APPLY_FAILURES)
            self.admission.release_insert()
            req.future.set_exception(e)
            return
        self.admission.release_insert()
        done = time.monotonic()
        req.future.set_result(InsertReply(watermark, versions))
        self.admission.observe("insert", done - req.arrived_at)

    def _publish_all(self) -> None:
        with obs_trace.span("serve.publish"):
            for v in self._views:
                v.publish()
        self._unpublished = 0
