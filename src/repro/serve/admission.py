"""Admission control, backpressure, and SLO tracking (DESIGN.md §13).

A server that accepts unbounded work does not degrade, it collapses: the
queue grows without bound, every request's latency grows with it, and by
the time the caller notices, *all* of them are late.  The admission
controller keeps the serving plane's queues bounded and rejects the
overflow *immediately* with a typed :class:`Overloaded` error carrying
enough context (kind, queue depth, limit, a retry hint) for a client to
back off — a fast "no" instead of a slow nothing.

Three independent budgets:

  * ``max_pending_requests`` — queued query requests (head-of-line count);
  * ``max_pending_points``   — queued probe *points* (the real work unit;
    a single request is also capped at the batcher's ``max_batch`` so it
    can always be coalesced whole);
  * ``max_pending_inserts``  — queued write batches (the writer applies
    them strictly in order; bounding the queue bounds the
    acknowledged-but-unapplied window).

SLO tracking rides on the PR 8 obs sketches: per (kind, tenant) request
latencies go into bounded-memory quantile histograms — both into the
process-wide registry (``repro.obs/v1`` snapshot schema) *and* into a
private always-on registry, so :meth:`stats` can report p50/p99 even
when the embedding process installed no collector.
"""
from __future__ import annotations

import threading

from repro.obs import metrics as obs_metrics


class Overloaded(RuntimeError):
    """Typed load-shed rejection: the request was *not* admitted.

    kind: "query" | "insert".
    reason: which budget rejected ("requests", "points", "inserts") or
        "shutdown" when the server is draining.
    depth / limit: the queue depth that triggered the rejection and its
        configured bound (depth is in the budget's own unit).
    retry_after_s: a crude backoff hint (one batching deadline window) —
        clients that wait this long see a drained queue or a consistent
        rejection, never a hang.
    """

    def __init__(self, kind: str, reason: str, depth: int, limit: int,
                 retry_after_s: float = 0.0):
        self.kind = kind
        self.reason = reason
        self.depth = int(depth)
        self.limit = int(limit)
        self.retry_after_s = float(retry_after_s)
        super().__init__(
            f"{kind} rejected ({reason}): depth {depth} >= limit {limit}"
            + (f"; retry after {retry_after_s * 1e3:.0f}ms"
               if retry_after_s else ""))


class AdmissionController:
    """Bounded admission + latency SLO sketches for one server."""

    def __init__(self, *, max_pending_requests: int = 256,
                 max_pending_points: int = 65536,
                 max_pending_inserts: int = 8,
                 retry_after_s: float = 0.0):
        if min(max_pending_requests, max_pending_points,
               max_pending_inserts) < 1:
            raise ValueError("admission limits must all be >= 1")
        self.max_pending_requests = int(max_pending_requests)
        self.max_pending_points = int(max_pending_points)
        self.max_pending_inserts = int(max_pending_inserts)
        self.retry_after_s = float(retry_after_s)
        self._lock = threading.Lock()
        self._requests = 0
        self._points = 0
        self._inserts = 0
        self._closed = False
        self._shed = {"query": 0, "insert": 0}
        self._done = {"query": 0, "insert": 0}
        self._slo = obs_metrics.Registry()   # private, always on

    # ---- admission ---------------------------------------------------- #

    def admit_query(self, n_points: int) -> None:
        """Admit one query request of ``n_points`` probes or raise
        :class:`Overloaded`; on success the request holds both budgets
        until :meth:`release_query`."""
        with self._lock:
            if self._closed:
                self._shed["query"] += 1
                raise Overloaded("query", "shutdown", self._requests,
                                 self.max_pending_requests)
            if self._requests + 1 > self.max_pending_requests:
                self._shed["query"] += 1
                obs_metrics.inc("serve_shed_total", kind="query",
                                reason="requests")
                raise Overloaded("query", "requests", self._requests,
                                 self.max_pending_requests,
                                 self.retry_after_s)
            if self._points + n_points > self.max_pending_points:
                self._shed["query"] += 1
                obs_metrics.inc("serve_shed_total", kind="query",
                                reason="points")
                raise Overloaded("query", "points", self._points,
                                 self.max_pending_points,
                                 self.retry_after_s)
            self._requests += 1
            self._points += n_points

    def release_query(self, n_points: int) -> None:
        with self._lock:
            self._requests -= 1
            self._points -= n_points

    def admit_insert(self) -> None:
        """Admit one insert batch or raise :class:`Overloaded`."""
        with self._lock:
            if self._closed:
                self._shed["insert"] += 1
                raise Overloaded("insert", "shutdown", self._inserts,
                                 self.max_pending_inserts)
            if self._inserts + 1 > self.max_pending_inserts:
                self._shed["insert"] += 1
                obs_metrics.inc("serve_shed_total", kind="insert",
                                reason="inserts")
                raise Overloaded("insert", "inserts", self._inserts,
                                 self.max_pending_inserts,
                                 self.retry_after_s)
            self._inserts += 1

    def release_insert(self) -> None:
        with self._lock:
            self._inserts -= 1

    def close(self) -> None:
        """Stop admitting (drain mode): every later admit raises
        ``Overloaded(reason="shutdown")``; already-admitted work keeps
        its budget until released."""
        with self._lock:
            self._closed = True

    # ---- SLO tracking ------------------------------------------------- #

    def observe(self, kind: str, seconds: float, *, tenant: str = "") -> None:
        """Record one completed request's latency (both registries)."""
        with self._lock:
            self._done[kind] = self._done.get(kind, 0) + 1
        self._slo.histogram("serve_request_seconds",
                            labels=("kind", "tenant")) \
            .labels(kind=kind, tenant=tenant).observe(seconds)
        obs_metrics.observe("serve_request_seconds", seconds, kind=kind,
                            tenant=tenant)

    def _quantile(self, kind: str, tenant: str, q: float) -> float:
        h = self._slo.get("serve_request_seconds", kind=kind, tenant=tenant)
        return h.quantile(q) if h is not None and h.count else float("nan")

    def stats(self, tenants: tuple[str, ...] = ("",)) -> dict:
        """Queue depths, shed counts, and p50/p99 latency per kind."""
        with self._lock:
            out = {
                "pending_requests": self._requests,
                "pending_points": self._points,
                "pending_inserts": self._inserts,
                "closed": self._closed,
                "shed": dict(self._shed),
                "completed": dict(self._done),
            }
        for kind in ("query", "insert"):
            # per-kind latency pooled across tenants: report the worst
            # tenant's quantile (an SLO is a guarantee, not an average)
            qs = [(self._quantile(kind, t, 0.5), self._quantile(kind, t, 0.99))
                  for t in tenants]
            qs = [(a, b) for a, b in qs if a == a]      # drop NaNs
            out[f"{kind}_p50_s"] = max(a for a, _ in qs) if qs else float("nan")
            out[f"{kind}_p99_s"] = max(b for _, b in qs) if qs else float("nan")
        return out
