"""Immutable versioned index snapshots for the serving plane (DESIGN.md §13).

The streaming handle's ``query`` walks the live tiered LBVH index —
correct under mutation, but every probe pays a divergent tree walk and
the walk shares its arrays with the writer.  The serving plane instead
freezes the handle into an :class:`IndexSnapshot`: an immutable,
eps-specialized *cell-summary grid* over exactly the active points, with
precomputed per-cell aggregates chosen so that the vast majority of
probes are answered from ~5^d cell summaries without touching a single
resident point.

Geometry (paper §3's eps-grid, specialized to read-only serving):

  * cell width ``w = eps / sqrt(d)`` — the cell diagonal is exactly eps,
    so every probe's eps-ball is covered by the 5^d block of cells at
    offsets in [-2, 2]^d around its own cell;
  * points are sorted by row-major cell key (contiguous runs along the
    last axis), with per-unique-cell ``counts`` and ``core-min-label``
    aggregates (non-core residents carry ``INT_MAX`` so the min is over
    core points only — exactly the ``QueryResult.labels`` semantics);
  * per probe, each candidate cell is classified against the eps-ball in
    float64 box arithmetic with a conservative relative margin ``PAD``:
    **inside** (``dmax^2 <= eps^2 (1-PAD)`` — every resident of the cell
    is provably within eps under float32 rounding), **partial**
    (``dmin^2 <= eps^2 (1+PAD)`` — may contribute), or skipped;
  * a probe needs exact point tests only when the inside-cell count has
    not yet saturated at ``min_pts`` while partial cells exist, or when
    a partial cell could still lower the label minimum.  On the serving
    workloads this flags ~5-10% of probes; the rest are answered from
    summaries alone.  Flagged probes run an exact float32 pass over
    their *partial* cells only (inside cells are already exactly
    counted), gathered ragged so the work is proportional to the points
    actually touched — on heavy-tailed data a padded gather would let
    one dense cell inflate the whole chunk.

The margins make the classification *conservative*, never wrong: any
boundary-ambiguous cell is point-tested with the same float32 distance
arithmetic the traversal engine uses, so snapshot answers are
bit-identical to ``StreamingDBSCAN.query`` on the frozen state (the
equivalence tests pin this on every dataset/eps the suite runs).

:class:`SnapshotStore` holds the *published* snapshot behind an atomic
reference swap: readers grab the current snapshot with one attribute
load (no lock on the read path) and keep using it for a whole batch even
if the writer publishes ten newer versions meanwhile — queries are never
blocked behind inserts, merges, or compactions, and a failed rebuild
simply never publishes (the old version keeps serving).
"""
from __future__ import annotations

import itertools
import threading
from typing import NamedTuple

import numpy as np

from repro.core.validate import check_points
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.stream import durability
from repro.stream.index import QueryResult

INT_MAX = np.int64(2**31 - 1)

# Relative classification margin: boundary-ambiguous cells (within
# eps^2 * PAD of the threshold) are demoted to exact point tests, so
# float32 rounding in the reference distance arithmetic can never
# disagree with a float64 box classification.
PAD = 1e-5

# Candidate-cell offset range per axis: w = eps/sqrt(d) keeps the
# eps-ball inside [-2, 2]^d for d in (2, 3).
_RANGE = 2

# Exact-pass probes are processed in chunks, bounding the ragged gather's
# peak memory (sum of partial-cell populations per chunk).
_EXACT_CHUNK = 256


class FrozenState(NamedTuple):
    """What :meth:`repro.stream.StreamingDBSCAN.freeze_view` exports: the
    active points with their serving values, plus the stream position."""
    pts: np.ndarray        # (n_active, d) float32, insertion order
    vals: np.ndarray       # (n_active,) int64: core -> component-min gid
                           # label; non-core -> INT_MAX
    watermark: int         # stream n_points at freeze time
    n_tombstoned: int


class IndexSnapshot:
    """An immutable, eps-specialized read-only view of the index.

    Built by :func:`freeze` (or :meth:`build`); never mutated afterwards
    — the serving plane swaps whole snapshots, it does not edit them.
    """

    def __init__(self, pts: np.ndarray, vals: np.ndarray, eps: float,
                 min_pts: int, *, version: int = 0, watermark: int = 0):
        if eps <= 0:
            raise ValueError(f"snapshot needs eps > 0; got {eps}")
        if min_pts < 1:
            raise ValueError(f"min_pts must be >= 1; got {min_pts}")
        pts = np.ascontiguousarray(pts, np.float32)
        vals = np.ascontiguousarray(vals, np.int64)
        if pts.ndim != 2 or pts.shape[1] not in (2, 3):
            raise ValueError(f"snapshot needs (n, 2|3) points; got "
                             f"{pts.shape}")
        if len(vals) != len(pts):
            raise ValueError(f"vals/pts length mismatch: {len(vals)} vs "
                             f"{len(pts)}")
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self.version = int(version)
        self.watermark = int(watermark)
        self.n_points = len(pts)
        self.d = int(pts.shape[1])
        self._eps2 = np.float32(np.float32(eps) ** 2)
        if self.n_points == 0:
            return
        d = self.d
        self._w = float(eps) / np.sqrt(d)
        self._lo = pts.min(0).astype(np.float64) - 3.0 * float(eps)
        cell = np.floor((pts.astype(np.float64) - self._lo)
                        / self._w).astype(np.int64)
        # per-axis cell-space extents (+5 slack so every resident's
        # [-2, 2]^d neighborhood stays strictly in range)
        self._nc = cell.max(0) + 5
        key = cell[:, 0]
        for i in range(1, d):
            key = key * self._nc[i] + cell[:, i]
        order = np.argsort(key, kind="stable")
        self._keys = key[order]
        self._pts = np.ascontiguousarray(pts[order], np.float32)
        self._vals = np.ascontiguousarray(vals[order], np.int64)
        self._uk, self._starts, self._cnts = np.unique(
            self._keys, return_index=True, return_counts=True)
        self._cmin = np.minimum.reduceat(self._vals, self._starts)
        # candidate offsets, pruned by the worst-case (corner) box
        # distance — an offset whose nearest box face exceeds eps for
        # every in-cell probe position can never contribute
        w2 = self._w * self._w
        offs = []
        for o in itertools.product(range(-_RANGE, _RANGE + 1), repeat=d):
            near2 = sum(max(abs(oi) - 1, 0) ** 2 for oi in o) * w2
            if near2 <= float(self._eps2) * (1 + PAD):
                offs.append(o)
        self._offs = np.array(offs, np.int64)               # (K, d)

    # ------------------------------------------------------------------ #

    @classmethod
    def build(cls, state: FrozenState, eps: float, min_pts: int, *,
              version: int = 0) -> "IndexSnapshot":
        """Build a snapshot from a handle's :class:`FrozenState`."""
        return cls(state.pts, state.vals, eps, min_pts, version=version,
                   watermark=state.watermark)

    def stats(self) -> dict:
        """Size/occupancy facts for logs and the bench record."""
        return {
            "version": self.version, "n_points": self.n_points,
            "watermark": self.watermark, "d": self.d,
            "eps": self.eps, "min_pts": self.min_pts,
            "n_cells": int(len(self._uk)) if self.n_points else 0,
            "n_offsets": int(len(self._offs)) if self.n_points else 0,
        }

    # ------------------------------------------------------------------ #
    # query                                                              #
    # ------------------------------------------------------------------ #

    def query(self, pts) -> QueryResult:
        """Cluster assignment for probe points against this frozen view.

        Same contract (and bit-identical results on the frozen state) as
        :meth:`repro.stream.StreamingDBSCAN.query`: ``labels`` is the
        component representative of the minimum adjacent core point (-1
        when none within eps), ``counts`` the eps-neighbor count among
        active residents saturated at ``min_pts``, ``would_be_core``
        whether the probe would be core if inserted now.
        """
        qb = check_points(pts, name="probe points", dims=(2, 3),
                          allow_empty=True)
        qb = np.ascontiguousarray(qb, np.float32)
        k = len(qb)
        if self.n_points and qb.shape[1] != self.d:
            raise ValueError(f"dimensionality mismatch: snapshot is "
                             f"{self.d}-d, got {qb.shape[1]}-d")
        if k == 0 or self.n_points == 0:
            return QueryResult(np.full(k, -1, np.int32),
                               np.zeros(k, np.int32),
                               np.ones(k, bool) if self.min_pts <= 1
                               else np.zeros(k, bool))
        labels, counts = self._query_arrays(qb)
        obs_metrics.inc("serve_snapshot_queries_total")
        return QueryResult(
            labels=np.where(labels == INT_MAX, -1, labels).astype(np.int32),
            counts=counts,
            would_be_core=counts + 1 >= self.min_pts)

    def _query_arrays(self, qb: np.ndarray):
        B, d = qb.shape
        q64 = qb.astype(np.float64)
        w, mp = self._w, self.min_pts
        qcf = np.floor((q64 - self._lo) / w)
        # clamp far-out probes into a bounded cell range: anything past
        # the slack band is provably > eps from every resident, and the
        # clamp keeps the in-cell offsets (and box distances) finite and
        # the key arithmetic overflow-free
        qc = np.clip(qcf, -3.0, self._nc.astype(np.float64) + 3.0) \
            .astype(np.int64)
        u = q64 - (qc * w + self._lo)                   # (B, d)
        eps2_hi = float(self._eps2) * (1 + PAD)
        eps2_lo = float(self._eps2) * (1 - PAD)

        offs = self._offs                               # (K, d)
        K = len(offs)
        dmin2 = np.zeros((B, K))
        dmax2 = np.zeros((B, K))
        ck = None
        inrange = np.ones((B, K), bool)
        for i in range(d):
            oi = offs[:, i][None, :]                    # (1, K)
            ui = u[:, i][:, None]                       # (B, 1)
            near = np.maximum(np.maximum(oi * w - ui, ui - (oi + 1) * w),
                              0.0)
            far = np.maximum(np.abs(ui - oi * w), np.abs(ui - (oi + 1) * w))
            dmin2 += near * near
            dmax2 += far * far
            ci = qc[:, i][:, None] + oi
            inrange &= (ci >= 0) & (ci < self._nc[i])
            ck = ci if ck is None else ck * self._nc[i] + ci

        idx = np.searchsorted(self._uk, ck.ravel()).reshape(B, K)
        idx = np.minimum(idx, len(self._uk) - 1)
        present = inrange & (self._uk[idx] == ck)
        ins = present & (dmax2 <= eps2_lo)
        par = present & ~ins & (dmin2 <= eps2_hi)
        cn = self._cnts[idx]
        cm = self._cmin[idx]
        inside_cnt = np.where(ins, cn, 0).sum(1)
        inside_min = np.where(ins, cm, INT_MAX).min(1)
        partial_min = np.where(par, cm, INT_MAX).min(1)
        # summaries are exact unless a partial cell could still push the
        # count past saturation or lower the label minimum
        need = (((inside_cnt < mp) & par.any(1))
                | (partial_min < inside_min))
        counts = np.minimum(inside_cnt, mp).astype(np.int32)
        labels = inside_min
        flagged = np.flatnonzero(need)
        obs_metrics.inc("serve_snapshot_exact_probes_total",
                        float(len(flagged)))
        for lo in range(0, len(flagged), _EXACT_CHUNK):
            f = flagged[lo:lo + _EXACT_CHUNK]
            fc, fl = self._exact(qb[f], par[f], idx[f],
                                 inside_cnt[f], inside_min[f])
            counts[f] = fc
            labels[f] = fl
        return labels, counts

    def _exact(self, qb: np.ndarray, par: np.ndarray, idx: np.ndarray,
               inside_cnt: np.ndarray, inside_min: np.ndarray):
        """Exact float32 point tests for flagged probes, over their
        *partial* cells only.

        Inside cells are already exactly accounted (every resident of a
        cell whose far corner is within eps is a hit), and skipped cells
        provably contribute nothing — only partial cells need per-point
        distance tests.  Their residents are gathered **ragged**
        (``np.repeat`` over per-cell spans, work proportional to the
        points actually touched) rather than padded to the longest span:
        on heavy-tailed data one dense cell otherwise pads the whole
        chunk to its length."""
        bi, ki = np.nonzero(par)
        cells = idx[bi, ki]
        lens = self._cnts[cells]
        tot = int(lens.sum())
        cnt = inside_cnt.copy()
        mn = inside_min.copy()
        if tot:
            probe = np.repeat(bi, lens)
            off = np.arange(tot) - np.repeat(np.cumsum(lens) - lens, lens)
            pos = np.repeat(self._starts[cells], lens) + off
            diff = qb[probe] - self._pts[pos]
            d2 = (diff * diff).sum(-1)                  # float32, as the
            hit = d2 <= self._eps2                      # traversal engine
            cnt += np.bincount(probe[hit], minlength=len(qb))
            np.minimum.at(mn, probe[hit], self._vals[pos[hit]])
        return np.minimum(cnt, self.min_pts).astype(np.int32), mn


def freeze(handle, *, version: int = 0) -> IndexSnapshot:
    """Freeze a live :class:`repro.stream.StreamingDBSCAN` handle into an
    immutable :class:`IndexSnapshot` at its (eps, min_pts)."""
    with obs_trace.span("serve.freeze", version=version):
        state = handle.freeze_view()
        snap = IndexSnapshot.build(state, handle.eps, handle.min_pts,
                                   version=version)
    return snap


class SnapshotStore:
    """The published-snapshot cell: one atomic reference, swapped whole.

    Readers call :meth:`current` — a single attribute load, never a lock
    — and use the returned snapshot for as long as they like; it is
    immutable, so a concurrent publish can't corrupt an in-flight batch.
    Writers build the next snapshot *off-path* and :meth:`publish` it;
    the ``mid-publish`` durability barrier sits between build and swap so
    the fault harness can prove a crash there leaves the old version
    serving after recovery.  ``keep`` > 1 retains a short version history
    (``get``) for the linearizability tests.
    """

    def __init__(self, snapshot: IndexSnapshot | None = None, *,
                 keep: int = 1):
        self._lock = threading.Lock()
        self._keep = max(1, int(keep))
        self._history: dict[int, IndexSnapshot] = {}
        self._current: IndexSnapshot | None = None
        if snapshot is not None:
            self.publish(snapshot)

    def current(self) -> IndexSnapshot | None:
        """The currently published snapshot (lock-free read)."""
        return self._current

    def get(self, version: int) -> IndexSnapshot | None:
        """A retained historical version (None once evicted)."""
        with self._lock:
            return self._history.get(version)

    @property
    def version(self) -> int:
        """Version of the current snapshot; -1 before the first publish."""
        snap = self._current
        return snap.version if snap is not None else -1

    def publish(self, snapshot: IndexSnapshot) -> IndexSnapshot:
        """Atomically swap ``snapshot`` in as the serving version.

        Versions must be monotonic — a stale writer (e.g. a recovered
        process racing an old one) cannot roll the serving view back.
        """
        cur = self._current
        if cur is not None and snapshot.version <= cur.version:
            raise ValueError(
                f"snapshot versions must be monotonic: have v{cur.version}, "
                f"got v{snapshot.version}")
        durability.barrier("mid-publish")   # crash here: the old (fully
        with self._lock:                    # durable) version keeps serving
            self._current = snapshot
            self._history[snapshot.version] = snapshot
            while len(self._history) > self._keep:
                del self._history[min(self._history)]
        # metrics: TenantView.publish owns the serve_snapshot_* series —
        # it knows the tenant label; a bare store stays silent
        return snapshot
