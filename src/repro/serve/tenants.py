"""Multi-tenant views over one shared point stream (DESIGN.md §13).

A tenant is an independent ``(eps, min_pts)`` *view* of the same data:
the anomaly team wants tight clusters at eps=0.01, the heat-map wants
coarse ones at eps=0.05, and neither should pay for — or be able to
break — the other.  The eps-independent part of the work (the Morton
sort + LBVH of the point set) is shared through ``dispatch.plan``'s
index cache: :func:`repro.core.dispatch.tenant_handles` builds every
tenant's streaming handle off **one** cached index build.  Everything
eps-dependent is private per tenant:

  * its own ``StreamingDBSCAN`` handle (labels, counts, core mask —
    these depend on eps/min_pts and cannot be shared);
  * its own :class:`~repro.serve.snapshot.SnapshotStore` with its own
    monotonic version counter — tenants publish independently, and a
    failed rebuild for one tenant leaves every other tenant's serving
    view untouched;
  * its own label namespace: ``QueryResult.labels`` are component
    representatives in the tenant's own clustering, never comparable
    across tenants;
  * its own durability files (``<dir>/<name>.wal`` / ``<name>.npz``)
    and its own per-tenant metric series (``tenant=<name>`` labels).
"""
from __future__ import annotations

import os
import re
from typing import NamedTuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import names as obs_names
from repro.serve import snapshot as snapshot_mod

_NAME_RE = re.compile(r"^[A-Za-z0-9_.-]{1,64}$")


class TenantSpec(NamedTuple):
    """Declarative tenant description: a name plus its view parameters."""
    name: str
    eps: float
    min_pts: int


def check_specs(specs) -> list[TenantSpec]:
    """Validate and normalize a tenant spec list (names unique and
    path-safe — they become WAL/checkpoint file stems)."""
    out = [TenantSpec(str(s[0]), float(s[1]), int(s[2])) for s in specs]
    if not out:
        raise ValueError("a server needs at least one tenant")
    seen = set()
    for s in out:
        if not _NAME_RE.match(s.name):
            raise ValueError(f"tenant name {s.name!r} must match "
                             f"{_NAME_RE.pattern} (it names durability "
                             "files and metric labels)")
        if s.name in seen:
            raise ValueError(f"duplicate tenant name {s.name!r}")
        seen.add(s.name)
        if s.eps <= 0:
            raise ValueError(f"tenant {s.name!r}: eps must be > 0")
        if s.min_pts < 1:
            raise ValueError(f"tenant {s.name!r}: min_pts must be >= 1")
    return out


def durability_paths(durability_dir: str | None, name: str):
    """(wal_path, checkpoint_path) for one tenant; (None, None) when
    durability is off."""
    if durability_dir is None:
        return None, None
    return (os.path.join(durability_dir, f"{name}.wal"),
            os.path.join(durability_dir, f"{name}.npz"))


class TenantView:
    """One tenant's serving state: handle + snapshot store + batcher slot.

    The view owns the tenant's version counter: :meth:`publish` freezes
    the handle into the next version and atomically swaps it in.  The
    freeze runs *off* the query path (the writer thread); queries only
    ever touch ``store.current()``.
    """

    def __init__(self, spec: TenantSpec, handle, *, keep_versions: int = 1):
        self.spec = spec
        self.name = spec.name
        self.handle = handle
        self.store = snapshot_mod.SnapshotStore(keep=keep_versions)
        self.publish()                      # v1: serving starts consistent

    def publish(self) -> "snapshot_mod.IndexSnapshot":
        """Freeze the handle's current state and swap it in as the next
        snapshot version.  Any exception during the freeze propagates
        *before* the swap — the old version keeps serving."""
        snap = snapshot_mod.freeze(self.handle,
                                   version=self.store.version + 1)
        self.store.publish(snap)
        obs_metrics.inc(obs_names.SERVE_SNAPSHOT_PUBLISHES,
                        tenant=self.name)
        obs_metrics.set_gauge(obs_names.SERVE_SNAPSHOT_VERSION,
                              float(snap.version), tenant=self.name)
        obs_metrics.set_gauge(obs_names.SERVE_TENANT_ACTIVE_POINTS,
                              float(snap.n_points), tenant=self.name)
        return snap

    def stats(self) -> dict:
        snap = self.store.current()
        return {
            "name": self.name, "eps": self.spec.eps,
            "min_pts": self.spec.min_pts,
            "version": self.store.version,
            "n_active": int(self.handle.n_active),
            "watermark": int(self.handle.n_points),
            "snapshot": snap.stats() if snap is not None else None,
        }


def build_views(points, specs, *, durability_dir: str | None = None,
                window: int | None = None, checkpoint_every: int = 0,
                keep_versions: int = 1, **handle_kwargs) -> list[TenantView]:
    """Build every tenant's view over one shared index build.

    Routes through :func:`repro.core.dispatch.tenant_handles`, so N
    tenants over the same points cost one Morton sort + one LBVH build
    (the ``dispatch_index_builds_total`` counter proves it), then wraps
    each handle in a :class:`TenantView` with its published v1 snapshot.
    """
    from repro.core import dispatch

    specs = check_specs(specs)
    if durability_dir is not None:
        os.makedirs(durability_dir, exist_ok=True)
    tenants = {}
    for s in specs:
        wal, ckpt = durability_paths(durability_dir, s.name)
        tenants[s.name] = dict(eps=s.eps, min_pts=s.min_pts, wal=wal,
                               checkpoint_path=ckpt, window=window,
                               checkpoint_every=checkpoint_every,
                               **handle_kwargs)
    handles = dispatch.tenant_handles(points, tenants)
    return [TenantView(s, handles[s.name], keep_versions=keep_versions)
            for s in specs]


def restore_views(specs, *, durability_dir: str,
                  window: int | None = None, checkpoint_every: int = 0,
                  keep_versions: int = 1, topup_batch: int = 512,
                  **handle_kwargs) -> list[TenantView]:
    """Recover every tenant's view from its durability files after a
    crash, then *top up* lagging tenants.

    Each tenant recovers independently (checkpoint + WAL replay, the PR 6
    path).  Because the writer applies one insert batch to the tenants in
    sequence, a crash mid-apply can leave replicas at different
    watermarks; the leader (highest watermark) holds the authoritative
    point stream, so every lagging tenant replays the leader's missing
    suffix through its normal ``insert`` path (re-logged to its own WAL —
    the top-up itself is durable).  After restore all tenants sit at the
    same watermark and serving resumes from freshly published snapshots.
    """
    from repro.stream import StreamingDBSCAN

    specs = check_specs(specs)
    handles = {}
    for s in specs:
        wal, ckpt = durability_paths(durability_dir, s.name)
        handles[s.name] = StreamingDBSCAN.restore(
            ckpt, wal=wal, window=window,
            checkpoint_every=checkpoint_every, **handle_kwargs)
        if (abs(handles[s.name].eps - s.eps) > 1e-12
                or handles[s.name].min_pts != s.min_pts):
            raise ValueError(
                f"tenant {s.name!r}: durable state has eps="
                f"{handles[s.name].eps}/min_pts={handles[s.name].min_pts}, "
                f"spec says eps={s.eps}/min_pts={s.min_pts}")
    leader = max(handles.values(), key=lambda h: h.n_points)
    for s in specs:
        h = handles[s.name]
        while h.n_points < leader.n_points:
            lo = h.n_points
            hi = min(lo + int(topup_batch), leader.n_points)
            h.insert(leader.stream_slice(lo, hi))
    return [TenantView(s, handles[s.name], keep_versions=keep_versions)
            for s in specs]
