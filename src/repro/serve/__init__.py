"""repro.serve — the serving subsystem (DESIGN.md §13).

Turns the streaming index into a long-lived multi-tenant service:

  * :mod:`repro.serve.batching` — continuous adaptive micro-batching on
    the shared jit bucket ladder;
  * :mod:`repro.serve.snapshot` — immutable versioned index snapshots
    with atomic swap (queries never block behind writes);
  * :mod:`repro.serve.tenants` — per-(eps, min_pts) views sharing one
    cached index build;
  * :mod:`repro.serve.admission` — bounded queues, typed load shedding,
    latency SLO sketches;
  * :mod:`repro.serve.server` — the :class:`Server` tying the planes
    together, with graceful shutdown and crash recovery.

``python -m repro.launch.serve`` is the CLI; ``benchmarks/bench_serve.py``
measures the plane and commits ``BENCH_serve.json``.
"""
from repro.serve.admission import AdmissionController, Overloaded
from repro.serve.batching import MicroBatcher, bucket_size
from repro.serve.server import InsertReply, QueryReply, Server, ServerConfig
from repro.serve.snapshot import (FrozenState, IndexSnapshot, SnapshotStore,
                                  freeze)
from repro.serve.tenants import TenantSpec, TenantView, build_views, \
    restore_views

__all__ = [
    "Server", "ServerConfig", "QueryReply", "InsertReply",
    "TenantSpec", "TenantView", "build_views", "restore_views",
    "IndexSnapshot", "SnapshotStore", "FrozenState", "freeze",
    "AdmissionController", "Overloaded",
    "MicroBatcher", "bucket_size",
]
