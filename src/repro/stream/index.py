"""Streaming DBSCAN over a two-level LBVH index (DESIGN.md §7).

``StreamingDBSCAN`` keeps density clusters live under online insertions —
the serving path the batch pipeline cannot cover (it reclusters from
scratch per call). Three operations:

  * ``query(pts)``    — read-only cluster assignment for a batch of probe
                        points (external-query traversal, no mutation);
  * ``insert(pts)``   — micro-batch ingestion with bidirectional core-count
                        updates and incremental label repair;
  * ``snapshot()``    — materialized labels, component-identical to batch
                        ``dbscan`` on the accumulated point set.

LSM-style two-level index: one large immutable *main* LBVH (built at
construction or at the last merge) plus one small *delta* LBVH over the
points inserted since.  Every operation traverses both trees with the
engine's external predicate batches
(``traversal.intersects(sphere(eps), pts=...)``, DESIGN.md §8), chaining
the running accumulator through the visitor carry exactly like the
sharded path chains across shards; when the delta outgrows
``merge_ratio`` times the main, a jitted merge re-sorts the union along
the Morton curve and rebuilds a single main tree.

Core-count bookkeeping is *bidirectional*: a new point counts its resident
neighbors (main + delta + within-batch), and every resident point within
eps of the batch has its count incremented — so an insert can promote an
existing borderline/noise point to core.  Counts saturate at ``min_pts``
(sound for the core threshold: ``min(c, mp) + inc >= mp  <=>
c + inc >= mp`` for ``inc >= 0``, the same saturation argument as the
sharded path's per-visit counts).

Label repair is an incremental union-find pass (``unionfind`` semantics on
the global insert-order ids): the only new core-core edges have an
endpoint in S = {new points} ∪ {promoted points}, all of which lie inside
the eps-dilated AABB of the batch, so the first repair sweep runs just the
S cores as queries gathering over the full core set; the whole seed is
then marked *changed* (its labels are new entries in the pool), and
subsequent sweeps run the exact frontier restriction of the batch pipeline
(gather only from changed points, queries only eps-near the change) until
the fixpoint — the reverse direction of every new edge is pulled in sweep
2 at masked-gather cost. Labels always satisfy ``labels[i] <= i`` with
component-minimum representatives at rest, so bulk pointer jumping can
never cycle.

Distance arithmetic is float32 end to end — including the NumPy brute
paths — so boundary decisions agree bit-for-bit with the traversal engine
and ``snapshot()`` reproduces the batch core mask exactly.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fdbscan, grid, lbvh, morton, traversal, unionfind
from repro.core.fdbscan import DBSCANResult
from repro.core.validate import check_points
from repro.stream import durability

INT_MAX = traversal.INT_MAX

# Delta/main size ratio above which an insert triggers an automatic merge,
# and the floor below which the delta never auto-merges (tiny deltas are
# cheap to traverse; rebuilding the main tree for them is not).
MERGE_RATIO = 0.25
MERGE_MIN = 256

# Sentinel padding offset in units of eps beyond the delta's own bounding
# box: >= 3*eps along every axis keeps any real query (which can lie
# anywhere) from ever *matching* a sentinel in masked modes and keeps the
# box tests cheap; unmasked count mode is never run against the delta.
_SENTINEL_EPS = 3.0


class _Level(NamedTuple):
    """One level of the two-level index (main or delta)."""
    segs: grid.Segments      # singleton segments, Morton order (+ sentinels)
    tree: lbvh.Tree | None   # None only for <2 resident points
    gids: np.ndarray         # (n_prims,) global insert id per sorted
                             # primitive; -1 marks a padding sentinel


class QueryResult(NamedTuple):
    """Read-only cluster assignment for a probe batch.

    labels: component representative (global insert id of the component's
            minimum member) of the min adjacent core point, or -1 when no
            core point lies within eps (the probe would be noise).
    counts: eps-neighbors among resident points, saturated at ``min_pts``.
    would_be_core: the probe would be a core point if inserted now
            (counts + itself >= min_pts).
    """
    labels: np.ndarray
    counts: np.ndarray
    would_be_core: np.ndarray


@jax.jit
def _build_index(pts, lo, hi):
    """Jitted Morton-sort + singleton-segment LBVH build.

    Serves both the merge (re-encode the union under its fresh bounds —
    inserts can stretch the extent, so codes cannot simply be merged from
    the two levels' old key streams) and the padded delta rebuild (``lo``/
    ``hi`` are the *valid* points' bounds, so sentinels clip to the top
    cell exactly like the sharded path's padding).
    """
    codes = morton.morton_encode(pts, lo=lo, hi=hi)
    order = jnp.argsort(codes)
    segs = grid.singleton_segments(pts[order], order.astype(jnp.int32),
                                   codes[order])
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return segs, tree


def _hits_blocked(a: np.ndarray, b: np.ndarray, eps2: np.float32,
                  block: int = 2048) -> np.ndarray:
    """# rows of ``b`` within eps of each row of ``a``; float32 arithmetic
    matching the traversal's d2 so boundary decisions cannot diverge."""
    out = np.zeros(len(a), np.int64)
    for lo in range(0, len(a), block):
        diff = a[lo:lo + block, None, :] - b[None, :, :]
        d2 = (diff * diff).sum(-1)
        out[lo:lo + block] = (d2 <= eps2).sum(1)
    return out


class StreamingDBSCAN:
    """Online DBSCAN handle: insert micro-batches, query probes, snapshot.

    points: optional initial point set (clustered with the batch pipeline);
        ``None`` starts empty (the serving loop's cold-start path).
    index: optional prebuilt plain-FDBSCAN ``(segs, tree)`` over ``points``
        — the dispatcher passes its cached eps-independent index here so
        streaming composes with eps/min_pts parameter sweeps.
    merge_ratio: delta/main size ratio that triggers an automatic merge.
    wal: optional write-ahead log path (or a prebuilt
        ``durability.WriteAheadLog``): every insert batch is durably
        appended *before* it is applied, so an acknowledged insert
        survives a crash (DESIGN.md §10). The file must be fresh — a WAL
        with leftover records means a previous process died; go through
        :meth:`restore` instead of silently shadowing its state. Without
        a ``checkpoint_path``, bootstrap points are logged as the log's
        first (gid-0) record, so WAL-only recovery covers them too.
    checkpoint_path: optional checkpoint file; written atomically by
        :meth:`checkpoint` (and once at construction when the handle
        bootstraps from initial points, so they are durable too).
    checkpoint_every: auto-checkpoint policy — write ``checkpoint_path``
        after every K index merges (0 = manual checkpoints only).
    """

    def __init__(self, points, eps: float, min_pts: int, *,
                 merge_ratio: float = MERGE_RATIO, index=None,
                 wal=None, checkpoint_path: str | None = None,
                 checkpoint_every: int = 0):
        if eps <= 0:
            raise ValueError(f"streaming index needs eps > 0; got {eps}")
        if min_pts < 1:
            raise ValueError(f"min_pts must be >= 1; got {min_pts}")
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self._eps2 = np.float32(jnp.asarray(eps, jnp.float32) ** 2)
        self._merge_ratio = float(merge_ratio)
        self._pts = np.zeros((0, 2), np.float32)
        self._counts = np.zeros(0, np.int32)   # |N_eps| incl. self, sat. mp
        self._core = np.zeros(0, bool)
        self._labels = np.zeros(0, np.int32)   # core: component-min gid;
                                               # non-core: own gid
        self._main: _Level | None = None
        self._n_main = 0
        self._delta: _Level | None = None
        self.n_inserts = 0
        self.n_merges = 0
        self.n_repair_sweeps = 0
        self._ckpt_path = checkpoint_path
        self._ckpt_every = int(checkpoint_every)
        self._merges_since_ckpt = 0
        if checkpoint_every and not checkpoint_path:
            raise ValueError("checkpoint_every needs a checkpoint_path")
        self._wal = None
        if wal is not None:
            if not isinstance(wal, durability.WriteAheadLog):
                wal = durability.WriteAheadLog(str(wal), eps=self.eps,
                                               min_pts=self.min_pts)
            _, stale, _ = durability.scan_wal(wal.path)
            if stale:
                raise durability.WALError(
                    f"{wal.path}: WAL already holds {len(stale)} record(s) "
                    "from a previous run — recover them with "
                    "StreamingDBSCAN.restore(...) or remove the file "
                    "before starting a fresh handle")
            self._wal = wal
        if points is not None:
            pts = np.array(points, np.float32)   # copy: never alias callers
            if pts.size:
                self._bootstrap(pts, index)
                if self._ckpt_path is not None:
                    # make the bootstrap set durable: the WAL only covers
                    # inserts, so without this a crash before the first
                    # checkpoint would lose the initial clustering
                    self.checkpoint()
                elif self._wal is not None:
                    # WAL-only durability: log the bootstrap set as the
                    # gid-0 record, otherwise recovery cold-starts empty,
                    # every later record sits past a gap, and acknowledged
                    # inserts would be unrecoverable
                    self._wal.append(self._pts, 0)

    # ------------------------------------------------------------------ #
    # public surface                                                     #
    # ------------------------------------------------------------------ #

    @property
    def n_points(self) -> int:
        return len(self._pts)

    @property
    def n_main(self) -> int:
        return self._n_main

    @property
    def n_delta(self) -> int:
        return len(self._pts) - self._n_main

    @property
    def points(self) -> np.ndarray:
        """The accumulated point set in insertion order (read-only view)."""
        view = self._pts.view()
        view.flags.writeable = False
        return view

    def query(self, pts) -> QueryResult:
        """Cluster assignment for probe points; never mutates the index."""
        qpts = self._check_pts(pts, grow=False)
        k = len(qpts)
        if k == 0 or self.n_points == 0:
            return QueryResult(np.full(k, -1, np.int32),
                               np.zeros(k, np.int32),
                               np.ones(k, bool) if self.min_pts <= 1
                               else np.zeros(k, bool))
        vals = np.where(self._core, self._labels, INT_MAX).astype(np.int32)
        acc = np.full(k, INT_MAX, np.int32)
        for lvl in self._levels():
            acc, _ = self._run(lvl, qpts, vals, self._core, acc,
                               mode="minlabel")
        counts = np.zeros(k, np.int64)
        for lvl in self._levels():
            counts += self._count(lvl, qpts)
        counts = np.minimum(counts, self.min_pts).astype(np.int32)
        return QueryResult(
            labels=np.where(acc == INT_MAX, -1, acc).astype(np.int32),
            counts=counts,
            would_be_core=counts + 1 >= self.min_pts)

    def insert(self, pts) -> "StreamingDBSCAN":
        """Ingest a micro-batch: counts update bidirectionally, labels are
        repaired incrementally, the delta tree is rebuilt (padded to a
        bucketed size for stable jit shapes), and an oversized delta
        triggers a merge.

        With a WAL attached the batch is durably appended (fsync) before
        any state changes, so by the time ``insert`` returns — the
        *acknowledgment* — the batch survives a crash at any barrier.
        Raises ValueError for empty batches and NaN/Inf coordinates
        (nothing is logged or applied for a rejected batch)."""
        batch = self._check_pts(pts, grow=True)
        b = len(batch)
        durability.barrier("pre-insert")    # crash: batch never durable
        if self._wal is not None:
            self._wal.append(batch, self.n_points)
            durability.barrier("wal-durable")   # crash: durable, unapplied
        n_old = self.n_points
        gid0 = n_old

        # ---- bidirectional core-count update --------------------------
        c_new = np.zeros(b, np.int64)
        for lvl in self._levels():          # vs main + vs *old* delta
            c_new += self._count(lvl, batch)
        c_new += _hits_blocked(batch, batch, self._eps2)  # within (incl self)
        new_counts = np.minimum(c_new, self.min_pts).astype(np.int32)

        # existing points eps-near the batch gain neighbors; the eps-cell
        # dilation filter is a sound superset of "within eps of a batch
        # point" (and a subset of the batch's eps-dilated AABB)
        all_pts = (np.concatenate([self._pts, batch]) if n_old else batch)
        keys = fdbscan._cell_keys(all_pts, self.eps)
        batch_mask = np.zeros(n_old + b, bool)
        batch_mask[n_old:] = True
        near = fdbscan._near_changed(keys, batch.shape[1], batch_mask)
        was_core = self._core
        aff = np.flatnonzero(near[:n_old])
        if len(aff):
            inc = _hits_blocked(self._pts[aff], batch, self._eps2)
            self._counts[aff] = np.minimum(
                self._counts[aff] + inc, self.min_pts).astype(np.int32)

        # ---- append + delta rebuild -----------------------------------
        self._pts = all_pts
        self._counts = np.concatenate([self._counts, new_counts])
        core_now = self._counts >= self.min_pts
        promoted = np.flatnonzero(core_now[:n_old] & ~was_core)
        self._core = core_now
        self._labels = np.concatenate(
            [self._labels, np.arange(gid0, gid0 + b, dtype=np.int32)])
        self._rebuild_delta()

        # ---- incremental label repair ---------------------------------
        seed = np.concatenate(
            [promoted, np.arange(gid0, gid0 + b, dtype=np.int64)])
        self._repair(seed, keys)
        self.n_inserts += 1

        # ---- merge policy ---------------------------------------------
        if self.n_delta > max(MERGE_MIN,
                              int(self._merge_ratio * self._n_main)):
            self.merge()
        durability.barrier("post-insert")   # crash: applied, un-acked —
        return self                         # replay re-applies identically

    def merge(self) -> "StreamingDBSCAN":
        """Fold the delta into the main level: one jitted Morton re-sort +
        LBVH rebuild over the union, padded to the same shape buckets as
        the delta so repeated merges at ever-growing point counts reuse
        compiled programs. Index-only — labels, counts, and the core mask
        are untouched, so a merge can never change ``snapshot``."""
        n = self.n_points
        if n == self._n_main:
            return self
        if n >= 2:
            new_main = self._build_level(
                self._pts, np.arange(n, dtype=np.int64))
        else:
            segs = grid.build_segments_fdbscan(jnp.asarray(self._pts))
            new_main = _Level(segs, None, np.asarray(segs.order, np.int64))
        durability.barrier("mid-merge")     # crash with the merge in
        self._main = new_main               # flight: all in-memory, the
        self._n_main = n                    # durable state is unaffected
        self._delta = None
        self.n_merges += 1
        self._merges_since_ckpt += 1
        if (self._ckpt_path is not None and self._ckpt_every
                and self._merges_since_ckpt >= self._ckpt_every):
            self.checkpoint()
        return self

    def snapshot(self, *, star: bool = False) -> DBSCANResult:
        """Materialized labels over the accumulated point set (insertion
        order), component-identical to batch ``dbscan``: exact core mask,
        exact noise set, identical core partition; border points take the
        min adjacent core representative. ``star=True`` is DBSCAN* (no
        border points)."""
        n = self.n_points
        if n == 0:
            return DBSCANResult(labels=jnp.zeros(0, jnp.int32),
                                core_mask=jnp.zeros(0, bool), n_clusters=0,
                                n_sweeps=self.n_repair_sweeps,
                                n_traversals=-1, backend="stream")
        core = self._core
        labels_full = np.where(core, self._labels, -1).astype(np.int32)
        if not star:
            nb = np.flatnonzero(~core)
            if len(nb) and core.any():
                vals = np.where(core, self._labels, INT_MAX).astype(np.int32)
                acc = np.full(len(nb), INT_MAX, np.int32)
                for lvl in self._levels():
                    acc, _ = self._run(lvl, self._pts[nb], vals, core, acc,
                                       mode="minlabel")
                labels_full[nb] = np.where(acc == INT_MAX, -1, acc)
        uniq = np.unique(labels_full[core]) if core.any() else \
            np.zeros(0, np.int32)
        out = np.full(n, -1, np.int32)
        pos = labels_full >= 0
        out[pos] = np.searchsorted(uniq, labels_full[pos]).astype(np.int32)
        return DBSCANResult(labels=jnp.asarray(out),
                            core_mask=jnp.asarray(core),
                            n_clusters=int(len(uniq)),
                            n_sweeps=self.n_repair_sweeps,
                            n_traversals=-1, backend="stream")

    # ------------------------------------------------------------------ #
    # durability (DESIGN.md §10)                                         #
    # ------------------------------------------------------------------ #

    def checkpoint(self, path: str | None = None) -> dict:
        """Atomically serialize the full handle state to ``path`` (default:
        the ``checkpoint_path`` the handle was built with).

        The checkpoint is a single ``.npz`` — points, saturated core
        counts, core mask, union-find labels, plus a manifest (format
        version, eps/min_pts, the insert-order watermark, a content
        checksum) — written tmp-file + fsync + rename, so a crash during
        the write leaves the previous checkpoint intact. A checkpoint
        written to the *configured* ``checkpoint_path`` (the file
        :meth:`restore` will read) also truncates the attached WAL —
        every logged record is now covered by the watermark; an ad-hoc
        side checkpoint to some other ``path`` leaves the WAL alone, so
        the records the configured path's recovery needs stay durable.
        Returns the manifest written.
        """
        path = path if path is not None else self._ckpt_path
        if path is None:
            raise ValueError("no checkpoint path: pass one to checkpoint() "
                             "or build the handle with checkpoint_path=")
        manifest = durability.save_checkpoint(self, path)
        if (self._ckpt_path is not None
                and os.path.realpath(path) == os.path.realpath(self._ckpt_path)):
            self._merges_since_ckpt = 0
            if self._wal is not None:
                self._wal.reset()
        return manifest

    @classmethod
    def restore(cls, checkpoint_path: str | None = None, *, wal=None,
                **kwargs) -> "StreamingDBSCAN":
        """Recover a live handle from durable state after a crash.

        Loads ``checkpoint_path`` (if the file exists), replays every WAL
        record past the checkpoint's watermark through the normal insert
        path, and silently truncates a torn/corrupt WAL tail (an
        interrupted append was by definition never acknowledged). The
        recovered handle re-attaches both files and keeps serving.

        Args:
            checkpoint_path: checkpoint file written by :meth:`checkpoint`
                (may not exist yet — then recovery is WAL-only).
            wal: the write-ahead log path the crashed handle appended to.
            **kwargs: handle options (``merge_ratio``,
                ``checkpoint_every``) for the recovered instance.

        Returns:
            A handle whose ``snapshot()`` is component-identical to batch
            ``dbscan`` on exactly the durable (acknowledged) points.

        Raises:
            repro.stream.durability.CheckpointError: the checkpoint file
                is corrupt or has an unknown format version.
            repro.stream.durability.WALError: the WAL header is not ours.
            ValueError: neither file holds any state to recover.
        """
        wal_path = wal.path if isinstance(wal, durability.WriteAheadLog) \
            else wal
        return durability.recover(checkpoint_path, wal_path, **kwargs)

    def _adopt_state(self, state: dict) -> None:
        """Install checkpointed arrays + rebuild the two-level index from
        them (used by ``durability.recover``; no reclustering — labels,
        counts, and the core mask are restored verbatim, the trees are
        deterministically rebuilt from the points)."""
        m = state["manifest"]
        pts = np.ascontiguousarray(state["pts"], np.float32)
        if len(pts):
            check_points(pts, name="checkpoint points", dims=(2, 3))
        self._pts = pts
        self._counts = np.ascontiguousarray(state["counts"], np.int32)
        self._core = np.ascontiguousarray(state["core"], bool)
        self._labels = np.ascontiguousarray(state["labels"], np.int32)
        self.n_inserts = int(m["n_inserts"])
        self.n_merges = int(m["n_merges"])
        self.n_repair_sweeps = int(m["n_repair_sweeps"])
        n_main = int(m["n_main"])
        self._n_main = n_main
        if n_main >= 2:
            self._main = self._build_level(
                self._pts[:n_main], np.arange(n_main, dtype=np.int64))
        elif n_main == 1:
            segs = grid.build_segments_fdbscan(
                jnp.asarray(self._pts[:n_main]))
            self._main = _Level(segs, None, np.asarray(segs.order, np.int64))
        else:
            self._main = None
        self._rebuild_delta()

    # ------------------------------------------------------------------ #
    # internals                                                          #
    # ------------------------------------------------------------------ #

    def _check_pts(self, pts, grow: bool) -> np.ndarray:
        # an empty *probe* batch is a valid request (empty QueryResult,
        # matching neighbors.*); an empty *insert* batch is rejected
        checked = check_points(pts, name="points", dims=(2, 3),
                               allow_empty=not grow)
        # np.array (not asarray): never alias a caller-owned buffer the
        # caller may mutate after we have indexed its coordinates
        arr = np.array(checked, np.float32)
        if self.n_points and arr.shape[1] != self._pts.shape[1]:
            raise ValueError(f"dimensionality mismatch: index is "
                             f"{self._pts.shape[1]}-d, got {arr.shape[1]}-d")
        if grow and self.n_points == 0 and self._pts.shape[1] != arr.shape[1]:
            self._pts = np.zeros((0, arr.shape[1]), np.float32)
        return arr

    def _bootstrap(self, pts: np.ndarray, index) -> None:
        """Initial batch clustering via the fused pipeline, converted to
        global (insertion-order) ids with component-minimum reps."""
        n = pts.shape[0]
        self._check_pts(pts, grow=True)
        if index is not None:
            segs, tree = index
            if segs.n_points != n:
                raise ValueError(f"index covers {segs.n_points} points, "
                                 f"got {n}")
            if bool(np.asarray(segs.dense_seg).any()):
                raise ValueError("streaming needs the plain (singleton) "
                                 "fdbscan index, not a densebox index")
            if tree is None and segs.n_segments >= 2:
                tree = lbvh.build_tree(segs.codes, segs.prim_lo,
                                       segs.prim_hi)
        else:
            segs = grid.build_segments_fdbscan(jnp.asarray(pts))
            tree = (lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
                    if segs.n_segments >= 2 else None)
        self._pts = pts
        order = np.asarray(segs.order, np.int64)
        if n >= 2 and tree is not None:
            core_s, labels0, vals0, absorbed, tr = fdbscan._fused_first_pass(
                tree, segs, self.eps, self.min_pts)
            core_labels, _, _ = fdbscan._sweep_to_fixpoint(
                tree, segs, self.eps, core_s, labels0,
                fused_init=(vals0, absorbed))
            counts_s = np.minimum(np.asarray(tr.hits) + 1,
                                  self.min_pts).astype(np.int32)
            core_np = np.asarray(core_s)
            roots_s = np.asarray(core_labels)
            counts = np.empty(n, np.int32)
            counts[order] = counts_s
            core = np.empty(n, bool)
            core[order] = core_np
            labels = np.arange(n, dtype=np.int32)
            if core_np.any():
                # sorted-space roots -> component-minimum *global* id, the
                # rep order the streaming hooks preserve (labels[i] <= i)
                rep_gid = np.full(n, n, np.int64)
                np.minimum.at(rep_gid, roots_s[core_np], order[core_np])
                labels[order[core_np]] = \
                    rep_gid[roots_s[core_np]].astype(np.int32)
        else:                       # n == 1
            counts = np.ones(n, np.int32)
            core = counts >= self.min_pts
            labels = np.zeros(n, np.int32)
        self._counts, self._core, self._labels = counts, core, labels
        self._main = _Level(segs, tree, order)
        self._n_main = n

    def _levels(self):
        if self._main is not None:
            yield self._main
        if self._delta is not None:
            yield self._delta

    def _rebuild_delta(self) -> None:
        nd = self.n_delta
        if nd == 0:
            self._delta = None
            return
        self._delta = self._build_level(
            self._pts[self._n_main:],
            np.arange(self._n_main, self._n_main + nd, dtype=np.int64))

    def _build_level(self, dpts: np.ndarray, gids: np.ndarray) -> _Level:
        """Jitted index build over ``dpts`` (global ids ``gids``), padded
        to a bucketed size with out-of-range sentinels (gid -1) so every
        level sees a bounded set of jit shapes."""
        nd = len(dpts)
        pad = max(fdbscan._pad_size(nd), 2)
        lo, hi = dpts.min(0), dpts.max(0)
        if pad > nd:
            sent = hi + np.float32(_SENTINEL_EPS * self.eps)
            dpts = np.concatenate(
                [dpts, np.broadcast_to(sent, (pad - nd, dpts.shape[1]))])
            gids = np.concatenate([gids, np.full(pad - nd, -1, np.int64)])
        segs, tree = _build_index(jnp.asarray(dpts),
                                  jnp.asarray(lo), jnp.asarray(hi))
        return _Level(segs, tree, gids[np.asarray(segs.order)])

    def _count(self, lvl: _Level, qpts: np.ndarray) -> np.ndarray:
        """eps-neighbor count of external queries against one level.

        A sentinel-free level uses plain ``count`` mode (early exit at
        min_pts); a padded level (the delta, or a merged main) uses the
        masked fused count (``count_minlabel``'s hits), which a sentinel
        can never enter — a probe may legitimately live anywhere,
        including near a sentinel's coordinates."""
        if lvl.tree is None:
            gv = lvl.gids[lvl.gids >= 0]
            if len(gv) == 0:
                return np.zeros(len(qpts), np.int64)
            return np.minimum(_hits_blocked(qpts, self._pts[gv], self._eps2),
                              self.min_pts)
        has_sentinel = bool((lvl.gids < 0).any())
        if not has_sentinel:
            acc, _ = self._run(lvl, qpts,
                               np.zeros(self.n_points, np.int32),
                               np.ones(self.n_points, bool),
                               np.zeros(len(qpts), np.int32),
                               mode="count", cap=self.min_pts)
            return acc.astype(np.int64)
        _, hits = self._run(lvl, qpts,
                            np.zeros(self.n_points, np.int32),
                            np.ones(self.n_points, bool),
                            np.full(len(qpts), INT_MAX, np.int32),
                            mode="count_minlabel", cap=self.min_pts)
        return hits.astype(np.int64)

    def _run(self, lvl: _Level, qpts: np.ndarray, vals: np.ndarray,
             mask: np.ndarray, init: np.ndarray, mode: str,
             cap: int = INT_MAX):
        """One external-query pass against one level; (acc, hits) sliced
        to the query count. ``init`` seeds the visitor's carry, chaining
        the running accumulator across levels (the two-tree analogue of
        the sharded path's traveling carry)."""
        k = len(qpts)
        gsafe = np.maximum(lvl.gids, 0)
        valid = lvl.gids >= 0
        if lvl.tree is None:        # <2 residents: trivial brute force
            gv = lvl.gids[valid]
            if len(gv) == 0:
                return init.copy(), np.zeros(k, np.int64)
            res = self._pts[gv]
            diff = qpts[:, None, :] - res[None]
            hit = (diff * diff).sum(-1) <= self._eps2
            ok = hit & mask[gv][None]
            vv = np.where(ok, vals[gv][None].astype(np.int64), INT_MAX)
            acc = np.minimum(init.astype(np.int64), vv.min(1))
            return acc.astype(np.int32), ok.sum(1).astype(np.int64)
        pad = fdbscan._pad_size(k)
        ids = np.full(pad, -1, np.int32)
        ids[:k] = 0
        qp = np.zeros((pad, qpts.shape[1]), np.float32)
        qp[:k] = qpts
        ini = np.full(pad, INT_MAX, np.int32)
        ini[:k] = init
        pv = np.where(valid, vals[gsafe], INT_MAX).astype(np.int32)
        pm = valid & mask[gsafe]
        node_mask = None
        if mode != "count":         # count needs every resident; the
            node_mask = lbvh.propagate_leaf_flags(   # others prune to mask
                lvl.tree, jnp.asarray(pm))
        if mode == "count":
            cb = traversal.CountVisitor(cap=cap)
        elif mode == "minlabel":
            cb = traversal.MinLabelVisitor(jnp.asarray(pv), jnp.asarray(pm))
        else:
            cb = traversal.CountMinLabelVisitor(jnp.asarray(pv),
                                                jnp.asarray(pm), cap=cap)
        preds = traversal.intersects(traversal.sphere(self.eps),
                                     ids=jnp.asarray(ids),
                                     pts=jnp.asarray(qp))
        carry = traversal.AccHits(acc=jnp.asarray(ini),
                                  hits=jnp.zeros(pad, jnp.int32))
        tr = traversal.traverse(lvl.tree, lvl.segs, preds, cb, carry=carry,
                                node_mask=node_mask)
        return (np.asarray(tr.acc)[:k].copy(),
                np.asarray(tr.hits)[:k].astype(np.int64))

    def _repair(self, seed: np.ndarray, keys: np.ndarray) -> None:
        """Incremental union-find repair after an insert.

        Every new core-core edge has an endpoint in ``seed`` (the batch +
        promotions). Sweep 1 runs *only the seed cores* as queries, each
        gathering over the full core set — the expensive direction of
        every new edge is covered once, by its seed endpoint. The reverse
        direction needs no sweep-1 query: a seed's label is a new entry in
        the label pool, so the whole seed is marked changed after sweep 1
        regardless of whether its *value* moved, and the standard frontier
        restriction (§4: gather only from changed points, query only core
        points eps-near a change, prune unchanged subtrees) lets the
        neighbors pull it in sweep 2 at masked-gather cost. From sweep 2
        on this is exactly ``fdbscan._sweep_to_fixpoint``'s loop, started
        from the old fixpoint instead of from scratch."""
        n = self.n_points
        core = self._core
        if len(seed) == 0 or not core[seed].any():
            return                  # no new core point => no new edges
        d = self._pts.shape[1]
        seed_mask = np.zeros(n, bool)
        seed_mask[seed] = True
        q_mask = core & seed_mask   # sweep 1: the seed cores only...
        gather = core               # ...gathering over every core point
        labels = self._labels
        first = True
        while True:
            q = np.flatnonzero(q_mask)
            if len(q) == 0:
                break
            acc = np.full(len(q), INT_MAX, np.int32)
            for lvl in self._levels():
                acc, _ = self._run(lvl, self._pts[q], labels, gather, acc,
                                   mode="minlabel")
            new = labels.copy()
            new[q] = np.minimum(labels[q], acc)
            new = unionfind.jump_to_fixpoint_np(new)
            changed = new != labels
            if first:               # seed labels are new to the pool:
                changed |= q_mask   # neighbors must gather them once
                first = False
            labels = new
            self.n_repair_sweeps += 1
            if not changed.any():
                break
            gather = changed & core
            q_mask = core & fdbscan._near_changed(keys, d, changed)
        self._labels = labels
