"""Streaming DBSCAN over a tiered LSM index of LBVHs (DESIGN.md §7, §11).

``StreamingDBSCAN`` keeps density clusters live under online insertions
*and deletions* — the serving path the batch pipeline cannot cover (it
reclusters from scratch per call). Five operations:

  * ``query(pts)``    — read-only cluster assignment for a batch of probe
                        points (external-query traversal, no mutation);
  * ``insert(pts)``   — micro-batch ingestion with bidirectional core-count
                        updates and incremental label repair;
  * ``delete(ids)``   — tombstone resident points by global insert id, with
                        exact core-count recomputation and demotion repair;
  * ``expire(w)``     — tombstone every point with insert id below the
                        watermark ``w`` (the sliding-window primitive —
                        ``window=`` automates it per insert);
  * ``snapshot()``    — materialized labels over the *surviving* points,
                        component-identical to batch ``dbscan`` on exactly
                        the active set.

LSM-style tiered index: one large *main* LBVH (tier 0, built at
construction or at the last full merge), a stack of sealed delta tiers of
geometrically growing sizes, and a small insert *buffer* rebuilt per
batch.  Every operation traverses all levels with the engine's external
predicate batches (``traversal.intersects(sphere(eps), pts=...)``,
DESIGN.md §8), chaining the running accumulator through the visitor carry
exactly like the sharded path chains across shards.  When the buffer
outgrows ``buffer_max`` live points it is sealed into a tier; adjacent
tiers of the same size class (``growth``-fold geometric classes) merge in
a cascade; and when the whole delta outgrows ``merge_ratio`` times the
main, a full merge re-sorts the active union along the Morton curve into
a single tier.  Compactions and merges drop tombstoned rows and touch
only the index — labels, counts, and the core mask live in flat gid-
indexed arrays, so they are label-invariant on survivors by construction.

Deletion is tombstoning + *exact recount* + *demotion repair*:

  * counts saturate at ``min_pts`` — sound for increments but not for
    decrements (``min(c, mp) - dec`` loses the overshoot), so the points
    eps-near a deleted row get their counts *recomputed* against the
    alive-masked levels rather than decremented;
  * removing a point or demoting a core can *split* a component, and
    min-label propagation can only shrink labels — a split needs labels
    to grow.  So the repair resets every surviving core of every affected
    component (old label in the set of reps touched by a dead or demoted
    core) to its own gid and re-runs exact frontier sweeps from that
    reset set.  Cores outside affected components are untouched: two
    cores within eps are density-connected, so no eps-edge crosses
    between an affected and an unaffected component (see DESIGN.md §11
    for the full soundness argument).

Labels always satisfy ``labels[i] <= i`` with component-minimum reps at
rest (tombstoned and non-core rows hold their own gid), so bulk pointer
jumping can never cycle.

Distance arithmetic is float32 end to end — including the NumPy brute
paths — so boundary decisions agree bit-for-bit with the traversal engine
and ``snapshot()`` reproduces the batch core mask exactly.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fdbscan, grid, lbvh, morton, traversal, unionfind
from repro.core.fdbscan import DBSCANResult
from repro.core.validate import check_points
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.stream import durability

INT_MAX = traversal.INT_MAX

# Delta/main size ratio above which an insert triggers an automatic full
# merge, and the floor below which the delta never auto-merges (tiny
# deltas are cheap to traverse; rebuilding the main tree for them is not).
MERGE_RATIO = 0.25
MERGE_MIN = 256

# Tiered-compaction defaults: the insert buffer seals into a tier at
# BUFFER_MAX live points, and tiers merge in a cascade whenever the newest
# tier reaches the size class of its elder (classes grow GROWTH-fold).
BUFFER_MAX = MERGE_MIN
GROWTH = 4

# A sealed tier whose live fraction drops to half is rewritten without its
# tombstoned rows (classic LSM space-amplification bound).
_TOMB_MAX_FRAC = 0.5

# Sentinel padding offset in units of eps beyond a level's own bounding
# box: >= 3*eps along every axis keeps any real query (which can lie
# anywhere) from ever *matching* a sentinel in masked modes and keeps the
# box tests cheap; unmasked count mode is never run against a padded level.
_SENTINEL_EPS = 3.0

# Program signatures the traversal path has launched, process-wide (the
# jit cache is process-wide too).  Because both probe batches and level
# builds pad to fdbscan._pad_size's bucket ladder, this set — and with it
# ``stream_query_recompiles_total`` — must go flat at steady state; a
# growing counter is the alarm that some caller leaked an unpadded shape
# into the traversal engine.
_seen_programs: set = set()


def _note_program(sig: tuple) -> None:
    if sig not in _seen_programs:
        _seen_programs.add(sig)
        obs_metrics.inc("stream_query_recompiles_total")


class _Level(NamedTuple):
    """One level of the tiered index (main tier, delta tier, or buffer)."""
    segs: grid.Segments      # singleton segments, Morton order (+ sentinels)
    tree: lbvh.Tree | None   # None only for <2 resident points
    gids: np.ndarray         # (n_prims,) global insert id per sorted
                             # primitive; -1 marks a padding sentinel


class QueryResult(NamedTuple):
    """Read-only cluster assignment for a probe batch.

    labels: component representative (global insert id of the component's
            minimum member) of the min adjacent core point, or -1 when no
            core point lies within eps (the probe would be noise).
    counts: eps-neighbors among *active* resident points, saturated at
            ``min_pts``.
    would_be_core: the probe would be a core point if inserted now
            (counts + itself >= min_pts).
    """
    labels: np.ndarray
    counts: np.ndarray
    would_be_core: np.ndarray


@jax.jit
def _build_index(pts, lo, hi):
    """Jitted Morton-sort + singleton-segment LBVH build.

    Serves the full merge, tier compactions, and the padded buffer rebuild
    alike (``lo``/``hi`` are the *valid* points' bounds, so sentinels clip
    to the top cell exactly like the sharded path's padding).
    """
    codes = morton.morton_encode(pts, lo=lo, hi=hi)
    order = jnp.argsort(codes)
    segs = grid.singleton_segments(pts[order], order.astype(jnp.int32),
                                   codes[order])
    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return segs, tree


def _hits_blocked(a: np.ndarray, b: np.ndarray, eps2: np.float32,
                  block: int = 2048) -> np.ndarray:
    """# rows of ``b`` within eps of each row of ``a``; float32 arithmetic
    matching the traversal's d2 so boundary decisions cannot diverge."""
    out = np.zeros(len(a), np.int64)
    for lo in range(0, len(a), block):
        diff = a[lo:lo + block, None, :] - b[None, :, :]
        d2 = (diff * diff).sum(-1)
        out[lo:lo + block] = (d2 <= eps2).sum(1)
    return out


class StreamingDBSCAN:
    """Online DBSCAN handle: insert/delete micro-batches, query, snapshot.

    points: optional initial point set (clustered with the batch pipeline);
        ``None`` starts empty (the serving loop's cold-start path).
    index: optional prebuilt plain-FDBSCAN ``(segs, tree)`` over ``points``
        — the dispatcher passes its cached eps-independent index here so
        streaming composes with eps/min_pts parameter sweeps.
    merge_ratio: delta/main size ratio that triggers an automatic full
        merge.
    window: optional sliding-window size — after every insert, points
        whose insert id falls below ``n_points - window`` are expired
        automatically (insert-order watermark semantics).
    buffer_max: live-point budget of the insert buffer before it is sealed
        into a delta tier (tiered compaction knob; default BUFFER_MAX).
    growth: geometric size-class factor of the tier cascade (default
        GROWTH).
    wal: optional write-ahead log path (or a prebuilt
        ``durability.WriteAheadLog``): every insert/delete/expire batch is
        durably appended *before* it is applied, so an acknowledged
        operation survives a crash (DESIGN.md §10). The file must be
        fresh — a WAL with leftover records means a previous process
        died; go through :meth:`restore` instead of silently shadowing
        its state. Without a ``checkpoint_path``, bootstrap points are
        logged as the log's first (gid-0) record, so WAL-only recovery
        covers them too.
    checkpoint_path: optional checkpoint file; written atomically by
        :meth:`checkpoint` (and once at construction when the handle
        bootstraps from initial points, so they are durable too).
    checkpoint_every: auto-checkpoint policy — write ``checkpoint_path``
        after every K full index merges (0 = manual checkpoints only).
    """

    def __init__(self, points, eps: float, min_pts: int, *,
                 merge_ratio: float = MERGE_RATIO, index=None,
                 window: int | None = None,
                 buffer_max: int = BUFFER_MAX, growth: int = GROWTH,
                 wal=None, checkpoint_path: str | None = None,
                 checkpoint_every: int = 0):
        if eps <= 0:
            raise ValueError(f"streaming index needs eps > 0; got {eps}")
        if min_pts < 1:
            raise ValueError(f"min_pts must be >= 1; got {min_pts}")
        if window is not None and int(window) < 1:
            raise ValueError(f"window must be >= 1 point; got {window}")
        if buffer_max < 1:
            raise ValueError(f"buffer_max must be >= 1; got {buffer_max}")
        if growth < 2:
            raise ValueError(f"growth must be >= 2; got {growth}")
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self._eps2 = np.float32(jnp.asarray(eps, jnp.float32) ** 2)
        self._merge_ratio = float(merge_ratio)
        self.window = int(window) if window is not None else None
        self._buffer_max = int(buffer_max)
        self._growth = int(growth)
        self._pts = np.zeros((0, 2), np.float32)
        self._counts = np.zeros(0, np.int32)   # |N_eps| incl. self, sat. mp
        self._core = np.zeros(0, bool)
        self._labels = np.zeros(0, np.int32)   # core: component-min gid;
                                               # non-core/dead: own gid
        self._tombstone = np.zeros(0, bool)
        self._n_tomb = 0
        self._tiers: list[_Level] = []         # oldest (largest) first
        self._buffer: _Level | None = None
        self._buffer_gids = np.zeros(0, np.int64)
        self._expire_watermark = 0
        self.n_inserts = 0
        self.n_deletes = 0                     # delete/expire ops applied
        self.n_merges = 0
        self.n_compactions = 0                 # tier seals/cascades/rewrites
        self.n_repair_sweeps = 0
        self._ckpt_path = checkpoint_path
        self._ckpt_every = int(checkpoint_every)
        self._merges_since_ckpt = 0
        if checkpoint_every and not checkpoint_path:
            raise ValueError("checkpoint_every needs a checkpoint_path")
        self._wal = None
        if wal is not None:
            if not isinstance(wal, durability.WriteAheadLog):
                wal = durability.WriteAheadLog(str(wal), eps=self.eps,
                                               min_pts=self.min_pts)
            _, stale, _ = durability.scan_wal(wal.path)
            if stale:
                raise durability.WALError(
                    f"{wal.path}: WAL already holds {len(stale)} record(s) "
                    "from a previous run — recover them with "
                    "StreamingDBSCAN.restore(...) or remove the file "
                    "before starting a fresh handle")
            self._wal = wal
        if points is not None:
            pts = np.array(points, np.float32)   # copy: never alias callers
            if pts.size:
                self._bootstrap(pts, index)
                if self._ckpt_path is not None:
                    # make the bootstrap set durable: the WAL only covers
                    # inserts, so without this a crash before the first
                    # checkpoint would lose the initial clustering
                    self.checkpoint()
                elif self._wal is not None:
                    # WAL-only durability: log the bootstrap set as the
                    # gid-0 record, otherwise recovery cold-starts empty,
                    # every later record sits past a gap, and acknowledged
                    # inserts would be unrecoverable
                    self._wal.append(self._pts, 0)
                if self.window is not None:
                    self.expire(self.n_points - self.window)

    # ------------------------------------------------------------------ #
    # public surface                                                     #
    # ------------------------------------------------------------------ #

    @property
    def n_points(self) -> int:
        """Total points ever inserted (the insert-order watermark);
        includes tombstoned rows — see :attr:`n_active`."""
        return len(self._pts)

    @property
    def n_active(self) -> int:
        """Surviving (non-tombstoned) points."""
        return len(self._pts) - self._n_tomb

    @property
    def n_tombstoned(self) -> int:
        """Deleted/expired points still occupying gid slots."""
        return self._n_tomb

    @property
    def n_main(self) -> int:
        """Live points in the main (oldest, largest) tier."""
        return self._live(self._tiers[0]) if self._tiers else 0

    @property
    def n_delta(self) -> int:
        """Live points outside the main tier (delta tiers + buffer)."""
        return self.n_active - self.n_main

    @property
    def n_tiers(self) -> int:
        """Sealed index tiers (excluding the insert buffer)."""
        return len(self._tiers)

    @property
    def _main(self) -> _Level | None:
        return self._tiers[0] if self._tiers else None

    @property
    def points(self) -> np.ndarray:
        """The *active* point set in insertion order (a copy)."""
        return self._pts[~self._tombstone]

    @property
    def active_gids(self) -> np.ndarray:
        """Global insert ids of the active points, ascending."""
        return np.flatnonzero(~self._tombstone)

    def freeze_view(self):
        """Export the active state for an immutable serving snapshot.

        Returns a ``repro.serve.snapshot.FrozenState``: the active points
        (copies — later inserts cannot mutate a published snapshot) with
        their serving values (core rows carry their component-min label,
        non-core rows ``INT_MAX``), plus the stream watermark.  Pure
        read; never touches the tiers or the jit cache.
        """
        from repro.serve.snapshot import FrozenState
        alive = ~self._tombstone
        vals = np.where(self._core, self._labels.astype(np.int64),
                        np.int64(INT_MAX))
        return FrozenState(pts=self._pts[alive].copy(),
                           vals=vals[alive].copy(),
                           watermark=self.n_points,
                           n_tombstoned=int(self._n_tomb))

    def stream_slice(self, lo: int, hi: int) -> np.ndarray:
        """Rows ``[lo, hi)`` of the raw insert stream (tombstoned rows
        included — the stream is the replication log, not the active
        set).  Used to top up a lagging replica after crash recovery."""
        lo, hi = int(lo), int(hi)
        if not 0 <= lo <= hi <= self.n_points:
            raise ValueError(f"stream slice [{lo}, {hi}) out of range "
                             f"[0, {self.n_points})")
        return self._pts[lo:hi].copy()

    def query(self, pts) -> QueryResult:
        """Cluster assignment for probe points; never mutates the index."""
        with obs_trace.span("stream.query"):
            res = self._query_impl(pts)
        obs_metrics.inc("stream_queries_total")
        return res

    def _query_impl(self, pts) -> QueryResult:
        qpts = self._check_pts(pts, grow=False)
        k = len(qpts)
        if k == 0 or self.n_active == 0:
            return QueryResult(np.full(k, -1, np.int32),
                               np.zeros(k, np.int32),
                               np.ones(k, bool) if self.min_pts <= 1
                               else np.zeros(k, bool))
        vals = np.where(self._core, self._labels, INT_MAX).astype(np.int32)
        acc = np.full(k, INT_MAX, np.int32)
        for lvl in self._levels():
            acc, _ = self._run(lvl, qpts, vals, self._core, acc,
                               mode="minlabel")
        counts = np.zeros(k, np.int64)
        for lvl in self._levels():
            counts += self._count(lvl, qpts)
        counts = np.minimum(counts, self.min_pts).astype(np.int32)
        return QueryResult(
            labels=np.where(acc == INT_MAX, -1, acc).astype(np.int32),
            counts=counts,
            would_be_core=counts + 1 >= self.min_pts)

    def insert(self, pts) -> "StreamingDBSCAN":
        """Ingest a micro-batch: counts update bidirectionally, labels are
        repaired incrementally, the buffer is rebuilt (padded to a
        bucketed size for stable jit shapes), and an oversized buffer or
        delta triggers compaction / a full merge.  In window mode the
        insert then auto-expires everything below the new watermark.

        With a WAL attached the batch is durably appended (fsync) before
        any state changes, so by the time ``insert`` returns — the
        *acknowledgment* — the batch survives a crash at any barrier.
        Raises ValueError for empty batches and NaN/Inf coordinates
        (nothing is logged or applied for a rejected batch)."""
        with obs_trace.span("stream.insert"):
            res = self._insert_impl(pts)
        obs_metrics.inc("stream_inserts_total")
        self._obs_gauges()
        return res

    def _insert_impl(self, pts) -> "StreamingDBSCAN":
        batch = self._check_pts(pts, grow=True)
        b = len(batch)
        obs_metrics.inc("stream_inserted_points_total", float(b))
        durability.barrier("pre-insert")    # crash: batch never durable
        if self._wal is not None:
            self._wal.append(batch, self.n_points)
            durability.barrier("wal-durable")   # crash: durable, unapplied
        n_old = self.n_points
        gid0 = n_old

        # ---- bidirectional core-count update --------------------------
        c_new = np.zeros(b, np.int64)
        for lvl in self._levels():          # vs every alive-masked level
            c_new += self._count(lvl, batch)
        c_new += _hits_blocked(batch, batch, self._eps2)  # within (incl self)
        new_counts = np.minimum(c_new, self.min_pts).astype(np.int32)

        # existing *active* points eps-near the batch gain neighbors; the
        # eps-cell dilation filter is a sound superset of "within eps of a
        # batch point" (and a subset of the batch's eps-dilated AABB)
        all_pts = (np.concatenate([self._pts, batch]) if n_old else batch)
        keys = fdbscan._cell_keys(all_pts, self.eps)
        batch_mask = np.zeros(n_old + b, bool)
        batch_mask[n_old:] = True
        near = fdbscan._near_changed(keys, batch.shape[1], batch_mask)
        was_core = self._core
        aff = np.flatnonzero(near[:n_old] & ~self._tombstone)
        if len(aff):
            inc = _hits_blocked(self._pts[aff], batch, self._eps2)
            self._counts[aff] = np.minimum(
                self._counts[aff] + inc, self.min_pts).astype(np.int32)

        # ---- append + buffer rebuild ----------------------------------
        self._pts = all_pts
        self._counts = np.concatenate([self._counts, new_counts])
        self._tombstone = np.concatenate(
            [self._tombstone, np.zeros(b, bool)])
        core_now = (self._counts >= self.min_pts) & ~self._tombstone
        promoted = np.flatnonzero(core_now[:n_old] & ~was_core)
        self._core = core_now
        self._labels = np.concatenate(
            [self._labels, np.arange(gid0, gid0 + b, dtype=np.int32)])
        self._buffer_gids = np.concatenate(
            [self._buffer_gids, np.arange(gid0, gid0 + b, dtype=np.int64)])
        self._rebuild_buffer()

        # ---- incremental label repair ---------------------------------
        seed = np.concatenate(
            [promoted, np.arange(gid0, gid0 + b, dtype=np.int64)])
        seed_mask = np.zeros(self.n_points, bool)
        seed_mask[seed] = True
        self._repair(self._core & seed_mask, keys, seed_new=True)
        self.n_inserts += 1

        # ---- compaction / merge policy --------------------------------
        self._maybe_compact()
        durability.barrier("post-insert")   # crash: applied, un-acked —
                                            # replay re-applies identically
        if self.window is not None and self.n_points > self.window:
            self.expire(self.n_points - self.window)
        return self

    def delete(self, ids) -> int:
        """Tombstone resident points by global insert id.

        Already-tombstoned ids are ignored (idempotent — WAL replay
        re-issues deletes); out-of-range or non-integer ids raise
        ValueError before anything is logged or applied.  Returns the
        number of points newly tombstoned.

        With a WAL attached the delete is durably logged before any state
        changes, mirroring the insert barriers (``pre-delete``,
        ``wal-durable-delete``)."""
        gids = self._check_gids(ids)
        gids = gids[~self._tombstone[gids]]
        if len(gids) == 0:
            return 0
        with obs_trace.span("stream.delete", k=len(gids)):
            durability.barrier("pre-delete")  # crash: delete never durable
            if self._wal is not None:
                self._wal.append_delete(gids, self.n_points,
                                        d=self._pts.shape[1])
                durability.barrier("wal-durable-delete")
            self._apply_delete(gids)
        self.n_deletes += 1
        obs_metrics.inc("stream_deletes_total", float(len(gids)))
        self._obs_gauges()
        return len(gids)

    def expire(self, watermark: int) -> int:
        """Tombstone every active point with insert id < ``watermark``
        (insert-order expiry — the sliding-window primitive).  Idempotent;
        a watermark past ``n_points`` raises ValueError.  Returns the
        number of points newly tombstoned."""
        wm = int(watermark)
        if wm > self.n_points:
            raise ValueError(f"expire watermark {wm} is past the stream "
                             f"end {self.n_points}")
        if wm > self._expire_watermark:
            self._expire_watermark = wm
        if wm <= 0:
            return 0
        gids = np.flatnonzero(~self._tombstone[:wm])
        if len(gids) == 0:
            return 0
        with obs_trace.span("stream.expire", k=len(gids)):
            durability.barrier("pre-delete")
            if self._wal is not None:
                self._wal.append_expire(wm, d=self._pts.shape[1])
                durability.barrier("wal-durable-delete")
            self._apply_delete(gids)
        self.n_deletes += 1
        obs_metrics.inc("stream_expired_points_total", float(len(gids)))
        self._obs_gauges()
        return len(gids)

    def merge(self) -> "StreamingDBSCAN":
        """Full compaction: fold every tier and the buffer into one main
        tier over the *active* points (tombstoned rows are dropped), via
        one jitted Morton re-sort + LBVH rebuild padded to the same shape
        buckets as the buffer so repeated merges reuse compiled programs.
        Index-only — labels, counts, and the core mask are untouched, so
        a merge can never change ``snapshot``."""
        act = np.flatnonzero(~self._tombstone)
        if (len(self._tiers) == 1 and self._buffer is None
                and int((self._tiers[0].gids >= 0).sum()) == len(act)
                and self._live(self._tiers[0]) == len(act)):
            return self                 # already a single clean main tier
        if len(act) == 0 and not self._tiers and self._buffer is None:
            return self
        with obs_trace.span("stream.merge", n_active=len(act)) as sp:
            new_main = (self._build_level(self._pts[act], act)
                        if len(act) else None)
            durability.barrier("mid-merge")  # crash with the merge in
            self._tiers = [new_main] if new_main is not None else []
            self._buffer = None             # flight: all in-memory, the
            self._buffer_gids = np.zeros(0, np.int64)   # durable state is
            self.n_merges += 1              # unaffected
            if new_main is not None:
                sp.watch(new_main.segs, new_main.tree)
        obs_metrics.inc("stream_merges_total")
        self._obs_gauges()
        self._merges_since_ckpt += 1
        if (self._ckpt_path is not None and self._ckpt_every
                and self._merges_since_ckpt >= self._ckpt_every):
            self.checkpoint()
        return self

    def compact(self) -> "StreamingDBSCAN":
        """Tiered compaction step: seal the insert buffer into the newest
        delta tier, rewrite tiers that are mostly tombstones, and cascade
        same-size-class tier merges (classes grow ``growth``-fold from
        ``buffer_max``).  Like :meth:`merge` this is index-only and drops
        tombstoned rows — label-invariant on survivors."""
        with obs_trace.span("stream.compact"):
            self._seal_buffer()
            self._drop_dead_tiers()
            self._cascade()
        self._obs_gauges()
        return self

    def snapshot(self, *, star: bool = False) -> DBSCANResult:
        """Materialized labels over the *active* point set (insertion
        order), component-identical to batch ``dbscan`` on exactly the
        surviving points: exact core mask, exact noise set, identical
        core partition; border points take the min adjacent core
        representative. ``star=True`` is DBSCAN* (no border points)."""
        with obs_trace.span("stream.snapshot", star=star) as sp:
            res = self._snapshot_impl(star=star)
            sp.watch(res.labels, res.core_mask)
        return res

    def _snapshot_impl(self, *, star: bool) -> DBSCANResult:
        act = np.flatnonzero(~self._tombstone)
        if len(act) == 0:
            return DBSCANResult(labels=jnp.zeros(0, jnp.int32),
                                core_mask=jnp.zeros(0, bool), n_clusters=0,
                                n_sweeps=self.n_repair_sweeps,
                                n_traversals=-1, backend="stream")
        core_full = self._core
        labels_full = np.where(core_full, self._labels, -1).astype(np.int32)
        if not star:
            nb = act[~core_full[act]]
            if len(nb) and core_full.any():
                vals = np.where(core_full, self._labels,
                                INT_MAX).astype(np.int32)
                acc = np.full(len(nb), INT_MAX, np.int32)
                for lvl in self._levels():
                    acc, _ = self._run(lvl, self._pts[nb], vals, core_full,
                                       acc, mode="minlabel")
                labels_full[nb] = np.where(acc == INT_MAX, -1, acc)
        core = core_full[act]
        labels_act = labels_full[act]
        uniq = np.unique(labels_act[core]) if core.any() else \
            np.zeros(0, np.int32)
        out = np.full(len(act), -1, np.int32)
        pos = labels_act >= 0
        out[pos] = np.searchsorted(uniq, labels_act[pos]).astype(np.int32)
        return DBSCANResult(labels=jnp.asarray(out),
                            core_mask=jnp.asarray(core),
                            n_clusters=int(len(uniq)),
                            n_sweeps=self.n_repair_sweeps,
                            n_traversals=-1, backend="stream")

    # ------------------------------------------------------------------ #
    # durability (DESIGN.md §10)                                         #
    # ------------------------------------------------------------------ #

    def checkpoint(self, path: str | None = None) -> dict:
        """Atomically serialize the full handle state to ``path`` (default:
        the ``checkpoint_path`` the handle was built with).

        The checkpoint is a single ``.npz`` — points, saturated core
        counts, core mask, union-find labels, the tombstone mask, plus a
        manifest (format version, eps/min_pts, the insert-order and expiry
        watermarks, a content checksum) — written tmp-file + fsync +
        rename, so a crash during the write leaves the previous checkpoint
        intact. A checkpoint written to the *configured*
        ``checkpoint_path`` (the file :meth:`restore` will read) also
        truncates the attached WAL — every logged record is now covered by
        the watermark; an ad-hoc side checkpoint to some other ``path``
        leaves the WAL alone, so the records the configured path's
        recovery needs stay durable.  Returns the manifest written.
        """
        path = path if path is not None else self._ckpt_path
        if path is None:
            raise ValueError("no checkpoint path: pass one to checkpoint() "
                             "or build the handle with checkpoint_path=")
        with obs_trace.span("stream.checkpoint", path=path):
            manifest = durability.save_checkpoint(self, path)
        if (self._ckpt_path is not None
                and os.path.realpath(path) == os.path.realpath(self._ckpt_path)):
            self._merges_since_ckpt = 0
            if self._wal is not None:
                self._wal.reset()
        return manifest

    @classmethod
    def restore(cls, checkpoint_path: str | None = None, *, wal=None,
                **kwargs) -> "StreamingDBSCAN":
        """Recover a live handle from durable state after a crash.

        Loads ``checkpoint_path`` (if the file exists), replays every WAL
        record past the checkpoint's watermark through the normal
        insert/delete/expire paths (deletes and expires are idempotent,
        so records the checkpoint already covers are harmless no-ops),
        and silently truncates a torn/corrupt WAL tail (an interrupted
        append was by definition never acknowledged). The recovered
        handle re-attaches both files and keeps serving.

        Args:
            checkpoint_path: checkpoint file written by :meth:`checkpoint`
                (may not exist yet — then recovery is WAL-only).
            wal: the write-ahead log path the crashed handle appended to.
            **kwargs: handle options (``merge_ratio``, ``window``,
                ``buffer_max``, ``growth``, ``checkpoint_every``) for the
                recovered instance.

        Returns:
            A handle whose ``snapshot()`` is component-identical to batch
            ``dbscan`` on exactly the durable (acknowledged) surviving
            points.

        Raises:
            repro.stream.durability.CheckpointError: the checkpoint file
                is corrupt or has an unknown format version.
            repro.stream.durability.WALError: the WAL header is not ours.
            ValueError: neither file holds any state to recover.
        """
        wal_path = wal.path if isinstance(wal, durability.WriteAheadLog) \
            else wal
        return durability.recover(checkpoint_path, wal_path, **kwargs)

    def _adopt_state(self, state: dict) -> None:
        """Install checkpointed arrays + rebuild the index from them (used
        by ``durability.recover``; no reclustering — labels, counts, core
        and tombstone masks are restored verbatim; the active points are
        deterministically rebuilt into a single main tier, which is
        index-only and therefore label-invariant)."""
        m = state["manifest"]
        pts = np.ascontiguousarray(state["pts"], np.float32)
        if len(pts):
            check_points(pts, name="checkpoint points", dims=(2, 3))
        self._pts = pts
        self._counts = np.ascontiguousarray(state["counts"], np.int32)
        self._core = np.ascontiguousarray(state["core"], bool)
        self._labels = np.ascontiguousarray(state["labels"], np.int32)
        tomb = state.get("tombstone")
        if tomb is None:                     # v1 checkpoint: nothing dead
            tomb = np.zeros(len(pts), bool)
        self._tombstone = np.ascontiguousarray(tomb, bool)
        self._n_tomb = int(self._tombstone.sum())
        self._expire_watermark = int(m.get("expire_watermark", 0))
        self.n_inserts = int(m["n_inserts"])
        self.n_deletes = int(m.get("n_deletes", 0))
        self.n_merges = int(m.get("n_merges", 0))
        self.n_compactions = int(m.get("n_compactions", 0))
        self.n_repair_sweeps = int(m["n_repair_sweeps"])
        act = np.flatnonzero(~self._tombstone)
        self._tiers = ([self._build_level(self._pts[act], act)]
                       if len(act) else [])
        self._buffer = None
        self._buffer_gids = np.zeros(0, np.int64)

    # ------------------------------------------------------------------ #
    # internals                                                          #
    # ------------------------------------------------------------------ #

    def _check_pts(self, pts, grow: bool) -> np.ndarray:
        # an empty *probe* batch is a valid request (empty QueryResult,
        # matching neighbors.*); an empty *insert* batch is rejected
        checked = check_points(pts, name="points", dims=(2, 3),
                               allow_empty=not grow)
        # np.array (not asarray): never alias a caller-owned buffer the
        # caller may mutate after we have indexed its coordinates
        arr = np.array(checked, np.float32)
        if self.n_points and arr.shape[1] != self._pts.shape[1]:
            raise ValueError(f"dimensionality mismatch: index is "
                             f"{self._pts.shape[1]}-d, got {arr.shape[1]}-d")
        if grow and self.n_points == 0 and self._pts.shape[1] != arr.shape[1]:
            self._pts = np.zeros((0, arr.shape[1]), np.float32)
        return arr

    def _check_gids(self, ids) -> np.ndarray:
        arr = np.asarray(ids)
        if arr.ndim == 0:
            arr = arr.reshape(1)
        if arr.ndim != 1:
            raise ValueError(f"delete ids must be a flat sequence; got "
                             f"shape {arr.shape}")
        if arr.size == 0:
            return np.zeros(0, np.int64)
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(f"delete ids must be integers; got dtype "
                             f"{arr.dtype}")
        arr = arr.astype(np.int64)
        if arr.min() < 0 or arr.max() >= self.n_points:
            raise ValueError(f"delete ids must lie in [0, {self.n_points}); "
                             f"got range [{arr.min()}, {arr.max()}]")
        return np.unique(arr)

    def _bootstrap(self, pts: np.ndarray, index) -> None:
        """Initial batch clustering via the fused pipeline, converted to
        global (insertion-order) ids with component-minimum reps."""
        n = pts.shape[0]
        self._check_pts(pts, grow=True)
        if index is not None:
            segs, tree = index
            if segs.n_points != n:
                raise ValueError(f"index covers {segs.n_points} points, "
                                 f"got {n}")
            if bool(np.asarray(segs.dense_seg).any()):
                raise ValueError("streaming needs the plain (singleton) "
                                 "fdbscan index, not a densebox index")
            if tree is None and segs.n_segments >= 2:
                tree = lbvh.build_tree(segs.codes, segs.prim_lo,
                                       segs.prim_hi)
        else:
            segs = grid.build_segments_fdbscan(jnp.asarray(pts))
            tree = (lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
                    if segs.n_segments >= 2 else None)
        self._pts = pts
        self._tombstone = np.zeros(n, bool)
        self._n_tomb = 0
        order = np.asarray(segs.order, np.int64)
        if n >= 2 and tree is not None:
            core_s, labels0, vals0, absorbed, tr = fdbscan._fused_first_pass(
                tree, segs, self.eps, self.min_pts)
            core_labels, _, _ = fdbscan._sweep_to_fixpoint(
                tree, segs, self.eps, core_s, labels0,
                fused_init=(vals0, absorbed))
            counts_s = np.minimum(np.asarray(tr.hits) + 1,
                                  self.min_pts).astype(np.int32)
            core_np = np.asarray(core_s)
            roots_s = np.asarray(core_labels)
            counts = np.empty(n, np.int32)
            counts[order] = counts_s
            core = np.empty(n, bool)
            core[order] = core_np
            labels = np.arange(n, dtype=np.int32)
            if core_np.any():
                # sorted-space roots -> component-minimum *global* id, the
                # rep order the streaming hooks preserve (labels[i] <= i)
                rep_gid = np.full(n, n, np.int64)
                np.minimum.at(rep_gid, roots_s[core_np], order[core_np])
                labels[order[core_np]] = \
                    rep_gid[roots_s[core_np]].astype(np.int32)
        else:                       # n == 1
            counts = np.ones(n, np.int32)
            core = counts >= self.min_pts
            labels = np.zeros(n, np.int32)
        self._counts, self._core, self._labels = counts, core, labels
        self._tiers = [_Level(segs, tree, order)]

    def _obs_gauges(self) -> None:
        """Mirror the handle's occupancy into the active registry
        (DESIGN.md §12); a no-op when no collector is installed."""
        if obs_metrics.active() is None:
            return
        obs_metrics.set_gauge("stream_active_points", float(self.n_active))
        obs_metrics.set_gauge("stream_tombstoned_points",
                              float(self.n_tombstoned))
        obs_metrics.set_gauge("stream_tiers", float(self.n_tiers))

    def _levels(self):
        yield from self._tiers
        if self._buffer is not None:
            yield self._buffer

    def _live(self, lvl: _Level) -> int:
        """Live (valid, non-tombstoned) primitives of one level."""
        g = lvl.gids
        valid = g >= 0
        if not valid.any():
            return 0
        return int((valid & ~self._tombstone[np.where(valid, g, 0)]).sum())

    def _rebuild_buffer(self) -> None:
        bg = self._buffer_gids
        if len(bg) == 0:
            self._buffer = None
            return
        self._buffer = self._build_level(self._pts[bg], bg)

    def _seal_buffer(self) -> None:
        """Freeze the insert buffer as the newest delta tier (dropping any
        tombstoned rows on the way)."""
        bg = self._buffer_gids
        bg = bg[~self._tombstone[bg]] if len(bg) else bg
        self._buffer = None
        self._buffer_gids = np.zeros(0, np.int64)
        if len(bg):
            self._tiers.append(self._build_level(self._pts[bg], bg))
            self.n_compactions += 1
            obs_metrics.inc("stream_compactions_total", kind="seal")

    def _tier_class(self, live: int) -> int:
        """Geometric size class of a tier: smallest c with
        live <= buffer_max * growth**c."""
        c, cap = 0, self._buffer_max
        while live > cap:
            cap *= self._growth
            c += 1
        return c

    def _cascade(self) -> None:
        """Merge the newest tier into its elder while they share a size
        class — the classic size-tiered LSM cascade.  Tombstoned rows are
        dropped by the rebuild; the merge is index-only."""
        while len(self._tiers) >= 2:
            a, b = self._tiers[-2], self._tiers[-1]
            if self._tier_class(self._live(b)) < self._tier_class(self._live(a)):
                break
            ga, gb = a.gids[a.gids >= 0], b.gids[b.gids >= 0]
            g = np.concatenate([ga[~self._tombstone[ga]],
                                gb[~self._tombstone[gb]]])
            new = self._build_level(self._pts[g], g) if len(g) else None
            durability.barrier("mid-compaction")    # all in-memory: the
            self._tiers = self._tiers[:-2] + (      # durable state is
                [new] if new is not None else [])   # unaffected
            self.n_compactions += 1
            obs_metrics.inc("stream_compactions_total", kind="cascade")

    def _drop_dead_tiers(self) -> None:
        """Rewrite (or drop) tiers whose tombstone fraction reached
        ``_TOMB_MAX_FRAC`` — bounds space amplification after deletes."""
        out = []
        for lvl in self._tiers:
            g = lvl.gids[lvl.gids >= 0]
            total = len(g)
            dead = int(self._tombstone[g].sum()) if total else 0
            if dead == 0 or (total - dead) > total * _TOMB_MAX_FRAC:
                out.append(lvl)
                continue
            durability.barrier("mid-compaction")
            self.n_compactions += 1
            obs_metrics.inc("stream_compactions_total", kind="rewrite")
            live = g[~self._tombstone[g]]
            if len(live):
                out.append(self._build_level(self._pts[live], live))
        self._tiers = out

    def _maybe_compact(self) -> None:
        """Post-insert policy: full merge when the whole delta outgrows
        ``merge_ratio`` times the main; otherwise seal + cascade when the
        buffer outgrows its budget."""
        if self.n_delta > max(MERGE_MIN,
                              int(self._merge_ratio * self.n_main)):
            self.merge()
            return
        bg = self._buffer_gids
        n_buf = int((~self._tombstone[bg]).sum()) if len(bg) else 0
        if n_buf > self._buffer_max:
            self.compact()

    def _apply_delete(self, gids: np.ndarray) -> None:
        """Tombstone ``gids`` (all alive), recount the survivors around
        them exactly, and run demotion repair (DESIGN.md §11).

        Order matters: rows are tombstoned *before* the recount so the
        alive-masked traversals no longer see them, and the old component
        representatives of dying/demoted cores are captured *before* any
        label is reset."""
        n = self.n_points
        d = self._pts.shape[1]
        old_core = self._core.copy()
        dead_core = gids[old_core[gids]]
        rep_dead = self._labels[dead_core].copy()   # old reps of dead cores

        self._tombstone[gids] = True
        self._n_tomb += len(gids)
        self._counts[gids] = 0
        self._core[gids] = False
        self._labels[gids] = gids.astype(np.int32)

        # exact recount of surviving points eps-near a deleted row — the
        # saturated counts cannot be decremented (min(c, mp) loses the
        # overshoot), and the eps-cell dilation is the same sound superset
        # the insert path uses
        keys = fdbscan._cell_keys(self._pts, self.eps)
        dead_mask = np.zeros(n, bool)
        dead_mask[gids] = True
        near = fdbscan._near_changed(keys, d, dead_mask)
        aff = np.flatnonzero(near & ~self._tombstone)
        demoted = np.zeros(0, np.int64)
        if len(aff):
            cnt = np.zeros(len(aff), np.int64)
            for lvl in self._levels():  # each gid resides in exactly one
                cnt += self._count(lvl, self._pts[aff])     # level, so the
            # sum counts the point's own resident copy exactly once —
            # matching the counts-include-self convention
            new_c = np.minimum(cnt, self.min_pts).astype(np.int32)
            now = new_c >= self.min_pts
            # deletion only removes neighbors: was-False implies an exact
            # (unsaturated) old count below min_pts, so now is never True
            # where was is False — no promotions, only demotions
            demoted = aff[old_core[aff] & ~now]
            self._counts[aff] = new_c
            self._core[aff] = old_core[aff] & now
        rep_demoted = self._labels[demoted].copy()  # still the old reps
        self._labels[demoted] = demoted.astype(np.int32)

        # demotion repair: a removed/demoted core can split its component,
        # and min-label propagation can only shrink labels — so reset every
        # surviving core of every affected component to its own gid and
        # re-derive by exact frontier sweeps.  Cores of unaffected
        # components are provably >eps from every affected one (two cores
        # within eps share a component), so their labels stay fixed.
        reps = np.unique(np.concatenate([rep_dead, rep_demoted]))
        if len(reps):
            reset = self._core & np.isin(self._labels, reps)
            ridx = np.flatnonzero(reset)
            self._labels[ridx] = ridx.astype(np.int32)
            self._repair(reset, keys, seed_new=False)

        # compact away the garbage: drop dead rows from the buffer, rewrite
        # mostly-dead tiers, and re-check the cascade classes
        bg = self._buffer_gids
        if len(bg) and self._tombstone[bg].any():
            self._buffer_gids = bg[~self._tombstone[bg]]
            self._rebuild_buffer()
        self._drop_dead_tiers()
        self._cascade()

    def _build_level(self, dpts: np.ndarray, gids: np.ndarray) -> _Level:
        """Jitted index build over ``dpts`` (global ids ``gids``), padded
        to a bucketed size with out-of-range sentinels (gid -1) so every
        level sees a bounded set of jit shapes."""
        nd = len(dpts)
        pad = max(fdbscan._pad_size(nd), 2)
        lo, hi = dpts.min(0), dpts.max(0)
        if pad > nd:
            sent = hi + np.float32(_SENTINEL_EPS * self.eps)
            dpts = np.concatenate(
                [dpts, np.broadcast_to(sent, (pad - nd, dpts.shape[1]))])
            gids = np.concatenate([gids, np.full(pad - nd, -1, np.int64)])
        segs, tree = _build_index(jnp.asarray(dpts),
                                  jnp.asarray(lo), jnp.asarray(hi))
        return _Level(segs, tree, gids[np.asarray(segs.order)])

    def _count(self, lvl: _Level, qpts: np.ndarray) -> np.ndarray:
        """eps-neighbor count of external queries against the *live*
        residents of one level.

        A clean level (no sentinels, no tombstoned rows) uses plain
        ``count`` mode (early exit at min_pts); otherwise the masked fused
        count (``count_minlabel``'s hits) — a sentinel or dead row can
        never enter it, while a probe may legitimately live anywhere,
        including near a sentinel's coordinates."""
        valid = lvl.gids >= 0
        if lvl.tree is None:
            gv = lvl.gids[valid]
            gv = gv[~self._tombstone[gv]]
            if len(gv) == 0:
                return np.zeros(len(qpts), np.int64)
            return np.minimum(_hits_blocked(qpts, self._pts[gv], self._eps2),
                              self.min_pts)
        alive = ~self._tombstone
        clean = bool(valid.all()) and bool(alive[lvl.gids].all())
        if clean:
            acc, _ = self._run(lvl, qpts,
                               np.zeros(self.n_points, np.int32),
                               np.ones(self.n_points, bool),
                               np.zeros(len(qpts), np.int32),
                               mode="count", cap=self.min_pts)
            return acc.astype(np.int64)
        _, hits = self._run(lvl, qpts,
                            np.zeros(self.n_points, np.int32),
                            alive,
                            np.full(len(qpts), INT_MAX, np.int32),
                            mode="count_minlabel", cap=self.min_pts)
        return hits.astype(np.int64)

    def _run(self, lvl: _Level, qpts: np.ndarray, vals: np.ndarray,
             mask: np.ndarray, init: np.ndarray, mode: str,
             cap: int = INT_MAX):
        """One external-query pass against one level; (acc, hits) sliced
        to the query count. ``init`` seeds the visitor's carry, chaining
        the running accumulator across levels (the multi-tree analogue of
        the sharded path's traveling carry).  ``mask`` is indexed by gid —
        callers pass the core mask (never true for tombstoned rows) or an
        explicit alive mask, so dead residents can never be gathered."""
        k = len(qpts)
        gsafe = np.maximum(lvl.gids, 0)
        valid = lvl.gids >= 0
        if lvl.tree is None:        # <2 residents: trivial brute force
            gv = lvl.gids[valid]
            if len(gv) == 0:
                return init.copy(), np.zeros(k, np.int64)
            res = self._pts[gv]
            diff = qpts[:, None, :] - res[None]
            hit = (diff * diff).sum(-1) <= self._eps2
            ok = hit & mask[gv][None]
            vv = np.where(ok, vals[gv][None].astype(np.int64), INT_MAX)
            acc = np.minimum(init.astype(np.int64), vv.min(1))
            return acc.astype(np.int32), ok.sum(1).astype(np.int64)
        pad = fdbscan._pad_size(k)
        # every distinct (mode, level shape, probe bucket, cap) tuple is
        # one compiled traversal program; see _note_program
        _note_program((mode, qpts.shape[1], pad, len(lvl.gids), cap))
        ids = np.full(pad, -1, np.int32)
        ids[:k] = 0
        qp = np.zeros((pad, qpts.shape[1]), np.float32)
        qp[:k] = qpts
        ini = np.full(pad, INT_MAX, np.int32)
        ini[:k] = init
        pv = np.where(valid, vals[gsafe], INT_MAX).astype(np.int32)
        pm = valid & mask[gsafe]
        node_mask = None
        if mode != "count":         # count needs every resident; the
            node_mask = lbvh.propagate_leaf_flags(   # others prune to mask
                lvl.tree, jnp.asarray(pm))
        if mode == "count":
            cb = traversal.CountVisitor(cap=cap)
        elif mode == "minlabel":
            cb = traversal.MinLabelVisitor(jnp.asarray(pv), jnp.asarray(pm))
        else:
            cb = traversal.CountMinLabelVisitor(jnp.asarray(pv),
                                                jnp.asarray(pm), cap=cap)
        preds = traversal.intersects(traversal.sphere(self.eps),
                                     ids=jnp.asarray(ids),
                                     pts=jnp.asarray(qp))
        carry = traversal.AccHits(acc=jnp.asarray(ini),
                                  hits=jnp.zeros(pad, jnp.int32))
        tr = traversal.traverse(lvl.tree, lvl.segs, preds, cb, carry=carry,
                                node_mask=node_mask)
        return (np.asarray(tr.acc)[:k].copy(),
                np.asarray(tr.hits)[:k].astype(np.int64))

    def _repair(self, q_mask: np.ndarray, keys: np.ndarray, *,
                seed_new: bool) -> None:
        """Incremental union-find repair from a seed query mask.

        Insert (``seed_new=True``): every new core-core edge has an
        endpoint in the seed (the batch + promotions). Sweep 1 runs *only
        the seed cores* as queries, each gathering over the full core set
        — the expensive direction of every new edge is covered once, by
        its seed endpoint. The reverse direction needs no sweep-1 query: a
        seed's label is a new entry in the label pool, so the whole seed
        is marked changed after sweep 1 regardless of whether its *value*
        moved, and the standard frontier restriction (§4: gather only from
        changed points, query only core points eps-near a change, prune
        unchanged subtrees) lets the neighbors pull it in sweep 2 at
        masked-gather cost.

        Delete (``seed_new=False``): the seed is the reset set of demotion
        repair — every surviving core of every affected component, whose
        labels were just reset to their own gids. Sweep 1 gathers the
        current labels for the whole reset set at once (an eps-edge from a
        reset core can only reach another reset core — see §11), so no
        forced-changed marking is needed; later sweeps run the same exact
        frontier restriction.

        From sweep 2 on this is exactly ``fdbscan._sweep_to_fixpoint``'s
        loop, started from the old fixpoint instead of from scratch."""
        if not q_mask.any():
            return                  # no seed cores => no edges to repair
        d = self._pts.shape[1]
        core = self._core
        gather = core               # sweep 1 gathers over every core point
        labels = self._labels
        first = True
        with obs_trace.span("stream.repair", seed=int(q_mask.sum())):
            while True:
                q = np.flatnonzero(q_mask)
                if len(q) == 0:
                    break
                acc = np.full(len(q), INT_MAX, np.int32)
                for lvl in self._levels():
                    acc, _ = self._run(lvl, self._pts[q], labels, gather,
                                       acc, mode="minlabel")
                new = labels.copy()
                new[q] = np.minimum(labels[q], acc)
                new = unionfind.jump_to_fixpoint_np(new)
                changed = new != labels
                if first and seed_new:  # seed labels are new to the pool:
                    changed |= q_mask   # neighbors must gather them once
                first = False
                labels = new
                self.n_repair_sweeps += 1
                obs_metrics.inc("stream_repair_sweeps_total")
                if not changed.any():
                    break
                gather = changed & core
                q_mask = core & fdbscan._near_changed(keys, d, changed)
        self._labels = labels
