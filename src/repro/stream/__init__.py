"""Streaming DBSCAN subsystem: tiered LSM index of LBVHs, online inserts
and deletes (tombstones + demotion repair), sliding windows, batched
cluster queries, snapshots (DESIGN.md §7, §11), and crash safety —
atomic checkpoints + a write-ahead log with replay recovery
(DESIGN.md §10, ``repro.stream.durability``).

``StreamingDBSCAN`` is the serving-path handle; the dispatcher's
``repro.core.dispatch.stream_handle`` builds one that shares the cached
eps-independent batch index. ``StreamingDBSCAN.restore`` rebuilds a
handle from a checkpoint + WAL after a crash.
"""
from . import durability
from .index import (StreamingDBSCAN, QueryResult, MERGE_RATIO, MERGE_MIN,
                    BUFFER_MAX, GROWTH)

__all__ = ["StreamingDBSCAN", "QueryResult", "MERGE_RATIO", "MERGE_MIN",
           "BUFFER_MAX", "GROWTH", "durability"]
