"""Streaming DBSCAN subsystem: two-level LBVH index, online inserts,
batched cluster queries, snapshots (DESIGN.md §7).

``StreamingDBSCAN`` is the serving-path handle; the dispatcher's
``repro.core.dispatch.stream_handle`` builds one that shares the cached
eps-independent batch index.
"""
from .index import StreamingDBSCAN, QueryResult, MERGE_RATIO, MERGE_MIN

__all__ = ["StreamingDBSCAN", "QueryResult", "MERGE_RATIO", "MERGE_MIN"]
