"""Streaming DBSCAN subsystem: two-level LBVH index, online inserts,
batched cluster queries, snapshots (DESIGN.md §7), and crash safety —
atomic checkpoints + a write-ahead log with replay recovery
(DESIGN.md §10, ``repro.stream.durability``).

``StreamingDBSCAN`` is the serving-path handle; the dispatcher's
``repro.core.dispatch.stream_handle`` builds one that shares the cached
eps-independent batch index. ``StreamingDBSCAN.restore`` rebuilds a
handle from a checkpoint + WAL after a crash.
"""
from . import durability
from .index import StreamingDBSCAN, QueryResult, MERGE_RATIO, MERGE_MIN

__all__ = ["StreamingDBSCAN", "QueryResult", "MERGE_RATIO", "MERGE_MIN",
           "durability"]
