"""Durability layer for the streaming index (DESIGN.md §10).

The serving path keeps its whole state — points, saturated core counts,
union-find labels, the two-level tree split — in process memory; a crash
mid-merge or mid-insert loses everything accumulated since boot.  This
module makes the handle crash-safe with the classic pairing:

  * **Checkpoints** — :func:`save_checkpoint` serializes the full handle
    state to a single ``.npz`` (arrays + a JSON manifest carrying a format
    version, the DBSCAN parameters, the insert-order *watermark* and a
    content checksum) with an atomic write protocol: serialize to a
    private tmp file in the target directory, ``fsync`` it, ``rename``
    over the destination, ``fsync`` the directory.  A reader can never
    observe a half-written checkpoint — it sees the old file or the new
    one.

  * **A write-ahead log** — :class:`WriteAheadLog` is an append-only file
    of insert micro-batches, each framed as a length-prefixed,
    CRC-checksummed record tagged with its start watermark (the handle's
    ``n_points`` before the batch).  ``insert`` appends + ``fsync``\\ s the
    record *before* touching in-memory state, so once an insert returns
    (is *acknowledged*) its batch is durable.  A crash mid-append leaves a
    torn tail record, which :func:`scan_wal` detects (short read or CRC
    mismatch) and truncates rather than propagating.

  * **Recovery** — :func:`recover` = load the newest valid checkpoint (if
    any) + replay every WAL record past its watermark through the normal
    ``insert`` path (with logging suppressed — the records are already
    durable).  The result is a live handle whose ``snapshot()`` is
    component-identical to batch ``dbscan`` on exactly the durable
    points: acknowledged batches are never lost, unacknowledged ones are
    never half-applied (a batch is either fully in the WAL or truncated
    with the tail).

Fault injection (tests/faults.py) arms :func:`barrier` at named crash
points — the streaming code calls it at every durability barrier and an
armed point terminates the process with ``os._exit`` (the closest
in-process stand-in for ``kill -9``: no atexit, no flushing, no cleanup).
"""
from __future__ import annotations

import io
import json
import os
import struct
import zlib

import numpy as np

# ---------------------------------------------------------------------- #
# fault injection                                                        #
# ---------------------------------------------------------------------- #

# Exit code the injected crashes die with (mirrors SIGKILL's 128 + 9).
FAULT_EXIT_CODE = 137

# Named crash points the streaming code guards with barrier() calls.
FAULT_POINTS = ("pre-insert", "wal-durable", "post-insert", "mid-merge",
                "mid-checkpoint", "mid-wal-append")

_fault_point: str | None = None
_fault_countdown: int = 0


def arm_fault(point: str | None, at: int = 1) -> None:
    """Arm a deterministic crash at the ``at``-th hit of ``point``.

    ``None`` disarms.  Used by the fault-injection harness only; the
    barriers are no-ops when nothing is armed.
    """
    global _fault_point, _fault_countdown
    if point is not None and point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}; "
                         f"one of {FAULT_POINTS}")
    _fault_point = point
    _fault_countdown = int(at)


def barrier(point: str) -> None:
    """Crash-test hook: die (as if kill -9) if ``point`` is armed."""
    global _fault_countdown
    if _fault_point != point:
        return
    _fault_countdown -= 1
    if _fault_countdown <= 0:
        os._exit(FAULT_EXIT_CODE)


def _fault_armed_now(point: str) -> bool:
    """True iff ``point`` is armed and its countdown fires on this hit
    (consumes one hit).  Lets the WAL implement the *torn write* fault,
    which needs custom behaviour (write half a record) rather than an
    immediate exit."""
    global _fault_countdown
    if _fault_point != point:
        return False
    _fault_countdown -= 1
    return _fault_countdown <= 0


# ---------------------------------------------------------------------- #
# errors                                                                 #
# ---------------------------------------------------------------------- #

class CheckpointError(ValueError):
    """A checkpoint file is unreadable: unknown format version, checksum
    mismatch, or a missing/malformed manifest.  Deliberately *not* raised
    for a torn WAL tail — that is expected after a crash and silently
    truncated; a corrupt checkpoint is not (the atomic write protocol
    means one can only arise from external damage)."""


# ---------------------------------------------------------------------- #
# checkpoints                                                            #
# ---------------------------------------------------------------------- #

CHECKPOINT_VERSION = 1

# Array fields serialized per checkpoint, in checksum order.
_CKPT_ARRAYS = ("pts", "counts", "core", "labels")


def _content_checksum(arrays: dict) -> str:
    """CRC-32 over the raw bytes of every array field, in fixed order."""
    crc = 0
    for name in _CKPT_ARRAYS:
        arr = np.ascontiguousarray(arrays[name])
        crc = zlib.crc32(arr.tobytes(), crc)
        crc = zlib.crc32(repr((name, arr.shape, str(arr.dtype))).encode(),
                         crc)
    return f"{crc:08x}"


def _fsync_dir(path: str) -> None:
    """fsync the directory containing ``path`` so a rename is durable."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:                      # e.g. platforms without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(handle, path: str) -> dict:
    """Atomically serialize a ``StreamingDBSCAN`` handle to ``path``.

    Returns the manifest that was written.  The write is atomic: the
    bytes go to a tmp file in the destination directory, are fsync'd,
    then renamed over ``path`` (and the directory fsync'd), so a crash at
    any barrier leaves either the previous checkpoint or the new one —
    never a torn file.
    """
    arrays = {
        "pts": handle._pts,
        "counts": handle._counts,
        "core": handle._core,
        "labels": handle._labels,
    }
    manifest = {
        "format": "repro-stream-checkpoint",
        "version": CHECKPOINT_VERSION,
        "dtype": "float32",
        "d": int(handle._pts.shape[1]),
        "eps": float(handle.eps),
        "min_pts": int(handle.min_pts),
        "merge_ratio": float(handle._merge_ratio),
        "watermark": int(handle.n_points),   # insert-order high-water mark
        "n_main": int(handle._n_main),
        "n_inserts": int(handle.n_inserts),
        "n_merges": int(handle.n_merges),
        "n_repair_sweeps": int(handle.n_repair_sweeps),
        "checksum": _content_checksum(arrays),
    }
    buf = io.BytesIO()
    np.savez(buf, manifest=np.frombuffer(
        json.dumps(manifest, sort_keys=True).encode(), np.uint8), **arrays)
    tmp = f"{path}.tmp.{os.getpid()}"
    fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
    try:
        # os.write may write fewer bytes than asked (Linux caps a single
        # write at ~2GB) — a short write that got fsync'd and renamed
        # would replace the previous good checkpoint with a torn one
        view = memoryview(buf.getvalue())
        while len(view):
            view = view[os.write(fd, view):]
        os.fsync(fd)
    finally:
        os.close(fd)
    barrier("mid-checkpoint")            # tmp durable, rename not yet done
    os.replace(tmp, path)
    _fsync_dir(path)
    return manifest


def load_checkpoint(path: str) -> dict:
    """Read + verify a checkpoint; returns ``{manifest, pts, counts, core,
    labels}``.

    Raises :class:`CheckpointError` on an unknown (future) format version,
    a content-checksum mismatch, or a missing/malformed manifest — a
    damaged checkpoint must fail loudly, never silently restore garbage.
    """
    try:
        with np.load(path) as z:
            if "manifest" not in z:
                raise CheckpointError(f"{path}: not a streaming checkpoint "
                                      "(no manifest)")
            try:
                manifest = json.loads(bytes(z["manifest"]).decode())
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise CheckpointError(f"{path}: malformed manifest: {e}")
            version = manifest.get("version")
            if version != CHECKPOINT_VERSION:
                raise CheckpointError(
                    f"{path}: unsupported checkpoint format version "
                    f"{version!r} (this build reads version "
                    f"{CHECKPOINT_VERSION}); refusing to guess")
            arrays = {name: z[name] for name in _CKPT_ARRAYS}
    except CheckpointError:
        raise
    except zipfile_errors() as e:
        raise CheckpointError(f"{path}: unreadable checkpoint: {e}")
    got = _content_checksum(arrays)
    if got != manifest.get("checksum"):
        raise CheckpointError(
            f"{path}: content checksum mismatch (manifest "
            f"{manifest.get('checksum')!r}, computed {got!r}) — "
            "the checkpoint is corrupt")
    if manifest.get("watermark") != len(arrays["pts"]):
        raise CheckpointError(
            f"{path}: watermark {manifest.get('watermark')} does not match "
            f"{len(arrays['pts'])} serialized points")
    return {"manifest": manifest, **arrays}


def zipfile_errors():
    """The exception types a damaged .npz can raise from np.load."""
    import zipfile
    return (OSError, ValueError, zipfile.BadZipFile, KeyError)


# ---------------------------------------------------------------------- #
# write-ahead log                                                        #
# ---------------------------------------------------------------------- #

WAL_VERSION = 1
_WAL_MAGIC = b"RWAL"
_REC_MAGIC = 0x5743_4552                       # "RECW" little-endian
# file header: magic, version, d, eps (f64), min_pts (i32)
_HDR = struct.Struct("<4sHHdi")
# record header: magic, start watermark, point count, crc32
_REC = struct.Struct("<IQII")


class WALError(ValueError):
    """A WAL file exists but its *header* is incompatible (wrong magic on
    a non-empty file, future version, parameter mismatch with the
    handle).  Torn/corrupt tail *records* never raise — they are
    truncated, which is the whole point of the log."""


def _record_crc(start_gid: int, k: int, payload: bytes) -> int:
    return zlib.crc32(struct.pack("<QI", start_gid, k) + payload)


def scan_wal(path: str):
    """Parse a WAL file, tolerating a torn tail.

    Returns ``(header, records, valid_end)`` where ``header`` is a dict
    (``None`` for a missing/empty file), ``records`` is a list of
    ``(start_gid, (k, d) float32 batch)`` in append order, and
    ``valid_end`` is the byte offset of the last fully-valid record —
    everything past it (a torn or checksum-corrupt tail) should be
    truncated before appending again.  A torn *header* (crash during the
    very first append) yields ``(None, [], 0)``.

    Raises :class:`WALError` only for a structurally incompatible header
    (bad magic on a non-empty file, future version) — i.e. "this is not
    our log", which replaying could not make right.
    """
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except FileNotFoundError:
        return None, [], 0
    if len(blob) < _HDR.size:
        return None, [], 0               # torn header: nothing durable yet
    magic, version, d, eps, min_pts = _HDR.unpack_from(blob, 0)
    if magic != _WAL_MAGIC:
        raise WALError(f"{path}: not a streaming WAL (bad magic)")
    if version != WAL_VERSION:
        raise WALError(f"{path}: unsupported WAL version {version} "
                       f"(this build reads {WAL_VERSION})")
    header = {"version": version, "d": d, "eps": eps, "min_pts": min_pts}
    records = []
    off = _HDR.size
    valid_end = off
    while off + _REC.size <= len(blob):
        rmagic, start_gid, k, crc = _REC.unpack_from(blob, off)
        if rmagic != _REC_MAGIC:
            break                        # corrupt tail: stop, truncate here
        body_end = off + _REC.size + k * d * 4
        if body_end > len(blob):
            break                        # torn payload
        payload = blob[off + _REC.size:body_end]
        if _record_crc(start_gid, k, payload) != crc:
            break                        # bit-damaged tail record
        records.append((int(start_gid),
                        np.frombuffer(payload, np.float32).reshape(k, d)))
        off = valid_end = body_end
    return header, records, valid_end


class WriteAheadLog:
    """Append-only durable log of insert micro-batches.

    Opened lazily: the file (and its parameter header) is created on the
    first append, so a cold-start handle can attach a WAL before its
    dimensionality is known.  Reopening an existing log validates the
    header against the handle's parameters and truncates any torn tail
    left by a previous crash.
    """

    def __init__(self, path: str, *, eps: float, min_pts: int):
        self.path = str(path)
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self._f = None                   # opened on first append/reopen
        self._d: int | None = None

    def _open_for_append(self, d: int) -> None:
        header, _, valid_end = scan_wal(self.path)
        if header is not None:
            if header["d"] != d:
                raise WALError(
                    f"{self.path}: WAL is {header['d']}-d, handle is {d}-d")
            if (header["eps"] != self.eps
                    or header["min_pts"] != self.min_pts):
                raise WALError(
                    f"{self.path}: WAL parameters (eps={header['eps']}, "
                    f"min_pts={header['min_pts']}) do not match the handle "
                    f"(eps={self.eps}, min_pts={self.min_pts})")
            self._f = open(self.path, "r+b")
            self._f.truncate(valid_end)  # drop any torn tail
            self._f.seek(valid_end)
        else:
            self._f = open(self.path, "wb")
            self._f.write(_HDR.pack(_WAL_MAGIC, WAL_VERSION, d,
                                    self.eps, self.min_pts))
        self._d = d

    def append(self, batch: np.ndarray, start_gid: int) -> None:
        """Durably append one insert batch (fsync before returning)."""
        batch = np.ascontiguousarray(batch, np.float32)
        k, d = batch.shape
        if self._f is None:
            self._open_for_append(d)
        payload = batch.tobytes()
        rec = _REC.pack(_REC_MAGIC, start_gid, k,
                        _record_crc(start_gid, k, payload)) + payload
        if _fault_armed_now("mid-wal-append"):
            # torn-write fault: half the record reaches the disk, then the
            # process dies without any cleanup
            self._f.write(rec[:max(1, len(rec) // 2)])
            self._f.flush()
            os.fsync(self._f.fileno())
            os._exit(FAULT_EXIT_CODE)
        self._f.write(rec)
        self._f.flush()
        os.fsync(self._f.fileno())

    def reset(self, _watermark: int | None = None) -> None:
        """Truncate the log back to its header — called after a successful
        checkpoint (whose watermark covers every logged record).  Safe
        against a crash at any point: until the truncate completes,
        recovery simply skips records below the checkpoint watermark."""
        if self._f is None:
            header, _, _ = scan_wal(self.path)
            if header is None:
                return
            self._open_for_append(header["d"])
        self._f.truncate(_HDR.size)
        self._f.seek(_HDR.size)
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


# ---------------------------------------------------------------------- #
# recovery                                                               #
# ---------------------------------------------------------------------- #

def recover(checkpoint_path: str | None = None, wal_path: str | None = None,
            **handle_kwargs):
    """Rebuild a live ``StreamingDBSCAN`` from durable state.

    Load the checkpoint (if the file exists), then replay every WAL
    record whose start watermark is at or past the checkpoint's through
    the normal ``insert`` path — records below the watermark are already
    folded into the checkpoint and are skipped; a torn/corrupt tail is
    truncated silently (those batches were never acknowledged).  With no
    checkpoint, replay starts from an empty handle using the parameters
    stored in the WAL header.  The recovered handle re-attaches the same
    WAL and checkpoint paths, so serving (and further crash/recovery
    cycles) continue seamlessly.

    Raises:
        CheckpointError: the checkpoint file exists but is damaged or has
            an unknown format version.
        WALError: the WAL header is structurally incompatible, its
            parameters disagree with the checkpoint manifest, or the log
            has a *gap* — a record whose start watermark is past the
            recovered state, meaning acknowledged records depend on a
            prefix that is missing (never silently dropped).
        ValueError: neither a checkpoint nor a non-empty WAL exists (there
            is nothing to recover and no parameters to start from).
    """
    from repro.stream.index import StreamingDBSCAN

    state = None
    if checkpoint_path is not None and os.path.exists(checkpoint_path):
        state = load_checkpoint(checkpoint_path)
    wal_header, records, _ = (scan_wal(wal_path) if wal_path is not None
                              else (None, [], 0))
    if state is None and wal_header is None:
        raise ValueError(
            "nothing to recover: no checkpoint file and no (non-empty) WAL "
            f"(checkpoint={checkpoint_path!r}, wal={wal_path!r})")
    if state is not None and wal_header is not None:
        m = state["manifest"]
        if (wal_header["d"] != m["d"] or wal_header["eps"] != m["eps"]
                or wal_header["min_pts"] != m["min_pts"]):
            raise WALError(
                f"{wal_path}: WAL header (d={wal_header['d']}, "
                f"eps={wal_header['eps']}, min_pts={wal_header['min_pts']}) "
                f"does not match the checkpoint manifest (d={m['d']}, "
                f"eps={m['eps']}, min_pts={m['min_pts']}) — the files are "
                "from different parameter runs; replaying would corrupt "
                "the index")

    if state is not None:
        m = state["manifest"]
        eps, min_pts = m["eps"], m["min_pts"]
        h = StreamingDBSCAN(None, eps, min_pts,
                            merge_ratio=m["merge_ratio"])
        h._adopt_state(state)
    else:
        eps, min_pts = wal_header["eps"], wal_header["min_pts"]
        h = StreamingDBSCAN(None, eps, min_pts, **{
            k: v for k, v in handle_kwargs.items() if k == "merge_ratio"})

    for start_gid, batch in records:
        if start_gid + len(batch) <= h.n_points:
            continue                     # already covered by the checkpoint
        if start_gid != h.n_points:
            # A gap means acknowledged records depend on state we do not
            # have (e.g. the WAL was truncated against a checkpoint that
            # is not the one being restored, or the checkpoint file was
            # swapped for an older/foreign one). Applying out of order
            # would silently violate the durability contract — fail loud.
            raise WALError(
                f"{wal_path}: WAL record starts at watermark {start_gid} "
                f"but the recovered state ends at {h.n_points} — the "
                "log's prefix is missing; refusing to replay a gapped "
                "log (acknowledged data would be silently lost)")
        h.insert(batch)                  # _wal is None here: no re-logging

    # re-attach durability so the recovered handle keeps serving durably
    if wal_path is not None:
        h._wal = WriteAheadLog(wal_path, eps=h.eps, min_pts=h.min_pts)
    if checkpoint_path is not None:
        h._ckpt_path = checkpoint_path
    for k, v in handle_kwargs.items():
        if k == "checkpoint_every":
            h._ckpt_every = int(v)
    return h
