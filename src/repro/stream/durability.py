"""Durability layer for the streaming index (DESIGN.md §10).

The serving path keeps its whole state — points, saturated core counts,
union-find labels, the tombstone mask, the tiered tree split — in process
memory; a crash mid-merge or mid-insert loses everything accumulated
since boot.  This module makes the handle crash-safe with the classic
pairing:

  * **Checkpoints** — :func:`save_checkpoint` serializes the full handle
    state to a single ``.npz`` (arrays + a JSON manifest carrying a format
    version, the DBSCAN parameters, the insert-order *watermark*, the
    expiry watermark and a content checksum) with an atomic write
    protocol: serialize to a private tmp file in the target directory,
    ``fsync`` it, ``rename`` over the destination, ``fsync`` the
    directory.  A reader can never observe a half-written checkpoint — it
    sees the old file or the new one.

  * **A write-ahead log** — :class:`WriteAheadLog` is an append-only file
    of stream operations, each framed as a length-prefixed,
    CRC-checksummed record.  Format version 2 carries three record types
    — INSERT (a float32 micro-batch tagged with its start watermark),
    DELETE (an int64 gid batch tagged with the stream watermark at append
    time), EXPIRE (a bare watermark) — while version-1 files (insert-only
    framing) remain fully replayable.  ``insert``/``delete``/``expire``
    append + ``fsync`` the record *before* touching in-memory state, so
    once an operation returns (is *acknowledged*) it is durable.  A crash
    mid-append leaves a torn tail record, which :func:`scan_wal` detects
    (short read or CRC mismatch) and truncates rather than propagating.

  * **Recovery** — :func:`recover` = load the newest valid checkpoint (if
    any) + replay every WAL record past its watermark through the normal
    ``insert``/``delete``/``expire`` paths (with logging suppressed — the
    records are already durable; deletes and expires are idempotent, so
    records the checkpoint already covers are harmless no-ops).  The
    result is a live handle whose ``snapshot()`` is component-identical
    to batch ``dbscan`` on exactly the durable *surviving* points:
    acknowledged operations are never lost, unacknowledged ones are never
    half-applied (an operation is either fully in the WAL or truncated
    with the tail).

Fault injection (tests/faults.py) arms :func:`barrier` at named crash
points — the streaming code calls it at every durability barrier and an
armed point terminates the process with ``os._exit`` (the closest
in-process stand-in for ``kill -9``: no atexit, no flushing, no cleanup).
"""
from __future__ import annotations

import io
import json
import os
import struct
import time
import zlib

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# ---------------------------------------------------------------------- #
# fault injection                                                        #
# ---------------------------------------------------------------------- #

# Exit code the injected crashes die with (mirrors SIGKILL's 128 + 9).
FAULT_EXIT_CODE = 137

# Named crash points the streaming code guards with barrier() calls.
FAULT_POINTS = ("pre-insert", "wal-durable", "post-insert", "mid-merge",
                "mid-checkpoint", "mid-wal-append",
                "pre-delete", "wal-durable-delete", "mid-compaction",
                "mid-publish")

_fault_point: str | None = None
_fault_countdown: int = 0


def arm_fault(point: str | None, at: int = 1) -> None:
    """Arm a deterministic crash at the ``at``-th hit of ``point``.

    ``None`` disarms.  Used by the fault-injection harness only; the
    barriers are no-ops when nothing is armed.
    """
    global _fault_point, _fault_countdown
    if point is not None and point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}; "
                         f"one of {FAULT_POINTS}")
    _fault_point = point
    _fault_countdown = int(at)


def barrier(point: str) -> None:
    """Crash-test hook: die (as if kill -9) if ``point`` is armed."""
    global _fault_countdown
    if _fault_point != point:
        return
    _fault_countdown -= 1
    if _fault_countdown <= 0:
        os._exit(FAULT_EXIT_CODE)


def _fault_armed_now(point: str) -> bool:
    """True iff ``point`` is armed and its countdown fires on this hit
    (consumes one hit).  Lets the WAL implement the *torn write* fault,
    which needs custom behaviour (write half a record) rather than an
    immediate exit."""
    global _fault_countdown
    if _fault_point != point:
        return False
    _fault_countdown -= 1
    return _fault_countdown <= 0


# ---------------------------------------------------------------------- #
# errors                                                                 #
# ---------------------------------------------------------------------- #

class CheckpointError(ValueError):
    """A checkpoint file is unreadable: unknown format version, checksum
    mismatch, or a missing/malformed manifest.  Deliberately *not* raised
    for a torn WAL tail — that is expected after a crash and silently
    truncated; a corrupt checkpoint is not (the atomic write protocol
    means one can only arise from external damage)."""


# ---------------------------------------------------------------------- #
# checkpoints                                                            #
# ---------------------------------------------------------------------- #

CHECKPOINT_VERSION = 2

# Array fields serialized per checkpoint, in checksum order.  Version 2
# added the tombstone mask; version-1 files (no tombstones — nothing was
# ever deleted when they were written) still load.
_CKPT_ARRAYS_V1 = ("pts", "counts", "core", "labels")
_CKPT_ARRAYS = _CKPT_ARRAYS_V1 + ("tombstone",)


def _content_checksum(arrays: dict, names=_CKPT_ARRAYS) -> str:
    """CRC-32 over the raw bytes of every array field, in fixed order."""
    crc = 0
    for name in names:
        arr = np.ascontiguousarray(arrays[name])
        crc = zlib.crc32(arr.tobytes(), crc)
        crc = zlib.crc32(repr((name, arr.shape, str(arr.dtype))).encode(),
                         crc)
    return f"{crc:08x}"


def _fsync_dir(path: str) -> None:
    """fsync the directory containing ``path`` so a rename is durable."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:                      # e.g. platforms without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(handle, path: str) -> dict:
    """Atomically serialize a ``StreamingDBSCAN`` handle to ``path``.

    Returns the manifest that was written.  The write is atomic: the
    bytes go to a tmp file in the destination directory, are fsync'd,
    then renamed over ``path`` (and the directory fsync'd), so a crash at
    any barrier leaves either the previous checkpoint or the new one —
    never a torn file.
    """
    arrays = {
        "pts": handle._pts,
        "counts": handle._counts,
        "core": handle._core,
        "labels": handle._labels,
        "tombstone": handle._tombstone,
    }
    manifest = {
        "format": "repro-stream-checkpoint",
        "version": CHECKPOINT_VERSION,
        "dtype": "float32",
        "d": int(handle._pts.shape[1]),
        "eps": float(handle.eps),
        "min_pts": int(handle.min_pts),
        "merge_ratio": float(handle._merge_ratio),
        "window": handle.window,
        "buffer_max": int(handle._buffer_max),
        "growth": int(handle._growth),
        "watermark": int(handle.n_points),   # insert-order high-water mark
        "expire_watermark": int(handle._expire_watermark),
        "n_active": int(handle.n_active),
        "n_tombstoned": int(handle.n_tombstoned),
        "n_main": int(handle.n_main),
        "n_inserts": int(handle.n_inserts),
        "n_deletes": int(handle.n_deletes),
        "n_merges": int(handle.n_merges),
        "n_compactions": int(handle.n_compactions),
        "n_repair_sweeps": int(handle.n_repair_sweeps),
        "checksum": _content_checksum(arrays),
    }
    t0 = time.perf_counter()
    buf = io.BytesIO()
    np.savez(buf, manifest=np.frombuffer(
        json.dumps(manifest, sort_keys=True).encode(), np.uint8), **arrays)
    tmp = f"{path}.tmp.{os.getpid()}"
    fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
    try:
        # os.write may write fewer bytes than asked (Linux caps a single
        # write at ~2GB) — a short write that got fsync'd and renamed
        # would replace the previous good checkpoint with a torn one
        view = memoryview(buf.getvalue())
        while len(view):
            view = view[os.write(fd, view):]
        os.fsync(fd)
    finally:
        os.close(fd)
    barrier("mid-checkpoint")            # tmp durable, rename not yet done
    os.replace(tmp, path)
    _fsync_dir(path)
    obs_metrics.observe("checkpoint_write_seconds",
                        time.perf_counter() - t0)
    obs_metrics.inc("checkpoints_total")
    return manifest


def load_checkpoint(path: str) -> dict:
    """Read + verify a checkpoint; returns ``{manifest, pts, counts, core,
    labels[, tombstone]}`` (``tombstone`` absent for version-1 files).

    Raises :class:`CheckpointError` on an unknown (future) format version,
    a content-checksum mismatch, or a missing/malformed manifest — a
    damaged checkpoint must fail loudly, never silently restore garbage.
    """
    try:
        with np.load(path) as z:
            if "manifest" not in z:
                raise CheckpointError(f"{path}: not a streaming checkpoint "
                                      "(no manifest)")
            try:
                manifest = json.loads(bytes(z["manifest"]).decode())
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise CheckpointError(f"{path}: malformed manifest: {e}")
            version = manifest.get("version")
            if version not in (1, CHECKPOINT_VERSION):
                raise CheckpointError(
                    f"{path}: unsupported checkpoint format version "
                    f"{version!r} (this build reads versions 1 and "
                    f"{CHECKPOINT_VERSION}); refusing to guess")
            names = _CKPT_ARRAYS_V1 if version == 1 else _CKPT_ARRAYS
            arrays = {name: z[name] for name in names}
    except CheckpointError:
        raise
    except zipfile_errors() as e:
        raise CheckpointError(f"{path}: unreadable checkpoint: {e}")
    got = _content_checksum(arrays, names)
    if got != manifest.get("checksum"):
        raise CheckpointError(
            f"{path}: content checksum mismatch (manifest "
            f"{manifest.get('checksum')!r}, computed {got!r}) — "
            "the checkpoint is corrupt")
    if manifest.get("watermark") != len(arrays["pts"]):
        raise CheckpointError(
            f"{path}: watermark {manifest.get('watermark')} does not match "
            f"{len(arrays['pts'])} serialized points")
    return {"manifest": manifest, **arrays}


def zipfile_errors():
    """The exception types a damaged .npz can raise from np.load."""
    import zipfile
    return (OSError, ValueError, zipfile.BadZipFile, KeyError)


# ---------------------------------------------------------------------- #
# write-ahead log                                                        #
# ---------------------------------------------------------------------- #

WAL_VERSION = 2
_WAL_COMPAT = (1, 2)                           # versions scan_wal reads
_WAL_MAGIC = b"RWAL"
_REC_MAGIC = 0x5743_4552                       # "RECW" little-endian
# file header: magic, version, d, eps (f64), min_pts (i32) — shared by
# both format versions, so a version-1 file is identified by its header
_HDR = struct.Struct("<4sHHdi")
# v1 record header: magic, start watermark, point count, crc32
_REC = struct.Struct("<IQII")
# v2 record header: magic, record type, argument, payload count, crc32
_REC2 = struct.Struct("<IBQII")

# v2 record types.  INSERT: arg = start watermark, payload = (k, d)
# float32 batch.  DELETE: arg = stream watermark (n_points) at append
# time (used as the replay gap check), payload = k int64 gids.  EXPIRE:
# arg = expiry watermark, no payload.
REC_INSERT, REC_DELETE, REC_EXPIRE = 1, 2, 3


class WALError(ValueError):
    """A WAL file exists but its *header* is incompatible (wrong magic on
    a non-empty file, future version, parameter mismatch with the
    handle), or an append is illegal for its format version (delete
    records into a version-1 log).  Torn/corrupt tail *records* never
    raise — they are truncated, which is the whole point of the log."""


def _record_crc(start_gid: int, k: int, payload: bytes) -> int:
    """v1 insert-record checksum."""
    return zlib.crc32(struct.pack("<QI", start_gid, k) + payload)


def _record_crc2(rtype: int, arg: int, k: int, payload: bytes) -> int:
    """v2 typed-record checksum (covers the type tag too)."""
    return zlib.crc32(struct.pack("<BQI", rtype, arg, k) + payload)


def _payload_nbytes(rtype: int, k: int, d: int) -> int:
    if rtype == REC_INSERT:
        return k * d * 4                 # (k, d) float32
    if rtype == REC_DELETE:
        return k * 8                     # k int64 gids
    return 0                             # EXPIRE carries no payload


def scan_wal(path: str):
    """Parse a WAL file (either format version), tolerating a torn tail.

    Returns ``(header, ops, valid_end)`` where ``header`` is a dict
    (``None`` for a missing/empty file), ``ops`` is a list of operation
    tuples in append order —

      * ``("insert", start_gid, (k, d) float32 batch)``
      * ``("delete", watermark, (k,) int64 gids)``
      * ``("expire", watermark, None)``

    (version-1 files only ever yield inserts) — and ``valid_end`` is the
    byte offset of the last fully-valid record; everything past it (a
    torn or checksum-corrupt tail) should be truncated before appending
    again.  A torn *header* (crash during the very first append) yields
    ``(None, [], 0)``.

    Raises :class:`WALError` only for a structurally incompatible header
    (bad magic on a non-empty file, future version) — i.e. "this is not
    our log", which replaying could not make right.
    """
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except FileNotFoundError:
        return None, [], 0
    if len(blob) < _HDR.size:
        return None, [], 0               # torn header: nothing durable yet
    magic, version, d, eps, min_pts = _HDR.unpack_from(blob, 0)
    if magic != _WAL_MAGIC:
        raise WALError(f"{path}: not a streaming WAL (bad magic)")
    if version not in _WAL_COMPAT:
        raise WALError(f"{path}: unsupported WAL version {version} "
                       f"(this build reads {_WAL_COMPAT})")
    header = {"version": version, "d": d, "eps": eps, "min_pts": min_pts}
    ops = []
    off = _HDR.size
    valid_end = off
    if version == 1:
        while off + _REC.size <= len(blob):
            rmagic, start_gid, k, crc = _REC.unpack_from(blob, off)
            if rmagic != _REC_MAGIC:
                break                    # corrupt tail: stop, truncate here
            body_end = off + _REC.size + k * d * 4
            if body_end > len(blob):
                break                    # torn payload
            payload = blob[off + _REC.size:body_end]
            if _record_crc(start_gid, k, payload) != crc:
                break                    # bit-damaged tail record
            ops.append(("insert", int(start_gid),
                        np.frombuffer(payload, np.float32).reshape(k, d)))
            off = valid_end = body_end
        return header, ops, valid_end
    while off + _REC2.size <= len(blob):
        rmagic, rtype, arg, k, crc = _REC2.unpack_from(blob, off)
        if rmagic != _REC_MAGIC or rtype not in (REC_INSERT, REC_DELETE,
                                                 REC_EXPIRE):
            break                        # corrupt tail: stop, truncate here
        body_end = off + _REC2.size + _payload_nbytes(rtype, k, d)
        if body_end > len(blob):
            break                        # torn payload
        payload = blob[off + _REC2.size:body_end]
        if _record_crc2(rtype, arg, k, payload) != crc:
            break                        # bit-damaged tail record
        if rtype == REC_INSERT:
            ops.append(("insert", int(arg),
                        np.frombuffer(payload, np.float32).reshape(k, d)))
        elif rtype == REC_DELETE:
            ops.append(("delete", int(arg),
                        np.frombuffer(payload, "<i8").astype(np.int64)))
        else:
            ops.append(("expire", int(arg), None))
        off = valid_end = body_end
    return header, ops, valid_end


class WriteAheadLog:
    """Append-only durable log of stream operations.

    Opened lazily: the file (and its parameter header) is created on the
    first append, so a cold-start handle can attach a WAL before its
    dimensionality is known.  New files are created at format version 2;
    reopening an existing log validates the header against the handle's
    parameters, keeps the file's own version for further appends, and
    truncates any torn tail left by a previous crash.  Version-1 files
    accept further *insert* appends (their only framing) — delete/expire
    appends raise :class:`WALError` until a checkpoint :meth:`reset`
    rewrites the file at the current version.
    """

    def __init__(self, path: str, *, eps: float, min_pts: int):
        self.path = str(path)
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self._f = None                   # opened on first append/reopen
        self._d: int | None = None
        self._version: int | None = None

    def _open_for_append(self, d: int) -> None:
        header, _, valid_end = scan_wal(self.path)
        if header is not None:
            if header["d"] != d:
                raise WALError(
                    f"{self.path}: WAL is {header['d']}-d, handle is {d}-d")
            if (header["eps"] != self.eps
                    or header["min_pts"] != self.min_pts):
                raise WALError(
                    f"{self.path}: WAL parameters (eps={header['eps']}, "
                    f"min_pts={header['min_pts']}) do not match the handle "
                    f"(eps={self.eps}, min_pts={self.min_pts})")
            self._f = open(self.path, "r+b")
            self._f.truncate(valid_end)  # drop any torn tail
            self._f.seek(valid_end)
            self._version = header["version"]
        else:
            self._f = open(self.path, "wb")
            self._f.write(_HDR.pack(_WAL_MAGIC, WAL_VERSION, d,
                                    self.eps, self.min_pts))
            self._version = WAL_VERSION
        self._d = d

    def _write_record(self, rec: bytes) -> None:
        if _fault_armed_now("mid-wal-append"):
            # torn-write fault: half the record reaches the disk, then the
            # process dies without any cleanup
            self._f.write(rec[:max(1, len(rec) // 2)])
            self._f.flush()
            os.fsync(self._f.fileno())
            os._exit(FAULT_EXIT_CODE)
        self._f.write(rec)
        self._f.flush()
        t0 = time.perf_counter()
        os.fsync(self._f.fileno())
        obs_metrics.observe("wal_fsync_seconds", time.perf_counter() - t0)
        obs_metrics.inc("wal_appends_total")

    def append(self, batch: np.ndarray, start_gid: int) -> None:
        """Durably append one insert batch (fsync before returning)."""
        batch = np.ascontiguousarray(batch, np.float32)
        k, d = batch.shape
        if self._f is None:
            self._open_for_append(d)
        payload = batch.tobytes()
        if self._version == 1:           # keep the file's own framing
            rec = _REC.pack(_REC_MAGIC, start_gid, k,
                            _record_crc(start_gid, k, payload)) + payload
        else:
            rec = _REC2.pack(
                _REC_MAGIC, REC_INSERT, start_gid, k,
                _record_crc2(REC_INSERT, start_gid, k, payload)) + payload
        self._write_record(rec)

    def append_delete(self, gids: np.ndarray, watermark: int,
                      *, d: int) -> None:
        """Durably append one delete batch (``watermark`` = the handle's
        ``n_points`` at append time, the replay gap check)."""
        if self._f is None:
            self._open_for_append(d)
        if self._version == 1:
            raise WALError(
                f"{self.path}: version-1 WAL has no delete framing — "
                "checkpoint the handle (which resets the log at the "
                "current version) before deleting, or start a fresh log")
        gids = np.ascontiguousarray(gids, "<i8")
        payload = gids.tobytes()
        k = len(gids)
        rec = _REC2.pack(
            _REC_MAGIC, REC_DELETE, watermark, k,
            _record_crc2(REC_DELETE, watermark, k, payload)) + payload
        self._write_record(rec)

    def append_expire(self, watermark: int, *, d: int) -> None:
        """Durably append one expiry watermark record."""
        if self._f is None:
            self._open_for_append(d)
        if self._version == 1:
            raise WALError(
                f"{self.path}: version-1 WAL has no expire framing — "
                "checkpoint the handle (which resets the log at the "
                "current version) before expiring, or start a fresh log")
        rec = _REC2.pack(_REC_MAGIC, REC_EXPIRE, watermark, 0,
                         _record_crc2(REC_EXPIRE, watermark, 0, b""))
        self._write_record(rec)

    def reset(self, _watermark: int | None = None) -> None:
        """Truncate the log and rewrite its header at the current format
        version — called after a successful checkpoint (whose watermark
        covers every logged record; this is also how a version-1 file
        upgrades to the delete-capable framing).  Safe against a crash at
        any point: until the rewrite completes, recovery simply skips
        records below the checkpoint watermark."""
        if self._f is None:
            header, _, _ = scan_wal(self.path)
            if header is None:
                return
            self._open_for_append(header["d"])
        self._f.truncate(0)
        self._f.seek(0)
        self._f.write(_HDR.pack(_WAL_MAGIC, WAL_VERSION, self._d,
                                self.eps, self.min_pts))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._version = WAL_VERSION

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


# ---------------------------------------------------------------------- #
# recovery                                                               #
# ---------------------------------------------------------------------- #

# Handle options recover() forwards to a freshly-built instance.
_HANDLE_KWARGS = ("merge_ratio", "window", "buffer_max", "growth")


def recover(checkpoint_path: str | None = None, wal_path: str | None = None,
            **handle_kwargs):
    """Rebuild a live ``StreamingDBSCAN`` from durable state.

    Load the checkpoint (if the file exists), then replay every WAL
    record through the normal operation paths — insert records fully
    below the checkpoint's watermark are already folded in and are
    skipped; deletes and expires are idempotent, so replaying ones the
    checkpoint covers is a no-op; a torn/corrupt tail is truncated
    silently (those operations were never acknowledged).  With no
    checkpoint, replay starts from an empty handle using the parameters
    stored in the WAL header.  The recovered handle re-attaches the same
    WAL and checkpoint paths, so serving (and further crash/recovery
    cycles) continue seamlessly.

    Raises:
        CheckpointError: the checkpoint file exists but is damaged or has
            an unknown format version.
        WALError: the WAL header is structurally incompatible, its
            parameters disagree with the checkpoint manifest, or the log
            has a *gap* — a record that references stream state past the
            recovered watermark, meaning acknowledged records depend on a
            prefix that is missing (never silently dropped).
        ValueError: neither a checkpoint nor a non-empty WAL exists (there
            is nothing to recover and no parameters to start from).
    """
    from repro.stream.index import StreamingDBSCAN

    state = None
    if checkpoint_path is not None and os.path.exists(checkpoint_path):
        state = load_checkpoint(checkpoint_path)
    wal_header, ops, _ = (scan_wal(wal_path) if wal_path is not None
                          else (None, [], 0))
    if state is None and wal_header is None:
        raise ValueError(
            "nothing to recover: no checkpoint file and no (non-empty) WAL "
            f"(checkpoint={checkpoint_path!r}, wal={wal_path!r})")
    if state is not None and wal_header is not None:
        m = state["manifest"]
        if (wal_header["d"] != m["d"] or wal_header["eps"] != m["eps"]
                or wal_header["min_pts"] != m["min_pts"]):
            raise WALError(
                f"{wal_path}: WAL header (d={wal_header['d']}, "
                f"eps={wal_header['eps']}, min_pts={wal_header['min_pts']}) "
                f"does not match the checkpoint manifest (d={m['d']}, "
                f"eps={m['eps']}, min_pts={m['min_pts']}) — the files are "
                "from different parameter runs; replaying would corrupt "
                "the index")

    if state is not None:
        m = state["manifest"]
        eps, min_pts = m["eps"], m["min_pts"]
        opts = {"merge_ratio": m.get("merge_ratio"),
                "window": m.get("window"),
                "buffer_max": m.get("buffer_max"),
                "growth": m.get("growth")}
        opts = {k: v for k, v in opts.items() if v is not None}
        opts.update({k: v for k, v in handle_kwargs.items()
                     if k in _HANDLE_KWARGS and v is not None})
        h = StreamingDBSCAN(None, eps, min_pts, **opts)
        h._adopt_state(state)
    else:
        eps, min_pts = wal_header["eps"], wal_header["min_pts"]
        h = StreamingDBSCAN(None, eps, min_pts, **{
            k: v for k, v in handle_kwargs.items() if k in _HANDLE_KWARGS})

    with obs_trace.span("stream.replay", n_ops=len(ops)):
        _replay(h, ops, wal_path)
    obs_metrics.inc("wal_replayed_ops_total", float(len(ops)))

    # re-attach durability so the recovered handle keeps serving durably
    if wal_path is not None:
        h._wal = WriteAheadLog(wal_path, eps=h.eps, min_pts=h.min_pts)
    if checkpoint_path is not None:
        h._ckpt_path = checkpoint_path
    for k, v in handle_kwargs.items():
        if k == "checkpoint_every":
            h._ckpt_every = int(v)
    return h


def _replay(h, ops, wal_path) -> None:
    """Apply scanned WAL ops to a recovered handle in append order (the
    body of :func:`recover`'s replay phase)."""
    for op in ops:
        kind, arg, data = op
        if kind == "insert":
            if arg + len(data) <= h.n_points:
                continue                 # already covered by the checkpoint
            if arg != h.n_points:
                # A gap means acknowledged records depend on state we do
                # not have (e.g. the WAL was truncated against a
                # checkpoint that is not the one being restored, or the
                # checkpoint file was swapped for an older/foreign one).
                # Applying out of order would silently violate the
                # durability contract — fail loud.
                raise WALError(
                    f"{wal_path}: WAL insert record starts at watermark "
                    f"{arg} but the recovered state ends at {h.n_points} — "
                    "the log's prefix is missing; refusing to replay a "
                    "gapped log (acknowledged data would be silently lost)")
            h.insert(data)               # _wal is None here: no re-logging
        elif kind == "delete":
            if arg > h.n_points or (len(data)
                                    and int(data.max()) >= h.n_points):
                raise WALError(
                    f"{wal_path}: WAL delete record references stream "
                    f"watermark {max(int(arg), int(data.max()) + 1 if len(data) else 0)} "
                    f"but the recovered state ends at {h.n_points} — the "
                    "log's prefix is missing; refusing to replay a gapped "
                    "log")
            h.delete(data)               # idempotent: dead gids are skipped
        else:                            # expire
            if arg > h.n_points:
                raise WALError(
                    f"{wal_path}: WAL expire record has watermark {arg} "
                    f"but the recovered state ends at {h.n_points} — the "
                    "log's prefix is missing; refusing to replay a gapped "
                    "log")
            h.expire(arg)                # idempotent
