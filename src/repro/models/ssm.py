"""Mamba (selective SSM) block for the Jamba hybrid architecture.

TPU-native scan strategy: the selective recurrence
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
is evaluated as a *chunked* scan — a sequential ``lax.scan`` over chunks of
``CHUNK`` timesteps carrying only the (B, d_inner, N) state, with a parallel
``lax.associative_scan`` inside each chunk. This bounds the materialized
(B, CHUNK, d_inner, N) tensor (VMEM/HBM friendly) while exposing
within-chunk parallelism to the VPU — the standard TPU formulation, vs the
CUDA kernel's warp-level scan in the original Mamba.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

CHUNK = 128


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(cfg, key):
    d, dn, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)
    cw = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    std = 0.02
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (dn, 1))
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * dn), jnp.float32) * std,
        "conv_w": jax.random.normal(ks[1], (cw, dn), jnp.float32) * std,
        "conv_b": jnp.zeros((dn,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (dn, r + 2 * n), jnp.float32) * std,
        "dt_proj": jax.random.normal(ks[3], (r, dn), jnp.float32) * (r ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((dn,), 0.01, jnp.float32))),
        "A_log": jnp.log(a),
        "D": jnp.ones((dn,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (dn, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
    }


def _conv1d_causal(p, x, init_state=None):
    """Depthwise causal conv over time. x: (B, S, dn) -> (B, S, dn)."""
    cw = p["conv_w"].shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    w = p["conv_w"].astype(x.dtype)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    return out + p["conv_b"].astype(x.dtype)


def _ssm_scan_chunked(dA, dBx, C, h0):
    """dA, dBx: (B, S, dn, N); C: (B, S, N); h0: (B, dn, N) -> (y, hS)."""
    B, S, dn, N = dA.shape
    c = min(CHUNK, S)
    if S % c:  # pad to a chunk multiple (identity steps: a=1, b=0)
        pad = c - S % c
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S_pad = dA.shape[1]
    k = S_pad // c
    dA_c = dA.reshape(B, k, c, dn, N).swapaxes(0, 1)
    dBx_c = dBx.reshape(B, k, c, dn, N).swapaxes(0, 1)
    C_c = C.reshape(B, k, c, N).swapaxes(0, 1)
    S_out = S

    def chunk_step(h, xs):
        a, b, cc = xs                       # (B, c, dn, N), ..., (B, c, N)

        def op(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])

        a_cum, b_cum = lax.associative_scan(op, (a, b), axis=1)
        h_t = a_cum * h[:, None] + b_cum    # (B, c, dn, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cc)
        return h_t[:, -1], y

    hS, ys = lax.scan(chunk_step, h0, (dA_c, dBx_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, S_pad, dn)[:, :S_out]
    return y, hS


def mamba(cfg, p, x, state=None):
    """x: (B, S, D). state: None (training) or (conv_state, h) for decode
    continuation of a full sequence — returns (out, new_state)."""
    B, S, D = x.shape
    dn, n = cfg.d_inner, cfg.ssm_state
    r = p["dt_proj"].shape[0]
    cw = cfg.ssm_conv
    xz = x @ p["in_proj"].astype(x.dtype)
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    conv_in = (jnp.zeros((B, cw - 1, dn), x.dtype) if state is None
               else state[0].astype(x.dtype))
    new_conv = jnp.concatenate([conv_in, xi_raw], 1)[:, -(cw - 1):, :]
    xi = jax.nn.silu(_conv1d_causal(p, xi_raw, conv_in))

    xdbl = xi @ p["x_proj"].astype(x.dtype)
    dt, Bs, Cs = jnp.split(xdbl, [r, r + n], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])                       # (B,S,dn) f32
    A = -jnp.exp(p["A_log"])                                   # (dn, N) f32
    dA = jnp.exp(dt[..., None] * A)                            # (B,S,dn,N)
    dBx = (dt * xi.astype(jnp.float32))[..., None] * Bs.astype(jnp.float32)[:, :, None, :]
    h0 = jnp.zeros((B, dn, n), jnp.float32) if state is None else state[1]
    y, hS = _ssm_scan_chunked(dA, dBx, Cs.astype(jnp.float32), h0)
    y = (y + xi.astype(jnp.float32) * p["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, hS)


def init_mamba_state(cfg, batch, dtype):
    dn, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return (jnp.zeros((batch, cw - 1, dn), dtype),
            jnp.zeros((batch, dn, n), jnp.float32))


def mamba_decode(cfg, p, x, state):
    """Single-token step. x: (B, 1, D); state = (conv_state, h)."""
    conv_state, h = state
    B = x.shape[0]
    dn, n = cfg.d_inner, cfg.ssm_state
    r = p["dt_proj"].shape[0]
    xz = x[:, 0] @ p["in_proj"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)                          # (B, dn)
    # conv over (conv_state ++ xi)
    w = p["conv_w"].astype(x.dtype)
    cw = w.shape[0]
    full = jnp.concatenate([conv_state.astype(x.dtype), xi[:, None, :]], 1)
    ci = sum(full[:, i, :] * w[i] for i in range(cw)) + p["conv_b"].astype(x.dtype)
    xi = jax.nn.silu(ci)
    xdbl = xi @ p["x_proj"].astype(x.dtype)
    dt, Bs, Cs = jnp.split(xdbl, [r, r + n], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])                       # (B, dn)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                            # (B, dn, N)
    h = dA * h + (dt * xi.astype(jnp.float32))[..., None] * Bs.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cs.astype(jnp.float32))
    y = (y + xi.astype(jnp.float32) * p["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    new_conv = full[:, 1:, :]
    return out, (new_conv, h)
