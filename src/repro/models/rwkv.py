"""RWKV6 "Finch" block: attention-free time mix with data-dependent decay.

Implements the published v6 structure [arXiv:2404.05892]:
  * ddlerp token-shift: mix of x_t and x_{t-1} with a data-dependent LoRA
    correction per projection (w, k, v, r, g),
  * per-channel data-dependent decay w_t = exp(-exp(w0 + lora_w(x))),
  * multi-head wkv state (head_dim x head_dim per head) with the "bonus" u
    term, group-normed output, silu(g) gate,
  * squared-relu channel mix.

The wkv recurrence is a sequential ``lax.scan`` over time carrying the
(B, H, hd, hd) state — O(1) memory in sequence length, which is what makes
the 500k-token decode cell feasible (DESIGN.md §4). A chunk-parallel variant
is a documented perf follow-up (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def init_rwkv(cfg, key):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    r = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    std = 0.02
    p = {
        # ddlerp token-shift parameters
        "maa_x": jnp.zeros((d,), jnp.float32),
        "maa": jnp.zeros((5, d), jnp.float32),           # w,k,v,r,g base mix
        "maa_w1": jax.random.normal(ks[0], (d, 5 * 32), jnp.float32) * std,
        "maa_w2": jax.random.normal(ks[1], (5, 32, d), jnp.float32) * std,
        # data-dependent decay
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w1": jax.random.normal(ks[2], (d, r), jnp.float32) * std,
        "w2": jax.random.normal(ks[3], (r, d), jnp.float32) * std,
        "u": jnp.zeros((H, hd), jnp.float32),            # bonus
        "wr": jax.random.normal(ks[4], (d, d), jnp.float32) * std,
        "wk": jax.random.normal(ks[5], (d, d), jnp.float32) * std,
        "wv": jax.random.normal(ks[6], (d, d), jnp.float32) * std,
        "wg": jax.random.normal(ks[7], (d, d), jnp.float32) * std,
        "wo": jax.random.normal(ks[8], (d, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_maa_k": jnp.zeros((d,), jnp.float32),
        "cm_maa_r": jnp.zeros((d,), jnp.float32),
        "cm_wk": jax.random.normal(ks[9], (d, cfg.d_ff), jnp.float32) * std,
        "cm_wv": jax.random.normal(ks[10], (cfg.d_ff, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
        "cm_wr": jax.random.normal(ks[11], (d, d), jnp.float32) * std,
    }
    return p


def _ddlerp(p, x, sx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    xxx = x + sx * p["maa_x"].astype(x.dtype)
    B, S, d = x.shape
    lo = jnp.tanh(xxx @ p["maa_w1"].astype(x.dtype)).reshape(B, S, 5, 32)
    delta = jnp.einsum("bsfr,frd->bsfd", lo, p["maa_w2"].astype(x.dtype))
    mix = p["maa"].astype(x.dtype)[None, None] + delta     # (B,S,5,d)
    return x[:, :, None, :] + sx[:, :, None, :] * mix


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v: (B,S,H,hd); w: (B,S,H,hd) decay in (0,1); s0: (B,H,hd,hd).

    y_t = r_t . (diag(u) k_t^T v_t + S_{t-1});  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    def step(s, xs):
        rt, kt, vt, wt = xs                    # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))    # (S,B,H,hd)
    sT, ys = lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), sT                          # (B,S,H,hd)


def time_mix(cfg, p, x, state=None):
    """state: None (training, zero init) or (x_prev (B,1,d), s (B,H,hd,hd))."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    x_prev = jnp.zeros((B, 1, d), x.dtype) if state is None else state[0].astype(x.dtype)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state[1]
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    sx = shifted - x
    mixed = _ddlerp(p, x, sx)                             # (B,S,5,d)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]
    w = jnp.exp(-jnp.exp(
        (p["w0"] + jnp.tanh(xw @ p["w1"].astype(x.dtype)).astype(jnp.float32)
         @ p["w2"]).astype(jnp.float32)))                 # (B,S,d) in (0,1)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    y, sT = _wkv_scan(r, k, v, w.reshape(B, S, H, hd), p["u"], s0)
    # group norm over each head
    y = y.reshape(B, S, H, hd)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = ((y - mu) * lax.rsqrt(var + 64e-5)).reshape(B, S, d)
    y = (y * p["ln_x_scale"] + p["ln_x_bias"]).astype(x.dtype)
    out = (y * g) @ p["wo"].astype(x.dtype)
    return out, (x[:, -1:], sT)


def channel_mix(cfg, p, x, state=None):
    B, S, d = x.shape
    x_prev = jnp.zeros((B, 1, d), x.dtype) if state is None else state.astype(x.dtype)
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    sx = shifted - x
    xk = x + sx * p["cm_maa_k"].astype(x.dtype)
    xr = x + sx * p["cm_maa_r"].astype(x.dtype)
    kh = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["cm_wr"].astype(x.dtype)) * (kh @ p["cm_wv"].astype(x.dtype))
    return out, x[:, -1:]


def init_rwkv_state(cfg, batch, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "tm_x": jnp.zeros((batch, 1, d), dtype),
        "tm_s": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "cm_x": jnp.zeros((batch, 1, d), dtype),
    }
