"""Shared transformer layers: norms, RoPE variants, GQA attention, MLPs.

Everything is a plain function over a params dict (pytrees of jnp arrays);
initialization mirrors each architecture's published scheme (trunc-normal
0.02 unless noted). Attention supports the union of the assigned archs'
features: GQA with grouped einsums (kv never materialized per-head), QKV
bias (qwen), NeoX / GLM-partial-interleaved / no RoPE, attn & final logit
softcaps (gemma2), sliding windows (mixtral/gemma2-local), non-causal
(whisper encoder) and cross attention, plus a cached single-token decode
path with rolling windows.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- norms ----


def init_norm(cfg, with_bias=None):
    bias = cfg.norm_style == "layernorm" if with_bias is None else with_bias
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if bias:
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_style == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True)
                                + cfg.norm_eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    out = xf * p["scale"]
    if "bias" in p:
        out = out + p["bias"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------- rope ----


def _rope_freqs(cfg, rot_dim):
    i = jnp.arange(rot_dim // 2, dtype=jnp.float32)
    return cfg.rope_theta ** (-2.0 * i / rot_dim)


def apply_rope(cfg, x, positions):
    """x: (B, S, n, head_dim); positions: (S,) or (B, S)."""
    if cfg.rope_style == "none":
        return x
    hd = x.shape[-1]
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    if cfg.rope_style == "neox":
        freqs = _rope_freqs(cfg, hd)
        ang = pos[..., None] * freqs            # (B, S, hd/2)
        cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
        sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
        x1, x2 = jnp.split(x, 2, axis=-1)
        return jnp.concatenate([x1 * cos - x2 * sin,
                                x2 * cos + x1 * sin], -1)
    if cfg.rope_style == "glm_partial":
        # rotate the first half of the head dims, interleaved pairing
        rot = hd // 2
        xr, xp = x[..., :rot], x[..., rot:]
        freqs = _rope_freqs(cfg, rot)
        ang = pos[..., None] * freqs
        cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
        sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
        xe, xo = xr[..., 0::2], xr[..., 1::2]
        re = xe * cos - xo * sin
        ro = xo * cos + xe * sin
        xr = jnp.stack([re, ro], -1).reshape(xr.shape)
        return jnp.concatenate([xr, xp], -1)
    raise ValueError(cfg.rope_style)


def sinusoid_positions(max_len: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * i / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# ------------------------------------------------------------ attention ----


def init_attention(cfg, key, cross=False):
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": jax.random.normal(k1, (d, hq), jnp.float32) * std,
        "wk": jax.random.normal(k2, (d, hkv), jnp.float32) * std,
        "wv": jax.random.normal(k3, (d, hkv), jnp.float32) * std,
        "wo": jax.random.normal(k4, (hq, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq,), jnp.float32)
        p["bk"] = jnp.zeros((hkv,), jnp.float32)
        p["bv"] = jnp.zeros((hkv,), jnp.float32)
    return p


def _qkv(cfg, p, xq, xkv):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    q = xq @ p["wq"].astype(xq.dtype)
    k = xkv @ p["wk"].astype(xq.dtype)
    v = xkv @ p["wv"].astype(xq.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _sdpa(cfg, q, k, v, mask):
    """Grouped-query attention. q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd),
    mask: broadcastable to (B, KV, G, Sq, Sk) or None."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        c = cfg.attn_softcap
        scores = c * jnp.tanh(scores / c)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H * hd)


def causal_mask(cfg, q_pos, k_pos, kind: str):
    """(…, Sq, Sk) validity mask from absolute positions."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    window = cfg.sliding_window
    if kind == "local" and window is not None:
        m = m & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return m


def attention(cfg, p, x, positions, kind: str, causal: bool = True,
              xkv=None):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    cross = xkv is not None
    q, k, v = _qkv(cfg, p, x, xkv if cross else x)
    if not cross:
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
        mask = None
        if causal:
            kp = positions if positions.ndim == 1 else positions[0]
            m = causal_mask(cfg, kp, kp, kind)       # (Sq, Sk)
            mask = m[None, None, None, :, :]
    else:
        mask = None
    out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"].astype(x.dtype)


def attention_decode(cfg, p, x, cache_k, cache_v, pos, kind: str):
    """Single-token decode with a (possibly rolling) KV cache.

    x: (B, 1, D); cache_k/v: (B, S_cache, KV, hd); pos: scalar absolute
    position of the new token. For local/SWA layers the cache is sized
    min(window, S_max) and written modulo its length (rolling); absolute
    positions are reconstructed for the RoPE and window mask.
    """
    B = x.shape[0]
    S_cache = cache_k.shape[1]
    q, k, v = _qkv(cfg, p, x, x)
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(cfg, q, posv)
    k = apply_rope(cfg, k, posv)
    slot = pos % S_cache
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    # absolute position of each cache slot (rolling reconstruction)
    idx = jnp.arange(S_cache, dtype=jnp.int32)
    wraps = (pos // S_cache) - (idx > slot)
    k_pos = wraps * S_cache + idx
    valid = (k_pos >= 0) & (k_pos <= pos)
    if kind == "local" and cfg.sliding_window is not None:
        valid = valid & (pos - k_pos < cfg.sliding_window)
    mask = valid[None, None, None, None, :]
    out = _sdpa(cfg, q, cache_k, cache_v, mask)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------- mlps ----


def init_mlp(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    std = 0.02
    if cfg.mlp_style == "swiglu":
        return {
            "w_gate": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
            "w_up": jax.random.normal(ks[1], (d, f), jnp.float32) * std,
            "w_down": jax.random.normal(ks[2], (f, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
        }
    return {  # gelu_mlp (whisper)
        "w_in": jax.random.normal(ks[0], (d, f), jnp.float32) * std,
        "b_in": jnp.zeros((f,), jnp.float32),
        "w_out": jax.random.normal(ks[1], (f, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
        "b_out": jnp.zeros((d,), jnp.float32),
    }


def mlp(cfg, p, x):
    act = jax.nn.silu if cfg.mlp_act == "silu" else partial(jax.nn.gelu, approximate=True)
    if cfg.mlp_style == "swiglu":
        h = act(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype),
                    approximate=True)
    return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
