from . import layers, model, moe, rwkv, ssm

__all__ = ["layers", "model", "moe", "rwkv", "ssm"]
