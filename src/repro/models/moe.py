"""Mixture-of-experts FFN with capacity-based dense dispatch (GShard-style).

TPU-native: dispatch/combine are one-hot einsums (MXU work, no scatters),
experts are batched into a single (E, C, D) x (E, D, F) einsum so the expert
dimension can be sharded over the `model` mesh axis (expert parallelism —
XLA inserts the all-to-alls from the shardings). Tokens beyond an expert's
capacity are dropped (standard); the router returns a switch-style
load-balancing auxiliary loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_moe(cfg, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    std = 0.02
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * std,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * std,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * std,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * std / math.sqrt(2 * cfg.n_layers),
    }


GROUP_SIZE = 4096  # tokens per dispatch group (~tokens/chip at prod shapes)


def moe_ffn(cfg, p, x, group_size: int = GROUP_SIZE):
    """x: (B, S, D) -> (out, aux_loss).

    *Grouped* dispatch: tokens are split into groups of ``group_size`` with
    a per-group capacity, so the one-hot dispatch/combine einsums cost
    2*T*E*C_local*D instead of 2*T*E*C_global*D — C_global grows with the
    global batch and made dispatch dominate total FLOPs (the naive variant
    measured 150x the expert FFN compute at train_4k; see EXPERIMENTS.md
    §Perf iteration 1). Groups follow token order, so under batch sharding
    the group axis aligns with the data axis and dispatch stays local.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    G = T // g
    xt = x.reshape(G, g, D)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,g,E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(logits, K)                        # (G,g,K)
    gates = jax.nn.softmax(gate_vals, -1)                            # mixtral renorm

    # switch aux loss: E * sum_e f_e * p_e (global)
    me = jnp.mean(probs, (0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), (0, 1))
    aux = E * jnp.sum(me * ce)

    C = max(1, int(cfg.capacity_factor * g * K / E))
    dispatch = jnp.zeros((G, g, E, C), x.dtype)
    combine = jnp.zeros((G, g, E, C), x.dtype)
    counts = jnp.zeros((G, E), jnp.int32)
    for s in range(K):  # K is small & static: unrolled
        m = jax.nn.one_hot(idx[..., s], E, dtype=jnp.int32)          # (G,g,E)
        pos = counts[:, None, :] + jnp.cumsum(m, 1) - m              # (G,g,E)
        counts = counts + jnp.sum(m, 1)
        ps = jnp.sum(pos * m, -1)                                    # (G,g)
        ok = (ps < C).astype(x.dtype)                                # capacity
        oh = jax.nn.one_hot(ps, C, dtype=x.dtype) * ok[..., None]
        slot_d = m.astype(x.dtype)[..., None] * oh[:, :, None, :]    # (G,g,E,C)
        dispatch = dispatch + slot_d
        combine = combine + slot_d * gates[..., s].astype(x.dtype)[..., None, None]

    xs = jnp.einsum("gtec,gtd->egcd", dispatch, xt)                  # (E,G,C,D)
    xs = xs.reshape(E, G * C, D)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ead,edf->eaf", xs, p["w_gate"].astype(x.dtype))) \
        * jnp.einsum("ead,edf->eaf", xs, p["w_up"].astype(x.dtype))
    out = jnp.einsum("eaf,efd->ead", h, p["w_down"].astype(x.dtype))
    out = out.reshape(E, G, C, D)
    yt = jnp.einsum("gtec,egcd->gtd", combine, out)
    return yt.reshape(B, S, D), aux
