"""Model assembly: superblock-scanned transformer for all assigned archs.

The repeated ``layer_pattern`` (config) is one *superblock*; parameters of
all superblocks are stacked on a leading axis and the forward pass is a
``lax.scan`` over it (optionally rematerialized). HLO size and compile time
are therefore depth-independent — essential for 40-layer models lowered on
512 fake devices in the dry-run.

Modes:
  * ``forward``      — full-sequence (training; also the prefill body),
  * ``prefill``      — forward + per-layer cache extraction,
  * ``decode_step``  — one token against a (rolling/SSM) cache,
all sharing the same layer functions (layers.py / moe.py / ssm.py / rwkv.py).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import layers, moe, rwkv, ssm

VISION_EMBED_DIM = 1024  # CLIP-L stub width for the llava frontend


# ------------------------------------------------------------------ init ----


def _init_layer(cfg, key, kind: str, pattern_idx: int):
    ks = jax.random.split(key, 4)
    p = {"ln1": layers.init_norm(cfg)}
    if kind in ("attn", "local"):
        p["attn"] = layers.init_attention(cfg, ks[0])
    elif kind == "mamba":
        p["mamba"] = ssm.init_mamba(cfg, ks[0])
    elif kind == "rwkv":
        p["tmix"] = rwkv.init_rwkv(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        p["ln2"] = layers.init_norm(cfg)
        return p
    p["ln2"] = layers.init_norm(cfg)
    if cfg.moe_at(pattern_idx):
        p["moe"] = moe.init_moe(cfg, ks[1])
    else:
        p["ffn"] = layers.init_mlp(cfg, ks[1])
    if cfg.post_norm:
        p["ln1_post"] = layers.init_norm(cfg)
        p["ln2_post"] = layers.init_norm(cfg)
    if cfg.is_encdec:  # decoder blocks carry cross attention
        p["ln_cross"] = layers.init_norm(cfg)
        p["cross"] = layers.init_attention(cfg, ks[2], cross=True)
    return p


def _init_block(cfg, key, encoder=False):
    keys = jax.random.split(key, len(cfg.layer_pattern))
    if encoder:
        # whisper encoder: plain non-causal attn + mlp, no cross
        import dataclasses
        enc_cfg = dataclasses.replace(cfg, is_encdec=False)
        return {"layers": [_init_layer(enc_cfg, k, "attn", i)
                           for i, k in enumerate(keys)]}
    return {"layers": [_init_layer(cfg, k, kind, i)
                       for i, (kind, k) in enumerate(zip(cfg.layer_pattern, keys))]}


def init_params(cfg, key, param_dtype=jnp.float32):
    k_embed, k_blocks, k_enc, k_head, k_front = jax.random.split(key, 5)
    params = {
        "embed": jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": layers.init_norm(cfg),
        "blocks": jax.vmap(lambda k: _init_block(cfg, k))(
            jax.random.split(k_blocks, cfg.n_blocks)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02
    if cfg.is_encdec:
        n_enc_blocks = cfg.n_enc_layers // len(cfg.layer_pattern)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_block(cfg, k, encoder=True))(
                jax.random.split(k_enc, n_enc_blocks))
        params["enc_norm"] = layers.init_norm(cfg)
    if cfg.frontend == "vision":
        params["projector"] = jax.random.normal(
            k_front, (VISION_EMBED_DIM, cfg.d_model), jnp.float32) * 0.02
    return jax.tree.map(lambda a: a.astype(param_dtype)
                        if a.dtype == jnp.float32 else a, params)


# --------------------------------------------------------------- layers ----


def _layer_fw(cfg, lp, x, positions, kind, pattern_idx, memory=None):
    """One layer, full sequence. Returns (x, aux, cache_entry)."""
    h = layers.norm(cfg, lp["ln1"], x)
    cache = {}
    if kind in ("attn", "local"):
        q, k, v = layers._qkv(cfg, lp["attn"], h, h)
        q = layers.apply_rope(cfg, q, positions)
        k = layers.apply_rope(cfg, k, positions)
        kp = positions if positions.ndim == 1 else positions[0]
        m = layers.causal_mask(cfg, kp, kp, kind)[None, None, None]
        mix = layers._sdpa(cfg, q, k, v, m) @ lp["attn"]["wo"].astype(x.dtype)
        win = cfg.sliding_window
        keep = min(x.shape[1], win) if (kind == "local" and win) else x.shape[1]
        cache = {"k": k[:, -keep:], "v": v[:, -keep:]}
    elif kind == "mamba":
        mix, state = ssm.mamba(cfg, lp["mamba"], h)
        cache = {"conv": state[0], "h": state[1]}
    elif kind == "rwkv":
        mix, state = rwkv.time_mix(cfg, lp["tmix"], h)
        cache = {"tm_x": state[0], "tm_s": state[1]}
    if cfg.post_norm:
        mix = layers.norm(cfg, lp["ln1_post"], mix)
    x = x + mix
    if memory is not None:  # cross attention (whisper decoder)
        h = layers.norm(cfg, lp["ln_cross"], x)
        q, ck, cv = layers._qkv(cfg, lp["cross"], h, memory)
        out = layers._sdpa(cfg, q, ck, cv, None)
        x = x + out @ lp["cross"]["wo"].astype(x.dtype)
        cache["ck"], cache["cv"] = ck, cv
    h = layers.norm(cfg, lp["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        out, cm_x = rwkv.channel_mix(cfg, lp["tmix"], h)
        cache["cm_x"] = cm_x
    elif cfg.moe_at(pattern_idx) and "moe" in lp:
        out, aux = moe.moe_ffn(cfg, lp["moe"], h)
    else:
        out = layers.mlp(cfg, lp["ffn"], h)
    if cfg.post_norm:
        out = layers.norm(cfg, lp["ln2_post"], out)
    return x + out, aux, cache


def _layer_decode(cfg, lp, x, bcache, pos, kind, pattern_idx):
    """One layer, single token with cache. Returns (x, new_cache_entry)."""
    h = layers.norm(cfg, lp["ln1"], x)
    new = {}
    if kind in ("attn", "local"):
        mix, ck, cv = layers.attention_decode(cfg, lp["attn"], h,
                                              bcache["k"], bcache["v"],
                                              pos, kind)
        new = {"k": ck, "v": cv}
    elif kind == "mamba":
        mix, st = ssm.mamba_decode(cfg, lp["mamba"], h,
                                   (bcache["conv"], bcache["h"]))
        new = {"conv": st[0], "h": st[1]}
    elif kind == "rwkv":
        mix, st = rwkv.time_mix(cfg, lp["tmix"], h,
                                state=(bcache["tm_x"], bcache["tm_s"]))
        new = {"tm_x": st[0], "tm_s": st[1]}
    if cfg.post_norm:
        mix = layers.norm(cfg, lp["ln1_post"], mix)
    x = x + mix
    if cfg.is_encdec:
        h = layers.norm(cfg, lp["ln_cross"], x)
        q, _, _ = layers._qkv(cfg, lp["cross"], h, h)
        out = layers._sdpa(cfg, q, bcache["ck"], bcache["cv"], None)
        x = x + out @ lp["cross"]["wo"].astype(x.dtype)
        new["ck"], new["cv"] = bcache["ck"], bcache["cv"]
    h = layers.norm(cfg, lp["ln2"], x)
    if kind == "rwkv":
        out, cm_x = rwkv.channel_mix(cfg, lp["tmix"], h, state=bcache["cm_x"])
        new["cm_x"] = cm_x
    elif cfg.moe_at(pattern_idx) and "moe" in lp:
        out, _ = moe.moe_ffn(cfg, lp["moe"], h)
    else:
        out = layers.mlp(cfg, lp["ffn"], h)
    if cfg.post_norm:
        out = layers.norm(cfg, lp["ln2_post"], out)
    return x + out, new


# -------------------------------------------------------------- forward ----


def _embed_inputs(cfg, params, batch):
    """Token (+frontend) embeddings -> (x, positions, n_prefix)."""
    emb = params["embed"]
    tokens = batch["tokens"]
    x = emb[tokens].astype(emb.dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    n_prefix = 0
    if cfg.frontend == "vision" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype) @ params["projector"]
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions, n_prefix


def _scan_blocks(cfg, blocks, x, positions, memory=None, remat=True,
                 return_cache=False, unroll=False):
    def block_fw(carry, bparams):
        x, aux = carry
        caches = []
        for i, kind in enumerate(cfg.layer_pattern):
            x, a, c = _layer_fw(cfg, bparams["layers"][i], x, positions,
                                kind, i, memory=memory)
            aux = aux + a
            caches.append(c)
        return (x, aux), (caches if return_cache else 0)

    fn = jax.checkpoint(block_fw) if remat else block_fw
    # unroll=True: used by the dry-run so cost_analysis sees every block
    # (XLA counts a while body once; see launch/dryrun.py).
    (x, aux), caches = lax.scan(fn, (x, jnp.zeros((), jnp.float32)), blocks,
                                unroll=cfg.n_blocks if unroll else 1)
    return x, aux, caches


def _encode(cfg, params, batch, remat=True, unroll=False):
    frames = batch["frames"]
    x = frames.astype(params["embed"].dtype)
    pos = layers.sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = x + pos[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    import dataclasses
    enc_cfg = dataclasses.replace(cfg, is_encdec=False)

    def block_fw(carry, bparams):
        x = carry
        for i in range(len(cfg.layer_pattern)):
            h = layers.norm(enc_cfg, bparams["layers"][i]["ln1"], x)
            mix = layers.attention(enc_cfg, bparams["layers"][i]["attn"], h,
                                   positions, "attn", causal=False)
            x = x + mix
            h = layers.norm(enc_cfg, bparams["layers"][i]["ln2"], x)
            x = x + layers.mlp(enc_cfg, bparams["layers"][i]["ffn"], h)
        return x, 0

    fn = jax.checkpoint(block_fw) if remat else block_fw
    nb = params["enc_blocks"]["layers"][0]["ln1"]["scale"].shape[0]
    x, _ = lax.scan(fn, x, params["enc_blocks"], unroll=nb if unroll else 1)
    return layers.norm(cfg, params["enc_norm"], x)


def forward(cfg, params, batch, remat=True, return_cache=False, unroll=False):
    """Full-sequence forward. Returns (x_final, aux, caches, n_prefix)."""
    memory = None
    if cfg.is_encdec:
        memory = _encode(cfg, params, batch, remat=remat, unroll=unroll)
    x, positions, n_prefix = _embed_inputs(cfg, params, batch)
    if cfg.is_encdec:
        pos_table = layers.sinusoid_positions(x.shape[1], cfg.d_model)
        x = x + pos_table.astype(x.dtype)[None]
    x, aux, caches = _scan_blocks(cfg, params["blocks"], x, positions,
                                  memory=memory, remat=remat,
                                  return_cache=return_cache, unroll=unroll)
    x = layers.norm(cfg, params["final_norm"], x)
    return x, aux, caches, n_prefix


def logits_from_hidden(cfg, params, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ----------------------------------------------------------------- loss ----


def loss_fn(cfg, params, batch, remat=True, chunk=1024, unroll=False):
    """Next-token CE (f32, logit-chunked over the sequence) + MoE aux."""
    x, aux, _, n_prefix = forward(cfg, params, batch, remat=remat,
                                  unroll=unroll)
    tokens = batch["tokens"]
    # hidden state at text position i predicts token i+1; the final
    # position is padded+masked so the chunk length stays a power of two.
    xs = x[:, n_prefix:]
    B, S, D = xs.shape
    tgt = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], 1)
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(tgt, bool) if mask is None else mask.astype(bool)
    mask = mask.at[:, -1].set(False)

    c = min(chunk, S)
    while S % c:
        c -= 1

    def chunk_loss(args):
        xc, tc, mc = args
        logits = logits_from_hidden(cfg, params, xc)
        lz = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((lz - ll) * mc), jnp.sum(mc)

    k = S // c
    xc = xs.reshape(B, k, c, D).swapaxes(0, 1)
    tc = tgt.reshape(B, k, c).swapaxes(0, 1)
    mc = mask.reshape(B, k, c).swapaxes(0, 1).astype(jnp.float32)
    fn = jax.checkpoint(chunk_loss) if remat else chunk_loss
    _, (sums, cnts) = lax.scan(lambda c, a: (c, fn(a)), None, (xc, tc, mc),
                               unroll=k if unroll else 1)
    loss = jnp.sum(sums) / jnp.maximum(jnp.sum(cnts), 1.0)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------- cache ----


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Zeroed per-block decode cache, leaves stacked (NB, ...)."""
    NB = cfg.n_blocks
    win = cfg.sliding_window
    entries = []
    for i, kind in enumerate(cfg.layer_pattern):
        if kind in ("attn", "local"):
            keep = min(seq_len, win) if (kind == "local" and win) else seq_len
            e = {"k": jnp.zeros((NB, batch, keep, cfg.n_kv_heads, cfg.head_dim), dtype),
                 "v": jnp.zeros((NB, batch, keep, cfg.n_kv_heads, cfg.head_dim), dtype)}
        elif kind == "mamba":
            e = {"conv": jnp.zeros((NB, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
                 "h": jnp.zeros((NB, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}
        elif kind == "rwkv":
            H = cfg.n_rwkv_heads
            hd = cfg.rwkv_head_dim
            e = {"tm_x": jnp.zeros((NB, batch, 1, cfg.d_model), dtype),
                 "tm_s": jnp.zeros((NB, batch, H, hd, hd), jnp.float32),
                 "cm_x": jnp.zeros((NB, batch, 1, cfg.d_model), dtype)}
        if cfg.is_encdec and kind in ("attn", "local"):
            e["ck"] = jnp.zeros((NB, batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype)
            e["cv"] = jnp.zeros((NB, batch, seq_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        entries.append(e)
    return tuple(entries)


def decode_step(cfg, params, cache, tokens, pos, unroll=False):
    """One decode step. tokens: (B, 1); pos: scalar absolute position.
    Returns (logits (B, 1, V) f32, new_cache)."""
    emb = params["embed"]
    x = emb[tokens].astype(emb.dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.is_encdec:
        i = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) if hasattr(pos, "astype") else float(pos)
        ang = ang / jnp.power(10000.0, 2 * i / cfg.d_model)
        pt = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pt.astype(x.dtype)[None, None]

    def body(x, xs):
        bparams, bcache = xs
        new = []
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc = _layer_decode(cfg, bparams["layers"][i], x,
                                  bcache[i], pos, kind, i)
            new.append(nc)
        return x, tuple(new)

    x, new_cache = lax.scan(body, x, (params["blocks"], cache),
                            unroll=cfg.n_blocks if unroll else 1)
    x = layers.norm(cfg, params["final_norm"], x)
    return logits_from_hidden(cfg, params, x), new_cache


def prefill(cfg, params, batch, cache_len: int | None = None,
            unroll=False):
    """Forward over a prompt, returning (last-position logits, cache)."""
    x, _, caches, n_prefix = forward(cfg, params, batch, remat=True,
                                     return_cache=True, unroll=unroll)
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    # scanned caches already carry the (NB, ...) leading axis
    cache = tuple(
        {k: v for k, v in entry.items()} for entry in caches
    ) if isinstance(caches, (list, tuple)) else caches
    return logits, cache
