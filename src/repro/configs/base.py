"""Architecture config schema + registry.

Every assigned architecture is a frozen ``ArchConfig`` (exact published
hyperparameters) plus a ``reduced()`` derivation used by CPU smoke tests.
The model graph is assembled from ``layer_pattern`` *superblocks*
(models/model.py): the pattern repeats ``n_layers / len(pattern)`` times and
is scanned over, so HLO size and compile time are independent of depth.

DBSCAN applicability (DESIGN.md §4): the paper's technique operates in the
data pipeline (embedding dedup — repro.data.dedup), not inside any model
graph; no per-arch variant exists, which is noted here once for all archs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention flavor
    layer_pattern: tuple = ("attn",)   # attn | local | mamba | rwkv
    rope_style: str = "neox"           # neox | glm_partial | none
    rope_theta: float = 1e4
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    # ffn
    mlp_style: str = "swiglu"          # swiglu | gelu_mlp | rwkv_cmix
    mlp_act: str = "silu"              # silu | gelu (gemma2 GeGLU)
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1                # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # ssm (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    # enc-dec
    is_encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: precomputed embeddings enter the backbone
    frontend: Optional[str] = None     # audio | vision | None
    n_frontend_tokens: int = 0         # e.g. llava anyres patch tokens
    # misc
    norm_style: str = "rmsnorm"        # rmsnorm | layernorm
    post_norm: bool = False            # gemma2 sandwich norms
    embed_scale: bool = False          # gemma scales embeddings by sqrt(d)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    notes: str = ""

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, self.name
        return self.n_layers // len(self.layer_pattern)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def moe_at(self, pattern_idx: int) -> bool:
        """Is the FFN at this pattern position an MoE layer?"""
        return self.n_experts > 0 and (pattern_idx % self.moe_period
                                       == self.moe_period - 1)

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the 500k-token decode cell (SSM / hybrid /
        all-windowed attention). Archs with *global* full-attention layers
        (and the enc-dec audio arch) are skipped per the assignment."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.is_encdec:
            return False
        kinds = set(self.layer_pattern)
        if "attn" in kinds:  # unwindowed global attention present
            return False
        return self.sliding_window is not None  # all-local (mixtral)

    def params_per_token_active(self) -> int:
        """~active params/token (MoE counts experts_per_token experts)."""
        return _count_params(self, active_only=True)

    def params_total(self) -> int:
        return _count_params(self, active_only=False)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = self.layer_pattern
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 * len(pat),
            d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
            d_ff=128, vocab_size=512,
            sliding_window=None if self.sliding_window is None else 16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            # drop-free capacity at smoke scale: capacity eviction is batch-
            # order dependent (standard MoE behaviour) and would make the
            # prefill<->decode and masking equalities only statistical
            capacity_factor=4.0,
            ssm_state=8, rwkv_head_dim=16, rwkv_decay_lora=8,
            n_enc_layers=2 if self.is_encdec else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
        )


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    d, f = cfg.d_model, cfg.d_ff
    per_layer = {}
    att = d * cfg.n_heads * cfg.head_dim * 2 + d * cfg.n_kv_heads * cfg.head_dim * 2
    per_layer["attn"] = att
    per_layer["local"] = att
    dn = cfg.d_inner
    per_layer["mamba"] = d * 2 * dn + dn * cfg.ssm_conv + \
        dn * (cfg.ssm_state * 2 + dn // 16) + dn * cfg.ssm_state + dn * d
    per_layer["rwkv"] = 6 * d * d + d * cfg.d_ff + cfg.d_ff * d
    total = 0
    n_blocks = cfg.n_layers // len(cfg.layer_pattern)
    for i, kind in enumerate(cfg.layer_pattern):
        total += per_layer[kind] * n_blocks
        if kind == "rwkv":
            continue  # rwkv_cmix counted in its entry
        if cfg.moe_at(i):
            e = cfg.experts_per_token if active_only else cfg.n_experts
            total += (3 * d * f) * e * n_blocks + d * cfg.n_experts * n_blocks
        else:
            mult = 3 if cfg.mlp_style == "swiglu" else 2
            total += mult * d * f * n_blocks
    if cfg.is_encdec:
        # encoder layers + cross attention
        total += cfg.n_enc_layers * (att + 2 * d * f)
        total += cfg.n_layers // len(cfg.layer_pattern) * att  # cross-attn
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return total


REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    from . import all_archs  # noqa: F401  (populate registry)
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def names() -> list[str]:
    from . import all_archs  # noqa: F401
    return sorted(REGISTRY)
