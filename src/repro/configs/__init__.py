from .base import ArchConfig, get, names, REGISTRY

__all__ = ["ArchConfig", "get", "names", "REGISTRY"]
