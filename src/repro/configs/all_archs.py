"""The 10 assigned architectures, exact published configurations.

Sources are noted per entry ([hf] = HuggingFace config.json, [arXiv] = paper).
DBSCAN applicability: the paper's technique lives in the data pipeline for
every one of these (DESIGN.md §4); none has an architecture-level variant.
"""
from .base import ArchConfig, register

# --- dense LMs ------------------------------------------------------------

QWEN15_4B = register(ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=5e6,
    notes="[hf:Qwen/Qwen1.5-4B] MHA (kv=20) with QKV bias, large vocab.",
))

CHATGLM3_6B = register(ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024,
    rope_style="glm_partial",  # 2d RoPE: interleaved pairs on half the dims
    qkv_bias=True,
    notes="[arXiv:2406.12793] extreme GQA (kv=2), partial interleaved RoPE.",
))

DEEPSEEK_7B = register(ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    notes="[arXiv:2401.02954] llama architecture, MHA.",
))

GEMMA2_2B = register(ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    layer_pattern=("local", "attn"),  # alternating sliding/global
    sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    mlp_style="swiglu", mlp_act="gelu",  # GeGLU
    post_norm=True, embed_scale=True, tie_embeddings=True,
    notes="[arXiv:2408.00118] local+global alternation, logit softcaps, "
          "sandwich norms, tied + scaled embeddings.",
))

# --- audio enc-dec ----------------------------------------------------------

WHISPER_BASE = register(ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    rope_style="none",  # sinusoidal absolute positions
    mlp_style="gelu_mlp", norm_style="layernorm", tie_embeddings=True,
    is_encdec=True, n_enc_layers=6, frontend="audio",
    notes="[arXiv:2212.04356] enc-dec; conv frontend is a STUB — "
          "input_specs() provides precomputed frame embeddings.",
))

# --- MoE -------------------------------------------------------------------

MIXTRAL_8X7B = register(ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    sliding_window=4096, layer_pattern=("local",),
    n_experts=8, experts_per_token=2,
    notes="[arXiv:2401.04088] 8 experts top-2, SWA 4096 on all layers.",
))

MOONSHOT_16B_A3B = register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840,
    n_experts=64, experts_per_token=6,
    notes="[hf:moonshotai/Moonlight-16B-A3B] fine-grained MoE: 64 small "
          "experts (d_ff=1408) top-6, ~3B active.",
))

# --- hybrid ----------------------------------------------------------------

JAMBA_52B = register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    # 1:7 attention:mamba, attention at position 4 of each 8-layer block
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    rope_style="none",  # Jamba uses no positional encoding in attn layers
    n_experts=16, experts_per_token=2, moe_period=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    notes="[arXiv:2403.19887] Mamba+attn 1:7 interleave, MoE every 2nd "
          "layer (16e top-2).",
))

# --- SSM / linear attention --------------------------------------------------

RWKV6_1B6 = register(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=7168, vocab_size=65536,
    layer_pattern=("rwkv",), rope_style="none",
    mlp_style="rwkv_cmix", norm_style="layernorm",
    rwkv_head_dim=64, rwkv_decay_lora=64,
    notes="[arXiv:2404.05892] Finch: attention-free, data-dependent decay "
          "(ddlerp token shift + decay LoRA), wkv head state 64x64.",
))

# --- VLM -------------------------------------------------------------------

LLAVA_NEXT_MISTRAL_7B = register(ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    frontend="vision", n_frontend_tokens=576,
    notes="[hf:llava-hf/llava-v1.6-mistral-7b-hf] Mistral-7B backbone; "
          "anyres tiling frontend is a STUB — input_specs() provides "
          "precomputed patch embeddings (projector is a trained param).",
))

ALL = [QWEN15_4B, CHATGLM3_6B, DEEPSEEK_7B, GEMMA2_2B, WHISPER_BASE,
       MIXTRAL_8X7B, MOONSHOT_16B_A3B, JAMBA_52B, RWKV6_1B6,
       LLAVA_NEXT_MISTRAL_7B]
