"""GPipe pipeline parallelism over the "pod" mesh axis.

The multi-pod mesh's leading axis is pure data-parallel by default; this
module gives it the alternative role: pipeline stages. The schedule is
classic GPipe — M microbatches flow through S stages in M + S - 1 ticks;
stage-to-stage activation transfer is a single ``lax.ppermute`` hop per
tick (nearest-neighbor on the pod interconnect), which overlaps with the
next tick's compute. Bubble fraction = (S-1)/(M+S-1), reported by
``gpipe_bubble``; EXPERIMENTS.md quotes it for the production shapes.

``gpipe`` is generic over a stage function so any superblock stack can be
cut into stages: stage parameters are sharded over the pipe axis (stage i's
params live only on its devices — the memory win of PP).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def gpipe_bubble(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe(stage_fn, mesh, axis: str = "pod"):
    """Build a pipelined apply: (stage_params, microbatches) -> outputs.

    stage_params: pytree with leading dim = n_stages (sharded over axis).
    microbatches: (n_micro, mb, ...) replicated input; outputs likewise.
    ``stage_fn(params_for_stage, x) -> y`` with x.shape == y.shape
    (equal-width stages, the standard GPipe constraint).
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def kernel(stage_params, mb):
        # shard_map gives each stage its own params slice (leading dim 1)
        params = jax.tree.map(lambda a: a[0], stage_params)
        me = lax.axis_index(axis)
        n_micro = mb.shape[0]
        ticks = n_micro + n_stages - 1
        from repro.distributed.sharding import vary as _vary
        vary = lambda x: _vary(x, axis)
        buf = vary(jnp.zeros(mb.shape[1:], mb.dtype))  # traveling activation
        outs = vary(jnp.zeros_like(mb))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others take the wire
            inject = mb[jnp.minimum(t, n_micro - 1)]
            x = jnp.where(me == 0, inject, buf)
            y = stage_fn(params, x)
            # last stage emits microbatch t - (S-1)
            out_idx = t - (n_stages - 1)
            emit = (me == n_stages - 1) & (out_idx >= 0)
            outs = lax.cond(
                emit,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            buf = lax.ppermute(y, axis, fwd_perm)
            return buf, outs

        _, outs = lax.fori_loop(0, ticks, tick, (buf, outs))
        # outputs live on the last stage; share them along the axis
        outs = lax.psum(jnp.where(me == n_stages - 1, outs, 0.0), axis)
        return outs

    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(kernel, mesh, in_specs=(P(axis), P()),
                            out_specs=P())
