from . import optimizer, step

__all__ = ["optimizer", "step"]
