"""In-house AdamW with f32 master weights (mixed-precision training).

Model params may live in bf16 (compute dtype); the optimizer carries f32
master weights and moments. With ZeRO-1 (distributed/sharding.py) the whole
optimizer state is additionally sharded over the data axis, so the f32
triplet never dominates per-chip memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: dict     # f32 copy of params
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      master=jax.tree.map(f32, params),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params, grads, opt: AdamWState, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0, clip_norm=1.0):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if clip_norm is not None:
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, clip_norm / (gn + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = opt.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt.v, grads)

    def upd(w, m_, v_):
        u = (m_ / b1c) / (jnp.sqrt(v_ / b2c) + eps)
        return w - lr * (u + weight_decay * w)

    master = jax.tree.map(upd, opt.master, m, v)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return new_params, AdamWState(step=step, master=master, m=m, v=v)
