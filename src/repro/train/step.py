"""Training / serving step factories.

``make_train_step`` builds the jittable step: microbatched gradient
accumulation via ``lax.scan`` (the per-microbatch backward overlaps with the
XLA-scheduled gradient reductions — the standard compute/comm overlap), f32
accumulation, optional simulated int8 gradient compression (the *transport*
demonstration with a real psum lives in repro.distributed.compression),
AdamW with master weights.

``make_serve_step`` / ``make_prefill_step`` wrap the cached decode paths.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model
from .optimizer import adamw_init, adamw_update  # noqa: F401 (re-export)


def quantize_int8(g):
    """Fake-quantize to int8 per-tensor scale (simulated compressed grads)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_loss(cfg, unroll=False):
    def loss(params, batch):
        return model.loss_fn(cfg, params, batch, unroll=unroll)
    return loss


def make_train_step(cfg, *, n_micro: int = 1, lr: float = 3e-4,
                    weight_decay: float = 0.1,
                    grad_compression: str | None = None, unroll: bool = False,
                    grad_shardings=None):
    """grad_shardings: optional pytree of NamedShardings (the ZeRO-1 layout)
    pinned onto the gradients before the optimizer — turns the data-axis
    gradient reduction into reduce-scatter + sharded optimizer math instead
    of all-reduce + replicated math (EXPERIMENTS.md §Perf iteration 5)."""
    loss = make_loss(cfg, unroll=unroll)

    def train_step(params, opt, batch):
        if n_micro == 1:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def micro(carry, b):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, b)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                   acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, lsum), _ = lax.scan(micro, (zeros, jnp.zeros(())), mb,
                                        unroll=n_micro if unroll else 1)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            l = lsum / n_micro
            metrics = {"ce": l, "aux": jnp.zeros(())}
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        if grad_compression == "int8":
            grads = jax.tree.map(quantize_int8, grads)
        params, opt = adamw_update(params, grads, opt, lr=lr,
                                   weight_decay=weight_decay)
        metrics = dict(metrics, loss=l, step=opt.step)
        return params, opt, metrics

    return train_step


def make_serve_step(cfg, unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(cfg, params, cache, tokens, pos,
                                          unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, next_tok
    return serve_step


def make_prefill_step(cfg, unroll: bool = False):
    def prefill_step(params, batch):
        logits, cache = model.prefill(cfg, params, batch, unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, next_tok
    return prefill_step
