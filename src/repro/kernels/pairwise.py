"""Pallas TPU tile kernels for the dense compute hot spot of DBSCAN.

The paper's DenseBox insight is that in dense regions most distance tests
are wasted. On a GPU the answer is to *skip* them (per-thread early exits,
linear cell scans). On a TPU, branches idle the MXU — the native move
(DESIGN.md §3) is to *batch* them: a 128x128 tile of squared distances

    d2[i, j] = |q_i|^2 + |r_j|^2 - 2 <q_i, r_j>

is one skinny MXU matmul plus VPU elementwise work, fully resident in VMEM.
The epilogues (neighbor counting for core-point determination; min-label
relaxation for the union-find hook) fuse into the same tile so the n x n
distance matrix is never materialized — the kernel streams over reference
tiles and keeps only O(TQ) accumulators, preserving the paper's
O(n)-memory on-the-fly property.

Kernels (each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py):
  * ``count_kernel``     — per query, # of reference points within eps
                           (saturating at a cap: the bulk analogue of the
                           paper's early exit at minpts).
  * ``minlabel_kernel``  — per query, min label over masked (core)
                           reference points within eps + matched count
                           (the fused hook of the main phase).

Grid layout: (n_q_tiles, n_r_tiles); the reference axis is the innermost
(sequential) dimension so output tiles are revisited and accumulated in
VMEM. Padding uses +inf coordinates (distances become +inf => never within
eps), so no validity masks are needed in the hot loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# Version shim: the pallas TPU surface renamed ``TPUMemorySpace`` ->
# ``MemorySpace`` and ``TPUCompilerParams`` -> ``CompilerParams``. Resolve
# whichever this jax ships so the kernels run on both sides of the rename.
# ---------------------------------------------------------------------------
_MEMORY_SPACE = getattr(pltpu, "MemorySpace", None) or getattr(
    pltpu, "TPUMemorySpace")
SMEM = _MEMORY_SPACE.SMEM
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

INT_MAX = jnp.iinfo(jnp.int32).max

# 128 matches both the MXU systolic dimension and the VPU lane count.
TILE_Q = 128
TILE_R = 128


def _tile_dist2(q, r):
    """(TQ, TR) squared distances via the MXU form."""
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # (TQ, 1)
    rn = jnp.sum(r * r, axis=-1, keepdims=True).T        # (1, TR)
    cross = jax.lax.dot_general(
        q, r, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # MXU: (TQ, TR)
    return qn + rn - 2.0 * cross


def count_kernel(q_ref, r_ref, eps2_ref, out_ref, *, cap: int):
    """out[i] (+)= saturating count of r within eps of q_i."""
    d2 = _tile_dist2(q_ref[...], r_ref[...])
    hits = jnp.sum((d2 <= eps2_ref[0, 0]).astype(jnp.int32), axis=1)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Saturate: the paper terminates traversal at minpts; here extra hits
    # saturate instead of branching (dense tiles beat branches on TPU).
    out_ref[...] = jnp.minimum(out_ref[...] + hits, cap)


def minlabel_kernel(q_ref, r_ref, lab_ref, mask_ref, eps2_ref,
                    out_lab_ref, out_cnt_ref):
    """Fused union-find hook tile: min core-neighbor label + matched count."""
    d2 = _tile_dist2(q_ref[...], r_ref[...])
    ok = (d2 <= eps2_ref[0, 0]) & (mask_ref[...][None, :] != 0)
    labs = jnp.where(ok, lab_ref[...][None, :], INT_MAX)
    tile_min = jnp.min(labs, axis=1)
    tile_cnt = jnp.sum(ok.astype(jnp.int32), axis=1)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_lab_ref[...] = jnp.full_like(out_lab_ref, INT_MAX)
        out_cnt_ref[...] = jnp.zeros_like(out_cnt_ref)

    out_lab_ref[...] = jnp.minimum(out_lab_ref[...], tile_min)
    out_cnt_ref[...] = out_cnt_ref[...] + tile_cnt


def _pad_to(x, mult, value):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=value)


@functools.partial(jax.jit, static_argnames=("cap", "tile_q", "tile_r",
                                             "interpret"))
def pairwise_count(points_q, points_r, eps, cap: int = INT_MAX,
                   tile_q: int = TILE_Q, tile_r: int = TILE_R,
                   interpret: bool = True):
    """Counts of reference points within eps per query (saturating at cap)."""
    nq = points_q.shape[0]
    q = _pad_to(points_q.astype(jnp.float32), tile_q, 1e30)
    r = _pad_to(points_r.astype(jnp.float32), tile_r, -1e30)
    eps2 = jnp.full((1, 1), eps * eps, jnp.float32)
    grid = (q.shape[0] // tile_q, r.shape[0] // tile_r)
    d = q.shape[1]
    out = pl.pallas_call(
        functools.partial(count_kernel, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_r, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=SMEM),
        ],
        out_specs=pl.BlockSpec((tile_q,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0],), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, r, eps2)
    return out[:nq]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_r", "interpret"))
def pairwise_minlabel(points_q, points_r, labels_r, mask_r, eps,
                      tile_q: int = TILE_Q, tile_r: int = TILE_R,
                      interpret: bool = True):
    """(min masked label within eps, matched count) per query point."""
    nq = points_q.shape[0]
    q = _pad_to(points_q.astype(jnp.float32), tile_q, 1e30)
    r = _pad_to(points_r.astype(jnp.float32), tile_r, -1e30)
    lab = _pad_to(labels_r.astype(jnp.int32), tile_r, INT_MAX)
    mask = _pad_to(mask_r.astype(jnp.int32), tile_r, 0)
    eps2 = jnp.full((1, 1), eps * eps, jnp.float32)
    grid = (q.shape[0] // tile_q, r.shape[0] // tile_r)
    d = q.shape[1]
    out_lab, out_cnt = pl.pallas_call(
        minlabel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_r, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_r,), lambda i, j: (j,)),
            pl.BlockSpec((tile_r,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=SMEM),
        ],
        out_specs=[
            pl.BlockSpec((tile_q,), lambda i, j: (i,)),
            pl.BlockSpec((tile_q,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((q.shape[0],), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, r, lab, mask, eps2)
    return out_lab[:nq], out_cnt[:nq]
