"""Pallas TPU kernels for the DBSCAN compute hot spots (+ jnp oracles)."""
from .pairwise import pairwise_count, pairwise_minlabel
from .ops import dbscan_tiled
from . import ref

__all__ = ["pairwise_count", "pairwise_minlabel", "dbscan_tiled", "ref"]
