"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


def pairwise_count_ref(points_q, points_r, eps, cap: int = INT_MAX):
    q = points_q.astype(jnp.float32)
    r = points_r.astype(jnp.float32)
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    cnt = jnp.sum(d2 <= eps * eps, axis=1).astype(jnp.int32)
    return jnp.minimum(cnt, cap)


def pairwise_minlabel_ref(points_q, points_r, labels_r, mask_r, eps):
    q = points_q.astype(jnp.float32)
    r = points_r.astype(jnp.float32)
    d2 = jnp.sum((q[:, None, :] - r[None, :, :]) ** 2, -1)
    ok = (d2 <= eps * eps) & mask_r.astype(bool)[None, :]
    labs = jnp.where(ok, labels_r.astype(jnp.int32)[None, :], INT_MAX)
    return jnp.min(labs, axis=1), jnp.sum(ok, axis=1).astype(jnp.int32)
