"""Pallas traversal kernel: the rope-based BVH walk as a lane-tiled kernel.

The engine in ``repro.core.traversal`` lowers the walk through a vmapped
``lax.while_loop`` — correct, but generic: XLA owns the memory placement
and the loop overhead. This module maps the same walk onto the hardware
the way the paper's ArborX kernels do on CUDA (DESIGN.md §9):

  * **lane tiling** — predicate lanes are tiled into blocks of
    ``LANE_TILE`` queries; the grid iterates over lane blocks the way a
    CUDA launch iterates over warps. Per-lane walk state (node cursor,
    member pointer, visitor carry, work counters) is a handful of
    ``(LANE_TILE,)`` vectors — the TPU analogue of the paper's O(1)
    per-thread state.
  * **index residency** — node AABBs, ropes, child links, and the segment
    tables ride in as whole-array VMEM block specs (``index_map`` pinned
    to block 0), so every box test and rope chase is a fast-memory gather;
    the engine's HBM-resident gathers become VMEM reads.
  * **inlined visitors** — the three hot DBSCAN callbacks
    (``CountVisitor``, ``MinLabelVisitor``, ``CountMinLabelVisitor``) are
    reconstructed *inside* the kernel from their array leaves and traced
    straight into the walk body: no callback dispatch survives lowering.
    Arbitrary user visitors (and ``nearest``/k-NN predicates) fall back to
    the interpreter-path engine — same semantics, generic lowering.
  * **K-unrolled dead-guarded walk** — each while-loop trip runs
    ``unroll`` work units per lane with every state select masked by the
    lane's liveness, exactly the reference engine's trip shape
    (DESIGN.md §4, §9 on why this is divergence-free in a lane-tiled
    kernel).

Bit-identity is by construction, not by luck: the kernel body calls the
*same* ``traversal.make_step`` the vmapped engine uses, so both trace the
identical op sequence over identical float32 arithmetic —
``tests/test_golden.py`` pins ``backend="pallas-tree"`` byte-equal to the
reference backends. The per-lane ``evals``/``iters`` work counters are
threaded out as kernel outputs so ``benchmarks/run.py --check`` gates the
kernel's traversal work exactly like the engine's.

On CPU (and GPU, where the TPU compiler params do not apply) the kernel
runs in Pallas interpret mode — the CI path that keeps the kernel body
exercised on every commit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import morton, traversal
from repro.core.grid import Segments
from repro.core.lbvh import Tree
from .pairwise import SMEM, CompilerParams

INT_MAX = traversal.INT_MAX

# Queries per kernel block. 128 matches the VPU lane count; each block's
# walk state is a few (128,) vectors, and a block retires when its slowest
# lane finishes (the warp-convergence analogue — see DESIGN.md §9).
LANE_TILE = 128

# The kernel is always lane-tiled, so the lockstep amortization argument
# of DESIGN.md §4 applies on every backend (the engine only defaults to 4
# on TPU/GPU because its *vmapped* loop is cheap on CPU).
PALLAS_UNROLL = 4

#: Visitor types whose hooks the kernel inlines; anything else falls back
#: to the interpreter-path engine.
FUSIBLE_VISITORS = (traversal.CountVisitor, traversal.MinLabelVisitor,
                    traversal.CountMinLabelVisitor)


class _Cfg(NamedTuple):
    """Static kernel specialization (part of the jit cache key)."""
    kind: str               # "count" | "minlabel" | "countminlabel"
    unroll: int
    use_range_mask: bool
    has_node_mask: bool
    dual_nodes: bool        # node_mask_wide present
    dual_gather: bool       # MinLabelVisitor.mask_wide present
    reorder: bool = False   # lane permutation by sort key (DESIGN.md §9)


def fusible(predicates, callback) -> bool:
    """Can this (predicate, callback) pair run as the Pallas kernel?

    True for ``intersects`` batches driving one of the three hot DBSCAN
    visitors (:data:`FUSIBLE_VISITORS`). ``nearest`` predicates and custom
    visitors are not fusible — :func:`traverse` transparently falls back
    to the interpreter-path engine for them.
    """
    return (isinstance(predicates, traversal.Intersects)
            and type(callback) in FUSIBLE_VISITORS)


def _walk_kernel(*refs, cfg: _Cfg):
    """The kernel body: one lane tile's full rope walk to quiescence."""
    it = iter(refs)
    # ---- lane-tiled state inputs -------------------------------------
    q = next(it)[...]
    qid = next(it)[...]
    self_id = next(it)[...]
    dense = next(it)[...] != 0
    rank = next(it)[...]
    wide = next(it)[...] != 0
    acc0 = next(it)[...]
    hits0 = next(it)[...]
    # ---- VMEM-resident index (whole-array block specs) ---------------
    pts = next(it)[...]
    seg_start = next(it)[...]
    seg_end = next(it)[...]
    dense_seg = next(it)[...] != 0
    left = next(it)[...]
    miss = next(it)[...]
    range_r = next(it)[...] if cfg.use_range_mask else None
    box_lo = next(it)[...]
    box_hi = next(it)[...]
    node_mask = (next(it)[...] != 0) if cfg.has_node_mask else None
    node_mask_wide = (next(it)[...] != 0) if cfg.dual_nodes else None
    if cfg.kind != "count":
        vals = next(it)[...]
        mask = next(it)[...] != 0
        mask_wide = (next(it)[...] != 0) if cfg.dual_gather else None
    # ---- scalars (SMEM) ----------------------------------------------
    r2 = next(it)[0, 0]
    cap = next(it)[0, 0]
    # ---- outputs ------------------------------------------------------
    acc_out, hits_out, evals_out, iters_out = refs[-4:]

    n_nodes = miss.shape[0]
    # Reassemble the index views the shared step closes over. Fields the
    # walk never touches stay None (the step only reads left/miss/range_r/
    # boxes and pts/seg_start/seg_end/dense_seg — see traversal.make_step).
    tree = Tree(left=left, right=None, parent=None, miss=miss,
                range_r=range_r if cfg.use_range_mask
                else jnp.zeros(n_nodes, jnp.int32),
                box_lo=box_lo, box_hi=box_hi)
    segs = Segments(pts=pts, order=None, seg_start=seg_start,
                    seg_end=seg_end, seg_of_point=None, dense_seg=dense_seg,
                    dense_pt=None, codes=None, prim_lo=None, prim_hi=None)
    # Inline the visitor: rebuild it from the kernel-resident leaves so
    # its visit/done/segment_done hooks trace into the walk body.
    if cfg.kind == "count":
        callback = traversal.CountVisitor(cap=cap)
    elif cfg.kind == "minlabel":
        callback = traversal.MinLabelVisitor(
            vals, mask, mask_wide if cfg.dual_gather else None)
    else:
        callback = traversal.CountMinLabelVisitor(vals, mask, cap=cap)

    ctx = traversal.QueryCtx(self_id=self_id, dense=dense, rank=rank,
                             wide=wide)
    step, live_of = traversal.make_step(
        tree, segs, callback, q=q, ctx=ctx, lane_wide=wide, r2=r2,
        is_nearest=False, node_mask=node_mask,
        node_mask_wide=node_mask_wide, use_range_mask=cfg.use_range_mask)

    lane_on = qid >= 0
    node0 = jnp.where(lane_on, jnp.int32(0), jnp.int32(-1))  # root = 0
    ptr0 = jnp.full_like(qid, -1)
    zeros = jnp.zeros_like(qid)
    carry0 = traversal.AccHits(acc=acc0, hits=hits0)

    def cond(state):
        node, ptr, carry, evals, iters = state
        return jnp.any(live_of(node, carry))

    def body(state):
        node, ptr, carry, evals, iters = state
        trip_live = live_of(node, carry)
        inner = (node, ptr, carry, evals)
        for _ in range(cfg.unroll):
            inner = step(inner)
        node, ptr, carry, evals = inner
        # per-lane trip counter: only lanes live at trip start advance,
        # so iters matches the vmapped engine's per-lane loop-trip count
        return (node, ptr, carry, evals,
                iters + jnp.where(trip_live, 1, 0))

    node, ptr, carry, evals, iters = lax.while_loop(
        cond, body, (node0, ptr0, carry0, zeros, zeros))
    acc_out[...] = carry.acc
    hits_out[...] = carry.hits
    evals_out[...] = evals
    iters_out[...] = iters


@functools.partial(jax.jit,
                   static_argnames=("cfg", "lane_tile", "interpret"))
def _run(cfg: _Cfg, lane_tile: int, interpret: bool,
         q, qid, self_id, dense, rank, wide, acc0, hits0, sort_key,
         pts, seg_start, seg_end, dense_seg, left, miss, range_r,
         box_lo, box_hi, node_mask, node_mask_wide, vals, mask, mask_wide,
         r2, cap):
    """Pad the lane axis, assemble block specs, and launch the kernel."""
    L = qid.shape[0]
    if cfg.reorder:
        # Permute lanes by sort_key so each tile walks correlated
        # subtrees; dead lanes carry the max key, packing them into
        # all-dead tiles that retire immediately. argsort is stable, so
        # equal keys keep lane order; the inverse permutation below makes
        # every per-lane output bit-identical to the unpermuted launch
        # (per-lane state never crosses lanes — DESIGN.md §9).
        perm = jnp.argsort(sort_key)
        inv = jnp.argsort(perm)
        q, qid, self_id, dense, rank, wide, acc0, hits0 = (
            x[perm] for x in (q, qid, self_id, dense, rank, wide,
                              acc0, hits0))
    Lp = -(-L // lane_tile) * lane_tile
    d = pts.shape[1]

    def pad(x, value):
        if x.shape[0] == Lp:
            return x
        width = ((0, Lp - x.shape[0]),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, width, constant_values=value)

    lane_inputs = [
        (pad(q, 0), pl.BlockSpec((lane_tile, d), lambda i: (i, 0))),
        (pad(qid, -1), None),           # -1: padding lanes are inert
        (pad(self_id, -1), None),
        (pad(dense.astype(jnp.int32), 0), None),
        (pad(rank, 0), None),
        (pad(wide.astype(jnp.int32), 0), None),
        (pad(acc0, 0), None),
        (pad(hits0, 0), None),
    ]
    lane_spec = pl.BlockSpec((lane_tile,), lambda i: (i,))

    def whole(x):
        """Whole-array VMEM residency: every block maps to block 0."""
        nd = x.ndim
        return pl.BlockSpec(x.shape, lambda i, _nd=nd: (0,) * _nd)

    full_inputs = [pts, seg_start, seg_end, dense_seg.astype(jnp.int32),
                   left, miss]
    if cfg.use_range_mask:
        full_inputs.append(range_r)
    full_inputs += [box_lo, box_hi]
    if cfg.has_node_mask:
        full_inputs.append(node_mask.astype(jnp.int32))
    if cfg.dual_nodes:
        full_inputs.append(node_mask_wide.astype(jnp.int32))
    if cfg.kind != "count":
        full_inputs.append(vals)
        full_inputs.append(mask.astype(jnp.int32))
        if cfg.dual_gather:
            full_inputs.append(mask_wide.astype(jnp.int32))

    scalar_inputs = [jnp.full((1, 1), r2, pts.dtype),
                     jnp.full((1, 1), cap, jnp.int32)]
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=SMEM)

    operands = ([x for x, _ in lane_inputs] + full_inputs + scalar_inputs)
    in_specs = ([spec or lane_spec for _, spec in lane_inputs]
                + [whole(x) for x in full_inputs]
                + [scalar_spec] * 2)
    # acc inherits the carry's dtype (MinLabelVisitor gathers whatever
    # dtype its vals are); hits/evals/iters are engine-owned int32
    out_shape = ([jax.ShapeDtypeStruct((Lp,), acc0.dtype)]
                 + [jax.ShapeDtypeStruct((Lp,), jnp.int32)] * 3)
    out_specs = [lane_spec] * 4

    acc, hits, evals, iters = pl.pallas_call(
        functools.partial(_walk_kernel, cfg=cfg),
        grid=(Lp // lane_tile,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*operands)
    outs = (acc[:L], hits[:L], evals[:L], iters[:L])
    if cfg.reorder:
        outs = tuple(x[inv] for x in outs)
    return outs


def traverse(tree: Tree, segs: Segments, predicates, callback, carry=None,
             node_mask=None, node_mask_wide=None, wide_lanes=None,
             use_range_mask: bool = False, unroll: int | None = None,
             lane_tile: int = LANE_TILE,
             interpret: bool | None = None, reorder: str = "none",
             depth_rank=None) -> traversal.Trace:
    """Drop-in Pallas replacement for :func:`repro.core.traversal.traverse`.

    Runs the rope-based BVH walk as a lane-tiled Pallas kernel when the
    (predicate, callback) pair is fusible (:func:`fusible`); anything else
    — ``nearest`` predicates, custom visitors, or an index too small to
    carry a tree — falls back to the interpreter-path engine with
    identical semantics.

    Args:
        tree: the LBVH over ``segs`` (``None`` falls back to the engine).
        segs: the segment index the tree was built over.
        predicates: an ``intersects``/``nearest`` batch (see
            ``repro.core.traversal``).
        callback: a :class:`~repro.core.traversal.Visitor`.
        carry: optional initial accumulator (chained multi-tree queries);
            ``None`` asks the callback's ``init_carry``.
        node_mask / node_mask_wide / wide_lanes: descent pruning and the
            split first sweep, exactly as in the reference engine.
        use_range_mask: the paper's "hide leaves j < i" subtree mask.
        unroll: work units per while-loop trip (default
            :data:`PALLAS_UNROLL`; the engine's backend-adaptive default
            does not apply — the kernel is always lane-tiled).
        lane_tile: queries per kernel block (default :data:`LANE_TILE`).
        interpret: force Pallas interpret mode; default auto — compiled
            on TPU, interpreted elsewhere (the CPU CI path).
        reorder: lane-permutation policy — ``"none"`` (default, today's
            launch order), ``"morton"`` (sort lanes by the query points'
            Morton code so a tile walks correlated subtrees), or
            ``"depth"`` (sort by descending ``depth_rank``, the measured
            per-query walk depth from a prior pass — the strongest
            divergence remedy; falls back to Morton for external batches
            and to identity when no rank is available). Results are
            bit-identical for every policy: per-lane walk state never
            crosses lanes, and the inverse permutation is applied to all
            per-lane outputs on exit (see :func:`repro.core.traversal.\
lane_sort_key` and DESIGN.md §9).
        depth_rank: optional ``(n_points,)`` int32 of per-query walk
            depth (e.g. ``Trace.iters`` from the fused first pass),
            indexed by sorted point id; used only by ``reorder="depth"``.

    Returns:
        A :class:`~repro.core.traversal.Trace` whose ``carry`` is an
        ``AccHits`` pytree and whose ``evals``/``iters`` are the kernel's
        per-lane work counters — bit-identical ``acc``/``hits``/``evals``
        to the reference engine on the same inputs.
    """
    if (tree is None or segs.n_segments < 2
            or not fusible(predicates, callback)):
        return traversal.traverse(
            tree, segs, predicates, callback, carry=carry,
            node_mask=node_mask, node_mask_wide=node_mask_wide,
            wide_lanes=wide_lanes, use_range_mask=use_range_mask,
            unroll=(traversal.DEFAULT_UNROLL if unroll is None
                    else unroll))
    if unroll is None:
        unroll = PALLAS_UNROLL
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    (query_ids, q_arr, self_arr, dense_arr, rank_arr, external, r2,
     _) = traversal.lane_arrays(segs, predicates, use_range_mask)
    if carry is None:
        carry = callback.init_carry(query_ids, external, segs)
    if wide_lanes is None:
        wide_lanes = jnp.zeros_like(query_ids, dtype=bool)

    kind = {traversal.CountVisitor: "count",
            traversal.MinLabelVisitor: "minlabel",
            traversal.CountMinLabelVisitor: "countminlabel"}[type(callback)]
    dual_gather = (kind == "minlabel"
                   and callback.mask_wide is not None)
    sort_key = traversal.lane_sort_key(reorder, query_ids, q_arr, external,
                                       depth_rank)
    cfg = _Cfg(kind=kind, unroll=int(unroll),
               use_range_mask=bool(use_range_mask),
               has_node_mask=node_mask is not None,
               dual_nodes=node_mask_wide is not None,
               dual_gather=dual_gather,
               reorder=sort_key is not None)

    cap = getattr(callback, "cap", INT_MAX)
    vals = getattr(callback, "vals", None)
    mask = getattr(callback, "mask", None)
    mask_wide = callback.mask_wide if dual_gather else None

    # Launch accounting (DESIGN.md §12): only outside jit tracing — the
    # pallas walk may also run nested inside a jitted first pass, where a
    # host-side counter bump would fire at trace time, not per run.
    from repro.obs import metrics as obs_metrics
    if (obs_metrics.active() is not None
            and not isinstance(segs.pts, jax.core.Tracer)):
        obs_metrics.inc("pallas_kernel_launches_total", kind=kind)
        obs_metrics.inc("pallas_kernel_lanes_total",
                        float(q_arr.shape[0]), kind=kind)

    acc, hits, evals, iters = _run(
        cfg, int(lane_tile), bool(interpret),
        q_arr, query_ids, self_arr, dense_arr, rank_arr, wide_lanes,
        carry.acc, carry.hits, sort_key,
        segs.pts, segs.seg_start, segs.seg_end, segs.dense_seg,
        tree.left, tree.miss, tree.range_r if cfg.use_range_mask else None,
        tree.box_lo, tree.box_hi, node_mask, node_mask_wide,
        vals, mask, mask_wide, r2, cap)
    return traversal.Trace(carry=traversal.AccHits(acc=acc, hits=hits),
                           evals=evals, iters=iters)
