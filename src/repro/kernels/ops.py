"""jit'd wrappers over the Pallas tile kernels + the tiled DBSCAN backend.

``dbscan_tiled`` is the TPU-native dense backend (DESIGN.md §3): the whole
two-phase PDSDBSCAN framework of the paper, but with neighbor determination
done by streaming MXU distance tiles instead of a tree walk. It is the
backend of choice when points/chip is small enough that n^2/chips tiles are
cheaper than divergent traversal (and it is what the distributed ring
version in repro.distributed.ring_dbscan runs per step). Memory stays O(n):
tiles live in VMEM only.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .pairwise import INT_MAX, pairwise_count, pairwise_minlabel
from . import ref as kernel_ref  # noqa: F401  (re-exported for benchmarks)


@partial(jax.jit, static_argnames=("min_pts", "interpret", "tile"))
def _tiled_phases(pts, eps, min_pts: int, interpret: bool, tile: int):
    n = pts.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    # -- preprocessing: early-exit (saturating) neighbor count ------------
    counts = pairwise_count(pts, pts, eps, cap=min_pts,
                            tile_q=tile, tile_r=tile, interpret=interpret)
    core = counts >= min_pts

    # -- main phase: fused hook tiles + pointer jumping to fixpoint -------
    labels0 = jnp.where(core, idx, INT_MAX)

    def cond(state):
        return state[1]

    def body(state):
        labels, _ = state
        gathered, _ = pairwise_minlabel(pts, pts, jnp.where(core, labels, INT_MAX),
                                        core, eps, tile_q=tile, tile_r=tile,
                                        interpret=interpret)
        new = jnp.where(core, jnp.minimum(labels, gathered), labels)
        safe = jnp.where(core, new, idx)
        compressed = lax.while_loop(lambda l: jnp.any(l != l[l]),
                                    lambda l: l[l], safe)
        new = jnp.where(core, compressed, labels)
        return (new, jnp.any(new != labels))

    labels, _ = lax.while_loop(cond, body, (labels0, jnp.bool_(True)))

    # -- borders ----------------------------------------------------------
    blab, bcnt = pairwise_minlabel(pts, pts, jnp.where(core, labels, INT_MAX),
                                   core, eps, tile_q=tile, tile_r=tile,
                                   interpret=interpret)
    labels = jnp.where(core, labels, blab)
    return jnp.where(labels == INT_MAX, jnp.int32(-1), labels), core


def dbscan_tiled(points, eps: float, min_pts: int, *, star: bool = False,
                 interpret: bool = True, tile: int = 128):
    """Full DBSCAN on MXU distance tiles (labels compacted, noise = -1).

    Unlike the paper's GPU preprocessing skip for minpts == 2, the tiled
    backend keeps the uniform count pass: a saturating count over dense
    tiles costs the same as the main sweep and keeps all lanes uniform.
    star=True implements DBSCAN* (non-core points become noise).
    """
    from repro.core.fdbscan import DBSCANResult, _finalize
    pts = jnp.asarray(points, jnp.float32)
    n = pts.shape[0]
    labels_rep, core = _tiled_phases(pts, eps, min_pts, interpret, tile)
    if star:
        labels_rep = jnp.where(core, labels_rep, jnp.int32(-1))
    labels, n_clusters = _finalize(labels_rep, jnp.arange(n, dtype=jnp.int32), n)
    return DBSCANResult(labels=labels, core_mask=core,
                        n_clusters=n_clusters, n_sweeps=-1,
                        n_traversals=0, backend="tiled")
