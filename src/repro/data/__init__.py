from . import pointclouds

__all__ = ["pointclouds"]
