"""DBSCAN-powered near-duplicate filtering for the LM data pipeline.

This is the paper's technique as a first-class framework feature
(DESIGN.md §4): each training batch's documents are embedded into 3-D
(lm_data.doc_embedding — low-dimensional by construction, the paper's
target regime), clustered with FDBSCAN-DenseBox, and each duplicate
cluster is thinned to ``keep_per_cluster`` representatives. Noise points
(unique documents) always survive. On-device, O(n) memory, and fast enough
to sit inline in the input pipeline; the distributed variant swaps in
ring_dbscan over the data axis.
"""
from __future__ import annotations

import numpy as np

from repro.core import dbscan
from .lm_data import doc_embedding


def dedup_indices(tokens: np.ndarray, *, eps: float = 0.15,
                  min_pts: int = 2, keep_per_cluster: int = 1,
                  embed_dim: int = 3, seed: int = 0,
                  algorithm: str = "fdbscan-densebox") -> np.ndarray:
    """Indices of documents to KEEP (stable order)."""
    emb = doc_embedding(tokens, dim=embed_dim, seed=seed)
    res = dbscan(emb, eps, min_pts, algorithm=algorithm)
    labels = np.asarray(res.labels)
    keep = np.zeros(len(labels), bool)
    keep[labels == -1] = True                       # unique docs survive
    for c in range(res.n_clusters):
        members = np.nonzero(labels == c)[0]
        keep[members[:keep_per_cluster]] = True
    return np.nonzero(keep)[0]


def dedup_batch(batch: dict, pad_to: int | None = None, **kw) -> dict:
    """Filter a batch dict (leading dim = documents); optionally re-pad by
    cycling survivors so downstream shapes stay static."""
    idx = dedup_indices(batch["tokens"], **kw)
    if pad_to is not None:
        idx = np.resize(idx, pad_to)
    return {k: v[idx] for k, v in batch.items()}, idx
