"""Surrogate point-cloud generators matching the paper's dataset regimes.

The paper's exact datasets (NGSIM trajectories, PortoTaxi, 3D Road, HACC
cosmology) are not redistributable in this offline container; these
generators produce statistically analogous surrogates with matched density
regimes. The benchmark harness accepts real files when present
(``--data path.npy``).

* ``trajectories_2d``  — NGSIM-like: a few extremely dense lane strips
  (>95% of points fall into dense cells, the regime where DenseBox wins).
* ``road_network_2d``  — 3D-Road-like: sparse polyline graph with noise.
* ``taxi_2d``          — PortoTaxi-like: heavy-tailed urban blob mixture.
* ``halos_3d``         — HACC-like: NFW-ish halos over a uniform background,
  sparse and evenly spread (the regime where plain FDBSCAN wins at high
  minpts — paper Fig. 6).
* ``blobs``            — generic Gaussian mixture for unit tests.
"""
from __future__ import annotations

import numpy as np


def blobs(n: int, d: int = 2, k: int = 5, spread: float = 0.03,
          seed: int = 0, noise_frac: float = 0.1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.0, 1.0, size=(k, d))
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    which = rng.integers(0, k, size=n_sig)
    pts = centers[which] + rng.normal(0.0, spread, size=(n_sig, d))
    noise = rng.uniform(-0.2, 1.2, size=(n_noise, d))
    return np.concatenate([pts, noise]).astype(np.float32)


def trajectories_2d(n: int, n_lanes: int = 6, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    per = n // n_lanes
    out = []
    for lane in range(n_lanes):
        t = rng.uniform(0, 1, size=(per,))
        base = np.stack([t, 0.05 * np.sin(6.28 * t + lane) + lane * 0.02], -1)
        out.append(base + rng.normal(0, 5e-4, size=base.shape))
    rest = n - per * n_lanes
    if rest:
        out.append(rng.uniform(0, 1, size=(rest, 2)) * [1.0, 0.15])
    return np.concatenate(out).astype(np.float32)


def road_network_2d(n: int, n_roads: int = 40, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    nodes = rng.uniform(0, 1, size=(n_roads + 1, 2))
    out = []
    per = n // n_roads
    for r in range(n_roads):
        a, b = nodes[r], nodes[(r + rng.integers(1, n_roads)) % n_roads]
        t = np.sort(rng.uniform(0, 1, size=(per,)))[:, None]
        seg = a * (1 - t) + b * t
        out.append(seg + rng.normal(0, 2e-3, size=seg.shape))
    rest = n - per * n_roads
    if rest:
        out.append(rng.uniform(0, 1, size=(rest, 2)))
    return np.concatenate(out).astype(np.float32)


def taxi_2d(n: int, k: int = 30, seed: int = 2) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 1, size=(k, 2))
    weights = rng.pareto(1.5, size=k) + 0.1
    weights /= weights.sum()
    which = rng.choice(k, size=n, p=weights)
    scales = rng.uniform(0.002, 0.05, size=k)
    pts = centers[which] + rng.normal(size=(n, 2)) * scales[which, None]
    return pts.astype(np.float32)


def halos_3d(n: int, n_halos: int = 50, background_frac: float = 0.5,
             seed: int = 3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n_bg = int(n * background_frac)
    n_h = n - n_bg
    centers = rng.uniform(0, 1, size=(n_halos, 3))
    mass = rng.pareto(1.2, size=n_halos) + 0.05
    mass /= mass.sum()
    which = rng.choice(n_halos, size=n_h, p=mass)
    # NFW-ish: radius ~ r^{-1} density falloff via inverse-CDF sampling
    u = rng.uniform(1e-4, 1, size=n_h)
    r = 0.02 * np.sqrt(u)
    direction = rng.normal(size=(n_h, 3))
    direction /= np.linalg.norm(direction, axis=1, keepdims=True)
    pts = centers[which] + direction * r[:, None]
    bg = rng.uniform(0, 1, size=(n_bg, 3))
    return np.concatenate([pts, bg]).astype(np.float32)


DATASETS = {
    "ngsim_like": trajectories_2d,
    "portotaxi_like": taxi_2d,
    "road3d_like": road_network_2d,
    "hacc_like": halos_3d,
    "blobs": blobs,
}


def load(name: str, n: int, seed: int = 0) -> np.ndarray:
    if name.endswith(".npy"):
        pts = np.load(name)[:n]
        return np.asarray(pts, np.float32)
    return DATASETS[name](n, seed=seed)
