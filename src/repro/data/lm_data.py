"""Synthetic LM data pipeline with controllable near-duplicate structure.

The stream is a mixture of (a) fresh zipfian token documents and (b) noisy
copies of a small template pool — the near-duplicate regime that embedding
dedup (dedup.py, via the paper's DBSCAN) is built to clean. Deterministic
per (seed, step): a restart resumes the exact stream position, which the
fault-tolerance test relies on.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 dup_frac: float = 0.3, n_templates: int = 8):
        self.vocab = vocab_size
        self.seq = seq_len
        self.seed = seed
        self.dup_frac = dup_frac
        tr = np.random.default_rng(seed ^ 0xD5A1)
        # low-entropy templates: repeated motifs make them learnable & dense
        motifs = tr.integers(1, min(vocab_size, 512), size=(n_templates, 16))
        reps = self.seq // 16 + 1
        self.templates = np.tile(motifs, (1, reps))[:, :seq_len]

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        is_dup = rng.random(batch_size) < self.dup_frac
        toks = np.empty((batch_size, self.seq), np.int32)
        # zipfian fresh docs
        fresh = rng.zipf(1.3, size=(batch_size, self.seq)) % self.vocab
        toks[:] = fresh
        # noisy template copies
        which = rng.integers(0, len(self.templates), size=batch_size)
        noise = rng.random((batch_size, self.seq)) < 0.005
        dup_tok = self.templates[which]
        dup_tok = np.where(noise, fresh, dup_tok)
        toks[is_dup] = dup_tok[is_dup]
        return {"tokens": toks, "is_dup": is_dup}


def doc_embedding(tokens: np.ndarray, dim: int = 3, seed: int = 0) -> np.ndarray:
    """Cheap content embedding: random-projected bigram histogram sketch.

    Parameter-free (no model in the loop) and low-dimensional by
    construction — exactly the regime the paper's tree algorithms target
    (DESIGN.md §4). Near-duplicate documents land within a tight eps ball.
    """
    B, S = tokens.shape
    h = (tokens[:, :-1].astype(np.int64) * 1000003 + tokens[:, 1:]) % 4096
    # drop bigrams touching the zipf head ("stopwords"): they correlate all
    # fresh documents and would swamp the near-duplicate signal
    keep = (tokens[:, :-1] >= 16) & (tokens[:, 1:] >= 16)
    hist = np.zeros((B, 4096), np.float32)
    rows = np.repeat(np.arange(B), S - 1)
    np.add.at(hist, (rows, h.reshape(-1)), keep.reshape(-1).astype(np.float32))
    hist /= np.linalg.norm(hist, axis=1, keepdims=True) + 1e-9
    proj = np.random.default_rng(seed).normal(
        size=(4096, dim)).astype(np.float32) / np.sqrt(dim)
    return hist @ proj
