"""Validate emitted observability artifacts against their schemas.

    PYTHONPATH=src python -m repro.obs.validate \\
        --metrics m.json --trace t.json [--require-span NAME ...]

Exit 0 iff every named file parses and validates (metrics snapshots
against ``metrics.SCHEMA``, traces against the Chrome trace-event form
``trace.TRACE_SCHEMA``) and every ``--require-span`` name appears in the
trace.  This is what the CI ``obs`` job runs over the artifacts a traced
serve/dbscan run emits.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import metrics, trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="metrics snapshot JSON to validate")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME",
                    help="fail unless the trace contains a span NAME "
                    "(repeatable)")
    ap.add_argument("--require-metric", action="append", default=[],
                    metavar="NAME",
                    help="fail unless the snapshot contains metric NAME "
                    "with at least one series (repeatable)")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("nothing to validate: pass --metrics and/or --trace")

    failures = []
    if args.metrics:
        try:
            with open(args.metrics) as f:
                doc = json.load(f)
            metrics.validate_snapshot(doc)
            names = {m["name"]: m for m in doc["metrics"]}
            for want in args.require_metric:
                if want not in names or not names[want]["series"]:
                    raise ValueError(f"required metric {want!r} absent "
                                     "or empty")
            print(f"[obs] {args.metrics}: valid snapshot, "
                  f"{len(names)} metrics")
        except (OSError, ValueError, KeyError, TypeError) as e:
            failures.append(f"{args.metrics}: {e}")
    if args.trace:
        try:
            with open(args.trace) as f:
                doc = json.load(f)
            trace.validate_chrome_trace(doc)
            spans = {ev["name"] for ev in doc["traceEvents"]}
            for want in args.require_span:
                if want not in spans:
                    raise ValueError(f"required span {want!r} absent "
                                     f"(trace has {sorted(spans)})")
            print(f"[obs] {args.trace}: valid Chrome trace, "
                  f"{len(doc['traceEvents'])} events, "
                  f"{len(spans)} distinct spans")
        except (OSError, ValueError, KeyError, TypeError) as e:
            failures.append(f"{args.trace}: {e}")

    for msg in failures:
        print(f"[obs] INVALID: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
