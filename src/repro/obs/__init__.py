"""Unified observability layer (DESIGN.md §12): metrics + tracing + the
``jax.profiler`` shim, zero dependencies, disabled by default.

  * :mod:`repro.obs.metrics` — named counters, gauges, and bounded-memory
    quantile histograms with labeled families and a stable JSON snapshot
    schema (``metrics.SCHEMA``).
  * :mod:`repro.obs.trace` — nestable phase spans with device-sync-aware
    timing, exported as Chrome trace-event JSON (loads in Perfetto /
    ``chrome://tracing``), plus a ``jax.profiler.TraceAnnotation`` shim.
  * :func:`instrumented` — install both for a scoped block and restore
    the previous collectors afterwards (what the tests and benchmarks
    use).

Until a collector is installed every instrumentation point in the
library is a module-global load + ``None`` check — jitted code paths are
untouched and results are bit-identical either way (the golden
observer-effect tests pin this).

    from repro.obs import metrics, trace
    reg = metrics.install()
    tr = trace.install(sync=True)
    ... run dbscan / a streaming handle / the serving loop ...
    reg.write_json("metrics.json")
    tr.export("trace.json")          # open in chrome://tracing
"""
from __future__ import annotations

from contextlib import contextmanager

from . import metrics, trace

__all__ = ["metrics", "trace", "instrumented"]


@contextmanager
def instrumented(*, sync: bool = True, annotate: bool = True):
    """Install a fresh registry + tracer for the enclosed block, yielding
    ``(registry, tracer)``; the previously installed collectors (possibly
    None) are restored on exit."""
    prev_reg, prev_tr = metrics.active(), trace.active()
    reg = metrics.install()
    tr = trace.install(sync=sync, annotate=annotate)
    try:
        yield reg, tr
    finally:
        metrics.install(prev_reg) if prev_reg is not None \
            else metrics.uninstall()
        trace.install(prev_tr) if prev_tr is not None else trace.uninstall()
