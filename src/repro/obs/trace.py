"""Span tracer — nestable phase spans exported as Chrome trace-event JSON
(DESIGN.md §12).

``span("build")`` / ``span("sweep", i=k)`` bracket *host-side* calls:
the jitted programs underneath are opaque to the tracer by design (a
span entered inside a ``jit`` trace would fire at trace time, not run
time, and poison the cache — see the observer-effect contract).  Spans
nest via a per-thread stack and serialize as Chrome trace-event
*complete* events (``"ph": "X"``), so ``export(path)`` produces a file
that loads directly in Perfetto / ``chrome://tracing``.

Device-sync semantics: JAX dispatch is asynchronous, so a span that only
measures the Python call would report dispatch cost, not compute cost.
A span can therefore *watch* values (``sp.watch(arrays)`` or the
module-level :func:`watch`); in ``sync=True`` mode (the default) the
span close runs ``jax.block_until_ready`` over everything watched before
taking the end timestamp, and the event is explicitly marked
(``args["sync"] == "blocked"``) so the observer cost is visible in the
trace rather than silently attributed.  ``sync=False`` is the production
mode: watches are recorded as ``"none"`` and nothing ever blocks.

``jax.profiler`` shim (the paxml ``cuda_profile_hook`` shape): with
``annotate=True`` every span also enters a
``jax.profiler.TraceAnnotation``, so when a JAX profiler capture is
active (e.g. under :func:`profiler_session`) the same phase names appear
on the profiler timeline; without an active capture the annotation is a
cheap no-op, and on builds without the profiler it degrades gracefully.

Disabled-by-default: with no tracer installed, :func:`span` returns a
shared no-op context manager — one module-global load per call site.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager

# Version tag of the exported document; carried in the trace metadata.
TRACE_SCHEMA = "repro.obs.trace/v1"

# Event-buffer cap: tracing is for runs a human inspects, not a flight
# recorder — past the cap new events are dropped and counted.
MAX_EVENTS = 200_000


class Span:
    """One phase bracket; use via ``with trace.span(name, **attrs):``."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_watched", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._watched: list = []
        self._ann = None

    def watch(self, *values) -> None:
        """Register values to ``block_until_ready`` at span close (sync
        mode); in no-sync mode the values are simply dropped."""
        if self._tracer.sync:
            self._watched.extend(values)

    def __enter__(self) -> "Span":
        self._tracer._stack().append(self)
        if self._tracer.annotate:
            self._ann = _enter_annotation(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        synced = False
        if self._watched:
            try:
                import jax
                jax.block_until_ready(jax.tree.leaves(self._watched))
                synced = True
            except Exception:
                pass
        t1 = time.perf_counter()
        if self._ann is not None:
            try:
                self._ann.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self.name, self._t0, t1, self.attrs, synced)


class _NoopSpan:
    """The shared disabled-path span: every method is a no-op."""

    __slots__ = ()

    def watch(self, *values) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _NoopSpan()


class Tracer:
    """Collects span events; ``export(path)`` writes Chrome trace JSON.

    sync: block on watched device values at span close (timing covers
        the compute, observer cost is explicit); False never blocks.
    annotate: mirror spans into ``jax.profiler.TraceAnnotation`` so an
        active profiler capture shows the same phase names.
    """

    def __init__(self, sync: bool = True, annotate: bool = True,
                 max_events: int = MAX_EVENTS):
        self.sync = bool(sync)
        self.annotate = bool(annotate)
        self.max_events = int(max_events)
        self.events: list[dict] = []
        self.n_dropped = 0
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def _record(self, name: str, t0: float, t1: float, attrs: dict,
                synced: bool) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.n_dropped += 1
                return
            args = {k: _jsonable(v) for k, v in attrs.items()}
            args["sync"] = "blocked" if synced else "none"
            self.events.append({
                "name": name, "ph": "X", "cat": "repro",
                "ts": (t0 - self._epoch) * 1e6,
                "dur": (t1 - t0) * 1e6,
                "pid": os.getpid(), "tid": threading.get_ident() % 2**31,
                "args": args,
            })

    def to_dict(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA,
                          "sync": "blocked" if self.sync else "none",
                          "dropped_events": self.n_dropped},
        }

    def export(self, path: str) -> dict:
        doc = self.to_dict()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)           # 0-d numpy / jax scalars
    except Exception:
        return str(v)


# ---------------------------------------------------------------------- #
# the installed tracer (module-global; None = tracing off)               #
# ---------------------------------------------------------------------- #

_active: Tracer | None = None


def install(tracer: Tracer | None = None, *, sync: bool = True,
            annotate: bool = True) -> Tracer:
    """Install ``tracer`` (or a fresh ``Tracer(sync=, annotate=)``) as the
    process-wide span collector and return it."""
    global _active
    _active = tracer if tracer is not None else Tracer(sync=sync,
                                                       annotate=annotate)
    return _active


def uninstall() -> None:
    global _active
    _active = None


def active() -> Tracer | None:
    """The installed tracer, or None when tracing is off."""
    return _active


def span(name: str, **attrs):
    """A span context manager on the installed tracer, or the shared
    no-op when tracing is off (the disabled fast path)."""
    t = _active
    if t is None:
        return _NOOP
    return t.span(name, **attrs)


def watch(*values) -> None:
    """Register values on the innermost open span of this thread for
    device sync at span close.  No-op when tracing is off, when the
    tracer is in no-sync mode, or outside any span."""
    t = _active
    if t is None or not t.sync:
        return
    stack = t._stack()
    if stack:
        stack[-1].watch(*values)


# ---------------------------------------------------------------------- #
# jax.profiler shim                                                      #
# ---------------------------------------------------------------------- #

def _enter_annotation(name: str):
    """Enter a ``jax.profiler.TraceAnnotation(name)`` if available; the
    annotation is visible only while a profiler capture is active."""
    try:
        from jax import profiler
        ann = profiler.TraceAnnotation(name)
        ann.__enter__()
        return ann
    except Exception:
        return None


@contextmanager
def profiler_session(log_dir: str):
    """Bracket a region with a JAX profiler capture (the
    ``cuda_profile_hook`` shape: arm the vendor profiler around exactly
    the region of interest).  Yields True when a capture actually
    started; degrades to a no-op (yielding False) on builds without
    profiler support, so call sites never need to gate on it."""
    started = False
    try:
        import jax
        jax.profiler.start_trace(log_dir)
        started = True
    except Exception:
        pass
    try:
        yield started
    finally:
        if started:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass


# ---------------------------------------------------------------------- #
# trace validation (CI gates artifacts through this)                     #
# ---------------------------------------------------------------------- #

def validate_chrome_trace(doc: dict) -> None:
    """Raise ValueError unless ``doc`` is a loadable Chrome trace-event
    document of ours (JSON-object form with complete events)."""
    if not isinstance(doc, dict):
        raise ValueError(f"trace must be a dict; got {type(doc).__name__}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace 'traceEvents' must be a list")
    if doc.get("otherData", {}).get("schema") != TRACE_SCHEMA:
        raise ValueError(f"trace schema "
                         f"{doc.get('otherData', {}).get('schema')!r} "
                         f"!= {TRACE_SCHEMA!r}")
    for ev in events:
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event missing {k!r}: {ev}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"complete event needs dur >= 0: {ev}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event ts must be a non-negative number: {ev}")
