"""Canonical metric names for the serving subsystem (DESIGN.md §12, §13).

One place to spell them, so the server, the CLI, the benchmarks, and the
dashboards cannot drift apart.  All names follow the registry's
conventions (``*_total`` counters, ``*_seconds`` latency histograms,
bare nouns for gauges) and export cleanly in the ``repro.obs/v1``
snapshot schema.

Label sets (by convention; the registry enforces per-family consistency):

  * ``SERVE_REQUESTS`` / ``SERVE_REQUEST_SECONDS`` — ``kind``
    ("query" | "insert"), ``tenant``;
  * ``SERVE_SHED`` — ``kind``, ``reason`` ("requests" | "points" |
    "inserts");
  * ``SERVE_FLUSHES`` — ``reason`` ("full" | "deadline" | "drain");
  * ``SERVE_SNAPSHOT_PUBLISHES`` / ``SERVE_SNAPSHOT_VERSION`` /
    ``SERVE_TENANT_ACTIVE_POINTS`` — ``tenant`` (emitted by
    ``TenantView.publish``, the one publisher that knows the tenant; a
    bare ``SnapshotStore`` emits nothing).
"""
from __future__ import annotations

# ---- request plane ---------------------------------------------------- #
SERVE_REQUESTS = "serve_requests_total"
SERVE_REQUEST_SECONDS = "serve_request_seconds"
SERVE_SHED = "serve_shed_total"
SERVE_FLUSHES = "serve_flushes_total"
SERVE_BATCH_PROBES = "serve_batch_probes"
SERVE_QUEUE_DEPTH = "serve_queue_depth"             # gauge; kind label

# ---- snapshot plane --------------------------------------------------- #
SERVE_SNAPSHOT_PUBLISHES = "serve_snapshot_publishes_total"
SERVE_SNAPSHOT_VERSION = "serve_snapshot_version"   # gauge
SERVE_SNAPSHOT_QUERIES = "serve_snapshot_queries_total"
SERVE_SNAPSHOT_EXACT_PROBES = "serve_snapshot_exact_probes_total"
SERVE_APPLY_FAILURES = "serve_apply_failures_total"
SERVE_TENANT_ACTIVE_POINTS = "serve_tenant_active_points"   # gauge

# ---- streaming handle (satellite: recompile accounting) ---------------- #
# Incremented once per *new* (mode, level shape, probe bucket) program
# signature seen by StreamingDBSCAN's query path; flat at steady state —
# the witness that probe-batch padding keeps the jit cache warm.
STREAM_QUERY_RECOMPILES = "stream_query_recompiles_total"

ALL = tuple(v for k, v in sorted(globals().items())
            if k.isupper() and isinstance(v, str))
