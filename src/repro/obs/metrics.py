"""Metrics registry — named counters, gauges, and bounded-memory quantile
histograms with labeled families (DESIGN.md §12).

The registry is the sink every instrumented path reports into: the
traversal engine's ``evals``/``iters`` work counters, fdbscan's sweep
counts, the streaming index's merge/compaction/repair counters, WAL
fsync and checkpoint durations, and the serving loop's latency and
drop/reject accounting.  Three metric kinds:

  * :class:`Counter` — monotone float, ``inc(v)``;
  * :class:`Gauge`   — last-write-wins float, ``set(v)``;
  * :class:`Histogram` — quantile sketch over observations.  Buckets are
    log-spaced (DDSketch-style: bucket ``i`` covers ``(gamma^(i-1),
    gamma^i]`` with ``gamma = (1+a)/(1-a)``), so p50/p95/p99 come out
    with bounded *relative* error ``a`` (default 1%) from a sparse dict
    whose size is bounded by the dynamic range of the data — never by
    the sample count.  This is what replaced the serving loop's
    unbounded all-time latency lists.

Every metric is a *family* keyed by label values (``backend=``,
``scenario=``, ``phase=`` ...); label names are fixed at first use.

Disabled-by-default contract: the module-level helpers (:func:`inc`,
:func:`set_gauge`, :func:`observe`) check one module global and return
immediately when no registry is installed — an instrumentation point in
a hot host loop costs a dict-attribute load and a ``None`` check.
Nothing here ever runs inside ``jax.jit``; callers only report host-side
values (see DESIGN.md §12 for the observer-effect contract).

Zero dependencies beyond the standard library.
"""
from __future__ import annotations

import json
import math
import threading

# Version tag of the snapshot document layout. Bump only with a schema
# migration note in DESIGN.md §12; tests pin the format against it.
SCHEMA = "repro.obs/v1"

KINDS = ("counter", "gauge", "histogram")

# Histogram sketch parameters: 1% relative accuracy; the bucket dict is
# hard-capped (lowest buckets collapse first) as a belt-and-braces bound
# — realistic latency/work ranges use a few hundred buckets at most.
REL_ACCURACY = 0.01
MAX_BUCKETS = 4096


class Counter:
    """Monotone counter. ``inc`` rejects negative increments."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0; got {v}")
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-memory quantile sketch (log buckets, relative accuracy).

    ``observe(v)`` is O(1); ``quantile(q)`` walks the sparse bucket dict.
    Non-positive observations land in a dedicated zero bucket (durations
    and sizes — the intended inputs — are never negative).  Memory is
    O(#distinct buckets), bounded by the data's dynamic range and capped
    at ``MAX_BUCKETS``, independent of ``count``.
    """

    __slots__ = ("count", "sum", "min", "max", "_zero", "_buckets",
                 "_log_gamma", "_gamma")

    def __init__(self, rel_accuracy: float = REL_ACCURACY):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._zero = 0                      # observations <= 0
        self._buckets: dict[int, int] = {}
        self._gamma = (1.0 + rel_accuracy) / (1.0 - rel_accuracy)
        self._log_gamma = math.log(self._gamma)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._zero += 1
            return
        i = math.ceil(math.log(v) / self._log_gamma)
        self._buckets[i] = self._buckets.get(i, 0) + 1
        if len(self._buckets) > MAX_BUCKETS:        # collapse the lowest
            lo = sorted(self._buckets)[:2]
            self._buckets[lo[1]] += self._buckets.pop(lo[0])

    def bucket_count(self) -> int:
        """Number of live sketch buckets (the memory-flatness witness)."""
        return len(self._buckets)

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]); NaN on no observations."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1]; got {q}")
        if self.count == 0:
            return math.nan
        rank = q * (self.count - 1)
        if rank < self._zero:
            return 0.0
        seen = self._zero
        for i in sorted(self._buckets):
            seen += self._buckets[i]
            if rank < seen:
                # bucket i covers (gamma^(i-1), gamma^i]; midpoint estimate
                return 2.0 * self._gamma ** i / (self._gamma + 1.0)
        return self.max


class _Family:
    """One named metric: a dict of children keyed by label values."""

    __slots__ = ("name", "kind", "help", "label_names", "_children")

    _MAKE = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self, name: str, kind: str, help: str,
                 label_names: tuple[str, ...]):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = tuple(sorted(label_names))
        self._children: dict[tuple, object] = {}

    def labels(self, **kv):
        """The child metric for these label values (created on first use).

        Label *names* must match the family's fixed set exactly — a typo'd
        label would otherwise silently fork a parallel series.
        """
        if tuple(sorted(kv)) != self.label_names:
            raise ValueError(
                f"metric {self.name!r} has labels {self.label_names}; "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[k]) for k in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._MAKE[self.kind]()
        return child


class Registry:
    """A collection of metric families with a stable JSON snapshot.

    ``counter``/``gauge``/``histogram`` fetch-or-create a family; re-
    requesting a name with a different kind or label set raises (one name
    means one thing for the registry's whole lifetime).  ``snapshot()``
    renders the deterministic document :func:`validate_snapshot` pins —
    families sorted by name, series sorted by label values, histograms
    summarized as count/sum/min/max/p50/p95/p99 (the sketch itself is an
    implementation detail and never serialized).
    """

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help: str,
                labels: tuple[str, ...]) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, kind, help,
                                                     labels)
            elif fam.kind != kind:
                raise ValueError(f"metric {name!r} is a {fam.kind}, "
                                 f"requested as {kind}")
            elif fam.label_names != tuple(sorted(labels)):
                raise ValueError(
                    f"metric {name!r} has labels {fam.label_names}; "
                    f"requested {tuple(sorted(labels))}")
            if help and not fam.help:
                fam.help = help
            return fam

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> _Family:
        return self._family(name, "counter", help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> _Family:
        return self._family(name, "gauge", help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = ()) -> _Family:
        return self._family(name, "histogram", help, tuple(labels))

    def get(self, name: str, **kv):
        """The child metric for ``name``/labels, or None if absent (read
        path for stats reporting; never creates)."""
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam._children.get(
            tuple(str(kv[k]) for k in fam.label_names) if fam.label_names
            else ())

    def snapshot(self) -> dict:
        """The stable, deterministic JSON-ready document (SCHEMA)."""
        metrics = []
        for name in sorted(self._families):
            fam = self._families[name]
            series = []
            for key in sorted(fam._children):
                child = fam._children[key]
                entry: dict = {"labels": dict(zip(fam.label_names, key))}
                if fam.kind == "histogram":
                    entry.update(
                        count=child.count,
                        sum=child.sum,
                        min=child.min if child.count else None,
                        max=child.max if child.count else None,
                        p50=_finite(child.quantile(0.50)),
                        p95=_finite(child.quantile(0.95)),
                        p99=_finite(child.quantile(0.99)))
                else:
                    entry["value"] = child.value
                series.append(entry)
            metrics.append({"name": name, "kind": fam.kind,
                            "help": fam.help,
                            "label_names": list(fam.label_names),
                            "series": series})
        return {"schema": SCHEMA, "metrics": metrics}

    def write_json(self, path: str) -> dict:
        doc = self.snapshot()
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        return doc


def _finite(v: float):
    return None if math.isnan(v) else v


# ---------------------------------------------------------------------- #
# the installed collector (module-global; None = instrumentation off)    #
# ---------------------------------------------------------------------- #

_active: Registry | None = None


def install(registry: Registry | None = None) -> Registry:
    """Install ``registry`` (or a fresh one) as the process-wide collector
    and return it.  Returns the *previous* state to the caller's care:
    use the value of :func:`active` beforehand to restore it."""
    global _active
    _active = registry if registry is not None else Registry()
    return _active


def uninstall() -> None:
    """Remove the collector: every instrumentation point returns to the
    dict-load + None-check no-op fast path."""
    global _active
    _active = None


def active() -> Registry | None:
    """The installed registry, or None when instrumentation is off."""
    return _active


def inc(name: str, value: float = 1.0, **labels) -> None:
    """Increment counter ``name`` (no-op when no registry is installed)."""
    reg = _active
    if reg is None:
        return
    reg.counter(name, labels=tuple(labels)).labels(**labels).inc(value)


def set_gauge(name: str, value: float, **labels) -> None:
    """Set gauge ``name`` (no-op when no registry is installed)."""
    reg = _active
    if reg is None:
        return
    reg.gauge(name, labels=tuple(labels)).labels(**labels).set(value)


def observe(name: str, value: float, **labels) -> None:
    """Record ``value`` into histogram ``name`` (no-op when disabled)."""
    reg = _active
    if reg is None:
        return
    reg.histogram(name, labels=tuple(labels)).labels(**labels).observe(value)


# ---------------------------------------------------------------------- #
# snapshot validation (CI gates artifacts through this)                  #
# ---------------------------------------------------------------------- #

def validate_snapshot(doc: dict) -> None:
    """Raise ValueError unless ``doc`` is a well-formed SCHEMA snapshot."""
    if not isinstance(doc, dict):
        raise ValueError(f"snapshot must be a dict; got {type(doc).__name__}")
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"snapshot schema {doc.get('schema')!r} != {SCHEMA!r}")
    metrics = doc.get("metrics")
    if not isinstance(metrics, list):
        raise ValueError("snapshot 'metrics' must be a list")
    seen = set()
    for m in metrics:
        name = m.get("name")
        if not isinstance(name, str) or not name:
            raise ValueError(f"metric name must be a non-empty str; got {m}")
        if name in seen:
            raise ValueError(f"duplicate metric {name!r}")
        seen.add(name)
        if m.get("kind") not in KINDS:
            raise ValueError(f"{name}: kind {m.get('kind')!r} not in {KINDS}")
        label_names = m.get("label_names")
        if not isinstance(label_names, list):
            raise ValueError(f"{name}: label_names must be a list")
        for s in m.get("series", ()):
            labels = s.get("labels")
            if not isinstance(labels, dict) or \
                    sorted(labels) != sorted(label_names):
                raise ValueError(f"{name}: series labels {labels!r} do not "
                                 f"match label_names {label_names}")
            if m["kind"] == "histogram":
                for k in ("count", "sum", "p50", "p95", "p99"):
                    if k not in s:
                        raise ValueError(f"{name}: histogram series missing "
                                         f"{k!r}")
                if s["count"] < 0:
                    raise ValueError(f"{name}: negative count")
            else:
                if "value" not in s:
                    raise ValueError(f"{name}: series missing 'value'")
