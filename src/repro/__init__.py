"""repro: tree-based DBSCAN (FDBSCAN / FDBSCAN-DenseBox) for TPU pods.

JAX + Pallas reproduction and extension of Prokopenko, Lebrun-Grandie,
Arndt: "Fast tree-based algorithms for DBSCAN for low-dimensional data
on GPUs" (2021): LBVH-fused traversal backends (including a lane-tiled
Pallas traversal kernel), multi-device sharding, and a streaming index,
behind one auto-dispatching entry point. See README.md and docs/api.md.

Stable public surface — everything an application needs lives here:

  * :func:`dbscan`        — clustering with automatic backend selection
                            (tree walk, MXU tiles, sharded multi-device,
                            or a one-shot streaming snapshot);
  * :func:`plan`          — backend decision + cached index build, for
                            amortizing eps/min_pts parameter sweeps;
  * :func:`stream_handle` — an online insert/query/snapshot handle over
                            the same cached index;
  * :mod:`neighbors`      — fixed-radius counts, k-nearest-neighbor
                            queries, and raw visitor traversals over the
                            shared tree index;
  * :class:`DBSCANResult` — the result record every backend returns.

Deeper layers (``repro.core.traversal``'s predicate/callback engine,
``repro.distributed``, ``repro.stream``) stay importable for power users;
see DESIGN.md.
"""
from .core import DBSCANResult, dbscan, plan, stream_handle
from .core import neighbors

__all__ = ["DBSCANResult", "dbscan", "plan", "stream_handle", "neighbors",
           "__version__"]

__version__ = "1.1.0"
