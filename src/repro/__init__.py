"""repro: tree-based DBSCAN (FDBSCAN / FDBSCAN-DenseBox) for TPU pods.

JAX + Pallas reproduction and extension of Prokopenko, Lebrun-Grandie,
Arndt: "Fast tree-based algorithms for DBSCAN for low-dimensional data on
GPUs" (2021), embedded in a multi-pod training/serving framework.
"""
__version__ = "1.0.0"
