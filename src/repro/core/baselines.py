"""Baselines the paper compares against, plus the ground-truth oracle.

* ``dbscan_bruteforce_np`` — textbook Ester et al. BFS DBSCAN in NumPy.
  Slow and obviously correct: the oracle for every property test.
* ``gdbscan`` — G-DBSCAN [Andrade et al. 2013] re-expressed in JAX: it
  *materializes the full adjacency* (the O(E) memory behaviour the paper
  criticizes — [32] measured 166x CUDA-DClust's footprint) and then runs a
  level-synchronous BFS. We reproduce it with a dense adjacency matrix, so
  its memory is Theta(n^2) bits regardless of eps — the memory benchmark
  (benchmarks/bench_memory.py) contrasts this against FDBSCAN's O(n).
* ``dbscan_tiled`` lives in repro.kernels.ops — the MXU tile backend.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .fdbscan import DBSCANResult
from .validate import neighbor_counts


def dbscan_bruteforce_np(points, eps: float, min_pts: int):
    """Oracle DBSCAN (labels, core_mask); labels compacted, noise = -1.

    Core determination shares the blocked tiles of ``validate`` (O(n*block)
    memory, float64-exact); the BFS recomputes one adjacency row per pop —
    the oracle stays obviously correct yet never holds the n x n matrix.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    e2 = eps * eps
    core = neighbor_counts(pts, eps) >= min_pts
    sq = (pts * pts).sum(-1)

    def row_adj(x):
        # same Gram form as validate.adjacency_blocks: one oracle, one
        # notion of adjacency
        return sq + sq[x] - 2.0 * (pts @ pts[x]) <= e2

    labels = np.full(n, -1, np.int64)
    cid = 0
    for s in range(n):
        if not core[s] or labels[s] != -1:
            continue
        stack = [s]
        labels[s] = cid
        while stack:
            x = stack.pop()
            if not core[x]:
                continue  # border: absorbed but does not expand
            for y in np.nonzero(row_adj(x))[0]:
                if labels[y] == -1:
                    labels[y] = cid
                    if core[y]:
                        stack.append(y)
        cid += 1
    return labels, core


@jax.jit
def _gdbscan_jit(pts, eps, min_pts):
    n = pts.shape[0]
    d2 = jnp.sum((pts[:, None, :] - pts[None, :, :]) ** 2, -1)
    adj = d2 <= eps * eps                       # the materialized graph
    core = jnp.sum(adj, 1) >= min_pts
    cc_adj = adj & core[:, None] & core[None, :]

    # Level-synchronous BFS from all sources at once == iterative min-label
    # frontier expansion over the core-core graph.
    labels = jnp.where(core, jnp.arange(n, dtype=jnp.int32), jnp.int32(n))

    def cond(state):
        labels, changed = state
        return changed

    def body(state):
        labels, _ = state
        relaxed = jnp.min(jnp.where(cc_adj, labels[None, :], n), axis=1)
        new = jnp.where(core, jnp.minimum(labels, relaxed), labels)
        return new, jnp.any(new != labels)

    labels, _ = jax.lax.while_loop(cond, body, (labels, jnp.bool_(True)))

    # borders: min core-neighbor label
    bl = jnp.min(jnp.where(adj & core[None, :], labels[None, :], n), axis=1)
    labels = jnp.where(core, labels, jnp.where(bl < n, bl, -1))
    return labels, core


def gdbscan(points, eps: float, min_pts: int) -> DBSCANResult:
    pts = jnp.asarray(points)
    labels, core = _gdbscan_jit(pts, eps, min_pts)
    labels = np.asarray(labels)
    uniq = {}
    out = np.full(labels.shape, -1, np.int32)
    for i, l in enumerate(labels):
        if l >= 0:
            out[i] = uniq.setdefault(int(l), len(uniq))
    return DBSCANResult(labels=jnp.asarray(out), core_mask=core,
                        n_clusters=len(uniq), n_sweeps=0)
