"""ε-grid binning and mixed primitives (FDBSCAN-DenseBox, paper §4.2).

The paper superimposes a regular grid with cell edge ``eps/sqrt(d)`` so the
cell diameter is <= eps: every cell holding >= minpts points is *dense* — all
its points are core points of the same cluster, and intra-cell distance
computations are eliminated entirely. Dense cells become box primitives mixed
with the remaining loose points in the *same* BVH.

Our unification (DESIGN.md §3): every BVH primitive is a *segment* — a
contiguous run ``[seg_start, seg_end)`` of the cell-sorted point array. A
dense cell is a multi-point segment; every loose point is a singleton
segment. Plain FDBSCAN is the degenerate case where all segments are
singletons in Morton order. One traversal engine serves both algorithms.

Grid resolution is capped at 2**16 cells/dim (2D) or 2**10 (3D) so cell
coordinates interleave into uint32 Morton keys. If the cap shrinks cells
below the requested eps/sqrt(d) the dense-cell shortcut would be unsound
(cell diameter could exceed eps), so ``dense_valid`` turns False and the
build degrades to singleton segments (correctness is never affected; only
the optimization is disabled). The paper's 3.5e9-cell cosmology grid is the
motivating case for keying by cell rather than by a dense cell array.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import morton


class Segments(NamedTuple):
    pts: jax.Array          # (n, d) points in cell/Morton-sorted order
    order: jax.Array        # (n,)  original index of sorted position
    seg_start: jax.Array    # (m,)  first member (sorted index) of segment
    seg_end: jax.Array      # (m,)  one-past-last member
    seg_of_point: jax.Array  # (n,) segment id of each sorted point
    dense_seg: jax.Array    # (m,)  segment is a dense cell
    dense_pt: jax.Array     # (n,)  point lies in a dense cell
    codes: jax.Array        # (m,)  Morton key per segment (sorted)
    prim_lo: jax.Array      # (m, d) tight AABB lower corner
    prim_hi: jax.Array      # (m, d) tight AABB upper corner

    @property
    def n_points(self) -> int:
        return self.pts.shape[0]

    @property
    def n_segments(self) -> int:
        return self.seg_start.shape[0]


def singleton_segments(pts_sorted: jax.Array, order: jax.Array,
                       codes_sorted: jax.Array) -> Segments:
    """Singleton-segment index over *already sorted* points.

    Fully traceable (static shapes, no host round-trips), so it can run
    inside ``shard_map``/``jit`` — the sharded distributed path builds its
    per-shard index with this under one jitted collective program.
    """
    n = pts_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    false = jnp.zeros(n, bool)
    return Segments(pts=pts_sorted, order=order, seg_start=idx,
                    seg_end=idx + 1, seg_of_point=idx, dense_seg=false,
                    dense_pt=false, codes=codes_sorted, prim_lo=pts_sorted,
                    prim_hi=pts_sorted)


def build_segments_fdbscan(points: jax.Array) -> Segments:
    """Singleton segments in Morton order (plain FDBSCAN index)."""
    pts, order, codes = morton.morton_sort(points)
    return singleton_segments(pts, order, codes)


def _cell_coords(points: jax.Array, eps: float) -> tuple[jax.Array, bool]:
    """Integer cell coordinates on the eps/sqrt(d) grid (+ validity flag)."""
    n, d = points.shape
    bits = morton.BITS_2D if d == 2 else morton.BITS_3D
    cell = eps / math.sqrt(d)
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    extent = jnp.maximum(hi - lo, jnp.finfo(points.dtype).tiny)
    ncell = jnp.ceil(extent / cell)
    capped = bool(jnp.any(ncell > 2**bits))
    scale = jnp.where(ncell > 2**bits, (2.0**bits) / extent, 1.0 / cell)
    c = jnp.floor((points - lo) * scale).astype(jnp.int32)
    c = jnp.clip(c, 0, 2**bits - 1)
    return c.astype(jnp.uint32), not capped


def _cell_morton(cells: jax.Array) -> jax.Array:
    d = cells.shape[1]
    if d == 2:
        return (morton._expand_bits_2d(cells[:, 0]) << 1) | morton._expand_bits_2d(cells[:, 1])
    return ((morton._expand_bits_3d(cells[:, 0]) << 2)
            | (morton._expand_bits_3d(cells[:, 1]) << 1)
            | morton._expand_bits_3d(cells[:, 2]))


def build_segments_densebox(points: jax.Array, eps: float, min_pts: int) -> Segments:
    """Mixed dense-cell / loose-point segments (FDBSCAN-DenseBox index).

    Host-side orchestration: the segment count ``m`` is data dependent, so
    this builder runs eagerly and the clustering phases are jitted against
    the concrete ``m`` (DESIGN.md §3; a padded fully-jitted variant simply
    pads ``m`` to ``n``).
    """
    n, d = points.shape
    if d not in (2, 3) or eps <= 0:
        # degenerate eps: no grid to build — singleton segments are always
        # correct, only the dense-cell optimization is lost
        return build_segments_fdbscan(points)
    cells, dense_valid = _cell_coords(points, eps)
    codes_pt = _cell_morton(cells)
    order = jnp.argsort(codes_pt)
    pts = points[order]
    codes_sorted = codes_pt[order]

    new_cell = jnp.concatenate([jnp.ones(1, bool),
                                codes_sorted[1:] != codes_sorted[:-1]])
    cell_rank = jnp.cumsum(new_cell) - 1  # dense cell rank per point
    n_cells = int(cell_rank[-1]) + 1
    counts = jax.ops.segment_sum(jnp.ones(n, jnp.int32), cell_rank,
                                 num_segments=n_cells)
    dense_pt = (counts[cell_rank] >= min_pts) & dense_valid

    # Segment boundaries: first member of a dense cell, or any loose point.
    is_new_seg = new_cell | ~dense_pt
    seg_of_point = (jnp.cumsum(is_new_seg) - 1).astype(jnp.int32)
    m = int(seg_of_point[-1]) + 1

    idx = jnp.arange(n, dtype=jnp.int32)
    seg_start = jax.ops.segment_min(idx, seg_of_point, num_segments=m)
    seg_end = jax.ops.segment_max(idx, seg_of_point, num_segments=m) + 1
    dense_seg = jax.ops.segment_max(dense_pt.astype(jnp.int32), seg_of_point,
                                    num_segments=m).astype(bool)
    prim_lo = jax.ops.segment_min(pts, seg_of_point, num_segments=m)
    prim_hi = jax.ops.segment_max(pts, seg_of_point, num_segments=m)
    seg_codes = codes_sorted[seg_start]
    return Segments(pts=pts, order=order, seg_start=seg_start, seg_end=seg_end,
                    seg_of_point=seg_of_point, dense_seg=dense_seg,
                    dense_pt=dense_pt, codes=seg_codes,
                    prim_lo=prim_lo, prim_hi=prim_hi)
