"""Bulk-synchronous union-find (TPU adaptation of ECL-CC; DESIGN.md §3).

The paper uses Jaiganesh & Burtscher's synchronization-free GPU union-find
with *intermediate pointer jumping* (every FIND halves the path it walks,
via atomic CAS hooks). XLA:TPU exposes no global atomics, so we realize the
same disjoint-set semantics with deterministic bulk primitives:

  * HOOK:  labels <- min(labels, candidate)  (elementwise / scatter-min),
  * JUMP:  labels <- labels[labels]          (one gather doubles every path
           compression step — the bulk analogue of intermediate pointer
           jumping),

iterated to a fixpoint. ``labels[i]`` always holds the index of some point
known to be in i's cluster, is monotonically non-increasing, and converges
to the minimum member index of the connected component (the canonical
representative). The finalization phase of the paper (make every label point
at the root) is ``jump_to_fixpoint``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def jump_once(labels: jax.Array) -> jax.Array:
    return labels[labels]


def jump_to_fixpoint_np(labels: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`jump_to_fixpoint` for host-driven repair loops
    (the streaming index hooks on host between jitted traversals).
    Requires ``labels[i] <= i`` — a decreasing pointer forest — so the
    doubling can never cycle."""
    while True:
        jumped = labels[labels]
        if (jumped == labels).all():
            return labels
        labels = jumped


@jax.jit
def jump_to_fixpoint(labels: jax.Array) -> jax.Array:
    """Full path compression: every label points at its root."""

    def cond(l):
        return jnp.any(l != l[l])

    return lax.while_loop(cond, jump_once, labels)


def hook(labels: jax.Array, candidate: jax.Array, mask=None) -> jax.Array:
    """labels <- min(labels, candidate) where mask (monotone hook)."""
    new = jnp.minimum(labels, candidate)
    if mask is not None:
        new = jnp.where(mask, new, labels)
    return new
