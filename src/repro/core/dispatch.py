"""Unified backend dispatch for DBSCAN (DESIGN.md §5).

One entry point — ``dbscan(points, eps, min_pts, algorithm="auto")`` —
serving the backends:

  * ``fdbscan``          — singleton-segment BVH (Morton order); the index
                           is eps-independent, so it is cached per point set
                           and reused verbatim across ``eps``/``min_pts``
                           sweeps (benchmarks/bench_eps.py's workload).
  * ``fdbscan-densebox`` — mixed dense-cell/loose-point BVH; the eps-grid
                           build doubles as the density probe that drives
                           the auto heuristic, so choosing this backend
                           costs no extra work.
  * ``tiled``            — the MXU Pallas tile backend (kernels/ops.py):
                           n^2 streamed distance tiles beat a divergent
                           tree walk when the point count is small.
  * ``pallas-tree``      — the same tree algorithms with every traversal
                           run as the lane-tiled Pallas kernel
                           (kernels/traverse.py; DESIGN.md §9). Auto
                           dispatch upgrades any tree decision to this
                           backend on TPU (where the DBSCAN visitors are
                           kernel-fusible and the index fits VMEM);
                           bit-identical labels.
  * ``sharded``          — the multi-device tree path (DESIGN.md §6):
                           shard-local LBVH traversal + eps-halo exchange
                           (distributed/ring_dbscan.tree_dbscan_sharded).
                           Auto-selected whenever a mesh is passed; its
                           per-shard index is built inside the collective
                           program, so the plan itself carries no index.

``plan()`` performs the (cacheable) decision + index build; ``dbscan()``
executes a plan. Plans are memoized in a small LRU keyed by point-set
content hash + parameters, with the eps-independent fdbscan index shared
across all eps/min_pts entries of the same point set. Sharded plans are
index-free and mesh-determined, so they skip the content hash and the LRU
entirely (the compiled collective programs are cached per mesh/shape in
``repro.distributed.ring_dbscan._sharded_programs``).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import fdbscan, grid, lbvh, tune
from .validate import check_points

# Below this size the n^2 tile sweep is cheaper than divergent traversal
# (one 128x128 MXU tile row per query block), and it keeps the CPU
# interpret-mode path exercised in tests.
TILED_MAX_POINTS = 1024
# Minimum fraction of points inside dense cells for the DenseBox index to
# pay for its grid pass (paper Fig. 6: sparse/high-minpts regimes have ~0).
DENSE_FRACTION_MIN = 0.05

_CACHE_MAX = 32
_plan_cache: "OrderedDict[Any, Any]" = OrderedDict()

ALGORITHMS = ("auto", "fdbscan", "fdbscan-densebox", "tiled", "sharded",
              "stream", "pallas-tree")


# The traversal kernel keeps the whole index (points, boxes, ropes,
# segment tables) VMEM-resident; past roughly half a core's ~16 MB the
# upgrade would trade a working vmapped walk for a compile failure, so
# auto dispatch stays on the reference engine beyond this footprint.
# Explicit algorithm="pallas-tree" bypasses the guard (the caller asked).
PALLAS_MAX_INDEX_BYTES = 8 << 20


def _accel() -> bool:
    """Does jit target the TPU Pallas lowering? (the pallas-tree auto
    heuristic; split out so tests can pin it). GPU is deliberately
    excluded: the kernel's TPU compiler params make Pallas fall back to
    interpret mode there, which would silently replace the fast vmapped
    engine with an emulated kernel."""
    import jax
    return jax.default_backend() == "tpu"


def _index_vmem_bytes(p: "Plan") -> int | None:
    """Rough whole-index footprint the kernel pins in VMEM (int32/float32
    arrays: points, per-point ids, segment tables, node boxes + links)."""
    if p.segs is None or p.tree is None:
        return None
    n, d = p.segs.pts.shape
    m = p.segs.seg_start.shape[0]
    n_nodes = p.tree.miss.shape[0]
    return 4 * (n * d + n + 3 * m + 2 * n_nodes * d + 4 * n_nodes)


def _maybe_pallas(p: "Plan", algorithm: str, eps: float,
                  min_pts: int) -> "Plan":
    """Upgrade an auto tree decision to the Pallas kernel engine on TPU.
    The kernel runs the same index with the same visitor callbacks
    (always fusible for the DBSCAN epilogues), so the upgrade changes
    only the walk's lowering — labels stay bit-identical. Skipped when
    the index would overflow the kernel's VMEM residency budget."""
    if algorithm != "auto" or not _accel():
        return p
    footprint = _index_vmem_bytes(p)
    if footprint is None or footprint > PALLAS_MAX_INDEX_BYTES:
        return p
    stats = dict(p.stats)
    stats["reason"] = (stats.get("reason", "") +
                       "; accelerator: pallas traversal kernel")
    return _attach_tune(p._replace(backend="pallas-tree", stats=stats),
                        eps, min_pts)


def _attach_tune(p: "Plan", eps: float, min_pts: int) -> "Plan":
    """Resolve a pallas-tree plan's tuner state (core.tune; DESIGN.md §9).

    The decision rides in the plan LRU alongside the eps-independent
    index, so repeat runs reuse it (including the depth-rank calibration
    the first run performs). ``REPRO_TUNE=search`` configs are
    additionally cached under the bucketed :func:`core.tune.stats_key`,
    sharing one measured search across equal-shaped plans.
    """
    if p.backend != "pallas-tree" or p.tree is None:
        return p
    m = tune.mode()
    if m == "search":
        skey = ("tune-config", tune.stats_key(p.segs, eps, min_pts))
        hit = _cache_get(skey)
        if hit is None:
            with obs_trace.span("tune.search"):
                hit = _cache_put(
                    skey, tune.search(p.segs, p.tree, eps, min_pts))
            obs_metrics.inc("tune_searches_total")
        cfg, info = hit
        state = tune.TuneState(cfg)
        state.info = dict(info)
    else:
        state = tune.TuneState(tune.config_for(p.segs, p.tree, eps,
                                               min_pts, m))
    stats = dict(p.stats)
    stats["tuned_config"] = state.describe()
    return p._replace(tune=state, stats=stats)


class Plan(NamedTuple):
    """A resolved backend choice plus the (reusable) index that drove it.

    backend: one of "fdbscan", "fdbscan-densebox", "pallas-tree",
        "tiled", "sharded", "stream".
    segs / tree: the segment index and its LBVH (None for the index-free
        tiled/sharded backends, and tree is None below two segments).
    stats: occupancy/size stats behind the choice; ``stats["reason"]``
        states why this backend won; pallas-tree plans also record
        ``stats["tuned_config"]``.
    tune: the plan's ``core.tune.TuneState`` (pallas-tree only) — the
        per-phase engine/lane-tile/unroll/reorder decision plus the
        lazily-calibrated walk-depth oracle. Cached with the plan, so
        repeat runs reuse both the index *and* the calibration.
    """
    backend: str
    segs: grid.Segments | None
    tree: lbvh.Tree | None
    stats: dict
    tune: Any = None


def _mesh_ndev(mesh, axis: str) -> int:
    """Devices along ``axis`` (1 when the mesh lacks it — a mesh without a
    data axis never routes auto dispatch to the sharded backend)."""
    if mesh is None:
        import jax
        return len(jax.devices())
    from repro.distributed.sharding import _axis_size
    return _axis_size(mesh, axis)


def clear_cache() -> None:
    _plan_cache.clear()


def cache_info() -> dict:
    return {"entries": len(_plan_cache), "max": _CACHE_MAX}


def _points_key(points) -> str:
    arr = np.ascontiguousarray(np.asarray(points))
    h = hashlib.sha1(arr.tobytes())
    h.update(repr((arr.shape, str(arr.dtype))).encode())
    return h.hexdigest()


def _cache_get(key):
    if key in _plan_cache:
        _plan_cache.move_to_end(key)
        return _plan_cache[key]
    return None


def _cache_put(key, val):
    _plan_cache[key] = val
    _plan_cache.move_to_end(key)
    while len(_plan_cache) > _CACHE_MAX:
        _plan_cache.popitem(last=False)
    return val


def _tree_of(segs: grid.Segments):
    if segs.n_segments < 2 or segs.n_points < 2:
        return None
    return lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)


def _fdbscan_plan(points, pkey: str, stats: dict) -> Plan:
    """Plain-FDBSCAN plan; the index is eps-independent and shared across
    every (eps, min_pts) plan for the same point set."""
    base_key = (pkey, "fdbscan-index")
    cached = _cache_get(base_key)
    if cached is None:
        with obs_trace.span("build", index="fdbscan") as sp:
            segs = grid.build_segments_fdbscan(points)
            tree = _tree_of(segs)
            sp.watch(segs, tree)
        obs_metrics.inc("dispatch_index_builds_total", index="fdbscan")
        cached = _cache_put(base_key, (segs, tree))
    segs, tree = cached
    return Plan("fdbscan", segs, tree, stats)


def plan(points, eps: float, min_pts: int,
         algorithm: str = "auto", mesh=None, axis: str = "data") -> Plan:
    """Choose a backend and build (or fetch) its index.

    Instrumented (DESIGN.md §12): with a collector installed, planning is
    bracketed by a ``plan`` span (index builds get a nested ``build``
    span) and reports plan/cache-hit counters per backend; with none
    installed every instrumentation point is a no-op and the result is
    bit-identical.

    The densebox grid build is reused as the density probe: its dense-point
    fraction decides densebox-vs-plain, and on a densebox decision the very
    same segments become the index (no duplicated work). An active ``mesh``
    routes to the sharded multi-device tree path (whose per-shard index is
    built inside the collective program — nothing to cache here beyond the
    decision). On TPU an auto tree decision upgrades to the
    ``pallas-tree`` kernel engine when the index fits its VMEM
    residency budget (DESIGN.md §9).

    Args:
        points: (n, d) point array (any array-like; converted to jnp).
        eps: DBSCAN radius (non-negative).
        min_pts: DBSCAN density threshold (the query point counts).
        algorithm: one of :data:`ALGORITHMS`; ``"auto"`` probes and picks.
        mesh: optional ``jax.sharding.Mesh``; with a ``axis`` data axis of
            size > 1 it routes auto dispatch to the sharded backend.
        axis: the mesh axis points are sharded over (default ``"data"``).

    Returns:
        A :class:`Plan` — resolved backend name, the (cacheable) index
        (``segs``/``tree``, ``None`` for index-free backends), and the
        stats dict that drove the decision (``stats["reason"]`` says why).

    Raises:
        ValueError: unknown ``algorithm``; negative ``eps``; malformed
            ``points`` (empty, non-numeric, NaN/Inf coordinates — see
            :func:`repro.core.validate.check_points`); ``mesh=`` combined
            with a single-device algorithm; a sharded request whose mesh
            lacks ``axis``; or a stream request with d ∉ {2, 3}.
    """
    with obs_trace.span("plan", algorithm=algorithm) as sp:
        p = _plan_impl(points, eps, min_pts, algorithm, mesh, axis)
        sp.watch(p.segs, p.tree)
    obs_metrics.inc("dispatch_plans_total", backend=p.backend)
    return p


def _plan_impl(points, eps: float, min_pts: int, algorithm: str,
               mesh, axis: str) -> Plan:
    """The planning decision body; :func:`plan` wraps it in the span +
    counter instrumentation."""
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    if eps < 0:
        raise ValueError(f"eps must be non-negative; got {eps}"
                         " (a negative eps would be squared away silently)")
    if mesh is not None and algorithm not in ("auto", "sharded"):
        raise ValueError(
            f"mesh= is incompatible with algorithm={algorithm!r}: the "
            f"{algorithm} backend is single-device and would silently "
            "ignore it (use algorithm='sharded' or 'auto' to shard)")
    check_points(points)
    points = jnp.asarray(points)
    n, d = points.shape
    if mesh is not None and axis not in mesh.axis_names:
        if algorithm == "sharded":
            raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
        mesh = None  # a mesh without the data axis cannot shard points
    if (algorithm == "sharded"
            or (algorithm == "auto" and mesh is not None
                and _mesh_ndev(mesh, axis) > 1)):
        # sharded plans carry no index and depend only on the mesh, so no
        # point-content hash (an O(n) host transfer) and no cache needed
        return Plan("sharded", None, None,
                    {"n": n, "d": d, "ndev": _mesh_ndev(mesh, axis),
                     "mesh": mesh, "axis": axis,
                     "reason": ("explicit" if algorithm == "sharded"
                                else "mesh active: shard-local trees")})
    pkey = _points_key(points)
    key = (pkey, float(eps), int(min_pts), algorithm)
    hit = _cache_get(key)
    if hit is not None:
        obs_metrics.inc("dispatch_plan_cache_hits_total")
        return hit
    obs_metrics.inc("dispatch_plan_cache_misses_total")

    stats: dict = {"n": n, "d": d}
    if algorithm == "stream":
        # the streaming handle wraps the plain fdbscan index, which is
        # eps-independent — every (eps, min_pts) stream plan for the same
        # point set shares one cached index build
        if d not in (2, 3):
            raise ValueError(f"streaming index needs d in (2, 3); got {d}")
        stats["reason"] = "explicit: streaming two-level index"
        return _cache_put(key,
                          _fdbscan_plan(points, pkey, stats)._replace(
                              backend="stream"))
    if algorithm == "tiled" or (algorithm == "auto" and n <= TILED_MAX_POINTS):
        stats["reason"] = ("explicit" if algorithm == "tiled"
                           else f"n <= {TILED_MAX_POINTS}: MXU tiles win")
        return _cache_put(key, Plan("tiled", None, None, stats))

    if algorithm == "pallas-tree":
        # the Pallas traversal kernel over the plain (eps-independent,
        # cached) fdbscan index — the explicit form of the auto upgrade
        stats["reason"] = "explicit: Pallas traversal kernel"
        return _cache_put(key, _attach_tune(
            _fdbscan_plan(points, pkey, stats)._replace(
                backend="pallas-tree"), eps, min_pts))

    if algorithm == "fdbscan" or d not in (2, 3):
        stats["reason"] = ("explicit" if algorithm == "fdbscan"
                           else "no eps-grid for this dimensionality")
        return _cache_put(key, _maybe_pallas(
            _fdbscan_plan(points, pkey, stats), algorithm, eps, min_pts))

    # eps-grid build: density probe and (potentially) the index itself
    with obs_trace.span("build", index="densebox") as sp:
        segs = grid.build_segments_densebox(points, eps, min_pts)
        sp.watch(segs)
    obs_metrics.inc("dispatch_index_builds_total", index="densebox")
    dense_frac = float(np.asarray(segs.dense_pt).mean())
    stats.update(dense_fraction=dense_frac, n_segments=segs.n_segments)
    if algorithm == "fdbscan-densebox" or dense_frac >= DENSE_FRACTION_MIN:
        stats["reason"] = ("explicit" if algorithm == "fdbscan-densebox"
                           else f"dense_fraction >= {DENSE_FRACTION_MIN}")
        return _cache_put(key, _maybe_pallas(
            Plan("fdbscan-densebox", segs, _tree_of(segs), stats),
            algorithm, eps, min_pts))
    stats["reason"] = f"dense_fraction < {DENSE_FRACTION_MIN}: plain tree"
    return _cache_put(key, _maybe_pallas(
        _fdbscan_plan(points, pkey, stats), algorithm, eps, min_pts))


def dbscan(points, eps: float, min_pts: int, *, algorithm: str = "auto",
           star: bool = False, frontier: bool = True, mesh=None,
           axis: str = "data",
           query_plan: Plan | None = None) -> fdbscan.DBSCANResult:
    """DBSCAN with automatic backend selection (the unified entry point).

    ``query_plan`` short-circuits planning entirely — pass the result of a
    previous :func:`plan` call *for the same point set* to amortize the
    index build across runs (the plan's index, not ``points``, is what a
    tree backend clusters). ``mesh`` (a jax Mesh with a data axis) routes
    auto dispatch to the sharded multi-device tree path.

    Args:
        points: (n, d) point array.
        eps: DBSCAN radius (non-negative).
        min_pts: DBSCAN density threshold (the query point counts, so a
            point with ``min_pts - 1`` neighbors is core).
        algorithm: backend request, see :func:`plan`.
        star: DBSCAN* variant — no border points, non-core points are
            noise (not supported by the sharded backend).
        frontier: restrict label sweeps to the changed-point frontier
            (exact, default True); only meaningful for the single-device
            tree backends.
        mesh / axis: multi-device routing, see :func:`plan`.
        query_plan: a previous :func:`plan` result for the same points.

    Returns:
        A :class:`repro.core.fdbscan.DBSCANResult`; ``labels[i] == -1``
        marks noise, ``backend`` names the backend that actually ran.

    Raises:
        ValueError: invalid parameters (see :func:`plan`), or
            ``frontier``/``star`` combined with a backend that would
            silently ignore them.
        NotImplementedError: ``star=True`` on the sharded backend.
    """
    check_points(points)    # before jnp.asarray: non-numeric dtypes must
    points = jnp.asarray(points)    # raise ValueError, not jax TypeError
    p = query_plan if query_plan is not None else plan(points, eps, min_pts,
                                                       algorithm, mesh=mesh,
                                                       axis=axis)
    if p.backend in ("tiled", "stream", "sharded") and frontier is not True:
        raise ValueError(
            f"frontier={frontier!r} is incompatible with the {p.backend} "
            "backend: frontier restriction only applies to the single-"
            "device tree-sweep backends and would silently be ignored "
            "(drop the kwarg, or pick "
            "algorithm='fdbscan'/'fdbscan-densebox')")
    with obs_trace.span("dbscan", backend=p.backend,
                        n=points.shape[0]) as sp:
        res = _execute(p, points, eps, min_pts, star=star,
                       frontier=frontier, mesh=mesh, axis=axis)
        sp.watch(res.labels, res.core_mask)
    obs_metrics.inc("dbscan_runs_total", backend=p.backend)
    obs_metrics.observe("dbscan_sweeps", res.n_sweeps, backend=p.backend)
    return res


def _execute(p: Plan, points, eps: float, min_pts: int, *, star: bool,
             frontier: bool, mesh, axis: str) -> fdbscan.DBSCANResult:
    """Run a resolved plan; :func:`dbscan` wraps it in the span +
    counter instrumentation."""
    if p.backend == "sharded":
        from repro.distributed.ring_dbscan import tree_dbscan_sharded
        if star:
            raise NotImplementedError("sharded backend has no DBSCAN* mode")
        res = tree_dbscan_sharded(points, eps, min_pts,
                                  mesh=p.stats.get("mesh", mesh),
                                  axis=p.stats.get("axis", axis))
        return res._replace(backend="sharded")
    if p.backend == "stream":
        # one-shot execution of a stream plan: bootstrap a handle over the
        # plan's (cached, eps-independent) index and materialize labels
        from repro.stream import StreamingDBSCAN
        h = StreamingDBSCAN(points, eps, min_pts, index=(p.segs, p.tree))
        return h.snapshot(star=star)
    if p.backend == "tiled":
        import jax
        from repro.kernels import ops
        # interpret mode only off-TPU (the Pallas kernels are TPU-tiled;
        # interpret=True is the CPU-test emulation path)
        return ops.dbscan_tiled(points, eps, min_pts, star=star,
                                interpret=jax.default_backend() != "tpu")
    if p.tune is not None:
        # Record the decision in the metrics snapshot (DESIGN.md §12):
        # an info-style gauge whose labels carry the per-phase choice.
        desc = p.tune.describe()
        for ph in ("first_pass", "sweep", "border"):
            c = desc[ph]
            obs_metrics.set_gauge(
                "tuned_config_info", 1.0, phase=ph, engine=c["engine"],
                lane_tile=str(c["lane_tile"]), unroll=str(c["unroll"]),
                reorder=c["reorder"], source=desc["source"])
    return fdbscan.cluster_from_index(p.segs, p.tree, eps, min_pts,
                                      star=star, frontier=frontier,
                                      backend=p.backend, tune=p.tune)


def stream_handle(points, eps: float, min_pts: int, *,
                  window: int | None = None,
                  wal=None, checkpoint_path: str | None = None,
                  checkpoint_every: int = 0, **kwargs):
    """Build a :class:`repro.stream.StreamingDBSCAN` handle over ``points``.

    Goes through :func:`plan`, so the handle's main tree is the *cached*
    eps-independent fdbscan index — building handles (or running batch
    ``dbscan``) for several ``eps``/``min_pts`` values over the same point
    set shares one index build.

    The durability options make the handle crash-safe (DESIGN.md §10):
    with ``wal`` every insert is durably logged before it is applied, and
    with ``checkpoint_path`` (+ ``checkpoint_every``) the full state is
    atomically serialized every K index merges.  After a crash,
    ``StreamingDBSCAN.restore(checkpoint_path, wal=wal)`` rebuilds the
    handle from the last checkpoint plus a WAL replay — this is what
    ``launch/serve.py --restore`` runs.

    Args:
        points: (n, d) initial points, d in (2, 3), n >= 2.
        eps: DBSCAN radius (non-negative).
        min_pts: DBSCAN density threshold.
        window: optional sliding-window size — every insert auto-expires
            points whose insert id falls below ``n_points - window``
            (insert-order watermark; see ``StreamingDBSCAN.expire``).
        wal: optional write-ahead-log path (or a prebuilt
            ``repro.stream.durability.WriteAheadLog``).
        checkpoint_path: optional checkpoint file for
            :meth:`StreamingDBSCAN.checkpoint` and the auto policy.
        checkpoint_every: auto-checkpoint after every K merges (0 = off).
        **kwargs: passed to the handle (e.g. ``merge_ratio``, the
            delta/main size ratio that triggers a full index merge, or
            ``buffer_max``/``growth``, the tiered-compaction knobs).

    Returns:
        A live ``StreamingDBSCAN`` handle exposing ``insert`` /
        ``delete`` / ``expire`` / ``query`` / ``snapshot`` / ``merge`` /
        ``compact`` / ``checkpoint`` (DESIGN.md §7, §10, §11); after any
        interleaving of inserts, deletes, expiries, merges and
        compactions, ``snapshot()`` is component-identical to batch
        :func:`dbscan` on exactly the surviving points.

    Raises:
        ValueError: malformed ``points`` (empty, NaN/Inf, d outside
            (2, 3)), negative ``eps``, or inserts that change
            dimensionality (raised by the handle).
        repro.stream.durability.WALError: ``wal`` names a file with
            leftover records from a crashed run (restore it instead).
    """
    from repro.stream import StreamingDBSCAN
    points = jnp.asarray(points)
    p = plan(points, eps, min_pts, algorithm="stream")
    return StreamingDBSCAN(points, eps, min_pts,
                           index=(p.segs, p.tree), window=window, wal=wal,
                           checkpoint_path=checkpoint_path,
                           checkpoint_every=checkpoint_every, **kwargs)


def tenant_handles(points, tenants: dict) -> dict:
    """Build one streaming handle per tenant over ONE shared index build.

    ``tenants`` maps tenant name -> kwargs for :func:`stream_handle`
    (``eps`` and ``min_pts`` required; durability/window/compaction
    options per tenant).  The eps-independent part of the bootstrap —
    the Morton sort + LBVH over ``points`` — is cached under the point
    set's content hash, so N tenants cost one index build plus N
    eps-dependent clusterings; ``dispatch_index_builds_total`` moves by
    exactly one however many tenants share the point set.  This is the
    serving plane's multi-tenant entry point
    (:func:`repro.serve.tenants.build_views`).
    """
    if not tenants:
        raise ValueError("tenant_handles needs at least one tenant")
    points = jnp.asarray(points)
    handles = {}
    with obs_trace.span("plan.tenants", n_tenants=len(tenants)):
        for name, kw in tenants.items():
            kw = dict(kw)
            try:
                eps = kw.pop("eps")
                min_pts = kw.pop("min_pts")
            except KeyError as e:
                raise ValueError(f"tenant {name!r}: missing {e} in spec")
            handles[name] = stream_handle(points, eps, min_pts, **kw)
    return handles
