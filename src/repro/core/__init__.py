"""Core library: the paper's tree-based DBSCAN algorithms on TPU/JAX."""
from .fdbscan import DBSCANResult, dbscan
from .baselines import dbscan_bruteforce_np, gdbscan
from . import grid, lbvh, morton, traversal, unionfind, validate

__all__ = ["DBSCANResult", "dbscan", "dbscan_bruteforce_np", "gdbscan",
           "grid", "lbvh", "morton", "traversal", "unionfind", "validate"]
