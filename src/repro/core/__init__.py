"""Core library: the paper's tree-based DBSCAN algorithms on TPU/JAX.

``dbscan`` is the unified auto-dispatching entry point (DESIGN.md §5): it
plans a backend (tree walk or MXU tiles) per input and reuses cached
indexes across eps/min_pts sweeps. The per-algorithm implementations stay
importable via ``fdbscan`` and ``kernels.ops``.
"""
from .fdbscan import DBSCANResult
from .dispatch import dbscan, plan, Plan, stream_handle
from .baselines import dbscan_bruteforce_np, gdbscan
from . import (dispatch, fdbscan, grid, lbvh, morton, neighbors, traversal,
               unionfind, validate)

__all__ = ["DBSCANResult", "dbscan", "plan", "Plan", "stream_handle",
           "dbscan_bruteforce_np", "gdbscan", "dispatch", "fdbscan", "grid",
           "lbvh", "morton", "neighbors", "traversal", "unionfind",
           "validate"]
