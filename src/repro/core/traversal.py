"""Batched stackless BVH traversal fused with DBSCAN epilogues.

This is the heart of FDBSCAN: the tree walk and the clustering update are a
single fused loop per query — neighbors are consumed *on the fly* and never
materialized (the paper's O(n)-memory claim; DESIGN.md §3).

GPU -> TPU mapping:
  * one CUDA thread per query  ->  one vmap lane per query; the vmapped
    ``lax.while_loop`` lowers to a single masked loop (lanes that finish go
    inert), the TPU analogue of a warp of independent traversals;
  * per-thread traversal stack  ->  precomputed ropes (``Tree.miss``), O(1)
    state per lane;
  * early exit (``count >= minpts``)  ->  loop-mask condition;
  * the paper's "hide leaves j < i" mask  ->  a range test on
    ``Tree.range_r`` (skip subtrees whose max primitive index is below the
    query's own), used by the edge-once extraction mode.

Fused single-pass engine (DESIGN.md §4):
  * ``mode="count_minlabel"`` computes the neighbor count *and* the
    min-neighbor-label candidate in one walk, collapsing core-point
    preprocessing and the first main-phase sweep into a single traversal
    (the paper's phase-fusion claim made real).
  * Each ``while_loop`` trip executes ``unroll`` work units (box tests or
    member distances) instead of one, amortizing the loop-carried overhead
    that otherwise dominates a one-unit-per-trip masked loop. Sub-steps are
    dead-guarded so lanes freeze exactly where the one-unit engine would.
  * Queries are addressed by an explicit ``query_ids`` vector, so frontier
    sweeps can traverse a *compacted* active subset (ECL-CC-style active-set
    restriction) instead of masking inert full-width lanes.

External queries (DESIGN.md §6): ``query_pts`` decouples the query set from
the tree's primitives — a lane traverses for an arbitrary point that is not
(necessarily) resident in the index. The sharded distributed path runs
eps-halo points received from other shards as external queries against the
local tree; self-exclusion and the dense/query-rank shortcuts (which assume
lane i <=> resident point i) are disabled for such lanes.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .lbvh import Tree, box_dist2 as _box_dist2
from .grid import Segments

INT_MAX = jnp.iinfo(jnp.int32).max

# Work units per while_loop trip. On lockstep accelerators (TPU/GPU) 4
# amortizes the loop-carried overhead (cond evaluation + state select per
# trip) without inflating tail waste: a lane overshoots by at most
# unroll-1 dead-guarded sub-steps. On CPU the while_loop is cheap and the
# masked sub-steps are pure overhead, so the default stays 1 there.
DEFAULT_UNROLL = 4 if jax.default_backend() in ("tpu", "gpu") else 1

MODES = ("count", "minlabel", "count_minlabel")


class Trace(NamedTuple):
    """Per-query traversal outputs (all shaped like ``query_ids``).

    acc:   mode accumulator — the saturated neighbor count (incl. self) for
           ``count``; the min gathered ``point_vals`` (init: the query's own
           value) for ``minlabel``/``count_minlabel``.
    hits:  matched neighbors *excluding* the query itself (mask-filtered in
           the minlabel modes; partial when a pass early-exits or a dense
           short-circuit fires).
    evals: member distance evaluations — the paper's work metric.
    iters: while_loop trips taken (after unrolling); the loop-overhead
           metric that ``unroll`` amortizes.
    """
    acc: jax.Array
    hits: jax.Array
    evals: jax.Array
    iters: jax.Array


def traverse_impl(tree: Tree, segs: Segments, eps: float,
             point_vals: jax.Array,
             point_mask: jax.Array,
             query_ids: jax.Array | None = None,
             cap: int | jax.Array = INT_MAX,
             mode: str = "count",
             use_range_mask: bool = False,
             node_mask: jax.Array | None = None,
             point_mask_wide: jax.Array | None = None,
             node_mask_wide: jax.Array | None = None,
             wide_lanes: jax.Array | None = None,
             query_pts: jax.Array | None = None,
             query_init: jax.Array | None = None,
             unroll: int = DEFAULT_UNROLL) -> Trace:
    """Run one fused traversal per entry of ``query_ids``.

    query_ids: int32 sorted-order point indices; ``-1`` marks an inert
        (padding) lane. ``None`` traverses every point.
    query_pts: optional (k, d) *external* query coordinates (DESIGN.md §6).
        When given, lane i traverses for ``query_pts[i]`` instead of a tree
        point; ``query_ids`` then only carries the inert-lane marker (-1
        inert, anything else active). External lanes have no resident
        identity, so self-exclusion is off (every masked hit counts),
        the dense-query shortcut is off, and ``use_range_mask`` is
        rejected. The minlabel accumulator starts from ``query_init``
        (per lane; INT_MAX when omitted) rather than the lane's own
        ``point_vals`` entry — a traveling query chains its running min
        across successive shard visits this way.
    node_mask: optional (2m-1,) per-node flag; subtrees whose flag is False
        are pruned as if their boxes missed. Frontier sweeps pass the
        "subtree contains a changed point" flag (DESIGN.md §4) so lanes far
        from any change die within a few box tests.
    point_mask_wide / node_mask_wide / wide_lanes: optional second
        (gather-mask, node-mask) pair selected per lane by the boolean
        ``wide_lanes`` (aligned with ``query_ids``). The split first main
        sweep runs narrow (changed-only) lanes and wide (full-core) lanes
        in one walk (DESIGN.md §4).

    mode="count":    acc = |N_eps(q)| (incl. self) saturated at ``cap``
                     (early exit: the lane dies once ``acc`` reaches cap).
    mode="minlabel": acc = min(point_vals[j]) over neighbors j with
                     point_mask[j] (init: the query's own value); entering a
                     *dense* segment stops at the first member hit (all
                     members share one label — the paper's dense-cell
                     short-circuit).
    mode="count_minlabel": the fused first pass (DESIGN.md §4) — acc as in
                     minlabel *and* hits = neighbor count saturated at
                     ``cap`` in the same walk. The lane itself never exits
                     early (the gather needs the full neighborhood), but
                     the dense short-circuit fires for dense queries and
                     for lanes whose count has saturated — one member hit
                     still yields a dense cell's unified label, so the
                     gather stays exact while the count work collapses to
                     the paper's early-exit budget.
    """
    if mode not in MODES:
        raise ValueError(f"unknown traversal mode {mode!r}")
    n = segs.n_points
    m = segs.n_segments
    leaf_off = m - 1
    eps2 = jnp.asarray(eps, segs.pts.dtype) ** 2
    pts = segs.pts
    root = jnp.int32(0 if m > 1 else leaf_off)  # m==1: the single leaf
    external = query_pts is not None
    if external:
        if use_range_mask:
            raise ValueError("use_range_mask needs tree-resident queries")
        if query_ids is None:
            query_ids = jnp.zeros(query_pts.shape[0], jnp.int32)
        q_arr = query_pts
        self_arr = jnp.full(query_ids.shape, -1, jnp.int32)   # never matches
        dense_arr = jnp.zeros(query_ids.shape, bool)
        rank_arr = jnp.zeros(query_ids.shape, jnp.int32)
        if mode == "count":
            acc0_arr = jnp.zeros(query_ids.shape, jnp.int32)
        elif query_init is not None:
            acc0_arr = query_init
        else:
            acc0_arr = jnp.full(query_ids.shape, INT_MAX, jnp.int32)
    else:
        if query_ids is None:
            query_ids = jnp.arange(n, dtype=jnp.int32)
        safe = jnp.maximum(query_ids, jnp.int32(0))
        q_arr = pts[safe]
        self_arr = query_ids
        dense_arr = segs.dense_pt[safe]
        rank_arr = segs.seg_of_point[safe]
        acc0_arr = (jnp.zeros(query_ids.shape, jnp.int32)
                    if mode == "count" else point_vals[safe])
    minlab = mode in ("minlabel", "count_minlabel")
    dual = wide_lanes is not None
    if not dual:
        wide_lanes = jnp.zeros_like(query_ids, dtype=bool)

    def one_query(qid, lane_wide, q, q_self, q_dense, q_rank, acc0):
        lane_on = qid >= 0

        def live_of(node, acc):
            live = node >= 0
            if mode == "count":
                live = live & (acc < cap)
            return live

        def step(state):
            """One unit of work; a no-op for lanes that already finished."""
            node, ptr, acc, hits, evals = state
            live = live_of(node, acc)
            node_safe = jnp.maximum(node, 0)
            is_member = live & (ptr >= 0)

            # ---- member step: one distance test against sorted point ptr --
            j = jnp.where(is_member, ptr, 0)
            diff = q - pts[j]
            d2 = jnp.sum(diff * diff)
            hit = is_member & (d2 <= eps2)
            seg_id = jnp.where(node_safe >= leaf_off, node_safe - leaf_off, 0)
            if mode == "count":
                acc_m = jnp.minimum(acc + jnp.where(hit, 1, 0), cap)
                hits_m = hits + jnp.where(hit & (j != q_self), 1, 0)
                stop_seg = jnp.bool_(False)
            else:
                if dual:
                    ok = hit & jnp.where(lane_wide, point_mask_wide[j],
                                         point_mask[j])
                else:
                    ok = hit & point_mask[j]
                acc_m = jnp.where(ok, jnp.minimum(acc, point_vals[j]), acc)
                hits_m = hits + jnp.where(ok & (j != q_self), 1, 0)
                # Dense segment: all members share one label & core status;
                # the first hit tells us everything (paper §4.2). The fused
                # pass additionally needs the *count*, but only up to its
                # saturation point ``cap`` (= min_pts - 1): once a lane's
                # count saturates — or the query is itself dense (core by
                # construction) — the dense short-circuit re-arms, since
                # one member hit still yields the cell's unified label.
                stop_seg = ok & segs.dense_seg[seg_id]
                if mode == "count_minlabel":
                    hits_m = jnp.minimum(hits_m, cap)
                    stop_seg = stop_seg & (q_dense | (hits_m >= cap))
            seg_done = (ptr + 1 >= segs.seg_end[seg_id]) | stop_seg
            member_next_node = jnp.where(seg_done, tree.miss[node_safe], node)
            member_next_ptr = jnp.where(seg_done, jnp.int32(-1), ptr + 1)

            # ---- node step: descend / skip -------------------------------
            is_leaf = node_safe >= leaf_off
            seg = jnp.where(is_leaf, node_safe - leaf_off, 0)
            bd2 = _box_dist2(q, tree.box_lo[node_safe], tree.box_hi[node_safe])
            overlap = bd2 <= eps2
            if use_range_mask:
                overlap = overlap & (tree.range_r[node_safe] >= q_rank)
            if node_mask is not None:
                if dual and node_mask_wide is not None:
                    overlap = overlap & jnp.where(lane_wide,
                                                  node_mask_wide[node_safe],
                                                  node_mask[node_safe])
                else:
                    overlap = overlap & node_mask[node_safe]
            # internal: go left on overlap else rope; leaf: enter members on
            # overlap (empty segments skip straight to the rope).
            child = jnp.where(node_safe < leaf_off,
                              jnp.where(overlap, tree_left(tree, node_safe),
                                        tree.miss[node_safe]),
                              node)
            enter_members = is_leaf & overlap & (segs.seg_start[seg]
                                                 < segs.seg_end[seg])
            node_next_node = jnp.where(is_leaf,
                                       jnp.where(enter_members, node,
                                                 tree.miss[node_safe]),
                                       child)
            node_next_ptr = jnp.where(enter_members, segs.seg_start[seg],
                                      jnp.int32(-1))

            node_new = jnp.where(is_member, member_next_node, node_next_node)
            ptr_new = jnp.where(is_member, member_next_ptr, node_next_ptr)
            acc_new = jnp.where(is_member, acc_m, acc)
            hits_new = jnp.where(is_member, hits_m, hits)
            evals_new = evals + jnp.where(is_member, 1, 0)
            # freeze finished lanes so unrolled sub-steps are no-ops
            return (jnp.where(live, node_new, node),
                    jnp.where(live, ptr_new, ptr),
                    jnp.where(live, acc_new, acc),
                    jnp.where(live, hits_new, hits),
                    jnp.where(live, evals_new, evals))

        def cond(state):
            node, ptr, acc, hits, evals, iters = state
            return live_of(node, acc)

        def body(state):
            node, ptr, acc, hits, evals, iters = state
            inner = (node, ptr, acc, hits, evals)
            for _ in range(unroll):
                inner = step(inner)
            return (*inner, iters + 1)

        start = jnp.where(lane_on, root, jnp.int32(-1))
        node, ptr, acc, hits, evals, iters = lax.while_loop(
            cond, body, (start, jnp.int32(-1), acc0, jnp.int32(0),
                         jnp.int32(0), jnp.int32(0)))
        return Trace(acc=acc, hits=hits, evals=evals, iters=iters)

    return jax.vmap(one_query)(query_ids, wide_lanes, q_arr, self_arr,
                               dense_arr, rank_arr, acc0_arr)


# The jitted entry point. Callers already inside a traced context (the
# sharded distributed kernel runs under shard_map) use ``traverse_impl``
# directly: a nested jit there would launch a separate per-device module
# whose collective-free body still participates in the host-device
# rendezvous machinery and can wedge the outer collectives.
traverse = partial(jax.jit, static_argnames=("mode", "use_range_mask",
                                             "unroll"))(traverse_impl)


def tree_left(tree: Tree, node):
    return tree.left[jnp.clip(node, 0, tree.left.shape[0] - 1)]


def _ids_from_mask(n: int, query_active) -> jax.Array:
    """Full-width id vector with inactive lanes marked -1 (no compaction)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    if query_active is None:
        return ids
    return jnp.where(query_active, ids, jnp.int32(-1))


def count_neighbors(tree: Tree, segs: Segments, eps: float, cap: int,
                    query_active=None) -> jax.Array:
    """|N_eps(x)| per sorted point, saturated at ``cap`` (early exit)."""
    return count_neighbors_with_work(tree, segs, eps, cap, query_active)[0]


def count_neighbors_with_work(tree: Tree, segs: Segments, eps: float,
                              cap: int, query_active=None):
    """(counts, distance_evaluations) — the paper's work metric."""
    n = segs.n_points
    dummy = jnp.zeros((n,), jnp.int32)
    tr = traverse(tree, segs, eps, dummy, jnp.ones(n, bool),
                  query_ids=_ids_from_mask(n, query_active),
                  cap=cap, mode="count")
    return tr.acc, tr.evals


def minlabel_sweep(tree: Tree, segs: Segments, eps: float, labels: jax.Array,
                   gather_mask: jax.Array, query_active: jax.Array):
    """Per active query: min(label) over neighbors with gather_mask.

    Returns (min_labels, matched_other_count); an inactive query returns
    its own ``labels`` value (no-op hook). ``labels`` must already be
    consistent within dense segments (the caller re-unifies after updates).
    """
    tr = traverse(tree, segs, eps, labels, gather_mask,
                  query_ids=_ids_from_mask(segs.n_points, query_active),
                  mode="minlabel")
    # inactive lanes carry no query identity inside the engine; restore
    # the own-value contract here where lane i <=> point i
    acc = jnp.where(query_active, tr.acc, labels)
    return acc, tr.hits


def fused_count_minlabel(tree: Tree, segs: Segments, eps: float,
                         point_vals: jax.Array, point_mask=None,
                         query_ids=None, cap: int | jax.Array = INT_MAX
                         ) -> Trace:
    """The fused first pass (DESIGN.md §4): one walk, two answers.

    Returns the full ``Trace``: ``acc`` is the min gathered value over all
    masked neighbors (candidate label — the caller validates it against the
    core mask once counts are known), ``hits`` the neighbor count excluding
    self, exact up to saturation at ``cap`` (pass ``min_pts - 1``; dense
    queries are core by construction and may undercount).
    """
    if point_mask is None:
        point_mask = jnp.ones(segs.n_points, bool)
    return traverse(tree, segs, eps, point_vals, point_mask,
                    query_ids=query_ids, cap=cap, mode="count_minlabel")


def border_gather(tree: Tree, segs: Segments, eps: float, root_labels,
                  core_mask, query_active):
    """Min core-neighbor root label per non-core query; INT_MAX if none."""
    sentinel = jnp.full_like(root_labels, INT_MAX)
    vals = jnp.where(core_mask, root_labels, sentinel)
    tr = traverse(tree, segs, eps, vals, core_mask,
                  query_ids=_ids_from_mask(segs.n_points, query_active),
                  mode="minlabel")
    # active lanes start from vals[q] (INT_MAX for non-core queries), so
    # acc == INT_MAX <=> no core neighbor (noise); inactive lanes return
    # their own vals[q] to keep the lane i <=> point i contract.
    acc = jnp.where(query_active, tr.acc, vals)
    return acc, tr.hits
