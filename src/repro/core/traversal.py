"""Batched stackless BVH traversal fused with DBSCAN epilogues.

This is the heart of FDBSCAN: the tree walk and the clustering update are a
single fused loop per query — neighbors are consumed *on the fly* and never
materialized (the paper's O(n)-memory claim; DESIGN.md §3).

GPU -> TPU mapping:
  * one CUDA thread per query  ->  one vmap lane per query; the vmapped
    ``lax.while_loop`` lowers to a single masked loop (lanes that finish go
    inert), the TPU analogue of a warp of independent traversals;
  * per-thread traversal stack  ->  precomputed ropes (``Tree.miss``), O(1)
    state per lane;
  * early exit (``count >= minpts``)  ->  loop-mask condition;
  * the paper's "hide leaves j < i" mask  ->  a range test on
    ``Tree.range_r`` (skip subtrees whose max primitive index is below the
    query's own), used by the edge-once extraction mode.

Each loop iteration performs exactly one unit of work — either one internal
node test or one segment-member distance — so the fused kernel is uniform
across lanes (low divergence in the paper's sense).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .lbvh import Tree
from .grid import Segments

INT_MAX = jnp.iinfo(jnp.int32).max


def _box_dist2(q, lo, hi):
    d = jnp.maximum(jnp.maximum(lo - q, q - hi), 0.0)
    return jnp.sum(d * d)


@partial(jax.jit, static_argnames=("mode", "use_range_mask"))
def traverse(tree: Tree, segs: Segments, eps: float,
             query_active: jax.Array,
             point_vals: jax.Array,
             point_mask: jax.Array,
             cap: int | jax.Array = INT_MAX,
             mode: str = "count",
             use_range_mask: bool = False):
    """Run one fused traversal for every (sorted-order) point.

    mode="count":    acc = |N_eps(q)| saturated at ``cap`` (early exit).
    mode="minlabel": acc = min(point_vals[j]) over neighbors j with
                     point_mask[j]; entering a *dense* segment stops at the
                     first member hit (all members share one label — the
                     paper's dense-cell short-circuit). Also returns the
                     found-any flag packed in the count output.

    Returns (acc, count) where count is the number of matched neighbors
    (mode minlabel counts matched neighbors excluding self).
    """
    n = segs.n_points
    m = segs.n_segments
    leaf_off = m - 1
    eps2 = jnp.asarray(eps, segs.pts.dtype) ** 2
    pts = segs.pts
    root = jnp.int32(0 if m > 1 else leaf_off)  # m==1: the single leaf

    def one_query(q_idx, active):
        q = pts[q_idx]

        def cond(state):
            node, ptr, acc, cnt = state
            live = node >= 0
            if mode == "count":
                live = live & (acc < cap)
            return live

        def body(state):
            node, ptr, acc, cnt = state
            is_member_step = ptr >= 0

            # ---- member step: one distance test against sorted point ptr --
            j = jnp.where(is_member_step, ptr, 0)
            diff = q - pts[j]
            d2 = jnp.sum(diff * diff)
            hit = is_member_step & (d2 <= eps2)
            hit_other = hit & (j != q_idx)
            if mode == "count":
                acc_new = acc + jnp.where(hit, 1, 0)
                # cnt tracks distance evaluations (the paper's work metric)
                cnt_new = cnt + jnp.where(is_member_step, 1, 0)
                stop_seg = False
            else:
                ok = hit & point_mask[j]
                acc_new = jnp.where(ok, jnp.minimum(acc, point_vals[j]), acc)
                cnt_new = cnt + jnp.where(ok & (j != q_idx), 1, 0)
                # dense segment: all members share one label & core status;
                # the first hit tells us everything (paper §4.2).
                seg_id = jnp.where(node >= leaf_off, node - leaf_off, 0)
                stop_seg = ok & segs.dense_seg[seg_id]
            seg_id = jnp.where(node >= leaf_off, node - leaf_off, 0)
            seg_done = (ptr + 1 >= segs.seg_end[seg_id]) | stop_seg
            member_next_node = jnp.where(seg_done, tree.miss[node], node)
            member_next_ptr = jnp.where(seg_done, jnp.int32(-1), ptr + 1)

            # ---- node step: descend / skip -------------------------------
            is_leaf = node >= leaf_off
            seg = jnp.where(is_leaf, node - leaf_off, 0)
            bd2 = _box_dist2(q, tree.box_lo[node], tree.box_hi[node])
            overlap = bd2 <= eps2
            if use_range_mask:
                overlap = overlap & (tree.range_r[node] >= segs.seg_of_point[q_idx])
            # internal: go left on overlap else rope; leaf: enter members on
            # overlap (empty segments skip straight to the rope).
            child = jnp.where(node < leaf_off,
                              jnp.where(overlap, tree_left(tree, node), tree.miss[node]),
                              node)
            enter_members = is_leaf & overlap & (segs.seg_start[seg] < segs.seg_end[seg])
            node_next_node = jnp.where(is_leaf,
                                       jnp.where(enter_members, node, tree.miss[node]),
                                       child)
            node_next_ptr = jnp.where(enter_members, segs.seg_start[seg], jnp.int32(-1))

            node_out = jnp.where(is_member_step, member_next_node, node_next_node)
            ptr_out = jnp.where(is_member_step, member_next_ptr, node_next_ptr)
            acc_out = jnp.where(is_member_step, acc_new, acc)
            cnt_out = jnp.where(is_member_step, cnt_new, cnt)
            return node_out, ptr_out, acc_out, cnt_out

        if mode == "count":
            acc0 = jnp.int32(0)
        else:
            acc0 = point_vals[q_idx] if point_vals.ndim else jnp.int32(INT_MAX)
        start = jnp.where(active, root, jnp.int32(-1))
        node, ptr, acc, cnt = lax.while_loop(
            cond, body, (start, jnp.int32(-1), acc0, jnp.int32(0)))
        return acc, cnt

    qs = jnp.arange(n, dtype=jnp.int32)
    return jax.vmap(one_query)(qs, query_active)


def tree_left(tree: Tree, node):
    return tree.left[jnp.clip(node, 0, tree.left.shape[0] - 1)]


def count_neighbors(tree: Tree, segs: Segments, eps: float, cap: int,
                    query_active=None) -> jax.Array:
    """|N_eps(x)| per sorted point, saturated at ``cap`` (early exit)."""
    return count_neighbors_with_work(tree, segs, eps, cap, query_active)[0]


def count_neighbors_with_work(tree: Tree, segs: Segments, eps: float,
                              cap: int, query_active=None):
    """(counts, distance_evaluations) — the paper's work metric."""
    n = segs.n_points
    if query_active is None:
        query_active = jnp.ones(n, bool)
    dummy = jnp.zeros((), jnp.int32)
    return traverse(tree, segs, eps, query_active, dummy,
                    jnp.ones(n, bool), cap=cap, mode="count")


def minlabel_sweep(tree: Tree, segs: Segments, eps: float, labels: jax.Array,
                   gather_mask: jax.Array, query_active: jax.Array):
    """Per active query: min(label) over neighbors with gather_mask.

    Returns (min_labels, matched_other_count). ``labels`` must already be
    consistent within dense segments (the caller re-unifies after updates).
    """
    return traverse(tree, segs, eps, query_active, labels, gather_mask,
                    mode="minlabel")


def border_gather(tree: Tree, segs: Segments, eps: float, root_labels,
                  core_mask, query_active):
    """Min core-neighbor root label per non-core query; INT_MAX if none."""
    sentinel = jnp.full_like(root_labels, INT_MAX)
    vals = jnp.where(core_mask, root_labels, sentinel)
    acc, cnt = traverse(tree, segs, eps, query_active, vals, core_mask,
                        mode="minlabel")
    # acc was initialized with vals[q]; for non-core queries that is INT_MAX,
    # so acc == INT_MAX  <=>  no core neighbor (noise).
    return acc, cnt
