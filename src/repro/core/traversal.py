"""Predicate/callback BVH traversal engine fused with visitor epilogues.

This is the heart of FDBSCAN, redesigned the way the paper's framework
(ArborX) exposes it: a *generic* fused-traversal engine

    ``traverse(tree, segs, predicates, callback, carry) -> Trace``

where a **predicate batch** describes the queries and their geometry —
``intersects(sphere(eps))`` for fixed-radius search, ``nearest(k)`` for
distance-bounded k-nearest-neighbor search — and a **callback** is a
JAX-traceable visitor consuming matched neighbors *on the fly* over an
arbitrary accumulator pytree (the ``carry``); neighbor lists are never
materialized (the paper's O(n)-memory claim; DESIGN.md §3, §8).

The DBSCAN epilogues that used to be a closed ``mode=`` string enum are
now just visitor instances over this engine (DESIGN.md §8):

  * :class:`CountVisitor`         — |N_eps(q)| with early exit at ``cap``;
  * :class:`MinLabelVisitor`      — min gathered label over masked
                                    neighbors (hook sweeps, border gather);
  * :class:`CountMinLabelVisitor` — the fused first pass: count *and*
                                    min-label candidate in one walk;
  * :class:`KNNVisitor`           — the k-best (dist2, id) list that powers
                                    ``repro.neighbors.knn``.

Custom workloads implement the same four hooks (``init_carry`` /
``visit`` / ``done`` / ``segment_done``) — see DESIGN.md §8 for the
contract and why the K-unrolled dead-guarding survives arbitrary
callbacks.

GPU -> TPU mapping (unchanged by the redesign):
  * one CUDA thread per query  ->  one vmap lane per query; the vmapped
    ``lax.while_loop`` lowers to a single masked loop (lanes that finish go
    inert), the TPU analogue of a warp of independent traversals;
  * per-thread traversal stack  ->  precomputed ropes (``Tree.miss``), O(1)
    state per lane;
  * early exit  ->  the callback's ``done(carry)`` hook feeds the loop-mask
    condition;
  * the paper's "hide leaves j < i" mask  ->  a range test on
    ``Tree.range_r`` (skip subtrees whose max primitive index is below the
    query's own), via ``use_range_mask``.

Fused single-pass engine (DESIGN.md §4):
  * Each ``while_loop`` trip executes ``unroll`` work units (box tests or
    member distances) instead of one, amortizing the loop-carried overhead.
    Sub-steps are dead-guarded — every state select is masked by the lane's
    liveness — so lanes freeze exactly where the one-unit engine would,
    for *any* callback.
  * Queries are addressed by the predicate batch's explicit ``ids`` vector,
    so frontier sweeps can traverse a *compacted* active subset
    (ECL-CC-style active-set restriction) instead of masking inert
    full-width lanes.

External queries (DESIGN.md §6): ``intersects(sphere(eps), pts=...)``
decouples the query set from the tree's primitives — a lane traverses for
an arbitrary point that is not (necessarily) resident in the index. The
sharded distributed path runs eps-halo points received from other shards
as external queries against the local tree; the stream index chains one
query batch across its two trees by threading the carry. External lanes
have no resident identity, so self-exclusion and the dense/query-rank
shortcuts (which assume lane i <=> resident point i) are disabled.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import morton
from .lbvh import Tree, box_dist2 as _box_dist2
from .grid import Segments

INT_MAX = jnp.iinfo(jnp.int32).max

# Work units per while_loop trip. On lockstep accelerators (TPU/GPU) 4
# amortizes the loop-carried overhead (cond evaluation + state select per
# trip) without inflating tail waste: a lane overshoots by at most
# unroll-1 dead-guarded sub-steps. On CPU the while_loop is cheap and the
# masked sub-steps are pure overhead, so the default stays 1 there.
DEFAULT_UNROLL = 4 if jax.default_backend() in ("tpu", "gpu") else 1


# --------------------------------------------------------------------- #
# predicates                                                            #
# --------------------------------------------------------------------- #

class Sphere(NamedTuple):
    """Search geometry: a ball of radius ``r`` around each query point."""
    r: Any


def sphere(r) -> Sphere:
    """The eps-ball geometry for :func:`intersects` predicates."""
    return Sphere(r)


class Intersects(NamedTuple):
    """A batch of fixed-radius queries (ArborX's ``intersects(sphere)``).

    geometry: the shared :class:`Sphere` (its radius is a traced value —
        eps sweeps reuse one compiled program).
    ids: int32 sorted-order point indices; ``-1`` marks an inert (padding)
        lane. ``None`` traverses every resident point.
    pts: optional (k, d) *external* query coordinates (DESIGN.md §6). When
        given, lane i traverses for ``pts[i]`` instead of a tree point and
        ``ids`` only carries the inert-lane marker (-1 inert, anything
        else active).
    """
    geometry: Sphere
    ids: Any = None
    pts: Any = None


def intersects(geometry, ids=None, pts=None) -> Intersects:
    """Fixed-radius predicate batch: ``intersects(sphere(eps))``."""
    if not isinstance(geometry, Sphere):
        geometry = Sphere(geometry)
    return Intersects(geometry, ids, pts)


class Nearest:
    """A batch of k-nearest-neighbor queries (ArborX's ``nearest(k)``).

    Traversal is *distance-bounded*: a lane's box tests and member tests
    prune against ``min(r^2, worst-so-far)`` where worst-so-far is the
    callback's current k-th best distance (``worst_d2`` hook), so the
    search ball shrinks as better neighbors are found. ``r`` optionally
    caps the search radius (``None`` = unbounded). ``k`` is static (it
    sizes the carry); ``ids``/``pts`` work as in :class:`Intersects`.
    """

    def __init__(self, k: int, r=None, ids=None, pts=None):
        self.k = int(k)
        self.r = r
        self.ids = ids
        self.pts = pts

    def tree_flatten(self):
        return (self.r, self.ids, self.pts), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        r, ids, pts = children
        return cls(k, r=r, ids=ids, pts=pts)


jax.tree_util.register_pytree_node_class(Nearest)


def nearest(k: int, r=None, ids=None, pts=None) -> Nearest:
    """k-NN predicate batch: ``nearest(k)``, optionally radius-capped."""
    return Nearest(k, r=r, ids=ids, pts=pts)


# --------------------------------------------------------------------- #
# callback protocol                                                     #
# --------------------------------------------------------------------- #

class QueryCtx(NamedTuple):
    """Per-lane engine context handed to every callback hook.

    self_id: the lane's own sorted point index (-1 for external lanes —
             self-exclusion tests are vacuously false there).
    dense:   the query point lives in a dense segment (core by
             construction under DenseBox).
    rank:    the query's segment rank (``use_range_mask`` support).
    wide:    this lane uses the callback's *wide* gather mask (the split
             first sweep, DESIGN.md §4).
    """
    self_id: jax.Array
    dense: jax.Array
    rank: jax.Array
    wide: jax.Array


class AccHits(NamedTuple):
    """The standard DBSCAN carry: a scalar accumulator + a match counter.

    acc:  the visitor's accumulator — saturated neighbor count (incl.
          self) for :class:`CountVisitor`; min gathered value for the
          min-label visitors. Seeding ``acc`` via an explicit ``carry``
          chains a traveling query across trees/shards (DESIGN.md §6, §7).
    hits: matched neighbors *excluding* the query itself (mask-filtered by
          the min-label visitors; partial when a pass early-exits or a
          dense short-circuit fires).
    """
    acc: jax.Array
    hits: jax.Array


class Trace(NamedTuple):
    """Traversal outputs: the final callback carry + engine work counters.

    carry: the callback's accumulator pytree, one entry per lane.
    evals: member distance evaluations — the paper's work metric.
    iters: while_loop trips taken (after unrolling); the loop-overhead
           metric that ``unroll`` amortizes.

    ``acc``/``hits`` forward into an :class:`AccHits` carry so the DBSCAN
    epilogues read like the pre-redesign engine's outputs.
    """
    carry: Any
    evals: jax.Array
    iters: jax.Array

    @property
    def acc(self):
        return self.carry.acc

    @property
    def hits(self):
        return self.carry.hits


class Visitor:
    """Base callback: visits every predicate match of every live lane.

    Hooks (all JAX-traceable, called per lane inside the vmapped loop):

      init_carry(ids, external, segs) -> carry
          Build the batch-wide initial accumulator pytree (leading dim =
          lane count). Only used when ``traverse`` gets ``carry=None``;
          callers chain multi-tree queries by passing the previous tree's
          carry instead.
      visit(carry, j, d2, hit, ctx) -> (carry, matched)
          Consume one member: ``j`` is the sorted point index, ``d2`` the
          squared distance, ``hit`` whether the predicate matched (the
          hook runs unconditionally — dead lanes/misses must be masked
          with ``jnp.where``, which keeps the K-unroll dead-guarding
          intact). ``matched`` reports whether the visitor *accepted* the
          neighbor (drives the dense-segment short-circuit).
      done(carry, ctx) -> bool
          Lane early-exit: a True lane stops traversing (feeds the
          while-loop mask — the engine never asks again).
      segment_done(carry, matched, seg_dense, ctx) -> bool
          After a visit: may the rest of the current segment be skipped?
          (The dense-cell short-circuit: all members of a dense segment
          share one label and core status, so one accepted hit can stand
          for the whole cell — paper §4.2.)

    Subclasses must be registered as pytrees whose leaves are the arrays
    the hooks close over (labels, masks, caps...) so the jitted engine
    caches on visitor *structure*, not identity.
    """

    def init_carry(self, ids, external: bool, segs: Segments):
        """Build the batch-wide initial accumulator pytree.

        Args:
            ids: (L,) int32 lane id vector (-1 marks inert padding).
            external: the batch queries points not resident in the tree.
            segs: the segment index being traversed.

        Returns:
            The carry pytree; every leaf's leading dim is the lane count.
        """
        raise NotImplementedError

    def visit(self, carry, j, d2, hit, ctx):
        """Consume one candidate member (called for every work unit).

        Args:
            carry: the lane's current accumulator pytree.
            j: sorted point index of the candidate member.
            d2: squared distance query→member.
            hit: whether the predicate matched — the hook runs
                unconditionally; misses and dead lanes must be masked
                with ``jnp.where`` (never branched on), which is what
                keeps the K-unroll dead-guarding intact.
            ctx: the per-lane :class:`QueryCtx`.

        Returns:
            ``(carry, matched)`` — the updated accumulator and whether
            the visitor *accepted* the neighbor (drives the dense-segment
            short-circuit via :meth:`segment_done`).
        """
        raise NotImplementedError

    def done(self, carry, ctx):
        """Lane early-exit: a True lane stops traversing (feeds the
        while-loop mask — the engine never asks it again). Default:
        never exit early.

        Returns:
            bool (per lane).
        """
        return jnp.bool_(False)

    def segment_done(self, carry, matched, seg_dense, ctx):
        """May the rest of the current segment be skipped after a visit?

        The dense-cell short-circuit (paper §4.2): all members of a dense
        segment share one label and core status, so one accepted hit can
        stand for the whole cell. Default: never skip.

        Args:
            carry: the accumulator *after* the visit.
            matched: did the visitor accept the member just visited?
            seg_dense: is the current segment a dense cell?
            ctx: the per-lane :class:`QueryCtx`.

        Returns:
            bool (per lane) — True skips the segment's remaining members.
        """
        return jnp.bool_(False)


@jax.tree_util.register_pytree_node_class
class CountVisitor(Visitor):
    """acc = |N_eps(q)| (incl. self) saturated at ``cap``; the lane dies
    once ``acc`` reaches ``cap`` (the paper's min_pts early exit). hits
    counts matches excluding the query itself."""

    def __init__(self, cap=INT_MAX):
        self.cap = cap

    def tree_flatten(self):
        return (self.cap,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def init_carry(self, ids, external, segs):
        z = jnp.zeros(ids.shape, jnp.int32)
        return AccHits(acc=z, hits=z)

    def visit(self, carry, j, d2, hit, ctx):
        acc = jnp.minimum(carry.acc + jnp.where(hit, 1, 0), self.cap)
        hits = carry.hits + jnp.where(hit & (j != ctx.self_id), 1, 0)
        return AccHits(acc=acc, hits=hits), hit

    def done(self, carry, ctx):
        return carry.acc >= self.cap


@jax.tree_util.register_pytree_node_class
class MinLabelVisitor(Visitor):
    """acc = min(vals[j]) over neighbors j with mask[j] (init: the query's
    own value); entering a *dense* segment stops at the first accepted
    member (all members share one label — the paper's dense-cell
    short-circuit). ``mask_wide`` + the engine's ``wide_lanes`` run the
    split first sweep's narrow/wide gather choice per lane."""

    def __init__(self, vals, mask, mask_wide=None):
        self.vals = vals
        self.mask = mask
        self.mask_wide = mask_wide

    def tree_flatten(self):
        return (self.vals, self.mask, self.mask_wide), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def init_carry(self, ids, external, segs):
        hits = jnp.zeros(ids.shape, jnp.int32)
        if external:
            return AccHits(acc=jnp.full(ids.shape, INT_MAX, jnp.int32),
                           hits=hits)
        safe = jnp.maximum(ids, jnp.int32(0))
        return AccHits(acc=self.vals[safe], hits=hits)

    def _accept(self, j, hit, ctx):
        if self.mask_wide is not None:
            return hit & jnp.where(ctx.wide, self.mask_wide[j], self.mask[j])
        return hit & self.mask[j]

    def visit(self, carry, j, d2, hit, ctx):
        ok = self._accept(j, hit, ctx)
        acc = jnp.where(ok, jnp.minimum(carry.acc, self.vals[j]), carry.acc)
        hits = carry.hits + jnp.where(ok & (j != ctx.self_id), 1, 0)
        return AccHits(acc=acc, hits=hits), ok

    def segment_done(self, carry, matched, seg_dense, ctx):
        return matched & seg_dense


@jax.tree_util.register_pytree_node_class
class CountMinLabelVisitor(MinLabelVisitor):
    """The fused first pass (DESIGN.md §4) — acc as in
    :class:`MinLabelVisitor` *and* hits = neighbor count saturated at
    ``cap`` in the same walk. The lane itself never exits early (the
    gather needs the full neighborhood), but the dense short-circuit
    fires for dense queries and for lanes whose count has saturated —
    one member hit still yields a dense cell's unified label, so the
    gather stays exact while the count work collapses to the paper's
    early-exit budget."""

    def __init__(self, vals, mask, cap=INT_MAX):
        super().__init__(vals, mask)
        self.cap = cap

    def tree_flatten(self):
        return (self.vals, self.mask, self.cap), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def visit(self, carry, j, d2, hit, ctx):
        ok = hit & self.mask[j]
        acc = jnp.where(ok, jnp.minimum(carry.acc, self.vals[j]), carry.acc)
        hits = jnp.minimum(
            carry.hits + jnp.where(ok & (j != ctx.self_id), 1, 0), self.cap)
        return AccHits(acc=acc, hits=hits), ok

    def segment_done(self, carry, matched, seg_dense, ctx):
        return matched & seg_dense & (ctx.dense | (carry.hits >= self.cap))


class KNNCarry(NamedTuple):
    """Per-lane k-best list, ascending by (d2, id); empty slots are
    (+inf, -1). ``ids`` are sorted-space point indices."""
    d2: jax.Array   # (k,) per lane
    ids: jax.Array  # (k,) per lane


@jax.tree_util.register_pytree_node_class
class KNNVisitor(Visitor):
    """Maintains the k nearest neighbors per lane under a shrinking
    distance bound (pairs with the :class:`Nearest` predicate).

    Selection is lexicographic on (d2, id) — exactly a stable argsort of
    the brute-force distance row — so ties at the k-th radius resolve
    deterministically to the smaller index, and tie *sets* match brute
    force. ``id_map`` remaps the engine's sorted point index before the
    comparison and the carry (pass ``segs.order`` to select/record by
    original index); ``None`` keeps sorted-space ids. ``worst_d2`` feeds
    the engine's per-lane pruning bound: subtrees (and members) farther
    than the current k-th best cannot improve the list. The query point
    itself is a neighbor at d2 = 0 (callers drop it if unwanted)."""

    def __init__(self, k: int, id_map=None):
        self.k = int(k)
        self.id_map = id_map

    def tree_flatten(self):
        return (self.id_map,), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        return cls(k, id_map=children[0])

    def init_carry(self, ids, external, segs):
        n = ids.shape[0]
        return KNNCarry(
            d2=jnp.full((n, self.k), jnp.inf, segs.pts.dtype),
            ids=jnp.full((n, self.k), -1, jnp.int32))

    def worst_d2(self, carry):
        return carry.d2[self.k - 1]

    def visit(self, carry, j, d2, hit, ctx):
        dd, ii = carry.d2, carry.ids
        jid = j if self.id_map is None else self.id_map[j].astype(jnp.int32)
        # slots strictly better than the candidate under (d2, id) order
        better = (dd < d2) | ((dd == d2) & (ii < jid))
        pos = jnp.sum(better.astype(jnp.int32))
        ar = jnp.arange(self.k, dtype=jnp.int32)
        d_sh, i_sh = jnp.roll(dd, 1), jnp.roll(ii, 1)
        nd = jnp.where(ar < pos, dd, jnp.where(ar == pos, d2, d_sh))
        ni = jnp.where(ar < pos, ii, jnp.where(ar == pos, jid, i_sh))
        take = hit & (pos < self.k)
        return KNNCarry(d2=jnp.where(take, nd, dd),
                        ids=jnp.where(take, ni, ii)), take


# --------------------------------------------------------------------- #
# the engine                                                            #
# --------------------------------------------------------------------- #

def lane_arrays(segs: Segments, predicates, use_range_mask: bool = False):
    """Resolve a predicate batch into per-lane query arrays.

    Returns ``(query_ids, q_arr, self_arr, dense_arr, rank_arr, external,
    r2, is_nearest)``: the lane id vector (-1 marks inert padding), the
    per-lane query coordinates, the engine context source arrays, whether
    the batch is external (DESIGN.md §6), the squared (initial) search
    radius, and whether the batch is distance-bounded k-NN.

    Shared by the vmapped reference engine (:func:`traverse_impl`) and the
    Pallas kernel backend (``repro.kernels.traverse``) so both resolve
    predicates identically.
    """
    n = segs.n_points
    pts = segs.pts
    is_nearest = isinstance(predicates, Nearest)
    if is_nearest:
        r2 = (jnp.asarray(jnp.inf, pts.dtype) if predicates.r is None
              else jnp.asarray(predicates.r, pts.dtype) ** 2)
    else:
        r2 = jnp.asarray(predicates.geometry.r, pts.dtype) ** 2
    query_ids, query_pts = predicates.ids, predicates.pts
    external = query_pts is not None
    if external:
        if use_range_mask:
            raise ValueError("use_range_mask needs tree-resident queries")
        if query_ids is None:
            query_ids = jnp.zeros(query_pts.shape[0], jnp.int32)
        q_arr = query_pts
        self_arr = jnp.full(query_ids.shape, -1, jnp.int32)   # never matches
        dense_arr = jnp.zeros(query_ids.shape, bool)
        rank_arr = jnp.zeros(query_ids.shape, jnp.int32)
    else:
        if query_ids is None:
            query_ids = jnp.arange(n, dtype=jnp.int32)
        safe = jnp.maximum(query_ids, jnp.int32(0))
        q_arr = pts[safe]
        self_arr = query_ids
        dense_arr = segs.dense_pt[safe]
        rank_arr = segs.seg_of_point[safe]
    return (query_ids, q_arr, self_arr, dense_arr, rank_arr, external, r2,
            is_nearest)


def lane_sort_key(reorder: str, query_ids, q_arr, external: bool,
                  depth_rank=None):
    """Per-lane sort key for divergence-aware lane reordering.

    The lane-tiled Pallas kernel retires a tile only when its *slowest*
    lane finishes, so wall clock is the sum of per-tile max walk depths.
    Sorting lanes so that similar-depth walks share a tile minimizes that
    sum without changing any per-lane result (the kernel applies the
    inverse permutation on exit — DESIGN.md §9). Policies:

      * ``"none"``   — no key (identity; today's launch order).
      * ``"morton"`` — the query points' Morton codes: lanes in a tile
        walk spatially-correlated subtrees (the ArborX pre-sort). The
        only option for external/halo batches, whose queries are not
        tree-resident.
      * ``"depth"``  — descending ``depth_rank[query_id]``, where
        ``depth_rank`` is the measured per-query loop-trip count of a
        prior pass over the same index (``Trace.iters`` of the fused
        first pass). Groups equal-depth walks directly instead of using
        locality as a proxy. Falls back to Morton for external batches
        and to identity when no rank is available (resident lanes are
        already Morton-ordered: ``segs.pts`` is Z-order sorted and
        compacted id vectors are ascending).

    Returns the key array, or ``None`` when reordering is the identity.
    Dead lanes (``query_ids < 0``) get the maximum key so they pack into
    all-dead tiles that retire immediately.
    """
    if reorder in (None, "none"):
        return None
    if reorder not in ("morton", "depth"):
        raise ValueError(
            f"reorder must be 'none', 'morton' or 'depth'; got {reorder!r}")
    live = query_ids >= 0
    if reorder == "depth" and not external:
        if depth_rank is None:
            return None
        safe = jnp.maximum(query_ids, jnp.int32(0))
        depth = depth_rank[safe].astype(jnp.int32)
        return jnp.where(live, -depth, jnp.int32(INT_MAX))
    codes = morton.morton_encode(q_arr)
    return jnp.where(live, codes, jnp.uint32(0xFFFFFFFF))


def make_step(tree: Tree, segs: Segments, callback, *, q, ctx: QueryCtx,
              lane_wide, r2, is_nearest: bool,
              node_mask=None, node_mask_wide=None,
              use_range_mask: bool = False):
    """Build the dead-guarded one-unit-of-work step for the rope walk.

    The returned ``(step, live_of)`` pair is *shape-polymorphic over a
    leading lane axis*: the reference engine instantiates it with scalar
    per-lane values under ``vmap``; the Pallas kernel backend
    (``repro.kernels.traverse``) instantiates it once per lane tile with
    ``(lane_tile,)``-shaped state. Both trace the exact same op sequence,
    which is what pins the kernel bit-identical to the interpreter-path
    engine.

    ``step`` maps ``(node, ptr, carry, evals) -> (node, ptr, carry,
    evals)`` where every state select is masked by the lane's liveness
    (the dead-guarding that makes K-unrolling exact); ``live_of(node,
    carry)`` is the lane's loop-mask condition.
    """
    m = segs.n_segments
    leaf_off = m - 1
    pts = segs.pts
    dual_nodes = node_mask_wide is not None

    def bound2(carry):
        """Per-lane squared search radius at this instant."""
        if is_nearest:
            return jnp.minimum(r2, callback.worst_d2(carry))
        return r2

    def live_of(node, carry):
        return (node >= 0) & ~callback.done(carry, ctx)

    def step(state):
        """One unit of work; a no-op for lanes that already finished."""
        node, ptr, carry, evals = state
        live = live_of(node, carry)
        node_safe = jnp.maximum(node, 0)
        is_member = live & (ptr >= 0)
        bnd = bound2(carry)

        # ---- member step: one distance test against sorted point ptr --
        j = jnp.where(is_member, ptr, 0)
        diff = q - pts[j]
        d2 = jnp.sum(diff * diff, axis=-1)
        hit = is_member & (d2 <= bnd)
        seg_id = jnp.where(node_safe >= leaf_off, node_safe - leaf_off, 0)
        carry_m, matched = callback.visit(carry, j, d2, hit, ctx)
        stop_seg = callback.segment_done(carry_m, matched,
                                         segs.dense_seg[seg_id], ctx)
        seg_done = (ptr + 1 >= segs.seg_end[seg_id]) | stop_seg
        member_next_node = jnp.where(seg_done, tree.miss[node_safe], node)
        member_next_ptr = jnp.where(seg_done, jnp.int32(-1), ptr + 1)

        # ---- node step: descend / skip -------------------------------
        is_leaf = node_safe >= leaf_off
        seg = jnp.where(is_leaf, node_safe - leaf_off, 0)
        bd2 = _box_dist2(q, tree.box_lo[node_safe], tree.box_hi[node_safe])
        overlap = bd2 <= bnd
        if use_range_mask:
            overlap = overlap & (tree.range_r[node_safe] >= ctx.rank)
        if node_mask is not None:
            if dual_nodes:
                overlap = overlap & jnp.where(lane_wide,
                                              node_mask_wide[node_safe],
                                              node_mask[node_safe])
            else:
                overlap = overlap & node_mask[node_safe]
        # internal: go left on overlap else rope; leaf: enter members on
        # overlap (empty segments skip straight to the rope).
        child = jnp.where(node_safe < leaf_off,
                          jnp.where(overlap, tree_left(tree, node_safe),
                                    tree.miss[node_safe]),
                          node)
        enter_members = is_leaf & overlap & (segs.seg_start[seg]
                                             < segs.seg_end[seg])
        node_next_node = jnp.where(is_leaf,
                                   jnp.where(enter_members, node,
                                             tree.miss[node_safe]),
                                   child)
        node_next_ptr = jnp.where(enter_members, segs.seg_start[seg],
                                  jnp.int32(-1))

        node_new = jnp.where(is_member, member_next_node, node_next_node)
        ptr_new = jnp.where(is_member, member_next_ptr, node_next_ptr)
        carry_new = jax.tree.map(
            lambda cm, c: jnp.where(is_member, cm, c), carry_m, carry)
        evals_new = evals + jnp.where(is_member, 1, 0)
        # freeze finished lanes so unrolled sub-steps are no-ops
        return (jnp.where(live, node_new, node),
                jnp.where(live, ptr_new, ptr),
                jax.tree.map(lambda cn, c: jnp.where(live, cn, c),
                             carry_new, carry),
                jnp.where(live, evals_new, evals))

    return step, live_of


def traverse_impl(tree: Tree, segs: Segments, predicates, callback,
                  carry=None,
                  node_mask: jax.Array | None = None,
                  node_mask_wide: jax.Array | None = None,
                  wide_lanes: jax.Array | None = None,
                  use_range_mask: bool = False,
                  unroll: int = DEFAULT_UNROLL) -> Trace:
    """Run one fused traversal per predicate lane, driving ``callback``.

    predicates: an :func:`intersects` or :func:`nearest` batch. Its
        ``ids``/``pts`` select resident vs external queries and mark inert
        (-1) padding lanes; its geometry sets the (initial) search radius.
    callback: a :class:`Visitor`; its hooks consume matches on the fly
        over the ``carry`` accumulator pytree.
    carry: initial accumulator (leading dim = lane count). ``None`` asks
        the callback (``init_carry``). Passing the previous tree's final
        carry chains one query batch across several trees — the stream
        index's two-level reads and the sharded path's traveling halo
        queries (their running min rides the carry between shard visits).
    node_mask: optional (2m-1,) per-node flag; subtrees whose flag is
        False are pruned as if their boxes missed. Frontier sweeps pass
        the "subtree contains a changed point" flag (DESIGN.md §4) so
        lanes far from any change die within a few box tests.
    node_mask_wide / wide_lanes: optional second node mask selected per
        lane by the boolean ``wide_lanes``; lanes flagged wide also get
        ``ctx.wide`` so a dual-mask visitor switches its gather mask
        (the split first main sweep, DESIGN.md §4).
    """
    m = segs.n_segments
    leaf_off = m - 1
    root = jnp.int32(0 if m > 1 else leaf_off)  # m==1: the single leaf
    (query_ids, q_arr, self_arr, dense_arr, rank_arr, external, r2,
     is_nearest) = lane_arrays(segs, predicates, use_range_mask)
    if carry is None:
        carry = callback.init_carry(query_ids, external, segs)
    if wide_lanes is None:
        wide_lanes = jnp.zeros_like(query_ids, dtype=bool)

    def one_query(qid, lane_wide, q, q_self, q_dense, q_rank, carry0):
        lane_on = qid >= 0
        ctx = QueryCtx(self_id=q_self, dense=q_dense, rank=q_rank,
                       wide=lane_wide)
        step, live_of = make_step(tree, segs, callback, q=q, ctx=ctx,
                                  lane_wide=lane_wide, r2=r2,
                                  is_nearest=is_nearest,
                                  node_mask=node_mask,
                                  node_mask_wide=node_mask_wide,
                                  use_range_mask=use_range_mask)

        def cond(state):
            node, ptr, carry, evals, iters = state
            return live_of(node, carry)

        def body(state):
            node, ptr, carry, evals, iters = state
            inner = (node, ptr, carry, evals)
            for _ in range(unroll):
                inner = step(inner)
            return (*inner, iters + 1)

        start = jnp.where(lane_on, root, jnp.int32(-1))
        node, ptr, carry, evals, iters = lax.while_loop(
            cond, body, (start, jnp.int32(-1), carry0, jnp.int32(0),
                         jnp.int32(0)))
        return Trace(carry=carry, evals=evals, iters=iters)

    return jax.vmap(one_query)(query_ids, wide_lanes, q_arr, self_arr,
                               dense_arr, rank_arr, carry)


# The jitted entry point. Callers already inside a traced context (the
# sharded distributed kernel runs under shard_map) use ``traverse_impl``
# directly: a nested jit there would launch a separate per-device module
# whose collective-free body still participates in the host-device
# rendezvous machinery and can wedge the outer collectives. Predicates and
# callbacks are pytrees — their array leaves (labels, masks, caps, eps)
# are traced operands, their structure (visitor class, k) is the cache
# key — so parameter sweeps reuse one compiled program per visitor shape.
traverse = partial(jax.jit,
                   static_argnames=("use_range_mask", "unroll"))(traverse_impl)


def tree_left(tree: Tree, node):
    return tree.left[jnp.clip(node, 0, tree.left.shape[0] - 1)]


def _ids_from_mask(n: int, query_active) -> jax.Array:
    """Full-width id vector with inactive lanes marked -1 (no compaction)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    if query_active is None:
        return ids
    return jnp.where(query_active, ids, jnp.int32(-1))


# --------------------------------------------------------------------- #
# DBSCAN epilogue helpers (visitor instances over the engine)           #
# --------------------------------------------------------------------- #

def count_neighbors(tree: Tree, segs: Segments, eps: float, cap: int,
                    query_active=None) -> jax.Array:
    """|N_eps(x)| per sorted point, saturated at ``cap`` (early exit)."""
    return count_neighbors_with_work(tree, segs, eps, cap, query_active)[0]


def count_neighbors_with_work(tree: Tree, segs: Segments, eps: float,
                              cap: int, query_active=None):
    """(counts, distance_evaluations) — the paper's work metric."""
    n = segs.n_points
    tr = traverse(tree, segs,
                  intersects(sphere(eps), ids=_ids_from_mask(n, query_active)),
                  CountVisitor(cap=cap))
    return tr.acc, tr.evals


def minlabel_sweep(tree: Tree, segs: Segments, eps: float, labels: jax.Array,
                   gather_mask: jax.Array, query_active: jax.Array):
    """Per active query: min(label) over neighbors with gather_mask.

    Returns (min_labels, matched_other_count); an inactive query returns
    its own ``labels`` value (no-op hook). ``labels`` must already be
    consistent within dense segments (the caller re-unifies after updates).
    """
    tr = traverse(tree, segs,
                  intersects(sphere(eps),
                             ids=_ids_from_mask(segs.n_points, query_active)),
                  MinLabelVisitor(labels, gather_mask))
    # inactive lanes carry no query identity inside the engine; restore
    # the own-value contract here where lane i <=> point i
    acc = jnp.where(query_active, tr.acc, labels)
    return acc, tr.hits


def fused_count_minlabel(tree: Tree, segs: Segments, eps: float,
                         point_vals: jax.Array, point_mask=None,
                         query_ids=None, cap: int | jax.Array = INT_MAX,
                         traverse_fn=None, depth_rank=None) -> Trace:
    """The fused first pass (DESIGN.md §4): one walk, two answers.

    Returns the full ``Trace``: ``acc`` is the min gathered value over all
    masked neighbors (candidate label — the caller validates it against the
    core mask once counts are known), ``hits`` the neighbor count excluding
    self, exact up to saturation at ``cap`` (pass ``min_pts - 1``; dense
    queries are core by construction and may undercount). ``traverse_fn``
    swaps the execution engine (the Pallas kernel backend passes
    ``repro.kernels.traverse.traverse``); the default is the vmapped
    reference engine.
    """
    if point_mask is None:
        point_mask = jnp.ones(segs.n_points, bool)
    if traverse_fn is None:   # the one place the engine default resolves
        traverse_fn = traverse
    # depth_rank is a kernel-only lane-scheduling hint (the reference
    # engine has no lane tiles to pack); forwarded only when present so
    # reference traverse_fn signatures stay unchanged.
    kw = {} if depth_rank is None else {"depth_rank": depth_rank}
    return traverse_fn(tree, segs, intersects(sphere(eps), ids=query_ids),
                       CountMinLabelVisitor(point_vals, point_mask, cap=cap),
                       **kw)


def border_gather(tree: Tree, segs: Segments, eps: float, root_labels,
                  core_mask, query_active):
    """Min core-neighbor root label per non-core query; INT_MAX if none."""
    sentinel = jnp.full_like(root_labels, INT_MAX)
    vals = jnp.where(core_mask, root_labels, sentinel)
    tr = traverse(tree, segs,
                  intersects(sphere(eps),
                             ids=_ids_from_mask(segs.n_points, query_active)),
                  MinLabelVisitor(vals, core_mask))
    # active lanes start from vals[q] (INT_MAX for non-core queries), so
    # acc == INT_MAX <=> no core neighbor (noise); inactive lanes return
    # their own vals[q] to keep the lane i <=> point i contract.
    acc = jnp.where(query_active, tr.acc, vals)
    return acc, tr.hits
