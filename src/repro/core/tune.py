"""Per-plan autotuning for the ``pallas-tree`` backend (DESIGN.md §9).

One fixed (LANE_TILE=128, K=4) kernel configuration cannot cover every
query-density regime: the committed ``BENCH_traversal.json`` trajectory
showed it losing wall clock to the reference engine on two of three
scenarios, because a lane tile retires only when its *slowest* lane
finishes and the unroll factor K multiplies tail waste in sparse-frontier
phases. This module picks, per clustering plan and per phase
(``first_pass`` / ``sweep`` / ``border``):

  * the **execution engine** — the lane-tiled Pallas kernel, or the
    vmapped reference engine for shapes where kernel launch overhead
    dominates (tiny compacted frontiers, small border sets);
  * the **lane tile** and **unroll factor K** from the candidate grid
    (:data:`TUNE_LANE_TILES` × :data:`TUNE_UNROLLS`), subject to the
    VMEM budget (lane state + whole-array index residency must fit);
  * the **lane reordering policy** (``repro.core.traversal.lane_sort_key``)
    — Morton order for external batches, measured walk-depth order for
    resident queries once the fused first pass has calibrated a
    per-query depth oracle (``Trace.iters`` is free: the kernel already
    returns it).

Every choice changes only the *schedule*; results are bit-identical by
construction (the kernel shares ``make_step`` with the reference engine
and inverse-permutes reordered lanes on exit), so the tuner needs no
conformance machinery of its own — ``tests/test_tune.py`` pins the full
config grid byte-equal to the golden fixtures.

Modes (``REPRO_TUNE`` environment variable):

  * ``off``        — the deterministic pin: every phase runs the Pallas
    kernel at (128, 4) with no reordering, reproducing the pre-tuner
    behavior exactly (golden tests, counter gates).
  * ``heuristic``  — the default: a stats-driven config (no measurement)
    derived from the backend and cheap index stats; includes the
    depth-rank calibration and the small-frontier reference fallback.
  * ``search``     — measured per-phase A/B over the candidate grid on
    the actual workload shapes; cached in the dispatcher's plan LRU
    under :func:`stats_key` so equal-shaped plans reuse the result.
    This is what ``make bench-tune`` runs.

The chosen config is recorded in the obs metrics snapshot (gauge
``tuned_config_info`` with per-phase labels) and in
``BENCH_traversal.json`` as ``tuned_config``.
"""
from __future__ import annotations

import os
import time
from functools import partial
from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from . import traversal

#: Candidate grid. The conformance test sweeps all of it; the measured
#: search uses the subset in _SEARCH_LANE_TILES/_SEARCH_UNROLLS.
TUNE_LANE_TILES = (64, 128, 256, 512)
TUNE_UNROLLS = (1, 2, 4, 8)

_SEARCH_LANE_TILES = (128, 256, 512)
_SEARCH_UNROLLS = (1, 4)

#: VMEM budget for whole-array index residency + per-lane walk state.
#: Matches dispatch.PALLAS_MAX_INDEX_BYTES semantics: beyond this the
#: kernel would spill, so candidate lane tiles are capped.
VMEM_BUDGET_BYTES = 8 << 20

#: Per-lane walk state footprint (node/ptr/carry/evals/iters + query
#: coords), conservative upper bound in bytes.
_LANE_STATE_BYTES = 64


class PhaseConfig(NamedTuple):
    """How one clustering phase executes its traversals."""
    engine: str = "pallas"      # "pallas" | "reference" | "auto"
    lane_tile: int = 128
    unroll: int = 4
    reorder: str = "none"       # "none" | "morton" | "depth"


class TunedConfig(NamedTuple):
    """A full per-plan tuning decision (one PhaseConfig per phase).

    ``min_lanes``: Pallas phases whose (padded) lane count falls below
    this run the reference engine instead — compacted-frontier sweeps
    shrink to a few dozen lanes where kernel launch overhead loses.
    ``border_min_frac``: an ``engine="auto"`` border phase picks the
    kernel only when the non-core fraction reaches this (noise-heavy
    datasets traverse nearly all lanes; clean ones a small minority).
    """
    first_pass: PhaseConfig = PhaseConfig()
    sweep: PhaseConfig = PhaseConfig()
    border: PhaseConfig = PhaseConfig()
    min_lanes: int = 0
    border_min_frac: float = 0.0
    source: str = "pinned"


#: REPRO_TUNE=off — today's fixed configuration, bit-and-schedule
#: identical to the pre-tuner kernel path.
PINNED = TunedConfig()


def mode() -> str:
    """Resolve the REPRO_TUNE environment variable to a tuner mode."""
    m = os.environ.get("REPRO_TUNE", "").strip().lower()
    if m in ("off", "0", "none", "pinned"):
        return "off"
    if m == "search":
        return "search"
    return "heuristic"


# ---------------------------------------------------------------------------
# Engine resolution: stable function identities per PhaseConfig
# ---------------------------------------------------------------------------

_ENGINE_FNS: dict[PhaseConfig, Any] = {}


def engine_fn(cfg: PhaseConfig):
    """The traversal engine callable for ``cfg``, with a *stable identity*.

    ``_fused_first_pass_jit`` takes the engine as a static jit argument,
    so the same PhaseConfig must always resolve to the same function
    object or every call would retrace. The default (128, 4, none)
    kernel config resolves to the bare ``repro.kernels.traverse.traverse``
    — the exact object the pre-tuner path used — so REPRO_TUNE=off hits
    the same jit cache entries as before the tuner existed.
    """
    if cfg.engine == "reference":
        return traversal.traverse
    fn = _ENGINE_FNS.get(cfg)
    if fn is None:
        from repro.kernels import traverse as pallas_traverse
        if (cfg.lane_tile == pallas_traverse.LANE_TILE
                and cfg.unroll == pallas_traverse.PALLAS_UNROLL
                and cfg.reorder == "none"):
            fn = pallas_traverse.traverse
        else:
            fn = partial(pallas_traverse.traverse, lane_tile=cfg.lane_tile,
                         unroll=cfg.unroll, reorder=cfg.reorder)
        _ENGINE_FNS[cfg] = fn
    return fn


def lane_tiles_within_budget(index_bytes: int,
                             candidates=TUNE_LANE_TILES) -> tuple:
    """Candidate lane tiles whose state + index fit the VMEM budget."""
    fit = tuple(t for t in candidates
                if index_bytes + t * _LANE_STATE_BYTES <= VMEM_BUDGET_BYTES)
    return fit or candidates[:1]


# ---------------------------------------------------------------------------
# Per-plan state
# ---------------------------------------------------------------------------

class TuneState:
    """Mutable tuning state attached to a dispatcher Plan.

    Holds the (immutable) :class:`TunedConfig` plus the lazily-calibrated
    depth oracle: after the first fused pass, ``calibrate`` stores that
    pass's per-query loop-trip counts (``Trace.iters``, indexed by sorted
    point id), and subsequent ``reorder="depth"`` traversals sort lanes
    by descending depth. The oracle only affects lane *order* (results
    are inverse-permuted), so a stale or missing oracle is a performance
    detail, never a correctness one.
    """

    def __init__(self, config: TunedConfig):
        self.config = config
        self.depth_rank = None
        self.info: dict = {}

    def phase(self, name: str, *, n_lanes: int | None = None,
              n: int | None = None) -> PhaseConfig:
        """Resolve the phase's config against the actual lane shape."""
        cfg: PhaseConfig = getattr(self.config, name)
        if cfg.engine == "auto":
            frac = 1.0 if not n else (n_lanes or 0) / n
            cfg = cfg._replace(
                engine="pallas" if frac >= self.config.border_min_frac
                else "reference")
        if (cfg.engine == "pallas" and n_lanes is not None
                and n_lanes < self.config.min_lanes):
            cfg = cfg._replace(engine="reference")
        return cfg

    def rank_for(self, cfg: PhaseConfig):
        """The depth oracle, iff this phase's kernel wants it."""
        if cfg.engine == "pallas" and cfg.reorder == "depth":
            return self.depth_rank
        return None

    def calibrate(self, iters) -> None:
        """Store the fused pass's per-query walk depth as the oracle."""
        if self.depth_rank is None and self.config.source != "pinned":
            self.depth_rank = iters

    def describe(self) -> dict:
        """JSON-safe record of the decision (bench artifact, obs gauge)."""
        out = {"source": self.config.source,
               "min_lanes": int(self.config.min_lanes),
               "border_min_frac": float(self.config.border_min_frac),
               "calibrated": self.depth_rank is not None}
        for name in ("first_pass", "sweep", "border"):
            cfg: PhaseConfig = getattr(self.config, name)
            out[name] = {"engine": cfg.engine,
                         "lane_tile": int(cfg.lane_tile),
                         "unroll": int(cfg.unroll),
                         "reorder": cfg.reorder}
        out.update(self.info)
        return out


# ---------------------------------------------------------------------------
# Config derivation: stats key, heuristic, measured search
# ---------------------------------------------------------------------------

def _index_bytes(segs, tree) -> int:
    """Whole-array VMEM footprint of the (segments, tree) index."""
    total = 0
    for holder in (segs, tree):
        if holder is None:
            continue
        for leaf in holder:
            if leaf is not None and hasattr(leaf, "nbytes"):
                total += int(leaf.nbytes)
    return total


def stats_key(segs, eps: float, min_pts: int) -> tuple:
    """Cheap index stats bucketed into a search-cache key.

    Log2-bucketed (n, leaf occupancy, eps-cell density estimate) plus the
    dimension: plans with the same bucket tuple share a measured config.
    The density estimate here is grid-based (occupied eps-cells), cheap
    enough to compute *before* any traversal; the measured search refines
    it with the fused count pass's mean hit count and records both in the
    tuner artifact.
    """
    n = int(segs.n_points)
    m = max(int(segs.n_segments), 1)
    d = int(segs.pts.shape[1])
    occupancy = n / m
    density = occupancy
    if eps > 0:
        from . import fdbscan
        keys = fdbscan._cell_keys(segs.pts, eps)
        density = n / max(len(np.unique(keys)), 1)

    def bucket(x: float) -> int:
        return int(round(np.log2(max(x, 1.0))))

    return (d, bucket(n), bucket(occupancy + 1), bucket(density + 1),
            int(min_pts))


def heuristic(segs, tree) -> TunedConfig:
    """Stats-driven config, no measurement.

    On TPU the compiled kernel's (128, 4) defaults stand (they match the
    VPU lane count and amortize the loop-carried overhead); the win there
    is depth reordering plus the small-frontier fallback. Off-TPU the
    kernel runs in interpret mode, where per-trip Python overhead
    dominates: the widest in-budget lane tile with K=1 minimizes trips,
    and measured phase costs (BENCH_traversal.json) show the reference
    engine winning small compacted batches — hence the fallbacks.
    """
    tiles = lane_tiles_within_budget(_index_bytes(segs, tree))
    if jax.default_backend() == "tpu":
        fp = PhaseConfig("pallas", 128, 4, "depth")
        sw = PhaseConfig("pallas", 128, 4, "depth")
        bd = PhaseConfig("auto", 128, 4, "none")
    else:
        wide = max(tiles)
        fp = PhaseConfig("pallas", wide, 1, "depth")
        sw = PhaseConfig("pallas", wide, 1, "depth")
        bd = PhaseConfig("auto", min(256, wide), 1, "none")
    return TunedConfig(first_pass=fp, sweep=sw, border=bd,
                       min_lanes=256, border_min_frac=0.9,
                       source="heuristic")


def _time_best(fn, repeats: int = 3) -> float:
    """Best-of-N wall time after a compile/warmup call."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def search(segs, tree, eps: float, min_pts: int
           ) -> tuple[TunedConfig, dict]:
    """Measured per-phase A/B over the candidate grid.

    Runs the fused first pass once with the reference engine to obtain
    the workload's real phase shapes (core mask, first-sweep frontier,
    border set) and the depth oracle, then times each candidate engine on
    those exact shapes and keeps the per-phase winner. All candidates
    produce bit-identical results, so this is purely a schedule decision;
    the caller (dispatch) caches the returned ``(config, info)`` under
    :func:`stats_key` — the config is shareable across equal-shaped
    plans, while the per-plan depth oracle is recalibrated by each plan's
    own first pass (it is indexed by that plan's sorted point ids).
    """
    from . import fdbscan

    base = heuristic(segs, tree)
    tiles = lane_tiles_within_budget(_index_bytes(segs, tree),
                                     _SEARCH_LANE_TILES)
    info: dict = {}

    core, labels0, vals0, absorbed, first = fdbscan._fused_first_pass(
        tree, segs, eps, min_pts)
    jax.block_until_ready(core)
    rank = first.iters
    info["mean_hits"] = float(jnp.mean(first.hits))

    def candidates(reorder: str):
        yield PhaseConfig("reference", 0, 0, "none")
        for lt in tiles:
            for k in _SEARCH_UNROLLS:
                yield PhaseConfig("pallas", lt, k, reorder)

    def pick(reorder: str, run) -> tuple[PhaseConfig, dict]:
        timings = {}
        for cand in candidates(reorder):
            fn = engine_fn(cand)
            kw = ({"depth_rank": rank}
                  if cand.engine == "pallas" and cand.reorder == "depth"
                  else {})
            label = (cand.engine if cand.engine == "reference" else
                     f"pallas/{cand.lane_tile}x{cand.unroll}/{cand.reorder}")
            timings[label] = _time_best(lambda: run(fn, kw))
        best_label = min(timings, key=timings.get)
        best = next(c for c in candidates(reorder)
                    if (c.engine if c.engine == "reference" else
                        f"pallas/{c.lane_tile}x{c.unroll}/{c.reorder}"
                        ) == best_label)
        return best, timings

    # -- first pass: the full fused count+minlabel walk ------------------
    def run_first(fn, kw):
        out = fdbscan._fused_first_pass(tree, segs, eps, min_pts,
                                        traverse_fn=fn, **kw)
        jax.block_until_ready(out[0])

    fp, t_fp = pick("depth", run_first)

    # -- sweep: the first (widest) min-label sweep shape -----------------
    core_np = np.asarray(core)
    ids_sweep = fdbscan._compact_ids(core_np)
    nm_core = fdbscan._frontier_node_mask(tree, segs, core)

    def run_sweep(fn, kw):
        tr = fn(tree, segs,
                traversal.intersects(traversal.sphere(eps), ids=ids_sweep),
                traversal.MinLabelVisitor(labels0, core),
                node_mask=nm_core, **kw)
        jax.block_until_ready(tr.acc)

    sw, t_sw = pick("depth", run_sweep)

    # -- border: the non-core gather shape -------------------------------
    ids_border = fdbscan._compact_ids(~core_np)
    border_vals = jnp.where(core, labels0, jnp.int32(traversal.INT_MAX))

    def run_border(fn, kw):
        tr = fn(tree, segs,
                traversal.intersects(traversal.sphere(eps), ids=ids_border),
                traversal.MinLabelVisitor(border_vals, core),
                node_mask=nm_core, **kw)
        jax.block_until_ready(tr.acc)

    bd, t_bd = pick("none", run_border)

    info["timings"] = {"first_pass": t_fp, "sweep": t_sw, "border": t_bd}
    cfg = TunedConfig(first_pass=fp, sweep=sw, border=bd,
                      min_lanes=base.min_lanes, border_min_frac=0.0,
                      source="search")
    return cfg, info


def config_for(segs, tree, eps: float, min_pts: int,
               mode_name: str | None = None) -> TunedConfig:
    """The non-measured config for the active (or given) mode."""
    m = mode_name or mode()
    if m == "off":
        return PINNED
    return heuristic(segs, tree)
