"""Morton (Z-order) codes for low-dimensional points.

The LBVH construction (Karras 2012) requires primitives sorted along a
space-filling curve. We quantize coordinates to a fixed per-dimension bit
budget (16 bits/dim for 2D, 10 bits/dim for 3D -> codes fit in uint32) and
interleave bits with the classic magic-number spreads.

TPU note: all of this is elementwise integer VPU work and vectorizes
trivially; no adaptation from the GPU version is required.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BITS_2D = 16
BITS_3D = 10


def _expand_bits_2d(v: jax.Array) -> jax.Array:
    """Spread the low 16 bits of ``v`` so there is a 0 bit between each."""
    v = v & jnp.uint32(0x0000FFFF)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def _expand_bits_3d(v: jax.Array) -> jax.Array:
    """Spread the low 10 bits of ``v`` so there are 2 zero bits in between."""
    v = v & jnp.uint32(0x000003FF)
    v = (v | (v << 16)) & jnp.uint32(0x030000FF)
    v = (v | (v << 8)) & jnp.uint32(0x0300F00F)
    v = (v | (v << 4)) & jnp.uint32(0x030C30C3)
    v = (v | (v << 2)) & jnp.uint32(0x09249249)
    return v


def quantize(points: jax.Array, n_bits: int, lo: jax.Array | None = None,
             hi: jax.Array | None = None) -> jax.Array:
    """Quantize ``points`` (n, d) into integer grid coords in [0, 2**n_bits)."""
    if lo is None:
        lo = jnp.min(points, axis=0)
    if hi is None:
        hi = jnp.max(points, axis=0)
    extent = jnp.maximum(hi - lo, jnp.finfo(points.dtype).tiny)
    scale = (2.0**n_bits - 1.0) / extent
    q = jnp.floor((points - lo) * scale)
    q = jnp.clip(q, 0.0, 2.0**n_bits - 1.0)
    return q.astype(jnp.uint32)


def morton_encode(points: jax.Array, lo: jax.Array | None = None,
                  hi: jax.Array | None = None) -> jax.Array:
    """Morton codes (uint32) for (n, 2) or (n, 3) float points.

    ``lo``/``hi`` override the quantization bounds (default: the data's own
    extent). The sharded distributed path passes the bounds of the *valid*
    resident points so padding sentinels cannot stretch the grid; sentinel
    coordinates simply clip to the top cell.
    """
    d = points.shape[-1]
    if d == 2:
        q = quantize(points, BITS_2D, lo, hi)
        return (_expand_bits_2d(q[:, 0]) << 1) | _expand_bits_2d(q[:, 1])
    if d == 3:
        q = quantize(points, BITS_3D, lo, hi)
        return ((_expand_bits_3d(q[:, 0]) << 2)
                | (_expand_bits_3d(q[:, 1]) << 1)
                | _expand_bits_3d(q[:, 2]))
    raise ValueError(f"morton_encode supports d in (2, 3); got d={d}")


def morton_sort(points: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort points along the Z-curve.

    Returns (sorted_points, order, sorted_codes); ``order[i]`` is the original
    index of sorted position i. ``argsort`` is stable, so equal codes keep
    their original relative order (the LBVH delta function breaks ties by
    index, which this guarantees to be consistent).
    """
    codes = morton_encode(points)
    order = jnp.argsort(codes)
    return points[order], order, codes[order]
