"""DBSCAN-axiom checker: validates a labeling against first principles.

Border points may legitimately belong to any adjacent cluster (the paper
assigns "first encountered", we assign min-representative), so label arrays
cannot be compared naively. This checker accepts exactly the set of valid
DBSCAN labelings:

  A1  core_mask is correct: |N_eps(x)| >= minpts  <=>  core.
  A2  density-connected core points share a label (same component of the
      core-core eps-graph).
  A3  core points in different components have different labels.
  A4  a border point (non-core with >= 1 core neighbor) carries the label of
      at least one core neighbor.
  A5  noise (non-core, no core neighbor) is labeled -1; nothing else is.
"""
from __future__ import annotations

import numpy as np


def check_dbscan(points, eps: float, min_pts: int, labels, core_mask) -> None:
    pts = np.asarray(points, np.float64)
    labels = np.asarray(labels)
    core = np.asarray(core_mask)
    n = pts.shape[0]
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    adj = d2 <= eps * eps

    counts = adj.sum(1)
    ref_core = counts >= min_pts
    assert (core == ref_core).all(), (
        f"A1 core mask mismatch at {np.nonzero(core != ref_core)[0][:10]}")

    # components of the core-core graph (union-find, NumPy)
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ci = np.nonzero(ref_core)[0]
    for i in ci:
        for j in np.nonzero(adj[i] & ref_core)[0]:
            ri, rj = find(i), find(int(j))
            if ri != rj:
                parent[max(ri, rj)] = min(ri, rj)
    comp = np.array([find(i) for i in range(n)])

    for i in ci:
        assert labels[i] >= 0, f"A2 core point {i} labeled noise"
    # A2/A3: label partition == component partition on core points
    for rep in np.unique(comp[ref_core]):
        ls = np.unique(labels[ref_core & (comp == rep)])
        assert len(ls) == 1, f"A2 component {rep} split into labels {ls}"
    by_label = {}
    for i in ci:
        by_label.setdefault(int(labels[i]), set()).add(int(comp[i]))
    for l, comps in by_label.items():
        assert len(comps) == 1, f"A3 label {l} merges components {comps}"

    for i in np.nonzero(~ref_core)[0]:
        core_nbrs = np.nonzero(adj[i] & ref_core)[0]
        if len(core_nbrs) == 0:
            assert labels[i] == -1, f"A5 isolated point {i} not noise"
        else:
            assert labels[i] in set(int(labels[j]) for j in core_nbrs), (
                f"A4 border {i} labeled {labels[i]} but core nbr labels are "
                f"{sorted(set(int(labels[j]) for j in core_nbrs))}")


def same_partition(labels_a, labels_b) -> bool:
    """True iff two labelings induce the same partition (noise == noise)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    if ((a == -1) != (b == -1)).any():
        return False
    fwd, bwd = {}, {}
    for x, y in zip(a, b):
        if x == -1:
            continue
        if fwd.setdefault(int(x), int(y)) != y:
            return False
        if bwd.setdefault(int(y), int(x)) != x:
            return False
    return True
