"""DBSCAN-axiom checker: validates a labeling against first principles.

Border points may legitimately belong to any adjacent cluster (the paper
assigns "first encountered", we assign min-representative), so label arrays
cannot be compared naively. This checker accepts exactly the set of valid
DBSCAN labelings:

  A1  core_mask is correct: |N_eps(x)| >= minpts  <=>  core.
  A2  density-connected core points share a label (same component of the
      core-core eps-graph).
  A3  core points in different components have different labels.
  A4  a border point (non-core with >= 1 core neighbor) carries the label of
      at least one core neighbor.
  A5  noise (non-core, no core neighbor) is labeled -1; nothing else is.

All adjacency questions are answered from *blocked* row tiles (~2k rows at
a time) so the checker never materializes the n x n float64 distance
matrix — conformance runs at n >= 50k stay within O(n * block) memory.
Component structure is recovered with vectorized min-label relaxation +
pointer jumping over the same tiles, re-deriving adjacency per pass instead
of storing it.
"""
from __future__ import annotations

import numpy as np


def check_points(points, *, name: str = "points", allow_empty: bool = False,
                 dims: tuple = None, d: int = None) -> np.ndarray:
    """Validate a user-supplied point batch at the public surface.

    One shared gate for every entry point (``dispatch.plan``/``dbscan``,
    the streaming handle's ``insert``/``query``, ``neighbors.*``): a
    malformed batch must raise a clear ``ValueError`` *here*, not produce
    garbage Morton codes and silently wrong labels three layers down.

    Rejects: non-numeric / bool / complex dtypes, non-2-d shapes, empty
    point sets (unless ``allow_empty``), NaN/Inf coordinates, and a
    dimensionality outside ``dims`` (or different from ``d``).

    Args:
        points: any array-like the caller intends as an (n, d) batch.
        name: how to call the argument in error messages.
        allow_empty: permit n == 0 (e.g. an optional initial set).
        dims: allowed dimensionalities, e.g. ``(2, 3)``; None = any.
        d: exact required dimensionality (e.g. an index's own d).

    Returns:
        The batch as a host ``np.ndarray`` (no copy when the input
        already is one); callers do their own dtype conversion.

    Raises:
        ValueError: any of the rejections above, with the offending
            rows named for the NaN/Inf case.
    """
    try:
        arr = np.asarray(points)
    except (ValueError, TypeError) as e:
        raise ValueError(f"{name} is not a numeric array: {e}")
    if (arr.dtype == object or arr.dtype.kind not in "iuf"):
        raise ValueError(
            f"{name} must be a real-valued numeric array; got dtype "
            f"{arr.dtype} (bool/complex/object inputs would be cast to "
            "garbage coordinates silently)")
    if arr.ndim != 2:
        raise ValueError(f"{name} must have shape (n, d); got {arr.shape}")
    if arr.shape[0] == 0 and not allow_empty:
        raise ValueError(f"{name} is empty: got shape {arr.shape} "
                         "(an empty point set has no clustering)")
    if d is not None and arr.shape[1] != d:
        raise ValueError(f"{name} must be {d}-dimensional to match the "
                         f"index; got {arr.shape[1]}-d")
    if dims is not None and arr.shape[1] not in dims:
        raise ValueError(f"{name} must have d in {dims}; got shape "
                         f"{arr.shape}")
    if arr.dtype.kind == "f" and arr.size and not np.isfinite(arr).all():
        bad = np.flatnonzero(~np.isfinite(arr).all(axis=1))
        raise ValueError(
            f"{name} contains {len(bad)} row(s) with non-finite (NaN/Inf) "
            f"coordinates, first at rows {bad[:5].tolist()} — these would "
            "corrupt the Morton codes, not cluster as outliers")
    return arr

# Row-tile height for all blocked adjacency passes: n * block boolean cells
# live at once (~2k * n bits), never the n^2 matrix.
ORACLE_BLOCK = 2048


def adjacency_blocks(points, eps: float, block: int = ORACLE_BLOCK):
    """Yield ``(lo, hi, adj)`` row tiles of the eps-adjacency matrix.

    ``adj`` is the boolean slice ``[lo:hi, :]``, float64, via the BLAS
    Gram form ``|a|^2 + |b|^2 - 2ab`` (a dgemm per tile — the blocked
    oracle stays usable at n >= 50k). On the integer-grid property data
    every term is an exact float64 integer, so boundary decisions are
    exact; float data in the test-suite keeps a separation band around eps
    many orders above the ~1e-16 relative rounding of this form. Shared by
    :func:`check_dbscan` and ``baselines.dbscan_bruteforce_np``.
    """
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    e2 = eps * eps
    sq = (pts * pts).sum(-1)
    for lo in range(0, n, block):
        hi = min(n, lo + block)
        d2 = sq[lo:hi, None] + sq[None, :] - 2.0 * (pts[lo:hi] @ pts.T)
        yield lo, hi, d2 <= e2


def neighbor_counts(points, eps: float, block: int = ORACLE_BLOCK
                    ) -> np.ndarray:
    """|N_eps(x)| per point (self included), blocked."""
    pts = np.asarray(points, np.float64)
    counts = np.zeros(pts.shape[0], np.int64)
    for lo, hi, adj in adjacency_blocks(pts, eps, block):
        counts[lo:hi] = adj.sum(1)
    return counts


# Core-core edge budget for the one-pass component path (~1.6 GB as two
# int64 arrays); denser graphs fall back to per-pass tile re-derivation.
_EDGE_CAP = 100_000_000


def _jump(comp: np.ndarray) -> np.ndarray:
    """Pointer-jump ``comp`` (an index-valued forest, comp[i] <= i) to its
    fixpoint."""
    while True:
        jumped = comp[comp]
        if (jumped == comp).all():
            return comp
        comp = jumped


def _core_components(pts, eps, core, block) -> np.ndarray:
    """Min-index representative of each core point's core-core component.

    One blocked tile pass extracts the core-core edge list; vectorized
    min-label relaxation (``np.minimum.at``) + pointer jumping then runs to
    a fixpoint over it — the NumPy analogue of the library's hook + jump
    loop, kept independent of the code under test. If the graph exceeds
    ``_EDGE_CAP`` edges, relaxation re-derives adjacency from tiles per
    pass instead (slower, still O(n * block) memory).
    """
    n = pts.shape[0]
    comp = np.arange(n)
    srcs, dsts, total = [], [], 0
    for lo, hi, adj in adjacency_blocks(pts, eps, block):
        sub = adj & core[None, :] & core[lo:hi, None]
        r, c = np.nonzero(sub)
        total += len(r)
        if total > _EDGE_CAP:
            srcs = None
            break
        srcs.append((r + lo).astype(np.int64))
        dsts.append(c.astype(np.int64))

    if srcs is not None:
        src = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
        dst = np.concatenate(dsts) if dsts else np.empty(0, np.int64)
        while True:
            new = comp.copy()
            np.minimum.at(new, src, comp[dst])
            new = _jump(new)
            if (new == comp).all():
                return comp
            comp = new

    while True:  # over-budget fallback: re-derive adjacency per pass
        new = comp.copy()
        for lo, hi, adj in adjacency_blocks(pts, eps, block):
            sub = adj & core[None, :]
            gathered = np.where(sub, comp[None, :], n).min(1)
            new[lo:hi] = np.where(core[lo:hi],
                                  np.minimum(new[lo:hi], gathered),
                                  new[lo:hi])
        new = _jump(new)
        if (new == comp).all():
            return comp
        comp = new


def check_dbscan(points, eps: float, min_pts: int, labels, core_mask,
                 block: int = ORACLE_BLOCK) -> None:
    pts = np.asarray(points, np.float64)
    labels = np.asarray(labels)
    core = np.asarray(core_mask)
    n = pts.shape[0]

    counts = neighbor_counts(pts, eps, block)
    ref_core = counts >= min_pts
    assert (core == ref_core).all(), (
        f"A1 core mask mismatch at {np.nonzero(core != ref_core)[0][:10]}")

    comp = _core_components(pts, eps, ref_core, block)

    ci = np.nonzero(ref_core)[0]
    for i in ci:
        assert labels[i] >= 0, f"A2 core point {i} labeled noise"
    # A2/A3: label partition == component partition on core points
    for rep in np.unique(comp[ref_core]):
        ls = np.unique(labels[ref_core & (comp == rep)])
        assert len(ls) == 1, f"A2 component {rep} split into labels {ls}"
    by_label = {}
    for i in ci:
        by_label.setdefault(int(labels[i]), set()).add(int(comp[i]))
    for l, comps in by_label.items():
        assert len(comps) == 1, f"A3 label {l} merges components {comps}"

    # A4/A5 witnesses per non-core point, gathered from the same row tiles
    has_core_nbr = np.zeros(n, bool)
    label_ok = np.zeros(n, bool)   # some core neighbor carries labels[i]
    for lo, hi, adj in adjacency_blocks(pts, eps, block):
        sub = adj & ref_core[None, :]
        has_core_nbr[lo:hi] = sub.any(1)
        label_ok[lo:hi] = (sub & (labels[None, :]
                                  == labels[lo:hi, None])).any(1)
    for i in np.nonzero(~ref_core)[0]:
        if not has_core_nbr[i]:
            assert labels[i] == -1, f"A5 isolated point {i} not noise"
        else:
            assert label_ok[i], (
                f"A4 border {i} labeled {labels[i]} but no core neighbor "
                f"carries that label")


def check_component_identical(labels_a, core_a, labels_b, core_b) -> None:
    """Assert two DBSCAN results are *component-identical*: exact core
    mask, exact noise set, identical partition of the core points.

    This is the strongest comparison that is well-defined across backends
    — border points may legitimately attach to any adjacent cluster (see
    the module docstring), so full label arrays are never compared
    elementwise. The streaming subsystem's snapshot()-vs-batch contract
    (DESIGN.md §7) is stated in exactly these terms; the benchmark, the
    serving loop's ``--validate``, and the test suite all share this one
    definition.
    """
    ca, cb = np.asarray(core_a), np.asarray(core_b)
    assert (ca == cb).all(), "core mask differs"
    la, lb = np.asarray(labels_a), np.asarray(labels_b)
    assert ((la == -1) == (lb == -1)).all(), "noise set differs"
    assert same_partition(la[ca], lb[ca]), "core partition differs"


def same_partition(labels_a, labels_b) -> bool:
    """True iff two labelings induce the same partition (noise == noise)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    if ((a == -1) != (b == -1)).any():
        return False
    fwd, bwd = {}, {}
    for x, y in zip(a, b):
        if x == -1:
            continue
        if fwd.setdefault(int(x), int(y)) != y:
            return False
        if bwd.setdefault(int(y), int(x)) != x:
            return False
    return True
