"""FDBSCAN and FDBSCAN-DenseBox — the paper's two tree-based algorithms.

Two bulk phases over a segment BVH (DESIGN.md §1, §3):

  preprocessing: determine core points with an early-exit neighbor count
      (``minpts`` neighbors suffice — the paper's "lightweight" approach);
      entirely skipped when ``minpts == 2`` (every ε-pair is core-core) and,
      for DenseBox, skipped for all points inside dense cells (all core).

  main: min-label propagation sweeps fused into the traversal (hook) +
      pointer jumping (DESIGN.md §3 explains why this replaces the GPU's
      atomic-CAS union-find), iterated to a fixpoint. Border points are
      assigned in one final gather and never propagate labels — this removes
      the paper's critical section (no cluster bridging by construction).

Memory is O(n + m): neighbor lists are never materialized.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import grid, lbvh, traversal, unionfind

INT_MAX = traversal.INT_MAX


class DBSCANResult(NamedTuple):
    labels: jax.Array      # (n,) cluster id in [0, n_clusters) or -1 (noise)
    core_mask: jax.Array   # (n,) point is a core point
    n_clusters: int
    n_sweeps: int          # main-phase sweeps until fixpoint


def _unify_dense(labels, segs: grid.Segments):
    """Equalize labels within dense segments (paper: one UNION per cell)."""
    m = segs.n_segments
    seg_min = jax.ops.segment_min(labels, segs.seg_of_point, num_segments=m)
    dense_lab = seg_min[segs.seg_of_point]
    return jnp.where(segs.dense_pt, jnp.minimum(labels, dense_lab), labels)


@partial(jax.jit, static_argnames=("min_pts",))
def _preprocess(tree, segs, eps, min_pts: int):
    """Core-point determination with early exit at min_pts."""
    # Dense members are core by construction; only loose points traverse.
    counts = traversal.count_neighbors(tree, segs, eps, cap=min_pts,
                                       query_active=~segs.dense_pt)
    core = segs.dense_pt | (counts >= min_pts)
    return core


@jax.jit
def _main_phase(tree, segs, eps, core):
    """Hook+jump sweeps until the core-core components stabilize."""
    n = segs.n_points
    labels0 = jnp.where(core, jnp.arange(n, dtype=jnp.int32), jnp.int32(INT_MAX))
    labels0 = jnp.where(core, _unify_dense(labels0, segs), labels0)

    def cond(state):
        _, changed, _ = state
        return changed

    def body(state):
        labels, _, sweeps = state
        gathered, _ = traversal.minlabel_sweep(tree, segs, eps, labels,
                                               gather_mask=core,
                                               query_active=core)
        new = unionfind.hook(labels, gathered, mask=core)
        new = _unify_dense(jnp.where(core, new, labels), segs)
        new = jnp.where(core, unionfind.jump_to_fixpoint(
            jnp.where(core, new, jnp.arange(n, dtype=jnp.int32))), new)
        changed = jnp.any(new != labels)
        return new, changed, sweeps + 1

    labels, _, sweeps = lax.while_loop(cond, body,
                                       (labels0, jnp.bool_(True), jnp.int32(0)))
    return labels, sweeps


@jax.jit
def _assign_borders(tree, segs, eps, core, core_labels):
    """Borders take the min adjacent core root; isolated non-core -> noise."""
    n = segs.n_points
    acc, _ = traversal.border_gather(tree, segs, eps, core_labels, core,
                                     query_active=~core)
    labels = jnp.where(core, core_labels, acc)
    return jnp.where(labels == INT_MAX, jnp.int32(-1), labels)


def _finalize(labels_sorted, order, n):
    """Map sorted-space representative labels to compact original-order ids."""
    out = jnp.full(n, -1, jnp.int32).at[order].set(labels_sorted)
    # representative (sorted index) -> original index for determinism
    rep_orig = jnp.where(out >= 0, order[jnp.clip(out, 0, n - 1)], -1)
    uniq, inv = jnp.unique(rep_orig, return_inverse=True, size=n + 1,
                           fill_value=-2)
    has_noise = jnp.any(rep_orig == -1)
    compact = inv - jnp.where(has_noise, 1, 0)
    compact = jnp.where(rep_orig == -1, -1, compact)
    n_clusters = int(jnp.sum(uniq >= 0))
    return compact.astype(jnp.int32), n_clusters


def dbscan(points, eps: float, min_pts: int, *, algorithm: str = "auto",
           star: bool = False) -> DBSCANResult:
    """DBSCAN via the paper's tree-based algorithms.

    algorithm: "fdbscan" | "fdbscan-densebox" | "auto" (densebox for 2/3-D,
    matching the paper's recommendation for dense low-dimensional data).
    star=True implements DBSCAN* (no border points; non-core -> noise).
    """
    points = jnp.asarray(points)
    n, d = points.shape
    if algorithm == "auto":
        algorithm = "fdbscan-densebox" if d in (2, 3) else "fdbscan"
    if algorithm == "fdbscan-densebox":
        segs = grid.build_segments_densebox(points, eps, min_pts)
    elif algorithm == "fdbscan":
        segs = grid.build_segments_fdbscan(points)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    if n == 1:
        noise = min_pts > 1
        return DBSCANResult(labels=jnp.array([-1 if noise else 0], jnp.int32),
                            core_mask=jnp.array([not noise]),
                            n_clusters=0 if noise else 1, n_sweeps=0)

    m = segs.n_segments
    if m == 1:
        # Everything inside one dense cell: one cluster, all core, 0 sweeps.
        labels = jnp.zeros(n, jnp.int32)
        return DBSCANResult(labels=labels, core_mask=jnp.ones(n, bool),
                            n_clusters=1, n_sweeps=0)

    tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)

    if min_pts == 2:
        # Paper §3.2: preprocessing is skipped — any ε-pair is core-core.
        # A point is core iff it has at least one other point within eps,
        # which falls out of the sweep's matched-neighbor count.
        n_idx = jnp.arange(n, dtype=jnp.int32)
        all_mask = jnp.ones(n, bool)
        _, cnt = traversal.minlabel_sweep(tree, segs, eps, n_idx,
                                          gather_mask=all_mask,
                                          query_active=all_mask)
        core = cnt > 0
        core = jnp.where(segs.dense_pt, True, core)
    else:
        core = _preprocess(tree, segs, eps, min_pts)

    core_labels, sweeps = _main_phase(tree, segs, eps, core)

    if star:
        labels_sorted = jnp.where(core, core_labels, jnp.int32(INT_MAX))
        labels_sorted = jnp.where(labels_sorted == INT_MAX, -1, labels_sorted)
    else:
        labels_sorted = _assign_borders(tree, segs, eps, core, core_labels)

    labels, n_clusters = _finalize(labels_sorted, segs.order, n)
    core_mask = jnp.zeros(n, bool).at[segs.order].set(core)
    return DBSCANResult(labels=labels, core_mask=core_mask,
                        n_clusters=n_clusters, n_sweeps=int(sweeps))
