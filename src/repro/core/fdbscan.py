"""FDBSCAN and FDBSCAN-DenseBox — the paper's two tree-based algorithms.

Two bulk phases over a segment BVH (DESIGN.md §1, §3):

  fused first pass (DESIGN.md §4): ONE traversal computes the neighbor
      count *and* a min-neighbor-label candidate, collapsing core-point
      preprocessing and the first main-phase sweep — the paper's claim that
      clustering costs stay within ~2x of neighbor determination hinges on
      exactly this fusion. The candidate is validated against the core mask
      after the pass (a candidate gathered from a non-core neighbor is
      discarded), so the hook only ever merges genuine core-core pairs.

  main: min-label propagation sweeps fused into the traversal (hook) +
      pointer jumping (DESIGN.md §3 explains why this replaces the GPU's
      atomic-CAS union-find), iterated to a fixpoint. Sweeps restrict
      their gathers to the *frontier* — the points whose label changed
      last sweep (ECL-CC-style active-set restriction; DESIGN.md §4).
      Because labels decrease monotonically under a min hook, the
      restriction is exact, so the first no-change sweep certifies the
      fixpoint with no separate verification pass. Border points are
      assigned in one final gather and never propagate labels — this
      removes the paper's critical section (no cluster bridging by
      construction).

Memory is O(n + m): neighbor lists are never materialized.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import grid, lbvh, traversal, unionfind
from .validate import check_points

INT_MAX = traversal.INT_MAX

# Frontier id vectors are padded to the next power of two (floor below) so
# the jitted traversal sees a bounded number of distinct shapes per run.
_PAD_MIN = 64


class DBSCANResult(NamedTuple):
    """The result record every DBSCAN backend returns.

    labels: (n,) int32 cluster id in [0, n_clusters), or -1 for noise,
        in the caller's original point order. Cluster ids are compact and
        deterministic (derived from each component's smallest original
        index), so equal inputs give byte-equal labels across runs.
    core_mask: (n,) bool — the point has >= min_pts neighbors within eps
        (itself included).
    n_clusters: number of distinct non-noise labels.
    n_sweeps: main-phase label sweeps until fixpoint, including the fused
        first pass (DESIGN.md §4).
    n_traversals: total tree walks this run (``n_sweeps + 1`` for the
        tree backends with border assignment; -1 where not applicable,
        e.g. the tiled backend).
    backend: the resolved backend name that produced this result.
    """
    labels: jax.Array
    core_mask: jax.Array
    n_clusters: int
    n_sweeps: int
    n_traversals: int = -1
    backend: str = ""


def _unify_dense(labels, segs: grid.Segments):
    """Equalize labels within dense segments (paper: one UNION per cell)."""
    m = segs.n_segments
    seg_min = jax.ops.segment_min(labels, segs.seg_of_point, num_segments=m)
    dense_lab = seg_min[segs.seg_of_point]
    return jnp.where(segs.dense_pt, jnp.minimum(labels, dense_lab), labels)


@partial(jax.jit, static_argnames=("min_pts",))
def _preprocess(tree, segs, eps, min_pts: int):
    """Standalone core-point determination with early exit at min_pts.

    Kept as the unfused reference (tests compare it against the fused first
    pass); the production path is ``_fused_first_pass``.
    """
    # Dense members are core by construction; only loose points traverse.
    counts = traversal.count_neighbors(tree, segs, eps, cap=min_pts,
                                       query_active=~segs.dense_pt)
    core = segs.dense_pt | (counts >= min_pts)
    return core


@partial(jax.jit, static_argnames=("traverse_fn",))
def _fused_first_pass_jit(tree, segs, eps, min_pts, depth_rank=None,
                          traverse_fn=traversal.traverse):
    n = segs.n_points
    idx = jnp.arange(n, dtype=jnp.int32)
    # Candidate labels as if every point were core: own index, unified
    # within dense cells. Every gathered value is therefore a sorted index
    # whose core status can be checked once counts are known.
    vals0 = _unify_dense(idx, segs)
    # hits excludes the query itself: |N_eps(q)| >= min_pts <=> hits >= mp-1,
    # so the count may saturate at min_pts - 1 (re-arming the dense
    # short-circuit for saturated lanes — the fused early exit).
    tr = traversal.fused_count_minlabel(tree, segs, eps, vals0,
                                        cap=min_pts - 1,
                                        traverse_fn=traverse_fn,
                                        depth_rank=depth_rank)
    core = segs.dense_pt | (tr.hits >= min_pts - 1)
    # Validate the candidate: vals0 maps loose points to themselves and
    # dense points to a dense (hence core) member, so core[cand] holds iff
    # the contributing neighbor is core — a sound hook (DESIGN.md §4).
    cand = tr.acc
    cand_ok = core[jnp.clip(cand, 0, n - 1)]
    labels0 = jnp.where(core, jnp.where(cand_ok, cand, vals0),
                        jnp.int32(INT_MAX))
    labels0 = jnp.where(core, _unify_dense(labels0, segs), labels0)
    labels0 = jnp.where(core, unionfind.jump_to_fixpoint(
        jnp.where(core, labels0, idx)), labels0)
    # A core query with a valid candidate has absorbed the min over *every*
    # neighbor's initial value; in the next sweep it only needs to gather
    # from points whose label changed since init (DESIGN.md §4).
    absorbed = cand_ok & core
    return core, labels0, vals0, absorbed, tr


def _fused_first_pass(tree, segs, eps, min_pts: int,
                      traverse_fn=traversal.traverse, depth_rank=None):
    """(core, labels0, vals0, absorbed, trace) from a single traversal.

    ``traverse_fn`` selects the walk's execution engine — default the
    vmapped reference engine; the ``pallas-tree`` backend passes a
    ``repro.kernels.traverse.traverse`` configuration (bit-identical
    results). ``depth_rank`` is the kernel's optional lane-scheduling
    oracle (``core.tune``); it never changes results.
    """
    return _fused_first_pass_jit(tree, segs, eps,
                                 jnp.asarray(min_pts, jnp.int32),
                                 depth_rank,
                                 traverse_fn=traverse_fn)


def _pad_size(k: int) -> int:
    """Pad length with quarter-power-of-two granularity: bounded distinct
    jit shapes (~4 per octave) without the up-to-2x lane waste of pure
    power-of-two buckets."""
    size = _PAD_MIN
    while size < k:
        size *= 2
    if size > _PAD_MIN:
        quarter = size // 4
        size = -(-k // quarter) * quarter
    return max(size, _PAD_MIN)


def _compact_ids(mask_np: np.ndarray) -> jax.Array:
    """Active sorted-point ids, padded with -1 to a bucketed length."""
    idx = np.flatnonzero(mask_np).astype(np.int32)
    out = np.full(_pad_size(len(idx)), -1, np.int32)
    out[:len(idx)] = idx
    return jnp.asarray(out)


def _engine_name(traverse_fn) -> str:
    """Metric label for the walk's execution engine."""
    return "reference" if traverse_fn is traversal.traverse else "pallas"


def _record_trace(phase: str, engine: str, tr) -> None:
    """Fold a traversal Trace's work counters into the active metrics
    registry (DESIGN.md §12).  Reading the counters forces a device sync,
    so this is gated on an installed registry — with none, the traversal
    result is never touched and timing is unperturbed."""
    if obs_metrics.active() is None:
        return
    obs_metrics.inc("traversal_evals_total", float(jnp.sum(tr.evals)),
                    phase=phase, engine=engine)
    obs_metrics.inc("traversal_iters_total", float(jnp.sum(tr.iters)),
                    phase=phase, engine=engine)


def _gather_minlabel(tree, segs, eps, labels, gather_mask, ids,
                     node_mask=None, traverse_fn=traversal.traverse,
                     depth_rank=None):
    """One (possibly compacted/pruned) min-label sweep, full-width output."""
    kw = {} if depth_rank is None else {"depth_rank": depth_rank}
    tr = traverse_fn(tree, segs,
                     traversal.intersects(traversal.sphere(eps), ids=ids),
                     traversal.MinLabelVisitor(labels, gather_mask),
                     node_mask=node_mask, **kw)
    n = segs.n_points
    safe = jnp.where(ids >= 0, ids, jnp.int32(n))  # padding -> dropped
    gathered = jnp.full(n, INT_MAX, jnp.int32).at[safe].set(
        jnp.where(ids >= 0, tr.acc, INT_MAX), mode="drop")
    return gathered, tr


@jax.jit
def _post_sweep(tree, segs, labels, core, ids, acc):
    """Scatter-back + hook + dense unification + pointer jumping + change
    detection + next sweep's node flags, fused into one dispatch (the host
    loop's per-sweep cost is dominated by dispatch overhead otherwise)."""
    n = labels.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    safe = jnp.where(ids >= 0, ids, jnp.int32(n))  # padding -> dropped
    gathered = jnp.full(n, INT_MAX, jnp.int32).at[safe].set(
        jnp.where(ids >= 0, acc, INT_MAX), mode="drop")
    new = unionfind.hook(labels, gathered, mask=core)
    new = _unify_dense(jnp.where(core, new, labels), segs)
    new = jnp.where(core, unionfind.jump_to_fixpoint(
        jnp.where(core, new, idx)), new)
    changed = (new != labels) & core
    return new, changed, _frontier_node_mask(tree, segs, changed)


@jax.jit
def _frontier_node_mask(tree, segs, changed):
    """Per-node 'subtree holds a changed point' flag for descent pruning."""
    seg_changed = jax.ops.segment_max(changed.astype(jnp.int32),
                                      segs.seg_of_point,
                                      num_segments=segs.n_segments).astype(bool)
    return lbvh.propagate_leaf_flags(tree, seg_changed)


# A pair within eps spans at most ceil(eps / cell_edge) cells per axis;
# cell_edge >= eps/sqrt(d) (d <= 3), so radius 2 always covers.
_CELL_DILATE = 2


def _cell_keys(pts, eps: float) -> np.ndarray:
    """int64 eps-grid cell key per (sorted) point, for the frontier filter."""
    cells, _ = grid._cell_coords(jnp.asarray(pts), eps)
    c = np.asarray(cells).astype(np.int64)
    if c.shape[1] == 2:
        return (c[:, 0] << 21) | c[:, 1]
    return (c[:, 0] << 42) | (c[:, 1] << 21) | c[:, 2]


def _near_changed(keys: np.ndarray, d: int, changed_np: np.ndarray
                  ) -> np.ndarray:
    """Points whose eps-cell is within the dilation radius of a changed
    point's cell — a sound superset of 'has a changed point within eps'."""
    changed_keys = np.unique(keys[changed_np])
    r = range(-_CELL_DILATE, _CELL_DILATE + 1)
    # arithmetic (not bitwise) composition: offsets have negative components
    if d == 2:
        offs = [(dx << 21) + dy for dx in r for dy in r]
    else:
        offs = [(dx << 42) + (dy << 21) + dz
                for dx in r for dy in r for dz in r]
    dilated = (changed_keys[:, None] + np.asarray(offs, np.int64)).ravel()
    return np.isin(keys, dilated)


def _sweep_to_fixpoint(tree, segs, eps, core, labels0, *,
                       frontier: bool = True, collect_stats: bool = False,
                       fused_init=None, traverse_fn=traversal.traverse,
                       tune=None):
    """Hook+jump sweeps until the core-core components stabilize.

    Frontier restriction (DESIGN.md §4): labels only ever decrease and the
    hook is a monotone min, so a point already holds everything it gathered
    in earlier sweeps — gathering over *only the points whose label changed
    last sweep* is exact, not a heuristic. Each frontier sweep therefore
    (a) masks the gather to changed points and (b) prunes tree descent into
    subtrees containing no changed point, so lanes far from any change die
    within a few box tests. Dense-cell unification marks every member of a
    changed cell as changed, which flags the cell's subtree — points that
    neighbor such a cell re-discover it through the unpruned walk. Labels
    and sweep counts are identical to full sweeps; only the work shrinks.

    Returns (labels, sweeps, stats) with per-sweep frontier sizes and
    loop-trip totals.
    """
    n = segs.n_points
    d = segs.pts.shape[1]
    core_np = np.asarray(core)
    n_core = int(core_np.sum())
    # Query-side restriction only pays once the frontier is genuinely
    # small; above this the cell filter is host overhead for nothing.
    small = max(_PAD_MIN, n_core // 4)
    labels = labels0
    ids_core = _compact_ids(core_np)  # default: every core point gathers
    ids = ids_core
    gather_mask = core            # sweep 1 is full: nothing gathered yet
    # every gather mask is a subset of core, so subtrees holding only
    # non-core points (noise regions) are prunable from sweep one on
    node_mask_core = _frontier_node_mask(tree, segs, core)
    node_mask = node_mask_core
    # eps <= 0 is degenerate (no grid): skip the cell filter, keep the
    # (still exact) gather-mask + node-mask frontier restriction
    cell_keys = _cell_keys(segs.pts, eps) if frontier and eps > 0 else None
    dual = None
    gather_wide = None            # wide lanes' gather mask (split sweep 1)
    if frontier and fused_init is not None:
        # Split first sweep: queries that absorbed every initial value in
        # the fused pass gather changed-since-init points only (narrow);
        # the validation-rejected minority gathers the full core set
        # (wide). One walk, per-lane mask choice — exact either way.
        vals0, absorbed = fused_init
        changed0 = core & (labels0 != vals0)
        changed0_np = np.asarray(changed0)
        wide_np = core_np & ~np.asarray(absorbed)
        if cell_keys is not None and int(changed0_np.sum()) <= small:
            near0 = (_near_changed(cell_keys, d, changed0_np)
                     if changed0_np.any() else np.zeros(n, bool))
            active_np = wide_np | (core_np & near0)
            ids = _compact_ids(active_np)
            ids_np = np.asarray(ids)
            lane_wide = jnp.asarray(
                np.where(ids_np >= 0, wide_np[np.maximum(ids_np, 0)], False))
            gather_mask = changed0
            gather_wide = core
            dual = dict(wide_lanes=lane_wide,
                        node_mask_wide=node_mask_core)
            node_mask = _frontier_node_mask(tree, segs, changed0)
    sweeps = 0
    stats = {"frontier_per_sweep": [], "active_per_sweep": [],
             "iters_per_sweep": [], "evals_per_sweep": []}
    while True:
        # Per-sweep engine resolution (core.tune): the compacted lane
        # count shrinks as the frontier drains, and small batches run the
        # reference engine. The padded id length is a host-known shape,
        # so no device sync is added.
        sweep_fn, rank_kw = traverse_fn, {}
        if tune is not None:
            from . import tune as tune_mod
            cfg = tune.phase("sweep", n_lanes=int(ids.shape[0]))
            sweep_fn = tune_mod.engine_fn(cfg)
            rank = tune.rank_for(cfg)
            if rank is not None:
                rank_kw = {"depth_rank": rank}
        engine = _engine_name(sweep_fn)
        with obs_trace.span("sweep", i=sweeps + 1, engine=engine) as sp:
            tr = sweep_fn(
                tree, segs,
                traversal.intersects(traversal.sphere(eps), ids=ids),
                traversal.MinLabelVisitor(labels, gather_mask,
                                          mask_wide=gather_wide),
                node_mask=node_mask, **(dual or {}), **rank_kw)
            dual = None           # only the first sweep may be split
            gather_wide = None
            new, changed, changed_flags = _post_sweep(tree, segs, labels,
                                                      core, ids, tr.acc)
            sp.watch(new, changed)
        _record_trace("sweep", engine, tr)
        sweeps += 1
        if collect_stats:
            stats["frontier_per_sweep"].append(int(jnp.sum(gather_mask)))
            stats["active_per_sweep"].append(int(jnp.sum(ids >= 0)))
            stats["iters_per_sweep"].append(int(jnp.sum(tr.iters)))
            stats["evals_per_sweep"].append(int(jnp.sum(tr.evals)))
        labels = new
        changed_np = np.asarray(changed)
        n_changed = int(changed_np.sum())
        if n_changed == 0:
            break
        if frontier:
            # gather only from changed points; prune unchanged subtrees;
            # and, once the frontier is small, re-traverse only queries
            # whose eps-cell neighborhood holds a changed point (anyone
            # else provably cannot improve)
            gather_mask = changed
            node_mask = changed_flags
            if cell_keys is not None and n_changed <= small:
                ids = _compact_ids(core_np & _near_changed(cell_keys, d,
                                                           changed_np))
            else:
                ids = ids_core
    return labels, sweeps, stats


def _main_phase(tree, segs, eps, core, *, frontier: bool = True):
    """Seed-compatible entry: (labels, sweeps) from a core mask."""
    n = segs.n_points
    labels0 = jnp.where(core, jnp.arange(n, dtype=jnp.int32),
                        jnp.int32(INT_MAX))
    labels0 = jnp.where(core, _unify_dense(labels0, segs), labels0)
    labels, sweeps, _ = _sweep_to_fixpoint(tree, segs, eps, core, labels0,
                                           frontier=frontier)
    return labels, sweeps


def _assign_borders(tree, segs, eps, core, core_labels,
                    traverse_fn=traversal.traverse, tune=None):
    """Borders take the min adjacent core root; isolated non-core -> noise.

    Traverses a compacted non-core query set (usually a small minority),
    pruning subtrees that hold no core point (nothing to gather there).
    """
    ids = _compact_ids(np.asarray(~core))
    depth_rank = None
    if tune is not None:
        from . import tune as tune_mod
        cfg = tune.phase("border", n_lanes=int(ids.shape[0]),
                         n=int(segs.n_points))
        traverse_fn = tune_mod.engine_fn(cfg)
        depth_rank = tune.rank_for(cfg)
    vals = jnp.where(core, core_labels, jnp.int32(INT_MAX))
    gathered, tr = _gather_minlabel(tree, segs, eps, vals, core, ids,
                                    node_mask=_frontier_node_mask(tree, segs,
                                                                  core),
                                    traverse_fn=traverse_fn,
                                    depth_rank=depth_rank)
    _record_trace("border", _engine_name(traverse_fn), tr)
    labels = jnp.where(core, core_labels, gathered)
    return jnp.where(labels == INT_MAX, jnp.int32(-1), labels)


def _finalize(labels_sorted, order, n):
    """Map sorted-space representative labels to compact original-order ids."""
    out = jnp.full(n, -1, jnp.int32).at[order].set(labels_sorted)
    # representative (sorted index) -> original index for determinism
    rep_orig = jnp.where(out >= 0, order[jnp.clip(out, 0, n - 1)], -1)
    uniq, inv = jnp.unique(rep_orig, return_inverse=True, size=n + 1,
                           fill_value=-2)
    has_noise = jnp.any(rep_orig == -1)
    compact = inv - jnp.where(has_noise, 1, 0)
    compact = jnp.where(rep_orig == -1, -1, compact)
    n_clusters = int(jnp.sum(uniq >= 0))
    return compact.astype(jnp.int32), n_clusters


def cluster_from_index(segs: grid.Segments, tree, eps: float, min_pts: int,
                       *, star: bool = False, frontier: bool = True,
                       backend: str = "", with_stats: bool = False,
                       tune=None):
    """Run the clustering phases over a prebuilt (segments, tree) index.

    ``tree`` may be None when ``segs.n_segments == 1`` (single dense cell).
    This is the entry the dispatcher (repro.core.dispatch) reuses so an
    index cached across ``eps``/``min_pts`` sweeps skips the build.
    ``backend="pallas-tree"`` runs every traversal through the Pallas
    kernel engine (``repro.kernels.traverse``; DESIGN.md §9) — labels,
    core masks, and sweep counts are bit-identical to the reference
    engine, only the walk's lowering changes. ``tune`` is an optional
    ``core.tune.TuneState`` selecting per-phase engine/lane-tile/unroll/
    reordering (the dispatcher attaches the plan's state; ``None`` with
    the pallas backend derives one from the ``REPRO_TUNE`` mode); tuning
    changes the schedule only, never the results.
    """
    n = segs.n_points
    stats: dict = {}
    # the walk's execution engine, resolved once for every phase below
    traverse_fn = traversal.traverse
    if backend == "pallas-tree":
        from repro.kernels import traverse as pallas_traverse
        from . import tune as tune_mod
        traverse_fn = pallas_traverse.traverse
        if tune is None and tree is not None:
            tune = tune_mod.TuneState(
                tune_mod.config_for(segs, tree, eps, min_pts))
    else:
        tune = None
    if n == 1:
        noise = min_pts > 1
        res = DBSCANResult(labels=jnp.array([-1 if noise else 0], jnp.int32),
                           core_mask=jnp.array([not noise]),
                           n_clusters=0 if noise else 1, n_sweeps=0,
                           n_traversals=0, backend=backend)
        return (res, stats) if with_stats else res

    if segs.n_segments == 1:
        # Everything inside one dense cell: one cluster, all core, 0 sweeps.
        res = DBSCANResult(labels=jnp.zeros(n, jnp.int32),
                           core_mask=jnp.ones(n, bool),
                           n_clusters=1, n_sweeps=0, n_traversals=0,
                           backend=backend)
        return (res, stats) if with_stats else res

    # Fused first pass: neighbor count + hooked labels in ONE traversal
    # (the seed spent two: a count pass and the first min-label sweep).
    fp_fn, fp_rank = traverse_fn, None
    if tune is not None:
        fp_cfg = tune.phase("first_pass")
        fp_fn = tune_mod.engine_fn(fp_cfg)
        fp_rank = tune.rank_for(fp_cfg)
    engine = _engine_name(fp_fn)
    with obs_trace.span("traverse", phase="first_pass", engine=engine) as sp:
        core, labels0, vals0, absorbed, first = _fused_first_pass(
            tree, segs, eps, min_pts, traverse_fn=fp_fn,
            depth_rank=fp_rank)
        sp.watch(core, labels0)
    _record_trace("first_pass", engine, first)
    if tune is not None:
        # The pass's per-query loop-trip counts are the depth oracle for
        # every later reorder="depth" traversal over this plan (free: the
        # kernel returns iters anyway).
        tune.calibrate(first.iters)
    core_labels, loop_sweeps, sweep_stats = _sweep_to_fixpoint(
        tree, segs, eps, core, labels0, frontier=frontier,
        collect_stats=with_stats, fused_init=(vals0, absorbed),
        traverse_fn=traverse_fn, tune=tune)
    n_sweeps = 1 + loop_sweeps          # the fused pass is sweep #1
    n_traversals = n_sweeps

    if star:
        labels_sorted = jnp.where(core, core_labels, jnp.int32(-1))
    else:
        with obs_trace.span("border", engine=engine) as sp:
            labels_sorted = _assign_borders(tree, segs, eps, core,
                                            core_labels,
                                            traverse_fn=traverse_fn,
                                            tune=tune)
            sp.watch(labels_sorted)
        n_traversals += 1

    with obs_trace.span("finalize") as sp:
        labels, n_clusters = _finalize(labels_sorted, segs.order, n)
        core_mask = jnp.zeros(n, bool).at[segs.order].set(core)
        sp.watch(labels, core_mask)
    res = DBSCANResult(labels=labels, core_mask=core_mask,
                       n_clusters=n_clusters, n_sweeps=n_sweeps,
                       n_traversals=n_traversals, backend=backend)
    if with_stats:
        stats = dict(sweep_stats)
        stats["first_pass_iters"] = int(jnp.sum(first.iters))
        stats["first_pass_evals"] = int(jnp.sum(first.evals))
        return res, stats
    return res


def dbscan(points, eps: float, min_pts: int, *, algorithm: str = "auto",
           star: bool = False, frontier: bool = True,
           mesh=None) -> DBSCANResult:
    """DBSCAN via the paper's tree-based algorithms.

    algorithm: "fdbscan" | "fdbscan-densebox" build the named tree index
    directly; "auto", "tiled", "sharded", "stream" and "pallas-tree" go
    through the unified dispatcher (repro.core.dispatch), which probes the
    eps-grid occupancy and may pick the MXU tile backend, the multi-device
    sharded tree path (when a ``mesh`` is active), the Pallas traversal
    kernel (DESIGN.md §9), or a one-shot streaming snapshot (DESIGN.md §7;
    use ``dispatch.stream_handle`` to keep the handle for inserts).
    star=True implements DBSCAN* (no border points; non-core -> noise).
    frontier=False forces full (unrestricted) sweeps.
    """
    points = jnp.asarray(points)
    if algorithm in ("auto", "tiled", "sharded", "stream", "pallas-tree"):
        from . import dispatch
        return dispatch.dbscan(points, eps, min_pts, algorithm=algorithm,
                               star=star, frontier=frontier, mesh=mesh)
    if eps < 0:
        raise ValueError(f"eps must be non-negative; got {eps}"
                         " (a negative eps would be squared away silently)")
    check_points(points)    # the dispatch route validates inside plan()
    n, d = points.shape
    if algorithm == "fdbscan-densebox":
        segs = grid.build_segments_densebox(points, eps, min_pts)
    elif algorithm == "fdbscan":
        segs = grid.build_segments_fdbscan(points)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    tree = None
    if segs.n_segments > 1 and n > 1:
        tree = lbvh.build_tree(segs.codes, segs.prim_lo, segs.prim_hi)
    return cluster_from_index(segs, tree, eps, min_pts, star=star,
                              frontier=frontier, backend=algorithm)
