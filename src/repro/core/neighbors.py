"""Neighbor-query workloads over the predicate/callback engine.

The redesigned traversal layer (DESIGN.md §8) makes the DBSCAN epilogues
*instances* of a generic query engine; this module exposes the other
workloads that engine now opens — the fixed-radius searches of
Wang/Gu/Shun's parallel-DBSCAN framing and the k-nearest-neighbor graphs
of KNN-DBSCAN (Chen et al.) — behind three entry points:

  * :func:`neighbor_count`   — |N_r(q)| per query (early-exit capable);
  * :func:`radius_visit`     — run *your own* visitor over every in-radius
                               neighbor (the raw extensibility hook);
  * :func:`knn`              — exact k nearest neighbors, optionally
                               radius-capped (``nearest(k)`` predicates).

All three route through :mod:`repro.core.dispatch`'s plan cache, so the
(eps-independent) plain-FDBSCAN index is shared with ``dbscan`` runs and
across repeated neighbor queries on the same point set. Queries may be the
resident points themselves or an external batch (``query_pts=``), exactly
like the clustering engine's halo/stream queries.

Inputs outside the tree's reach — d not in (2, 3) (no Morton curve) or
fewer than two points — fall back to an exact brute-force path with the
same tie rules, so the API is total.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from . import traversal
from .validate import check_points

INT_MAX = traversal.INT_MAX


def _check_inputs(points, query_pts):
    """Shared input gate: resident points must be a non-empty finite
    batch; external queries (when given) must match their d.  An *empty*
    external batch is fine — it just returns empty results."""
    pts = check_points(points)
    if query_pts is not None:
        check_points(query_pts, name="query_pts", allow_empty=True,
                     d=pts.shape[1])


class KNNResult(NamedTuple):
    """k nearest neighbors per query, ascending by (distance, index).

    indices:   (q, k) int32 neighbor ids in *original* point order; -1
               pads slots beyond the reachable neighbor count (k > n, or a
               radius cap excluded the rest).
    distances: (q, k) euclidean distances (+inf on padded slots).

    A resident query (``query_pts=None``) is its own nearest neighbor at
    distance 0 — slice it off if self-matches are unwanted.
    """
    indices: jax.Array
    distances: jax.Array


def _tree_plan(points):
    """The cached eps-independent plain-FDBSCAN index for ``points``."""
    from . import dispatch
    return dispatch.plan(points, 0.0, 1, algorithm="fdbscan")


def _predicate_lanes(segs, query_pts):
    """(ids, pts) for a resident-or-external predicate batch."""
    if query_pts is None:
        return None, None
    return None, jnp.asarray(query_pts, segs.pts.dtype)


def _scatter_resident(segs, per_lane):
    """Map a sorted-order per-lane array back to original point order."""
    n = segs.n_points
    out_shape = (n,) + per_lane.shape[1:]
    return jnp.zeros(out_shape, per_lane.dtype).at[segs.order].set(per_lane)


def radius_visit(points, r: float, callback, carry=None, *,
                 query_pts=None) -> traversal.Trace:
    """Run ``callback`` over every neighbor within ``r`` of each query.

    The raw engine hook: ``callback`` is any :class:`traversal.Visitor`
    and the returned :class:`traversal.Trace` holds its final carry (in
    the index's *sorted* lane order for resident queries — the visitor
    sees sorted point ids ``j``; ``segs.order[j]`` maps them back).
    Builds (or fetches) the cached tree index for ``points``.

    Args:
        points: (n, d) resident points, d in (2, 3), n >= 2.
        r: search radius per query.
        callback: a :class:`repro.core.traversal.Visitor` instance
            (registered as a pytree).
        carry: optional initial accumulator; ``None`` asks the
            callback's ``init_carry``.
        query_pts: optional (q, d) external query batch; ``None``
            traverses for every resident point.

    Returns:
        The :class:`repro.core.traversal.Trace` — final carry plus the
        engine's per-lane ``evals``/``iters`` work counters.

    Raises:
        ValueError: malformed inputs (empty/NaN/Inf, see
            :func:`repro.core.validate.check_points`), or no tree index
            exists for these points (< 2 points or d outside (2, 3)) —
            use :func:`neighbor_count`/:func:`knn`, whose brute-force
            fallbacks cover degenerate inputs.
    """
    _check_inputs(points, query_pts)
    points = jnp.asarray(points)
    p = _tree_plan(points)
    if p.tree is None:
        raise ValueError("radius_visit needs a tree index (>= 2 points "
                         "with d in (2, 3)); use neighbor_count/knn, whose "
                         "brute-force fallbacks cover degenerate inputs")
    ids, pts = _predicate_lanes(p.segs, query_pts)
    return traversal.traverse(
        p.tree, p.segs,
        traversal.intersects(traversal.sphere(r), ids=ids, pts=pts),
        callback, carry=carry)


def neighbor_count(points, r: float, *, query_pts=None,
                   cap: int = INT_MAX) -> jax.Array:
    """|N_r(q)| per query point, saturated at ``cap`` (early exit).

    Resident queries count themselves (|N_r| includes the center, as in
    DBSCAN's core test); external queries count every resident match.

    Args:
        points: (n, d) resident points (any n, any d — inputs outside
            the tree's reach fall back to exact brute force).
        r: search radius.
        query_pts: optional (q, d) external queries; ``None`` counts for
            every resident point.
        cap: saturation bound — a lane stops traversing once its count
            reaches ``cap`` (the paper's min_pts early exit).

    Returns:
        int32 counts in original point order (resident queries) or
        ``query_pts`` order (external queries).

    Raises:
        ValueError: malformed inputs (empty resident set, NaN/Inf
            coordinates, query/resident dimensionality mismatch).
    """
    _check_inputs(points, query_pts)
    points = jnp.asarray(points)
    n, d = points.shape
    if n < 2 or d not in (2, 3):
        q = points if query_pts is None else jnp.asarray(query_pts)
        d2 = jnp.sum((q[:, None, :] - points[None, :, :]) ** 2, -1)
        r2 = jnp.asarray(r, points.dtype) ** 2
        return jnp.minimum(jnp.sum(d2 <= r2, axis=1), cap).astype(jnp.int32)
    p = _tree_plan(points)      # one plan fetch serves traverse + scatter
    ids, pts = _predicate_lanes(p.segs, query_pts)
    tr = traversal.traverse(
        p.tree, p.segs,
        traversal.intersects(traversal.sphere(r), ids=ids, pts=pts),
        traversal.CountVisitor(cap=cap))
    if query_pts is not None:
        return tr.acc
    return _scatter_resident(p.segs, tr.acc)


def knn(points, k: int, *, query_pts=None, radius=None) -> KNNResult:
    """Exact k nearest neighbors via the ``nearest(k)`` predicate.

    Distance-bounded rope traversal: each lane prunes subtrees farther
    than its current k-th best (shrinking ball), optionally capped at
    ``radius``. Ties at the k-th distance resolve to the smaller original
    index — identical to a stable sort of the brute-force distance row.

    Args:
        points: (n, d) resident points (degenerate inputs fall back to
            an exact brute-force path with the same tie rules).
        k: neighbors per query (static — it sizes the result).
        query_pts: optional (q, d) external queries; ``None`` queries
            every resident point (each is its own nearest neighbor at
            distance 0).
        radius: optional search-radius cap; slots beyond the reachable
            neighbor count pad with index -1 / distance +inf.

    Returns:
        A :class:`KNNResult` with (q, k) ``indices`` (original point
        order) and ``distances``, ascending by (distance, index).

    Raises:
        ValueError: ``k < 1``, or malformed inputs (empty resident set,
            NaN/Inf coordinates, dimensionality mismatch).
    """
    if k < 1:
        raise ValueError(f"k must be >= 1; got {k}")
    _check_inputs(points, query_pts)
    points = jnp.asarray(points)
    n, d = points.shape
    q = points if query_pts is None else jnp.asarray(query_pts, points.dtype)
    if n < 2 or d not in (2, 3):
        return _knn_brute(points, q, k, radius)
    p = _tree_plan(points)
    ids, pts = _predicate_lanes(p.segs, query_pts)
    # id_map=segs.order makes the visitor select AND record by *original*
    # index, so exact-distance tie sets at the k-th radius match a stable
    # brute-force argsort (not just the ordering within the set)
    tr = traversal.traverse(
        p.tree, p.segs, traversal.nearest(k, r=radius, ids=ids, pts=pts),
        traversal.KNNVisitor(k, id_map=p.segs.order))
    idx, dist = tr.carry.ids, tr.carry.d2
    if query_pts is None:
        idx = _scatter_resident(p.segs, idx)
        dist = _scatter_resident(p.segs, dist)
    return KNNResult(indices=idx, distances=jnp.sqrt(dist))


def _knn_brute(points, q, k: int, radius) -> KNNResult:
    """Exact fallback with the same (d2, id) tie rule (host NumPy)."""
    pts = np.asarray(points, np.float32)
    qs = np.asarray(q, np.float32)
    n = len(pts)
    kk = min(k, n) if n else 0
    diff = qs[:, None, :] - pts[None, :, :]
    d2 = (diff * diff).sum(-1)
    if radius is not None:
        d2 = np.where(d2 <= np.float32(radius) ** 2, d2, np.inf)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :kk]
    dd = np.take_along_axis(d2, idx, axis=1)
    out_i = np.full((len(qs), k), -1, np.int32)
    out_d = np.full((len(qs), k), np.inf, np.float32)
    out_i[:, :kk] = np.where(np.isinf(dd), -1, idx)
    out_d[:, :kk] = dd
    return KNNResult(indices=jnp.asarray(out_i),
                     distances=jnp.sqrt(jnp.asarray(out_d)))
