"""Linear bounding volume hierarchy (Karras 2012) in pure JAX.

The paper uses ArborX's LBVH as the search index because of its fast fully
parallel construction and low-divergence batched traversal. We reproduce the
same construction:

  * primitives are sorted by Morton code (``repro.core.morton``),
  * every internal node's primitive range / split is found independently with
    binary searches over the common-prefix-length function ``delta`` -> the
    whole hierarchy is built in a single fully-vectorized pass (no recursion),
  * bounding boxes are fitted bottom-up.

GPU -> TPU adaptations (see DESIGN.md §3):
  * Karras' bottom-up AABB fit uses per-node atomic flags (second child to
    arrive continues upward). TPUs have no global atomics, so we fit AABBs
    with *level-synchronous* bulk sweeps: a node becomes ready once both
    children are ready; iterate until the root is ready. O(depth) vectorized
    sweeps, deterministic.
  * Traversal is stackless: we precompute *ropes* (miss links = next node in
    DFS order when a subtree is skipped), so a traversal needs O(1) state per
    query lane instead of a per-thread stack (VREG pressure).

Node numbering: internal nodes are ``0 .. n-2`` (root = 0), leaf ``k`` is node
``(n-1) + k``. ``n`` is the number of *primitives* (segments), which for plain
FDBSCAN are single points and for FDBSCAN-DenseBox are mixed dense-cell boxes
and singleton points (DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# Enough doublings/halvings to cover any practical primitive count (2**30).
_SEARCH_ITERS = 31


def box_dist2(q, lo, hi):
    """Squared distance from point ``q`` to the AABB ``[lo, hi]`` (0 inside).

    The traversal's node test and the distributed path's eps-halo slab test
    (is this query within eps of a shard's resident AABB?) are the same
    geometric primitive, so it lives here with the boxes.
    """
    d = jnp.maximum(jnp.maximum(lo - q, q - hi), 0.0)
    return jnp.sum(d * d, axis=-1)


class Tree(NamedTuple):
    """Flat LBVH arrays. Internal nodes first, then leaves.

    All index arrays are int32 over node ids in [0, 2n-1); -1 is the
    "no node" sentinel (end of traversal).
    """
    left: jax.Array      # (n-1,) left child node id of internal node i
    right: jax.Array     # (n-1,) right child node id
    parent: jax.Array    # (2n-1,) parent node id (-1 for root)
    miss: jax.Array      # (2n-1,) rope: node to visit when skipping this one
    range_r: jax.Array   # (2n-1,) max leaf (primitive) index under this node
    box_lo: jax.Array    # (2n-1, d) AABB lower corners
    box_hi: jax.Array    # (2n-1, d) AABB upper corners

    @property
    def n_leaves(self) -> int:
        return (self.parent.shape[0] + 1) // 2

    def leaf_id(self, k):
        return k + self.n_leaves - 1


def _delta_fn(codes: jax.Array):
    """Common-prefix length between sorted codes i and j, with the standard
    Karras index tie-break (equal codes -> 32 + clz(i ^ j)); -1 outside."""
    n = codes.shape[0]

    def delta(i, j):
        oob = (j < 0) | (j >= n)
        j_safe = jnp.clip(j, 0, n - 1)
        ci = codes[i]
        cj = codes[j_safe]
        x = ci ^ cj
        same = x == 0
        base = lax.clz(x)
        tie = jnp.uint32(32) + lax.clz(i.astype(jnp.uint32) ^ j_safe.astype(jnp.uint32))
        d = jnp.where(same, tie, base).astype(jnp.int32)
        return jnp.where(oob, jnp.int32(-1), d)

    return delta


def _build_topology(codes: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Karras internal-node construction, vectorized over all internal nodes.

    Returns (left, right, first, last): children node ids and the primitive
    index range [first, last] covered by each internal node.
    """
    n = codes.shape[0]
    delta = _delta_fn(codes)

    def node(i):
        i = i.astype(jnp.int32)
        d = jnp.sign(delta(i, i + 1) - delta(i, i - 1)).astype(jnp.int32)
        delta_min = delta(i, i - d)

        # Exponential search for an upper bound on the range length. For
        # sorted codes delta is non-increasing away from i, so the masked
        # doubling below is monotone (once the test fails it stays false).
        def dbl(_, lmax):
            grow = delta(i, i + lmax * d) > delta_min
            return jnp.where(grow, lmax * 2, lmax)

        l_max = lax.fori_loop(0, _SEARCH_ITERS, dbl, jnp.int32(2))

        # Binary search for the exact length; l_max is a power of two, so the
        # halving sequence visits each power exactly once (t==0 is inert).
        def bisect(k, carry):
            l, t = carry
            t = t // 2
            ok = (t > 0) & (delta(i, i + (l + t) * d) > delta_min)
            return jnp.where(ok, l + t, l), t

        l, _ = lax.fori_loop(0, _SEARCH_ITERS, bisect, (jnp.int32(0), l_max))
        j = i + l * d  # other end of the range

        # Split search (ceil-halving with a done flag so t==1 fires once).
        delta_node = delta(i, j)

        def split_step(k, carry):
            s, t, done = carry
            t_new = (t + 1) // 2
            ok = (~done) & (delta(i, i + (s + t_new) * d) > delta_node)
            s = jnp.where(ok, s + t_new, s)
            done = done | (t_new <= 1)
            return s, t_new, done

        s, _, _ = lax.fori_loop(0, _SEARCH_ITERS,
                                split_step, (jnp.int32(0), l, jnp.bool_(False)))
        gamma = i + s * d + jnp.minimum(d, 0)

        first = jnp.minimum(i, j)
        last = jnp.maximum(i, j)
        leaf_off = jnp.int32(n - 1)
        left = jnp.where(first == gamma, gamma + leaf_off, gamma)
        right = jnp.where(last == gamma + 1, gamma + 1 + leaf_off, gamma + 1)
        return left, right, first, last

    return jax.vmap(node)(jnp.arange(n - 1, dtype=jnp.int32))


def _fit_boxes(left, right, parent, prim_lo, prim_hi):
    """Level-synchronous bottom-up AABB fit (no atomics; DESIGN.md §3)."""
    n = prim_lo.shape[0]
    n_int = n - 1
    d = prim_lo.shape[1]
    box_lo = jnp.concatenate([jnp.full((n_int, d), jnp.inf, prim_lo.dtype), prim_lo])
    box_hi = jnp.concatenate([jnp.full((n_int, d), -jnp.inf, prim_hi.dtype), prim_hi])
    ready = jnp.concatenate([jnp.zeros(n_int, bool), jnp.ones(n, bool)])

    def cond(state):
        _, _, ready = state
        return ~ready[0]

    def body(state):
        box_lo, box_hi, ready = state
        can = ready[left] & ready[right] & ~ready[:n_int]
        new_lo = jnp.minimum(box_lo[left], box_lo[right])
        new_hi = jnp.maximum(box_hi[left], box_hi[right])
        box_lo = box_lo.at[:n_int].set(jnp.where(can[:, None], new_lo, box_lo[:n_int]))
        box_hi = box_hi.at[:n_int].set(jnp.where(can[:, None], new_hi, box_hi[:n_int]))
        ready = ready.at[:n_int].set(ready[:n_int] | can)
        return box_lo, box_hi, ready

    box_lo, box_hi, _ = lax.while_loop(cond, body, (box_lo, box_hi, ready))
    return box_lo, box_hi


def _compute_ropes(left, right, parent, n_nodes):
    """miss[v] = right sibling if v is a left child, else miss[parent].

    Resolved with bulk sweeps (value propagates one tree level per sweep).
    """
    n_int = left.shape[0]
    is_left = jnp.zeros(n_nodes, bool).at[left].set(True)
    sibling = jnp.full(n_nodes, -1, jnp.int32).at[left].set(right)
    miss = jnp.where(is_left, sibling, jnp.int32(-1))
    miss = miss.at[0].set(-1)  # root: end of traversal

    def cond(state):
        miss, done = state
        return ~jnp.all(done)

    def body(state):
        miss, done = state
        par = jnp.maximum(parent, 0)
        new = jnp.where(done, miss, miss[par])
        new_done = done | done[par]
        new = new.at[0].set(-1)
        return new, new_done.at[0].set(True)

    done0 = is_left.at[0].set(True)
    miss, _ = lax.while_loop(cond, body, (miss, done0))
    return miss


@jax.jit
def propagate_leaf_flags(tree: Tree, leaf_flags: jax.Array) -> jax.Array:
    """(2n-1,) per-node OR of ``leaf_flags`` over each subtree's leaves.

    Level-synchronous bottom-up sweeps like ``_fit_boxes`` (no atomics).
    Frontier sweeps use this to mark subtrees containing changed points so
    the traversal can prune unchanged regions (DESIGN.md §4).
    """
    n_int = tree.left.shape[0]
    flags = jnp.concatenate([jnp.zeros(n_int, bool), leaf_flags])

    def cond(state):
        flags, changed = state
        return changed

    def body(state):
        flags, _ = state
        new_int = flags[tree.left] | flags[tree.right]
        new = flags.at[:n_int].set(new_int)
        return new, jnp.any(new != flags)

    flags, _ = lax.while_loop(cond, body, (flags, jnp.bool_(True)))
    return flags


def build_tree(codes: jax.Array, prim_lo: jax.Array, prim_hi: jax.Array) -> Tree:
    """Build the LBVH over primitives sorted by ``codes``.

    ``prim_lo``/``prim_hi`` are (n, d) AABB corners of the (sorted)
    primitives. n must be >= 2 (callers special-case n < 2).
    """
    n = codes.shape[0]
    left, right, first, last = _build_topology(codes)
    n_nodes = 2 * n - 1

    parent = jnp.full(n_nodes, -1, jnp.int32)
    parent = parent.at[left].set(jnp.arange(n - 1, dtype=jnp.int32))
    parent = parent.at[right].set(jnp.arange(n - 1, dtype=jnp.int32))

    # range_r: needed by the paper's "j > i" traversal mask (skip subtrees
    # whose max primitive index is below the query's); leaves cover [k, k].
    range_r = jnp.concatenate([last, jnp.arange(n, dtype=jnp.int32)])

    miss = _compute_ropes(left, right, parent, n_nodes)
    box_lo, box_hi = _fit_boxes(left, right, parent, prim_lo, prim_hi)
    return Tree(left=left, right=right, parent=parent, miss=miss,
                range_r=range_r, box_lo=box_lo, box_hi=box_hi)
