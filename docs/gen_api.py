"""Generate docs/api.md from the live docstrings of the stable surface.

    PYTHONPATH=src python docs/gen_api.py            # (re)write docs/api.md
    PYTHONPATH=src python docs/gen_api.py --check    # CI: fail if stale

The reference is *generated*, never hand-edited: it covers everything in
``repro.__all__`` plus the extension surface DESIGN.md §8 documents (the
visitor contract and the predicate constructors). The CI docs job runs
``--check`` so the committed file can't drift from the docstrings.
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "api.md")

HEADER = """\
# repro API reference

*Generated from docstrings by `docs/gen_api.py` — do not edit by hand
(`PYTHONPATH=src python docs/gen_api.py` regenerates; CI checks it is
current).*

The stable public surface is what `repro.__all__` exports; everything
else (including the `repro.core.*` modules documented at the end for
extension authors) is importable but not part of the stability
contract. See [README.md](../README.md) for the quickstart and
[DESIGN.md](../DESIGN.md) for the architecture.
"""


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"


def _doc(obj) -> str:
    return inspect.getdoc(obj) or "*(no docstring)*"


def _entry(title: str, obj, kind: str = "function",
           sig: str | None = None) -> str:
    lines = [f"### `{title}`", ""]
    if kind == "function":
        name = title.rsplit(".", 1)[-1]
        lines += ["```python", f"{name}{sig or _signature(obj)}", "```", ""]
    lines += [_doc(obj), ""]
    return "\n".join(lines)


def _method_entries(cls, names, prefix: str) -> list[str]:
    out = []
    for name in names:
        member = inspect.getattr_static(cls, name)
        if isinstance(member, property):
            out.append(_entry(f"{prefix}.{name}", member.fget,
                              kind="property"))
        else:
            out.append(_entry(f"{prefix}.{name}", getattr(cls, name)))
    return out


def generate() -> str:
    import repro
    from repro import obs
    from repro.core import traversal
    from repro.core import neighbors
    from repro.core import tune
    from repro.kernels import traverse as pallas_traverse
    from repro.stream import StreamingDBSCAN, durability
    from repro import serve

    parts = [HEADER]

    parts.append("## Top level (`repro.__all__`)\n")
    parts.append(_entry("repro.dbscan", repro.dbscan))
    parts.append(_entry("repro.plan", repro.plan))
    parts.append(_entry("repro.stream_handle", repro.stream_handle))
    parts.append(_entry("repro.DBSCANResult", repro.DBSCANResult,
                        kind="class"))

    parts.append("## Streaming handle (`repro.stream_handle(...)`)\n")
    parts.append(_entry("StreamingDBSCAN", StreamingDBSCAN, kind="class"))
    parts.extend(_method_entries(
        StreamingDBSCAN,
        ["insert", "query", "snapshot", "merge", "checkpoint", "restore",
         "n_points", "n_main", "n_delta", "points"],
        "StreamingDBSCAN"))

    parts.append("## Durability (`repro.stream.durability`)\n")
    parts.append(_doc(durability) + "\n")
    parts.append(_entry("durability.save_checkpoint",
                        durability.save_checkpoint))
    parts.append(_entry("durability.load_checkpoint",
                        durability.load_checkpoint))
    parts.append(_entry("durability.scan_wal", durability.scan_wal))
    parts.append(_entry("durability.recover", durability.recover))
    parts.append(_entry("durability.WriteAheadLog", durability.WriteAheadLog,
                        kind="class"))
    parts.append(_entry("durability.CheckpointError",
                        durability.CheckpointError, kind="class"))
    parts.append(_entry("durability.WALError", durability.WALError,
                        kind="class"))

    parts.append("## Serving (`repro.serve`)\n")
    parts.append(_doc(serve) + "\n")
    parts.append(_entry("serve.Server", serve.Server, kind="class"))
    parts.extend(_method_entries(
        serve.Server,
        ["restore", "submit_query", "query", "submit_insert", "insert",
         "stats", "shutdown", "tenants"],
        "Server"))
    parts.append(_entry("serve.ServerConfig", serve.ServerConfig,
                        kind="class"))
    parts.append(_entry("serve.QueryReply", serve.QueryReply, kind="class"))
    parts.append(_entry("serve.InsertReply", serve.InsertReply,
                        kind="class"))
    parts.append(_entry("serve.TenantSpec", serve.TenantSpec, kind="class"))
    parts.append(_entry("serve.IndexSnapshot", serve.IndexSnapshot,
                        kind="class"))
    parts.extend(_method_entries(
        serve.IndexSnapshot, ["build", "query", "stats"], "IndexSnapshot"))
    parts.append(_entry("serve.freeze", serve.freeze))
    parts.append(_entry("serve.SnapshotStore", serve.SnapshotStore,
                        kind="class"))
    parts.extend(_method_entries(
        serve.SnapshotStore, ["current", "get", "publish", "version"],
        "SnapshotStore"))
    parts.append(_entry("serve.MicroBatcher", serve.MicroBatcher,
                        kind="class"))
    parts.append(_entry("serve.bucket_size", serve.bucket_size))
    parts.append(_entry("serve.AdmissionController",
                        serve.AdmissionController, kind="class"))
    parts.append(_entry("serve.Overloaded", serve.Overloaded, kind="class"))

    parts.append("## Neighbor queries (`repro.neighbors`)\n")
    parts.append(_doc(neighbors) + "\n")
    parts.append(_entry("repro.neighbors.neighbor_count",
                        neighbors.neighbor_count))
    parts.append(_entry("repro.neighbors.knn", neighbors.knn))
    parts.append(_entry("repro.neighbors.radius_visit",
                        neighbors.radius_visit))
    parts.append(_entry("repro.neighbors.KNNResult", neighbors.KNNResult,
                        kind="class"))

    parts.append("## Observability (`repro.obs`)\n")
    parts.append(_doc(obs) + "\n")
    parts.append(_entry("obs.instrumented", obs.instrumented))
    parts.append(_entry("obs.metrics.Registry", obs.metrics.Registry,
                        kind="class"))
    parts.extend(_method_entries(
        obs.metrics.Registry,
        ["counter", "gauge", "histogram", "get", "snapshot", "write_json"],
        "Registry"))
    parts.append(_entry("obs.metrics.Histogram", obs.metrics.Histogram,
                        kind="class"))
    for fn in (obs.metrics.install, obs.metrics.uninstall,
               obs.metrics.active, obs.metrics.inc, obs.metrics.set_gauge,
               obs.metrics.observe, obs.metrics.validate_snapshot):
        parts.append(_entry(f"obs.metrics.{fn.__name__}", fn))
    parts.append(_entry("obs.trace.Tracer", obs.trace.Tracer, kind="class"))
    for fn in (obs.trace.span, obs.trace.watch, obs.trace.install,
               obs.trace.uninstall, obs.trace.active,
               obs.trace.profiler_session, obs.trace.validate_chrome_trace):
        parts.append(_entry(f"obs.trace.{fn.__name__}", fn))

    parts.append("## Predicates (`repro.core.traversal`)\n")
    parts.append(
        "Predicate batches name the queries a traversal runs and their\n"
        "search geometry (DESIGN.md §8). They are pytrees: array leaves\n"
        "(radii, id vectors, external coordinates) are traced operands,\n"
        "so parameter sweeps reuse one compiled program.\n")
    parts.append(_entry("traversal.intersects", traversal.intersects))
    parts.append(_entry("traversal.sphere", traversal.sphere))
    parts.append(_entry("traversal.nearest", traversal.nearest))

    parts.append("## The visitor contract (`repro.core.traversal`)\n")
    parts.append(_entry("traversal.Visitor", traversal.Visitor,
                        kind="class"))
    parts.extend(_method_entries(
        traversal.Visitor,
        ["init_carry", "visit", "done", "segment_done"],
        "Visitor"))
    for cls in (traversal.CountVisitor, traversal.MinLabelVisitor,
                traversal.CountMinLabelVisitor, traversal.KNNVisitor):
        parts.append(_entry(f"traversal.{cls.__name__}", cls, kind="class"))

    parts.append("## Traversal engines\n")
    # DEFAULT_UNROLL resolves per backend (4 on TPU/GPU, 1 on CPU);
    # render the symbol so the generated file is machine-independent
    # (annotations render quoted under `from __future__ import annotations`)
    engine_sig = _signature(traversal.traverse_impl).replace(
        f"unroll: 'int' = {traversal.DEFAULT_UNROLL}",
        "unroll: 'int' = DEFAULT_UNROLL")
    parts.append(_entry("repro.core.traversal.traverse",
                        traversal.traverse_impl, sig=engine_sig))
    parts.append(_entry("repro.kernels.traverse.traverse",
                        pallas_traverse.traverse))
    parts.append(_entry("traversal.Trace", traversal.Trace, kind="class"))
    parts.append(_entry("traversal.QueryCtx", traversal.QueryCtx,
                        kind="class"))
    parts.append(_entry("traversal.AccHits", traversal.AccHits,
                        kind="class"))
    parts.append(_entry("traversal.lane_sort_key", traversal.lane_sort_key))

    parts.append("## Autotuning (`repro.core.tune`)\n")
    parts.append(_doc(tune) + "\n")
    parts.append(_entry("tune.PhaseConfig", tune.PhaseConfig, kind="class"))
    parts.append(_entry("tune.TunedConfig", tune.TunedConfig, kind="class"))
    parts.append(_entry("tune.TuneState", tune.TuneState, kind="class"))
    parts.extend(_method_entries(
        tune.TuneState, ["phase", "rank_for", "calibrate", "describe"],
        "TuneState"))
    for fn in (tune.mode, tune.engine_fn, tune.lane_tiles_within_budget,
               tune.stats_key, tune.heuristic, tune.search,
               tune.config_for):
        parts.append(_entry(f"tune.{fn.__name__}", fn))

    return "\n".join(parts).rstrip() + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if docs/api.md is stale")
    args = ap.parse_args()
    content = generate()
    if args.check:
        on_disk = open(OUT).read() if os.path.exists(OUT) else ""
        if on_disk != content:
            print("docs/api.md is stale — regenerate with "
                  "`PYTHONPATH=src python docs/gen_api.py`",
                  file=sys.stderr)
            return 1
        print("docs/api.md is current")
        return 0
    with open(OUT, "w") as f:
        f.write(content)
    print(f"wrote {OUT} ({len(content.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
